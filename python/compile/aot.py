"""AOT lowering: jax graphs -> HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` /
serialized HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction
ids which the ``xla`` crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``). The HLO text parser on the Rust side
re-assigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are written to ``artifacts/{graph}_{tag}.hlo.txt`` plus a TSV
manifest (``artifacts/manifest.tsv``) the Rust runtime indexes:

    graph<TAB>p<TAB>b<TAB>k<TAB>relative_path

Run as ``python -m compile.aot [--out-dir ../artifacts]`` from python/,
or via ``make artifacts`` at the repo root (a no-op when inputs are older
than the manifest).
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# Shape variants compiled by default. One per experiment family:
#   p=512,  B=256, K=5  — synthetic blob experiments (Figs 1..6), FWHT
#   p=784,  B=256, K=3  — digit dimension with the DCT preconditioner
#   p=1024, B=256, K=3  — digit pipeline as actually run by the Rust
#                         coordinator (784 zero-padded to 1024, FWHT)
DEFAULT_CONFIGS = (
    model.ShapeConfig(p=512, b=256, k=5),
    model.ShapeConfig(p=784, b=256, k=3),
    model.ShapeConfig(p=1024, b=256, k=3),
)

GRAPH_NAMES = tuple(model.GRAPHS)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text, with return_tuple=True so
    every graph output (even single ones) round-trips as a tuple."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(cfg: model.ShapeConfig, name: str) -> str:
    fn = model.GRAPHS[name](cfg)
    lowered = jax.jit(fn).lower(*model.example_args(cfg, name))
    return to_hlo_text(lowered)


def build(out_dir: str, configs=DEFAULT_CONFIGS, graphs=GRAPH_NAMES, verbose=True) -> str:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for cfg in configs:
        for name in graphs:
            fname = f"{name}_{cfg.tag()}.hlo.txt"
            text = lower_one(cfg, name)
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            rows.append((name, cfg.p, cfg.b, cfg.k, fname))
            if verbose:
                print(f"  lowered {name:22s} {cfg.tag():16s} -> {fname} ({len(text)} chars)")
    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# graph\tp\tb\tk\tfile\n")
        for r in rows:
            f.write("\t".join(str(x) for x in r) + "\n")
    if verbose:
        print(f"wrote {manifest} ({len(rows)} artifacts)")
    return manifest


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)
    build(os.path.abspath(args.out_dir), verbose=not args.quiet)


if __name__ == "__main__":
    main()
