"""Pallas kernel: fused sparse-masked K-means assignment distances.

This is the hot spot of sparsified K-means (Eq. 36): for every sample
``b`` in a chunk and every center ``k``,

    D[b, k] = sum_j mask[j, b] * (w[j, b] - mu[j, k])^2.

The paper's CPU implementation walks the m kept indices of each sample
(sparse gather). On TPU irregular gathers waste the MXU, so the kernel is
re-expressed as three dense contractions over the same masked data
(sparsity -> masking; see DESIGN.md "Hardware adaptation"):

    D = colnorm(w)^T . 1  -  2 * w^T mu  +  mask^T (mu * mu)

using ``mask * w == w`` and ``mask^2 == mask``. Both matmuls are
(B, p) x (p, K) MXU contractions; the FLOP overhead vs sparse traversal is
p/m, but MXU utilization (vs scalar gathers) more than pays for it at the
paper's compression range (gamma in [0.01, 0.3]).

Grid: one step per column-block of the chunk; each step holds a
``(p, BLOCK_B)`` tile of ``w`` and ``mask`` plus the full ``(p, K)``
center panel in VMEM (K is small: 3..16 in all experiments).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _masked_distance_kernel(w_ref, m_ref, mu_ref, o_ref):
    w = w_ref[...]
    msk = m_ref[...]
    mu = mu_ref[...]
    f32 = w.dtype
    # ||w_b||^2 per column: (1, B)
    wn = jnp.sum(w * w, axis=0, keepdims=True)
    # cross term: (B, K) on the MXU
    cross = jax.lax.dot_general(
        w, mu, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )
    # masked center energy: (B, K) on the MXU
    mu2 = jax.lax.dot_general(
        msk, mu * mu, (((0,), (0,)), ((), ())), preferred_element_type=f32
    )
    o_ref[...] = wn.T - 2.0 * cross + mu2


def masked_distance(
    w: jnp.ndarray, mask: jnp.ndarray, mu: jnp.ndarray, *, block_b: int = DEFAULT_BLOCK_B
) -> jnp.ndarray:
    """Distances (B, K) between masked samples and centers, Eq. 36.

    ``w``/``mask``: (p, B) kept-entry values / 0-1 indicators;
    ``mu``: (p, K) centers in the preconditioned domain.
    """
    p, b = w.shape
    if mask.shape != (p, b):
        raise ValueError(f"mask shape {mask.shape} != {(p, b)}")
    k = mu.shape[1]
    if mu.shape[0] != p:
        raise ValueError(f"mu rows {mu.shape[0]} != p={p}")
    block_b = min(block_b, b)
    if b % block_b != 0:
        raise ValueError(f"B={b} not divisible by block_b={block_b}")
    grid = (b // block_b,)
    return pl.pallas_call(
        _masked_distance_kernel,
        out_shape=jax.ShapeDtypeStruct((b, k), w.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_b), lambda j: (0, j)),
            pl.BlockSpec((p, block_b), lambda j: (0, j)),
            pl.BlockSpec((p, k), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, k), lambda j: (j, 0)),
        interpret=True,
    )(w, mask, mu)
