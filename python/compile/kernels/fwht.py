"""Pallas kernel: blocked fast Walsh-Hadamard transform (the ROS ``H``).

The paper's preconditioner (Section III, Eq. 1) applies ``y = H D x`` per
column in O(p log p). On TPU the natural expression is a butterfly network
executed entirely in VMEM: one grid step owns a ``(p, BLOCK_B)`` tile of
the chunk (all of ``p`` must be resident — p*BLOCK_B*4 bytes, well under
the ~16 MiB VMEM budget for p <= 4096, BLOCK_B <= 512) and runs the
``log2(p)`` add/sub stages with reshape-strided operands, which lower to
cheap in-register shuffles rather than HBM traffic. The HBM <-> VMEM
schedule over column-blocks is expressed by the BlockSpec grid, replacing
the paper's "embarrassingly parallel across columns" CPU loop.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Columns per grid step. 128 keeps the lane dimension MXU/VPU aligned.
DEFAULT_BLOCK_B = 128


def _fwht_stages(x: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized in-VMEM butterfly over axis 0 (length must be a power
    of two). Static python loop: shapes are compile-time constants, so the
    trace unrolls into log2(p) fused add/sub stages."""
    p = x.shape[0]
    h = 1
    while h < p:
        x = x.reshape(p // (2 * h), 2, h, -1)
        a = x[:, 0]
        b = x[:, 1]
        x = jnp.stack([a + b, a - b], axis=1).reshape(p, -1)
        h *= 2
    return x


def _fwht_kernel(x_ref, o_ref, *, p: int):
    cols = x_ref[...]
    o_ref[...] = (_fwht_stages(cols) / jnp.sqrt(p).astype(cols.dtype)).reshape(cols.shape)


def fwht(x: jnp.ndarray, *, block_b: int = DEFAULT_BLOCK_B) -> jnp.ndarray:
    """Normalized Walsh-Hadamard transform of the columns of ``x`` (p, B).

    Matches ``ref.fwht_ref`` (Sylvester ordering); involutive and
    orthonormal. ``p`` must be a power of two; ``B`` must be divisible by
    the column block (callers pad chunks, the coordinator always sends
    fixed-shape chunks).
    """
    p, b = x.shape
    if p & (p - 1) != 0:
        raise ValueError(f"fwht: p={p} must be a power of 2")
    block_b = min(block_b, b)
    if b % block_b != 0:
        raise ValueError(f"fwht: B={b} not divisible by block_b={block_b}")
    grid = (b // block_b,)
    return pl.pallas_call(
        functools.partial(_fwht_kernel, p=p),
        out_shape=jax.ShapeDtypeStruct((p, b), x.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((p, block_b), lambda j: (0, j))],
        out_specs=pl.BlockSpec((p, block_b), lambda j: (0, j)),
        interpret=True,
    )(x)


def precondition(x: jnp.ndarray, signs: jnp.ndarray, *, block_b: int = DEFAULT_BLOCK_B) -> jnp.ndarray:
    """Full ROS map ``y = H D x`` with ``H`` the Hadamard transform.

    The sign flip is fused into the same pallas grid pass (one HBM read).
    """
    p, b = x.shape
    if p & (p - 1) != 0:
        raise ValueError(f"precondition: p={p} must be a power of 2")
    block_b = min(block_b, b)
    if b % block_b != 0:
        raise ValueError(f"precondition: B={b} not divisible by block_b={block_b}")

    def kernel(x_ref, s_ref, o_ref):
        cols = x_ref[...] * s_ref[...].reshape(p, 1).astype(x_ref.dtype)
        o_ref[...] = (_fwht_stages(cols) / jnp.sqrt(p).astype(cols.dtype)).reshape(cols.shape)

    grid = (b // block_b,)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((p, b), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_b), lambda j: (0, j)),
            pl.BlockSpec((p,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((p, block_b), lambda j: (0, j)),
        interpret=True,
    )(x, signs)
