"""Pure-jnp reference oracles for the Pallas kernels.

Everything in this file is deliberately naive and allocation-heavy: the
references exist only as the correctness ground truth that the Pallas
kernels (and, transitively, the AOT-compiled HLO the Rust coordinator
executes) are pinned against in pytest.

Shapes follow the paper's convention: data matrices are ``(p, B)`` with
samples as *columns* (``B`` = chunk/batch size), centers are ``(p, K)``.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def hadamard_matrix(p: int) -> np.ndarray:
    """Orthonormal Sylvester-ordered Hadamard matrix, ``p`` a power of two.

    ``H @ H.T = I`` (entries are ``±1/sqrt(p)``). This is the ``H`` of the
    paper's ROS preconditioner (Section III, Eq. 1) with eta = 1.
    """
    if p <= 0 or (p & (p - 1)) != 0:
        raise ValueError(f"hadamard_matrix: p={p} is not a positive power of 2")
    h = np.array([[1.0]])
    while h.shape[0] < p:
        h = np.block([[h, h], [h, -h]])
    return (h / np.sqrt(p)).astype(np.float64)


def dct_matrix(p: int) -> np.ndarray:
    """Orthonormal DCT-II matrix (any ``p``), the paper's alternative ``H``
    (eta = 1/2 in Theorem 1). Row ``j``, col ``k``:
    ``c_j * cos(pi*(2k+1)*j / (2p))`` with ``c_0 = sqrt(1/p)``,
    ``c_j = sqrt(2/p)`` otherwise.
    """
    j = np.arange(p)[:, None].astype(np.float64)
    k = np.arange(p)[None, :].astype(np.float64)
    mat = np.cos(np.pi * (2.0 * k + 1.0) * j / (2.0 * p))
    mat *= np.sqrt(2.0 / p)
    mat[0, :] *= np.sqrt(0.5)
    return mat


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Normalized Walsh-Hadamard transform of the columns of ``x`` (p, B)
    via an explicit matrix multiply. Involutive: ``fwht_ref(fwht_ref(x)) == x``.
    """
    p = x.shape[0]
    h = jnp.asarray(hadamard_matrix(p), dtype=x.dtype)
    return h @ x


def precondition_ref(x: jnp.ndarray, signs: jnp.ndarray, transform: str = "fwht") -> jnp.ndarray:
    """ROS preconditioner ``y = H D x`` (Eq. 1). ``signs`` is the diagonal of
    ``D`` (entries ±1), ``transform`` selects ``H``.
    """
    xd = x * signs[:, None].astype(x.dtype)
    if transform == "fwht":
        return fwht_ref(xd)
    if transform == "dct":
        p = x.shape[0]
        return jnp.asarray(dct_matrix(p), dtype=x.dtype) @ xd
    raise ValueError(f"unknown transform {transform!r}")


def masked_distance_ref(w: jnp.ndarray, mask: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Sparsified K-means assignment distances (Eq. 36).

    ``D[b, k] = sum_j mask[j, b] * (w[j, b] - mu[j, k])**2``

    ``w`` (p, B) holds the kept entries of each preconditioned sample (zero
    where not sampled), ``mask`` (p, B) is the 0/1 sampling indicator
    (``R_i R_i^T`` as a column), ``mu`` (p, K) holds candidate centers in the
    preconditioned domain. Output (B, K).
    """
    diff = w[:, :, None] - mu[:, None, :]          # (p, B, K)
    return jnp.sum(mask[:, :, None] * diff * diff, axis=0)


def center_update_ref(w: jnp.ndarray, mask: jnp.ndarray, onehot: jnp.ndarray):
    """Masked per-entry center accumulation (Eq. 39) for one chunk.

    ``sums[j, k]   = sum_b w[j, b]    * onehot[b, k]``
    ``counts[j, k] = sum_b mask[j, b] * onehot[b, k]``

    Dividing ``sums`` by ``counts`` (where positive) over all chunks gives
    the entry-wise sample-mean center update of Algorithm 1 line 8.
    """
    sums = w @ onehot
    counts = mask @ onehot
    return sums, counts


def cov_update_ref(w: jnp.ndarray) -> jnp.ndarray:
    """Chunk Gram accumulation for the covariance estimator (Eq. 19):
    ``sum_i w_i w_i^T`` = ``W @ W.T`` (p, p). The p/m rescale and the
    diagonal unbiasing (Eq. 21) are applied by the Rust accumulator.
    """
    return w @ w.T


def kmeans_step_ref(w: jnp.ndarray, mask: jnp.ndarray, mu: jnp.ndarray):
    """Fused assignment + accumulation for one chunk: returns
    ``(assign (B,) int32, sums (p, K), counts (p, K))``.
    """
    d = masked_distance_ref(w, mask, mu)
    assign = jnp.argmin(d, axis=1).astype(jnp.int32)
    onehot = jnp.eye(mu.shape[1], dtype=w.dtype)[assign].reshape(w.shape[1], mu.shape[1])
    sums, counts = center_update_ref(w, mask, onehot)
    return assign, sums, counts
