# L1: Pallas kernels for the paper's compute hot-spots.
from . import fwht, masked_distance, ref  # noqa: F401
