"""L2: the jax compute graphs of the sparsified-data pipeline.

One function per pipeline *step*; each is lowered once by ``aot.py`` to
HLO text and executed from the Rust coordinator via PJRT. All shapes are
static (fixed at lowering time from a ``ShapeConfig``), samples are
columns, dtype is f32.

Graphs
------
``precondition``   y = H D x          (Eq. 1; Pallas FWHT when p is 2^k,
                                       orthonormal DCT-II matmul otherwise)
``assign``         masked distances   (Eq. 36; Pallas masked_distance)
``center_update``  masked sums/counts (Eq. 39)
``cov_update``     chunk Gram W W^T   (Eq. 19 accumulation term)
``kmeans_step``    fused assign + accumulate (ablation: one round trip
                                       instead of two per chunk)
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import fwht as fwht_kernel
from .kernels import masked_distance as md_kernel
from .kernels.ref import dct_matrix


@dataclass(frozen=True)
class ShapeConfig:
    """Static shape signature of one compiled pipeline variant."""

    p: int  # ambient dimension
    b: int  # chunk size (columns per executable call)
    k: int  # number of clusters (ignored by precondition/cov graphs)

    @property
    def pow2(self) -> bool:
        return self.p & (self.p - 1) == 0

    def tag(self) -> str:
        return f"p{self.p}_b{self.b}_k{self.k}"


def _block_b(cfg: ShapeConfig) -> int:
    return min(fwht_kernel.DEFAULT_BLOCK_B, cfg.b)


def precondition(cfg: ShapeConfig):
    """(x (p,B), signs (p,)) -> y = HDx (p,B)."""
    if cfg.pow2:

        def fn(x, signs):
            return (fwht_kernel.precondition(x, signs, block_b=_block_b(cfg)),)

    else:
        # Non-power-of-two p (e.g. MNIST's 784): orthonormal DCT-II as a
        # constant-matrix contraction. O(p^2) per column instead of
        # O(p log p) — acceptable at p<=1024 and still one fused matmul on
        # the MXU; the pow2-padded FWHT variant is the fast path.
        h = jnp.asarray(dct_matrix(cfg.p), dtype=jnp.float32)

        def fn(x, signs):
            return (h @ (x * signs[:, None].astype(x.dtype)),)

    return fn


def precondition_adjoint(cfg: ShapeConfig):
    """(y (p,B), signs (p,)) -> x = (HD)^T y, the exact inverse of
    ``precondition`` (HD is orthonormal). Used to unmix centers (Eq. 32)."""
    if cfg.pow2:

        def fn(y, signs):
            return (fwht_kernel.fwht(y, block_b=_block_b(cfg)) * signs[:, None].astype(y.dtype),)

    else:
        ht = jnp.asarray(dct_matrix(cfg.p).T, dtype=jnp.float32)

        def fn(y, signs):
            return ((ht @ y) * signs[:, None].astype(y.dtype),)

    return fn


def assign(cfg: ShapeConfig):
    """(w (p,B), mask (p,B), mu (p,K)) -> (distances (B,K), assign (B,) i32)."""

    def fn(w, mask, mu):
        d = md_kernel.masked_distance(w, mask, mu, block_b=_block_b(cfg))
        return d, jnp.argmin(d, axis=1).astype(jnp.int32)

    return fn


def center_update(cfg: ShapeConfig):
    """(w (p,B), mask (p,B), onehot (B,K)) -> (sums (p,K), counts (p,K))."""

    def fn(w, mask, onehot):
        return w @ onehot, mask @ onehot

    return fn


def cov_update(cfg: ShapeConfig):
    """(w (p,B)) -> (W W^T (p,p),). Streaming Gram accumulation for Eq. 19."""

    def fn(w):
        return (jax.lax.dot_general(w, w, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32),)

    return fn


def kmeans_step(cfg: ShapeConfig):
    """Fused chunk step: (w, mask, mu) -> (assign (B,) i32, sums, counts).

    One executable launch per chunk per Lloyd iteration instead of two;
    benchmarked against the split pipeline in `ablation_engine`.
    """

    def fn(w, mask, mu):
        d = md_kernel.masked_distance(w, mask, mu, block_b=_block_b(cfg))
        a = jnp.argmin(d, axis=1).astype(jnp.int32)
        onehot = jax.nn.one_hot(a, cfg.k, dtype=w.dtype)
        return a, w @ onehot, mask @ onehot

    return fn


def example_args(cfg: ShapeConfig, name: str):
    """ShapeDtypeStructs used to lower each graph."""
    f32 = jnp.float32
    s = jax.ShapeDtypeStruct
    p, b, k = cfg.p, cfg.b, cfg.k
    if name in ("precondition", "precondition_adjoint"):
        return (s((p, b), f32), s((p,), f32))
    if name == "assign":
        return (s((p, b), f32), s((p, b), f32), s((p, k), f32))
    if name == "center_update":
        return (s((p, b), f32), s((p, b), f32), s((b, k), f32))
    if name == "cov_update":
        return (s((p, b), f32),)
    if name == "kmeans_step":
        return (s((p, b), f32), s((p, b), f32), s((p, k), f32))
    raise KeyError(name)


GRAPHS = {
    "precondition": precondition,
    "precondition_adjoint": precondition_adjoint,
    "assign": assign,
    "center_update": center_update,
    "cov_update": cov_update,
    "kmeans_step": kmeans_step,
}
