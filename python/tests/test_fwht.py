"""Pallas FWHT kernel vs pure-jnp oracle: hypothesis sweep over shapes,
plus algebraic invariants (involution, orthonormality, linearity)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fwht, ref

SETTINGS = dict(deadline=None, max_examples=25)


def rand(shape, seed, dtype=np.float32, scale=1.0):
    return (np.random.default_rng(seed).normal(size=shape) * scale).astype(dtype)


@settings(**SETTINGS)
@given(
    logp=st.integers(min_value=1, max_value=9),
    b=st.sampled_from([1, 2, 4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fwht_matches_ref(logp, b, seed):
    p = 1 << logp
    x = rand((p, b), seed)
    got = np.asarray(fwht.fwht(jnp.asarray(x), block_b=b))
    want = np.asarray(ref.fwht_ref(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    logp=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fwht_involutive(logp, seed):
    p = 1 << logp
    x = rand((p, 4), seed)
    twice = np.asarray(fwht.fwht(fwht.fwht(jnp.asarray(x), block_b=4), block_b=4))
    np.testing.assert_allclose(twice, x, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    logp=st.integers(min_value=2, max_value=9),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_fwht_preserves_column_norms(logp, seed):
    p = 1 << logp
    x = rand((p, 8), seed, scale=3.0)
    y = np.asarray(fwht.fwht(jnp.asarray(x), block_b=8))
    np.testing.assert_allclose(
        np.linalg.norm(y, axis=0), np.linalg.norm(x, axis=0), rtol=1e-4
    )


def test_fwht_linearity():
    p, b = 128, 8
    x, y = rand((p, b), 0), rand((p, b), 1)
    fx = np.asarray(fwht.fwht(jnp.asarray(x), block_b=b))
    fy = np.asarray(fwht.fwht(jnp.asarray(y), block_b=b))
    fxy = np.asarray(fwht.fwht(jnp.asarray(2.0 * x - 3.0 * y), block_b=b))
    np.testing.assert_allclose(fxy, 2.0 * fx - 3.0 * fy, rtol=1e-4, atol=1e-5)


def test_fwht_block_grid_equivalence():
    """Result must not depend on the BlockSpec column tiling."""
    p, b = 256, 64
    x = jnp.asarray(rand((p, b), 7))
    full = np.asarray(fwht.fwht(x, block_b=64))
    for block in (8, 16, 32):
        np.testing.assert_allclose(
            np.asarray(fwht.fwht(x, block_b=block)), full, rtol=1e-5, atol=1e-6
        )


@settings(**SETTINGS)
@given(
    logp=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_precondition_matches_ref(logp, seed):
    p = 1 << logp
    x = rand((p, 4), seed)
    rng = np.random.default_rng(seed + 1)
    signs = np.where(rng.random(p) < 0.5, -1.0, 1.0).astype(np.float32)
    got = np.asarray(fwht.precondition(jnp.asarray(x), jnp.asarray(signs), block_b=4))
    want = np.asarray(ref.precondition_ref(jnp.asarray(x), jnp.asarray(signs)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_precondition_is_orthonormal_map():
    """(HD)^T (HD) = I: preconditioning then adjoint recovers the input."""
    p, b = 128, 8
    x = rand((p, b), 3)
    signs = np.where(np.random.default_rng(4).random(p) < 0.5, -1.0, 1.0).astype(np.float32)
    y = fwht.precondition(jnp.asarray(x), jnp.asarray(signs), block_b=b)
    back = np.asarray(fwht.fwht(y, block_b=b)) * signs[:, None]
    np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-5)


def test_fwht_smooths_spike():
    """Theorem 1's point: a 1-sparse (incoherent-worst-case) column becomes
    flat with |entries| exactly 1/sqrt(p)."""
    p = 256
    x = np.zeros((p, 1), dtype=np.float32)
    x[17, 0] = 1.0
    signs = np.ones(p, dtype=np.float32)
    y = np.asarray(fwht.precondition(jnp.asarray(x), jnp.asarray(signs), block_b=1))
    np.testing.assert_allclose(np.abs(y), 1.0 / np.sqrt(p), rtol=1e-5)


def test_fwht_rejects_non_pow2():
    with pytest.raises(ValueError):
        fwht.fwht(jnp.zeros((100, 4), jnp.float32))


def test_fwht_rejects_bad_block():
    with pytest.raises(ValueError):
        fwht.fwht(jnp.zeros((64, 6), jnp.float32), block_b=4)


def test_dct_matrix_orthonormal():
    for p in (3, 16, 100, 784):
        c = ref.dct_matrix(p)
        np.testing.assert_allclose(c @ c.T, np.eye(p), atol=1e-10)
