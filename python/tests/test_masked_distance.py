"""Pallas masked-distance kernel vs oracle + brute-force numpy."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_distance as md
from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=25)


def make_case(p, b, k, m, seed):
    rng = np.random.default_rng(seed)
    y = rng.normal(size=(p, b)).astype(np.float32)
    mask = np.zeros((p, b), dtype=np.float32)
    for col in range(b):
        keep = rng.choice(p, size=m, replace=False)
        mask[keep, col] = 1.0
    w = y * mask
    mu = rng.normal(size=(p, k)).astype(np.float32)
    return w, mask, mu


def brute(w, mask, mu):
    p, b = w.shape
    k = mu.shape[1]
    out = np.zeros((b, k), dtype=np.float64)
    for i in range(b):
        for j in range(k):
            d = w[:, i] - mu[:, j]
            out[i, j] = float(np.sum(mask[:, i] * d * d))
    return out.astype(np.float32)


@settings(**SETTINGS)
@given(
    p=st.sampled_from([8, 32, 100, 128]),
    b=st.sampled_from([1, 4, 8]),
    k=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    frac=st.sampled_from([0.1, 0.3, 0.9]),
)
def test_matches_brute_force(p, b, k, seed, frac):
    m = max(1, int(frac * p))
    w, mask, mu = make_case(p, b, k, m, seed)
    got = np.asarray(md.masked_distance(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu), block_b=b))
    np.testing.assert_allclose(got, brute(w, mask, mu), rtol=1e-3, atol=1e-4)


@settings(**SETTINGS)
@given(
    p=st.sampled_from([16, 64, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref(p, seed):
    w, mask, mu = make_case(p, 8, 4, max(1, p // 4), seed)
    got = np.asarray(md.masked_distance(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu), block_b=8))
    want = np.asarray(ref.masked_distance_ref(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_full_mask_equals_euclidean():
    """mask = all-ones reduces Eq. 36 to plain squared distances (the
    R_i = I_p case called out under Eq. 35)."""
    p, b, k = 64, 8, 3
    rng = np.random.default_rng(0)
    w = rng.normal(size=(p, b)).astype(np.float32)
    mask = np.ones((p, b), dtype=np.float32)
    mu = rng.normal(size=(p, k)).astype(np.float32)
    got = np.asarray(md.masked_distance(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu), block_b=b))
    want = ((w[:, :, None] - mu[:, None, :]) ** 2).sum(axis=0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_zero_mask_scores_center_energy_zero():
    """A sample with an empty mask is distance-0 to every center: the
    objective Eq. 34 carries no information for unseen coordinates."""
    p, b, k = 32, 4, 3
    w = np.zeros((p, b), dtype=np.float32)
    mask = np.zeros((p, b), dtype=np.float32)
    mu = np.random.default_rng(1).normal(size=(p, k)).astype(np.float32)
    got = np.asarray(md.masked_distance(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu), block_b=b))
    np.testing.assert_allclose(got, np.zeros((b, k), np.float32), atol=1e-6)


def test_distances_nonnegative():
    w, mask, mu = make_case(128, 16, 5, 32, 99)
    got = np.asarray(md.masked_distance(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu), block_b=16))
    assert (got >= -1e-4).all()


def test_block_grid_equivalence():
    w, mask, mu = make_case(128, 64, 4, 40, 5)
    full = np.asarray(md.masked_distance(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu), block_b=64))
    for block in (8, 16, 32):
        got = np.asarray(md.masked_distance(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu), block_b=block))
        np.testing.assert_allclose(got, full, rtol=1e-5, atol=1e-5)


def test_shape_validation():
    w = jnp.zeros((16, 4), jnp.float32)
    with pytest.raises(ValueError):
        md.masked_distance(w, jnp.zeros((16, 5), jnp.float32), jnp.zeros((16, 2), jnp.float32))
    with pytest.raises(ValueError):
        md.masked_distance(w, jnp.zeros((16, 4), jnp.float32), jnp.zeros((8, 2), jnp.float32))
