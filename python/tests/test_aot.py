"""AOT path: HLO text emission + manifest format (the Rust runtime's
contract). Uses a tiny shape config to keep lowering fast."""

import os

import pytest

from compile import aot, model

TINY = (model.ShapeConfig(p=32, b=8, k=2),)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, configs=TINY, verbose=False)
    return out, manifest


def test_manifest_lists_all_graphs(built):
    out, manifest = built
    lines = [l for l in open(manifest) if not l.startswith("#")]
    assert len(lines) == len(model.GRAPHS)
    names = set()
    for line in lines:
        name, p, b, k, fname = line.rstrip("\n").split("\t")
        assert (int(p), int(b), int(k)) == (32, 8, 2)
        assert os.path.exists(os.path.join(out, fname))
        names.add(name)
    assert names == set(model.GRAPHS)


def test_artifacts_are_hlo_text_not_proto(built):
    out, manifest = built
    for line in open(manifest):
        if line.startswith("#"):
            continue
        fname = line.rstrip("\n").split("\t")[-1]
        text = open(os.path.join(out, fname)).read()
        # HLO text contract: parseable header, tuple-rooted entry computation
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # no serialized-proto leakage
        assert "\x00" not in text


def test_root_is_tuple(built):
    """return_tuple=True is load-bearing: the Rust side unconditionally
    unpacks a tuple literal."""
    out, manifest = built
    for line in open(manifest):
        if line.startswith("#"):
            continue
        fname = line.rstrip("\n").split("\t")[-1]
        text = open(os.path.join(out, fname)).read()
        entry = text[text.index("ENTRY"):]
        root = [l for l in entry.splitlines() if "ROOT" in l][0]
        assert "tuple(" in root or "tuple<" in root or ") tuple" in root, root


def test_shapes_in_hlo(built):
    out, manifest = built
    for line in open(manifest):
        if line.startswith("#") :
            continue
        name, p, b, k, fname = line.rstrip("\n").split("\t")
        text = open(os.path.join(out, fname)).read()
        if name in ("precondition", "precondition_adjoint", "cov_update"):
            assert f"f32[{p},{b}]" in text
        if name in ("assign", "kmeans_step"):
            assert f"f32[{p},{k}]" in text
