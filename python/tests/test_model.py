"""L2 graph semantics: each model graph vs its oracle and the statistical
identities the Rust estimators rely on."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=10)


def sample_chunk(cfg, seed, m=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(cfg.p, cfg.b)).astype(np.float32)
    m = m or max(2, cfg.p // 4)
    mask = np.zeros((cfg.p, cfg.b), dtype=np.float32)
    for col in range(cfg.b):
        mask[rng.choice(cfg.p, size=m, replace=False), col] = 1.0
    return x, mask


CFG_POW2 = model.ShapeConfig(p=64, b=16, k=3)
CFG_DCT = model.ShapeConfig(p=28, b=16, k=3)  # non-pow2 -> DCT path


def test_precondition_pow2_matches_ref():
    x, _ = sample_chunk(CFG_POW2, 0)
    signs = np.where(np.random.default_rng(1).random(CFG_POW2.p) < 0.5, -1, 1).astype(np.float32)
    (y,) = model.precondition(CFG_POW2)(jnp.asarray(x), jnp.asarray(signs))
    want = ref.precondition_ref(jnp.asarray(x), jnp.asarray(signs), "fwht")
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_precondition_dct_matches_ref():
    x, _ = sample_chunk(CFG_DCT, 0)
    signs = np.where(np.random.default_rng(1).random(CFG_DCT.p) < 0.5, -1, 1).astype(np.float32)
    (y,) = model.precondition(CFG_DCT)(jnp.asarray(x), jnp.asarray(signs))
    want = ref.precondition_ref(jnp.asarray(x), jnp.asarray(signs), "dct")
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_adjoint_inverts_precondition_both_paths(seed):
    for cfg in (CFG_POW2, CFG_DCT):
        x, _ = sample_chunk(cfg, seed)
        signs = np.where(np.random.default_rng(seed + 1).random(cfg.p) < 0.5, -1, 1).astype(np.float32)
        (y,) = model.precondition(cfg)(jnp.asarray(x), jnp.asarray(signs))
        (back,) = model.precondition_adjoint(cfg)(y, jnp.asarray(signs))
        np.testing.assert_allclose(np.asarray(back), x, rtol=1e-3, atol=1e-4)


def test_assign_matches_ref():
    x, mask = sample_chunk(CFG_POW2, 2)
    w = x * mask
    mu = np.random.default_rng(3).normal(size=(CFG_POW2.p, CFG_POW2.k)).astype(np.float32)
    d, a = model.assign(CFG_POW2)(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu))
    dref = ref.masked_distance_ref(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu))
    np.testing.assert_allclose(np.asarray(d), np.asarray(dref), rtol=1e-3, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(a), np.argmin(np.asarray(dref), axis=1))


def test_center_update_matches_ref():
    x, mask = sample_chunk(CFG_POW2, 4)
    w = x * mask
    rng = np.random.default_rng(5)
    assign = rng.integers(0, CFG_POW2.k, size=CFG_POW2.b)
    onehot = np.eye(CFG_POW2.k, dtype=np.float32)[assign]
    s, c = model.center_update(CFG_POW2)(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(onehot))
    sr, cr = ref.center_update_ref(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(onehot))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), rtol=1e-4, atol=1e-5)
    # counts never exceed per-entry mask totals and are integers
    assert np.all(np.asarray(c) >= 0)
    np.testing.assert_allclose(np.asarray(c).sum(axis=1), mask.sum(axis=1), rtol=1e-5)


def test_cov_update_is_gram():
    x, mask = sample_chunk(CFG_POW2, 6)
    w = x * mask
    (g,) = model.cov_update(CFG_POW2)(jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(g), w @ w.T, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g).T, atol=1e-5)


def test_kmeans_step_consistent_with_split_graphs():
    x, mask = sample_chunk(CFG_POW2, 7)
    w = x * mask
    mu = np.random.default_rng(8).normal(size=(CFG_POW2.p, CFG_POW2.k)).astype(np.float32)
    a, s, c = model.kmeans_step(CFG_POW2)(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu))
    d, a2 = model.assign(CFG_POW2)(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(mu))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a2))
    onehot = np.eye(CFG_POW2.k, dtype=np.float32)[np.asarray(a)]
    s2, c2 = model.center_update(CFG_POW2)(jnp.asarray(w), jnp.asarray(mask), jnp.asarray(onehot))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c2), rtol=1e-4, atol=1e-5)


def test_unbiased_mean_identity():
    """E[R R^T] = (m/p) I  (Theorem B4): empirical check through the masked
    chunk representation — the rescaled masked mean converges to the mean."""
    p, b, m = 32, 4096, 8
    cfg = model.ShapeConfig(p=p, b=b, k=2)
    rng = np.random.default_rng(11)
    xbar = rng.normal(size=(p, 1)).astype(np.float32)
    x = np.repeat(xbar, b, axis=1)
    mask = np.zeros((p, b), dtype=np.float32)
    for col in range(b):
        mask[rng.choice(p, size=m, replace=False), col] = 1.0
    w = x * mask
    est = (p / m) * w.mean(axis=1)
    err = np.abs(est - xbar[:, 0]).max()
    assert err < 0.5, err  # O(1/sqrt(b)) concentration


def test_graph_registry_and_example_args():
    for name in model.GRAPHS:
        args = model.example_args(CFG_POW2, name)
        fn = model.GRAPHS[name](CFG_POW2)
        out = jax.eval_shape(fn, *args)
        assert isinstance(out, tuple) and len(out) >= 1
