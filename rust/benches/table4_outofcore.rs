//! Bench: regenerate Table IV (out-of-core run with disk accounting).
use pds::cli::Args;
fn main() {
    pds::bench::section("Table IV: out-of-core streaming run");
    let args = Args::parse(&["--n".into(), "30000".into()]).unwrap();
    pds::experiments::table4::run(&args).unwrap();
}
