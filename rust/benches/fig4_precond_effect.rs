//! Bench: regenerate Fig. 4 (preconditioning ablation) and time the ROS.
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 4: preconditioning effect on covariance error");
    let args = Args::parse(&["--runs".into(), "3".into()]).unwrap();
    pds::experiments::fig4_table1::run_fig4(&args).unwrap();
    use pds::{linalg::Mat, rng::Pcg64, sampling::{Sparsifier, SparsifyConfig},
              transform::TransformKind};
    let mut rng = Pcg64::seed(1);
    let x = Mat::from_fn(512, 1024, |_, _| rng.normal());
    for kind in [TransformKind::Hadamard, TransformKind::Dct] {
        let cfg = SparsifyConfig { gamma: 0.2, transform: kind, seed: 2 };
        let sp = Sparsifier::new(512, cfg).unwrap();
        pds::bench::bench(&format!("fig4/ROS {kind:?} (p=512,n=1024)"), 1, 5, || {
            sp.precondition_dense(&x).get(0, 0)
        });
    }
}
