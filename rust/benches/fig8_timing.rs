//! Bench: regenerate Fig. 8 (time vs gamma on digits).
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 8: digit clustering time vs gamma");
    let args = Args::parse(&["--n".into(), "2000".into(), "--trials".into(), "2".into(),
                             "--gammas".into(), "0.02,0.05,0.1".into()]).unwrap();
    pds::experiments::fig7_8::run_fig8(&args).unwrap();
}
