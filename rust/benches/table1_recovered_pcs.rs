//! Bench: regenerate Table I (recovered PCs with/without preconditioning).
use pds::cli::Args;
fn main() {
    pds::bench::section("Table I: recovered principal components");
    let args = Args::parse(&["--runs".into(), "3".into()]).unwrap();
    pds::experiments::fig4_table1::run_table1(&args).unwrap();
}
