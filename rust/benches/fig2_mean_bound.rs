//! Bench: regenerate Fig. 2 (mean estimator vs Thm 4 bound) and time the
//! streaming mean accumulation.
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 2: mean estimator error vs Theorem 4 bound");
    let args = Args::parse(&["--runs".into(), "30".into()]).unwrap();
    pds::experiments::fig2::run(&args).unwrap();
    use pds::{estimators::SparseMeanEstimator, linalg::Mat, rng::Pcg64,
              sampling::{Sparsifier, SparsifyConfig}, transform::TransformKind};
    let mut rng = Pcg64::seed(1);
    let x = Mat::from_fn(128, 20_000, |_, _| rng.normal());
    let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 2 };
    let sp = Sparsifier::new(128, cfg).unwrap();
    let chunk = sp.compress_chunk(&x, 0).unwrap();
    pds::bench::bench("fig2/mean accumulate (p=128,n=20k,m=38)", 1, 10, || {
        let mut est = SparseMeanEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        est.estimate()[0]
    });
}
