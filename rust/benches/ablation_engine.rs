//! Ablation bench: native Rust assigner vs the PJRT/XLA AOT `assign`
//! artifact (the Pallas masked-distance kernel) on identical chunks.
//! Skips the XLA arm when artifacts are absent.
use pds::data::gaussian_blobs;
use pds::kmeans::{NativeAssigner, SparseAssigner};
use pds::rng::Pcg64;
use pds::runtime::{artifact_dir, XlaEngine};
use pds::sampling::{Sparsifier, SparsifyConfig};
use pds::transform::TransformKind;

fn main() {
    pds::bench::section("Ablation: assignment engine (native vs xla/pallas)");
    let (p, n, k) = (512usize, 2048usize, 5usize);
    let mut rng = Pcg64::seed(1);
    let d = gaussian_blobs(p, n, k, 0.1, &mut rng);
    for gamma in [0.02f64, 0.05, 0.2] {
        let cfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 2 };
        let sp = Sparsifier::new(p, cfg).unwrap();
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();
        let centers = sp.precondition_dense(&d.centers);
        pds::bench::bench(&format!("assign/native gamma={gamma} (p=512,n=2048,K=5)"), 1, 10, || {
            NativeAssigner::new().assign(&chunk, &centers).unwrap().1
        });
        if artifact_dir().join("manifest.tsv").exists() {
            let engine = XlaEngine::new(None).unwrap();
            // warm compile outside the timing
            let _ = engine.assign(&chunk, &centers).unwrap();
            pds::bench::bench(&format!("assign/xla    gamma={gamma} (p=512,n=2048,K=5)"), 1, 10, || {
                engine.assign(&chunk, &centers).unwrap().1
            });
        } else {
            println!("(artifacts missing; xla arm skipped)");
        }
    }
}
