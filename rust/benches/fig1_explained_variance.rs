//! Bench: regenerate Fig. 1 (explained variance vs gamma) at bench scale
//! and time one full sparsify->covariance->PCA arm.
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 1: explained variance (precond+sparsify vs column sampling)");
    let args = Args::parse(&["--runs".into(), "5".into()]).unwrap();
    pds::experiments::fig1::run(&args).unwrap();
    // hot arm timing
    use pds::{data::multivariate_t, estimators::CovarianceEstimator, rng::Pcg64,
              sampling::{Sparsifier, SparsifyConfig}, transform::TransformKind, pca::Pca};
    let mut rng = Pcg64::seed(1);
    let d = multivariate_t(512, 1024, 1.0, &mut rng);
    let cfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 2 };
    let sp = Sparsifier::new(512, cfg).unwrap();
    pds::bench::bench("fig1/sparsify+cov+pca (p=512,n=1024,g=0.2)", 1, 5, || {
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();
        let mut est = CovarianceEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        Pca::from_covariance(&est.estimate(), 10, 3).eigenvalues[0]
    });
}
