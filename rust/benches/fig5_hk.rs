//! Bench: regenerate Fig. 5 (H_k concentration) and time mask sampling.
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 5: ||H_k - I|| vs Theorem 7 bound");
    let args = Args::parse(&["--runs".into(), "100".into()]).unwrap();
    pds::experiments::fig5::run(&args).unwrap();
    use pds::{rng::Pcg64, sampling::sample_indices};
    let mut rng = Pcg64::seed(1);
    let (p, m) = (1024usize, 51usize);
    let mut idx = vec![0u32; m];
    let mut perm = vec![0u32; p];
    pds::bench::bench("fig5/sample m-of-p masks x1000 (p=1024,m=51)", 2, 10, || {
        for _ in 0..1000 {
            sample_indices(&mut rng, p, &mut idx, &mut perm);
        }
        idx[0]
    });
}
