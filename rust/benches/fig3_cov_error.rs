//! Bench: regenerate Fig. 3 (covariance error vs n and gamma) and time
//! the m^2-scatter covariance accumulation hot path.
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 3: covariance estimator error vs Theorem 6 bound");
    let args = Args::parse(&["--runs".into(), "3".into(), "--p".into(), "128".into()]).unwrap();
    pds::experiments::fig3::run(&args).unwrap();
    use pds::{data::spiked, estimators::CovarianceEstimator, rng::Pcg64,
              sampling::{Sparsifier, SparsifyConfig}, transform::TransformKind};
    let mut rng = Pcg64::seed(1);
    let d = spiked(256, 2560, &[10.0, 8.0, 6.0, 4.0, 2.0], false, &mut rng);
    let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 2 };
    let sp = Sparsifier::new(256, cfg).unwrap();
    let chunk = sp.compress_chunk(&d.data, 0).unwrap();
    pds::bench::bench("fig3/cov accumulate (p=256,n=2560,m=77)", 1, 5, || {
        let mut est = CovarianceEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        est.n()
    });
}
