//! Bench: regenerate Fig. 7 (accuracy vs gamma on digits, 5 algorithms).
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 7: digit clustering accuracy vs gamma");
    let args = Args::parse(&["--n".into(), "2000".into(), "--trials".into(), "2".into(),
                             "--gammas".into(), "0.02,0.05,0.1".into()]).unwrap();
    pds::experiments::fig7_8::run_fig7(&args).unwrap();
}
