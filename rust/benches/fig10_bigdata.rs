//! Bench: regenerate Fig. 10 (big-data accuracy, streaming digits).
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 10: streaming big-data accuracy vs gamma");
    let args = Args::parse(&["--n".into(), "20000".into(), "--trials".into(), "1".into(),
                             "--gammas".into(), "0.01,0.05".into()]).unwrap();
    pds::experiments::fig10_table3::run_fig10(&args).unwrap();
}
