//! Bench: regenerate Fig. 6 (standard vs sparsified K-means speedup).
use pds::cli::Args;
fn main() {
    pds::bench::section("Fig 6: standard vs sparsified K-means");
    let args = Args::parse(&["--n".into(), "10000".into()]).unwrap();
    pds::experiments::fig6::run(&args).unwrap();
}
