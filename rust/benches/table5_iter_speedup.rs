//! Bench: regenerate Table V (per-iteration assignment/update speedup).
use pds::cli::Args;
fn main() {
    pds::bench::section("Table V: per-iteration speedup");
    let args = Args::parse(&["--n".into(), "30000".into()]).unwrap();
    pds::experiments::table5::run(&args).unwrap();
}
