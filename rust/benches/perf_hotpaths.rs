//! Perf-pass harness: the L3 hot paths measured in isolation, with
//! arithmetic-intensity context so the §Perf log in `rust/EXPERIMENTS.md`
//! is reproducible.
//!
//! Measures (1) the blocked FWHT with scalar-vs-SIMD arms, (2) mask
//! sampling (O(p)-reset reference vs the O(m) `IndexSampler`), (3) masked
//! assignment — scalar-vs-SIMD and f64-vs-f32 arms, plus thread scaling —
//! (4) the covariance scatter at 1/2/4 workers and the shared
//! `col_dot`/`col_scatter` kernel pair in isolation, (5) the PCA solver
//! comparison: materialized-covariance (`sym_eig_topk` on the p×p
//! estimate) vs covariance-free block-Krylov (`SparseCovOp`) at
//! p = 2^12..2^14 — and (6) the K-means solver comparison: the in-memory
//! chunk fit vs the source-driven streaming fit (`CenterStep` over
//! store-budget-sized chunks) at p = 4096/8192, workers 1/2/4, in ms per
//! Lloyd iteration — and (7) the serve daemon's query read path
//! (snapshot load + project/assign, no transport), reported as p50/p99
//! µs per query since tail latency is the serving SLO, plus amortized
//! single-sample vs batch=64 µs/query through the panel kernel — the
//! micro-batching lane's payoff. A final
//! non-timing check records the f32-vs-f64 explained-variance parity on
//! the Fig-1 digits shape. Results are also emitted as
//! `BENCH_hotpaths.json` at the repository root (schema documented in
//! EXPERIMENTS.md §Perf log).
//!
//! `PDS_BENCH_QUICK=1` shrinks iteration counts and skips the slow
//! solver-comparison sections (5 and 6) — the profile the CI perf gate
//! runs; the gated rows (FWHT / assignment / scatter-kernel arms and the
//! parity check) are all still emitted.

use std::io::Write as _;

use pds::bench::BenchResult;
use pds::data::{digits, DigitConfig};
use pds::estimators::{CovarianceEstimator, SparseCovOp};
use pds::kmeans::{kmeans_pp_dense, NativeAssigner, SparseAssigner};
use pds::linalg::Mat;
use pds::pca::Pca;
use pds::rng::Pcg64;
use pds::sampling::{sample_indices, IndexSampler, Sparsifier, SparsifyConfig};
use pds::simd::Isa;
use pds::sparse::{Precision, SparseChunk};
use pds::testing::fixtures::sparse_chunk;
use pds::transform::fwht_inplace;
use pds::transform::TransformKind;

/// One emitted benchmark row: the raw timing plus one derived
/// throughput metric.
struct Entry {
    result: BenchResult,
    metric: &'static str,
    value: f64,
}

/// One emitted pass/fail numeric check (not a timing): the CI gate
/// verifies `value <= tolerance`.
struct Check {
    name: &'static str,
    value: f64,
    tolerance: f64,
}

fn main() {
    let quick = std::env::var("PDS_BENCH_QUICK").is_ok();
    // (warmup, iters) for the cheap kernel sections; the O(seconds)
    // solver sections below use their own smaller budgets
    let (bw, bi) = if quick { (1, 8) } else { (2, 20) };
    let mut entries: Vec<Entry> = Vec::new();
    let mut checks: Vec<Check> = Vec::new();
    let best = pds::simd::detect();

    pds::bench::section(&format!("perf: L3 hot paths (detected ISA: {})", best.name()));
    // 1) FWHT throughput (the compress hot loop); 16384 is the
    //    firmly-out-of-L1 size the blocked schedule targets. The scalar
    //    arm pins the dispatcher to the reference schedule; every tier is
    //    bitwise identical, so the arms differ only in speed.
    for p in [512usize, 1024, 4096, 16384] {
        for (arm, isa) in [("scalar", Some(Isa::Scalar)), ("simd", None)] {
            pds::simd::force(isa);
            let mut rng = Pcg64::seed(1);
            let mut cols: Vec<Vec<f64>> =
                (0..64).map(|_| (0..p).map(|_| rng.normal()).collect()).collect();
            let r = pds::bench::bench(&format!("fwht p={p} x64cols [{arm}]"), bw, bi, || {
                for c in cols.iter_mut() {
                    fwht_inplace(c);
                }
                cols[0][0]
            });
            let bytes = (64 * p * 8) as f64;
            let flops = (64 * p) as f64 * (p as f64).log2();
            let gbs = bytes * 2.0 / r.median_s / 1e9;
            println!("   -> {:.2} GB/s streamed, {:.2} GFLOP/s", gbs, flops / r.median_s / 1e9);
            entries.push(Entry { result: r, metric: "GB/s", value: gbs });
        }
    }
    pds::simd::force(None);

    // 2) mask sampling: O(p)-reset reference vs the O(m) IndexSampler at
    //    the gamma=0.05, p=4096 point where the reset dominates
    {
        let (p, m) = (4096usize, 205usize);
        let mut out = vec![0u32; m];
        let mut perm = vec![0u32; p];
        let mut rng = Pcg64::seed(11);
        let r = pds::bench::bench("mask sample reference (p=4096,m=205) x1k", bw, bi, || {
            for _ in 0..1000 {
                sample_indices(&mut rng, p, &mut out, &mut perm);
            }
            out[0]
        });
        let masks = 1000.0 / r.median_s / 1e6;
        println!("   -> {masks:.2} M masks/s (O(p) reset)");
        entries.push(Entry { result: r, metric: "M masks/s", value: masks });

        let mut sampler = IndexSampler::new(p);
        let mut rng = Pcg64::seed(11);
        let r = pds::bench::bench("mask sample O(m) sampler (p=4096,m=205) x1k", bw, bi, || {
            for _ in 0..1000 {
                sampler.sample(&mut rng, &mut out);
            }
            out[0]
        });
        let masks = 1000.0 / r.median_s / 1e6;
        println!("   -> {masks:.2} M masks/s (O(m) epoch overlay)");
        entries.push(Entry { result: r, metric: "M masks/s", value: masks });
    }

    // 3) masked assignment (the kmeans hot loop): the gated
    //    scalar-vs-SIMD / f64-vs-f32 arms at w=1, then thread scaling.
    //    The f32-store arm runs the f64 kernels over a quantized chunk
    //    (what a `--precision f32` store round trip yields); the packed
    //    arm drives the 4-lane f32 kernel directly on an f32 value array
    //    to isolate the bandwidth effect of halving the value bytes.
    let d = digits(20_000, DigitConfig::default());
    let cfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 2 };
    let sp = Sparsifier::new(784, cfg).unwrap();
    let chunk = sp.compress_chunk(&d.data, 0).unwrap();
    let chunk32 = chunk.clone().with_precision(Precision::F32);
    let mut rng = Pcg64::seed(3);
    let centers = sp.precondition_dense(&kmeans_pp_dense(&d.data, 3, &mut rng));
    let m = chunk.m();
    let gathers = (20_000 * m * 3) as f64;
    {
        let arms: [(&str, &SparseChunk, NativeAssigner); 3] = [
            ("[scalar f64]", &chunk, NativeAssigner::new().with_isa(Isa::Scalar)),
            ("[simd f64]", &chunk, NativeAssigner::new().with_isa(best)),
            ("[scalar f32-store]", &chunk32, NativeAssigner::new().with_isa(Isa::Scalar)),
        ];
        for (arm, c, assigner) in &arms {
            let mut ids = vec![0u32; c.n()];
            let mut dist = vec![0.0f64; c.n()];
            let r = pds::bench::bench(
                &format!("assign (n=20k,m={m},K=3) {arm}"),
                bw,
                bi,
                || {
                    assigner.assign_into(c, &centers, 1, &mut ids, &mut dist).unwrap();
                    dist.iter().sum::<f64>()
                },
            );
            let rate = gathers / r.median_s / 1e6;
            println!("   -> {rate:.1} M masked-gathers/s");
            entries.push(Entry { result: r, metric: "M masked-gathers/s", value: rate });
        }

        // packed f32: the x4 kernel on an actual f32 value array. K=3
        // fits one 4-wide group; only the 3 live lanes are scanned.
        let p = sp.p();
        let k = centers.cols();
        let mut panel = vec![0.0f64; p * 4];
        for c in 0..k {
            for (j, &v) in centers.col(c).iter().enumerate() {
                panel[j * 4 + c] = v;
            }
        }
        let n = chunk32.n();
        let mut vals32 = Vec::with_capacity(n * m);
        let mut off = Vec::with_capacity(n + 1);
        off.push(0usize);
        for i in 0..n {
            vals32.extend(chunk32.col_values(i).iter().map(|&v| v as f32));
            off.push(vals32.len());
        }
        let mut ids = vec![0u32; n];
        let mut dist = vec![0.0f64; n];
        let r = pds::bench::bench(
            &format!("assign packed (n=20k,m={m},K=3) [simd f32]"),
            bw,
            bi,
            || {
                for i in 0..n {
                    let mut d4 = [0.0f64; 4];
                    pds::simd::masked_dist2_x4_f32(
                        best,
                        chunk32.col_indices(i),
                        &vals32[off[i]..off[i + 1]],
                        &panel,
                        &mut d4,
                    );
                    let (mut bc, mut bd) = (0u32, d4[0]);
                    for (c, &dc) in d4.iter().enumerate().take(k).skip(1) {
                        if dc < bd {
                            bc = c as u32;
                            bd = dc;
                        }
                    }
                    ids[i] = bc;
                    dist[i] = bd;
                }
                dist.iter().sum::<f64>()
            },
        );
        let rate = gathers / r.median_s / 1e6;
        println!("   -> {rate:.1} M masked-gathers/s");
        entries.push(Entry { result: r, metric: "M masked-gathers/s", value: rate });
    }
    for workers in [1usize, 2, 4] {
        let mut ids = vec![0u32; chunk.n()];
        let mut dist = vec![0.0f64; chunk.n()];
        let r = pds::bench::bench(
            &format!("assign native (n=20k,m={m},K=3) w={workers}"),
            bw,
            bi,
            || {
                NativeAssigner::new()
                    .assign_into(&chunk, &centers, workers, &mut ids, &mut dist)
                    .unwrap();
                dist.iter().sum::<f64>()
            },
        );
        let rate = gathers / r.median_s / 1e6;
        println!("   -> {rate:.1} M masked-gathers/s");
        entries.push(Entry { result: r, metric: "M masked-gathers/s", value: rate });
    }

    // 4) covariance scatter accumulation: first the shared
    //    col_dot/col_scatter kernel pair in isolation (the b-wide
    //    dot+scatter phases SparseCovOp/SourceCovOp run per block
    //    multiply), then the full estimator at 1/2/4 workers
    let mut rng = Pcg64::seed(5);
    let x = Mat::from_fn(256, 2560, |_, _| rng.normal());
    let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 7 };
    let sp = Sparsifier::new(256, cfg).unwrap();
    let chunk = sp.compress_chunk(&x, 0).unwrap();
    let m = sp.m();
    {
        const B: usize = 14; // block width k+4 at the k=10 default
        let p = sp.p();
        let n = chunk.n();
        let mut rng = Pcg64::seed(17);
        let bt: Vec<f64> = (0..p * B).map(|_| rng.normal()).collect();
        let mut out = vec![0.0f64; p * B];
        let mut dcol = vec![0.0f64; B];
        let madds = (2 * n * m * B) as f64;
        for (arm, isa) in [("scalar", Isa::Scalar), ("simd", best)] {
            let r = pds::bench::bench(
                &format!("cov scatter kernels (p=256,n={n},m={m},b={B}) [{arm}]"),
                bw,
                bi,
                || {
                    out.iter_mut().for_each(|v| *v = 0.0);
                    for i in 0..n {
                        dcol.iter_mut().for_each(|v| *v = 0.0);
                        let idx = chunk.col_indices(i);
                        let val = chunk.col_values(i);
                        pds::simd::col_dot(isa, &mut dcol, idx, val, &bt);
                        pds::simd::col_scatter(isa, &mut out, idx, val, 0, &dcol);
                    }
                    out[0]
                },
            );
            let rate = madds / r.median_s / 1e6;
            println!("   -> {rate:.1} M madds/s (dot+scatter)");
            entries.push(Entry { result: r, metric: "M madds/s", value: rate });
        }
    }
    let scatters = 2560.0 * (m * m) as f64 / 2.0; // lower triangle only
    for workers in [1usize, 2, 4] {
        let r = pds::bench::bench(
            &format!("cov accumulate (p=256,n=2560,m={m}) w={workers}"),
            if quick { 0 } else { 1 },
            if quick { 5 } else { 10 },
            || {
                let mut est = CovarianceEstimator::new(sp.p(), sp.m()).with_workers(workers);
                est.accumulate(&chunk);
                est.n()
            },
        );
        let rate = scatters / r.median_s / 1e6;
        println!("   -> {rate:.1} M scatter-madds/s");
        entries.push(Entry { result: r, metric: "M scatter-madds/s", value: rate });
    }

    // 5) PCA solver comparison at p = 2^12..2^14: the p×p-materializing
    //    covariance path (scatter + estimate + subspace iteration) vs the
    //    covariance-free block-Krylov path on the same chunk. Matched
    //    iteration budgets so the comparison isolates the data structure.
    //    The covariance arm allocates O(p²) — ~6 GB transient at p=16384
    //    (accumulator + two estimate copies) — so that one size is gated
    //    behind PDS_BENCH_FULL=1; the krylov arm runs everywhere in
    //    O(p·(k+4)) on top of the ~5 MB chunk.
    let full = std::env::var("PDS_BENCH_FULL").is_ok();
    if quick {
        println!("\n(PDS_BENCH_QUICK=1: skipping the solver-comparison sections)");
    } else {
        pds::bench::section("pca solver: covariance (p x p) vs krylov (covariance-free)");
        const SOLVER_K: usize = 10;
        const SOLVER_ITERS: usize = 4;
        for p in [4096usize, 8192, 16384] {
            let n = 512usize;
            let m = p / 20; // gamma = 0.05
            let chunk = sparse_chunk(p, m, n, 0, 0xC0FFEE ^ p as u64);
            if p < 16384 || full {
                let r = pds::bench::bench(
                    &format!("pca solve covariance p={p} (n={n},m={m},k={SOLVER_K})"),
                    0,
                    3,
                    || {
                        let mut est = CovarianceEstimator::new(p, m);
                        est.accumulate(&chunk);
                        let c = est.estimate();
                        let (vals, _) = pds::linalg::sym_eig_topk(&c, SOLVER_K, SOLVER_ITERS, 1);
                        vals[0]
                    },
                );
                let ms = r.median_s * 1e3;
                println!("   -> {ms:.1} ms/solve, holds a {p}x{p} f64 matrix");
                entries.push(Entry { result: r, metric: "ms/solve", value: ms });
            } else {
                println!(
                    "bench pca solve covariance p={p}: skipped (O(p^2) = {:.1} GB transient; \
                     set PDS_BENCH_FULL=1 to run)",
                    3.0 * (p * p * 8) as f64 / 1e9
                );
            }
            for workers in [1usize, 4] {
                let chunks = [chunk.clone()];
                let r = pds::bench::bench(
                    &format!("pca solve krylov p={p} (n={n},m={m},k={SOLVER_K}) w={workers}"),
                    0,
                    3,
                    || {
                        let mut op = SparseCovOp::new(&chunks, workers).unwrap();
                        let pca =
                            Pca::from_sparse_operator(&mut op, SOLVER_K, SOLVER_ITERS, 1).unwrap();
                        pca.eigenvalues[0]
                    },
                );
                let ms = r.median_s * 1e3;
                println!("   -> {ms:.1} ms/solve, no p x p allocation");
                entries.push(Entry { result: r, metric: "ms/solve", value: ms });
            }
        }

        // 6) K-means solver comparison: in-memory chunk fit vs the
        //    source-driven streaming fit (CenterStep folding budget-sized
        //    chunks — the exact shape a memory-budgeted store reader hands
        //    out, minus disk noise). Both run the same seeding + Lloyd
        //    schedule and produce bitwise identical fits; the delta is pure
        //    per-chunk fold overhead. Reported as ms per Lloyd iteration.
        pds::bench::section("kmeans solver: in-memory fit vs streaming CenterStep fit");
        {
            use pds::kmeans::{KmeansOpts, SparsifiedKmeans};
            use pds::sparse::SparseVecSource;
            const KM_K: usize = 8;
            const KM_ITERS: usize = 3;
            for p in [4096usize, 8192] {
                let n = 4096usize;
                let mut rng = Pcg64::seed(0xBEEF ^ p as u64);
                let x = Mat::from_fn(p, n, |_, _| rng.normal());
                let cfg =
                    SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 3 };
                let sp = Sparsifier::new(p, cfg).unwrap();
                let whole = sp.compress_chunk(&x, 0).unwrap();
                // 512-column pieces ≈ a few-MB reader budget at this (p, m)
                let mut pieces = Vec::new();
                let mut a = 0usize;
                while a < n {
                    let b = (a + 512).min(n);
                    pieces.push(sp.compress_chunk(&x.col_range(a, b), a).unwrap());
                    a = b;
                }
                let opts = KmeansOpts { n_init: 1, max_iters: KM_ITERS, tol_frac: 0.0, seed: 1 };
                for workers in [1usize, 2, 4] {
                    let chunks = [whole.clone()];
                    let r = pds::bench::bench(
                        &format!("kmeans inmemory p={p} (n={n},K={KM_K}) w={workers}"),
                        0,
                        3,
                        || {
                            let sk =
                                SparsifiedKmeans::new(cfg, KM_K, opts).with_workers(workers);
                            let m = sk.fit_chunks(&sp, &chunks, &NativeAssigner::new()).unwrap();
                            m.result.objective
                        },
                    );
                    let ms = r.median_s * 1e3 / KM_ITERS as f64;
                    println!("   -> {ms:.1} ms/iteration (in-memory)");
                    entries.push(Entry { result: r, metric: "ms/iter", value: ms });

                    let r = pds::bench::bench(
                        &format!("kmeans stream p={p} (n={n},K={KM_K},chunk=512) w={workers}"),
                        0,
                        3,
                        || {
                            let mut src = SparseVecSource::new(pieces.clone()).unwrap();
                            let sk =
                                SparsifiedKmeans::new(cfg, KM_K, opts).with_workers(workers);
                            let (m, _passes) =
                                sk.fit_source(&sp, &mut src, &NativeAssigner::new(), true).unwrap();
                            m.result.objective
                        },
                    );
                    let ms = r.median_s * 1e3 / KM_ITERS as f64;
                    println!("   -> {ms:.1} ms/iteration (streaming)");
                    entries.push(Entry { result: r, metric: "ms/iter", value: ms });
                }
            }
        }
    }

    // 7) serve query latency: the daemon's read path (snapshot load +
    //    project/assign), minus transport. Two views per task: p50/p99
    //    of per-call queries (tail latency is the serving SLO), and
    //    amortized µs/query single vs batch=64 — the micro-batching
    //    lane's payoff, measured through the same panel kernel that
    //    answers requests (a single query is a panel of one, so the
    //    comparison isolates pure amortization, not a different code
    //    path). Runs in quick mode too (it is cheap).
    pds::bench::section("serve query latency (snapshot read path, no transport)");
    {
        use pds::serve::snapshot::{KmeansSnapshot, ModelKind, ModelSnapshot, PcaSnapshot};
        let p = 512usize;
        const BATCH: usize = 64;
        let iters = if quick { 4_000 } else { 40_000 };
        let mut rng = Pcg64::seed(21);
        let samples: Vec<Vec<f64>> =
            (0..BATCH).map(|_| (0..p).map(|_| rng.normal()).collect()).collect();
        let panel: Vec<&[f64]> = samples.iter().map(Vec::as_slice).collect();
        let pca = ModelSnapshot::new(
            1,
            10_000,
            Precision::F64,
            ModelKind::Pca(PcaSnapshot {
                components: Mat::from_fn(p, 8, |_, _| rng.normal()),
                mean: (0..p).map(|_| rng.normal()).collect(),
                eigenvalues: vec![1.0; 8],
            }),
        );
        let kmeans = ModelSnapshot::new(
            1,
            10_000,
            Precision::F64,
            ModelKind::Kmeans(KmeansSnapshot {
                centers: Mat::from_fn(p, 16, |_, _| rng.normal()),
                center_bound: f64::NAN,
                iterations: 10,
                converged: true,
            }),
        );
        for (label, snap) in [("pca p=512 topk=8", &pca), ("kmeans p=512 K=16", &kmeans)] {
            let mut times = Vec::with_capacity(iters);
            for i in 0..iters {
                let s = &samples[i % samples.len()];
                let t0 = std::time::Instant::now();
                std::hint::black_box(snap.query(s).unwrap());
                times.push(t0.elapsed().as_secs_f64());
            }
            times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let (p50, p99) = (times[times.len() / 2], times[times.len() * 99 / 100]);
            for (q, secs) in [("p50", p50), ("p99", p99)] {
                let r = BenchResult {
                    name: format!("serve query {label} [{q}]"),
                    iters,
                    median_s: secs,
                    mad_s: 0.0,
                    min_s: times[0],
                };
                println!("{}", r.report());
                entries.push(Entry { result: r, metric: "us/query", value: secs * 1e6 });
            }

            // batched vs single-sample throughput, amortized per query
            let r = pds::bench::bench(&format!("serve query {label} [single]"), 1, 5, || {
                for s in &samples {
                    std::hint::black_box(snap.query(s).unwrap());
                }
            });
            // one bench iteration answers BATCH single queries
            let us = r.median_s * 1e6 / BATCH as f64;
            println!("   -> {us:.3} us/query (single-sample)");
            entries.push(Entry { result: r, metric: "us/query", value: us });

            let r =
                pds::bench::bench(&format!("serve query {label} [batch={BATCH}]"), 1, 5, || {
                    std::hint::black_box(snap.query_panel(&panel).unwrap()).len()
                });
            let us = r.median_s * 1e6 / BATCH as f64;
            println!("   -> {us:.3} us/query (batched)");
            entries.push(Entry { result: r, metric: "us/query", value: us });
        }
    }

    // 8) precision parity check (not a timing): explained variance of the
    //    top-10 subspace on the Fig-1 digits shape, f32-quantized chunk
    //    vs f64. f64 accumulation on top of f32 storage keeps this at
    //    quantization level — orders of magnitude under the 1e-3 bound
    //    the format documents.
    pds::bench::section("precision check: f32 vs f64 explained variance (fig1 digits)");
    {
        let nd = if quick { 2000 } else { 5000 };
        let d = digits(nd, DigitConfig::default());
        let cfg = SparsifyConfig { gamma: 0.15, transform: TransformKind::Hadamard, seed: 4 };
        let sp = Sparsifier::new(784, cfg).unwrap();
        let c64 = sp.compress_chunk(&d.data, 0).unwrap();
        let c32 = c64.clone().with_precision(Precision::F32);
        let ev = |c: &SparseChunk| {
            let mut est = CovarianceEstimator::new(sp.p(), sp.m());
            est.accumulate(c);
            let cov = est.estimate();
            let (vals, _) = pds::linalg::sym_eig_topk(&cov, 10, 6, 1);
            vals.iter().sum::<f64>()
        };
        let (e64, e32) = (ev(&c64), ev(&c32));
        let rel = ((e64 - e32) / e64).abs();
        println!("top-10 explained variance: f64 {e64:.6e}, f32 {e32:.6e}, rel diff {rel:.3e}");
        checks.push(Check {
            name: "fig1 digits explained-variance parity (f32 vs f64)",
            value: rel,
            tolerance: 1e-3,
        });
    }

    if let Err(e) = write_json(&entries, &checks) {
        eprintln!("warning: could not write BENCH_hotpaths.json: {e}");
    }
}

/// Emit the machine-readable perf log at the repository root (one dir
/// above the crate).
fn write_json(entries: &[Entry], checks: &[Check]) -> std::io::Result<()> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_hotpaths.json");
    let mut body = String::new();
    body.push_str("{\n  \"bench\": \"perf_hotpaths\",\n");
    body.push_str("  \"source\": \"cargo bench --bench perf_hotpaths\",\n");
    body.push_str("  \"results\": [\n");
    for (i, e) in entries.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"median_s\": {:e}, \"mad_s\": {:e}, \
             \"min_s\": {:e}, \"metric\": \"{}\", \"value\": {:.3}}}{}\n",
            e.result.name,
            e.result.iters,
            e.result.median_s,
            e.result.mad_s,
            e.result.min_s,
            e.metric,
            e.value,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    body.push_str("  ],\n  \"checks\": [\n");
    for (i, c) in checks.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"name\": \"{}\", \"value\": {:e}, \"tolerance\": {:e}}}{}\n",
            c.name,
            c.value,
            c.tolerance,
            if i + 1 < checks.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]\n}\n");
    let mut f = std::fs::File::create(&path)?;
    f.write_all(body.as_bytes())?;
    println!("\nwrote {}", path.display());
    Ok(())
}
