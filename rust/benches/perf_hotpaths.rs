//! Perf-pass harness: the three L3 hot paths measured in isolation, with
//! arithmetic-intensity context so the §Perf roofline discussion in
//! EXPERIMENTS.md is reproducible.
use pds::data::{digits, DigitConfig};
use pds::kmeans::{kmeans_pp_dense, NativeAssigner, SparseAssigner};
use pds::estimators::CovarianceEstimator;
use pds::linalg::Mat;
use pds::rng::Pcg64;
use pds::sampling::{Sparsifier, SparsifyConfig};
use pds::transform::fwht_inplace;
use pds::transform::TransformKind;

fn main() {
    pds::bench::section("perf: L3 hot paths");
    // 1) FWHT throughput (the compress hot loop)
    for p in [512usize, 1024, 4096] {
        let mut rng = Pcg64::seed(1);
        let mut cols: Vec<Vec<f64>> = (0..64).map(|_| (0..p).map(|_| rng.normal()).collect()).collect();
        let r = pds::bench::bench(&format!("fwht p={p} x64cols"), 2, 20, || {
            for c in cols.iter_mut() { fwht_inplace(c); }
            cols[0][0]
        });
        let bytes = (64 * p * 8) as f64;
        let flops = (64 * p * (p as f64).log2() as usize) as f64;
        println!("   -> {:.2} GB/s streamed, {:.2} GFLOP/s", bytes * 2.0 / r.median_s / 1e9, flops / r.median_s / 1e9);
    }
    // 2) masked assignment (the kmeans hot loop)
    let d = digits(20_000, DigitConfig::default());
    let cfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 2 };
    let sp = Sparsifier::new(784, cfg).unwrap();
    let chunk = sp.compress_chunk(&d.data, 0).unwrap();
    let mut rng = Pcg64::seed(3);
    let centers = sp.precondition_dense(&kmeans_pp_dense(&d.data, 3, &mut rng));
    let r = pds::bench::bench("assign native (n=20k,m=51,K=3)", 2, 20, || {
        NativeAssigner.assign(&chunk, &centers).unwrap().1
    });
    let gathers = (20_000 * 51 * 3) as f64;
    println!("   -> {:.1} M masked-gathers/s", gathers / r.median_s / 1e6);
    // 3) covariance scatter accumulation
    let mut rng = Pcg64::seed(5);
    let x = Mat::from_fn(256, 2560, |_, _| rng.normal());
    let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 7 };
    let sp = Sparsifier::new(256, cfg).unwrap();
    let chunk = sp.compress_chunk(&x, 0).unwrap();
    let r = pds::bench::bench("cov accumulate (p=256,n=2560,m=77)", 1, 10, || {
        let mut est = CovarianceEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        est.n()
    });
    let scatters = (2560.0) * (77.0 * 77.0);
    println!("   -> {:.1} M scatter-madds/s", scatters / r.median_s / 1e6);
}
