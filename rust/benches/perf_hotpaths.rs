//! Perf-pass harness: the L3 hot paths measured in isolation, with
//! arithmetic-intensity context so the §Perf log in `rust/EXPERIMENTS.md`
//! is reproducible.
//!
//! Measures (1) the blocked FWHT, (2) mask sampling (O(p)-reset reference
//! vs the O(m) `IndexSampler`), (3) masked assignment, (4) the
//! covariance scatter — the latter two at 1/2/4 workers to show thread
//! scaling — (5) the PCA solver comparison: materialized-covariance
//! (`sym_eig_topk` on the p×p estimate) vs covariance-free block-Krylov
//! (`SparseCovOp`) at p = 2^12..2^14 — and (6) the K-means solver
//! comparison: the in-memory chunk fit vs the source-driven streaming
//! fit (`CenterStep` over store-budget-sized chunks) at p = 4096/8192,
//! workers 1/2/4, in ms per Lloyd iteration. Results are also emitted as
//! `BENCH_hotpaths.json` at the repository root (schema documented in
//! EXPERIMENTS.md).

use std::io::Write as _;

use pds::bench::BenchResult;
use pds::data::{digits, DigitConfig};
use pds::estimators::{CovarianceEstimator, SparseCovOp};
use pds::kmeans::{kmeans_pp_dense, NativeAssigner, SparseAssigner};
use pds::linalg::Mat;
use pds::pca::Pca;
use pds::rng::Pcg64;
use pds::sampling::{sample_indices, IndexSampler, Sparsifier, SparsifyConfig};
use pds::testing::fixtures::sparse_chunk;
use pds::transform::fwht_inplace;
use pds::transform::TransformKind;

/// One emitted benchmark row: the raw timing plus one derived
/// throughput metric.
struct Entry {
    result: BenchResult,
    metric: &'static str,
    value: f64,
}

fn main() {
    let mut entries: Vec<Entry> = Vec::new();

    pds::bench::section("perf: L3 hot paths");
    // 1) FWHT throughput (the compress hot loop); 16384 is the
    //    firmly-out-of-L1 size the blocked schedule targets
    for p in [512usize, 1024, 4096, 16384] {
        let mut rng = Pcg64::seed(1);
        let mut cols: Vec<Vec<f64>> =
            (0..64).map(|_| (0..p).map(|_| rng.normal()).collect()).collect();
        let r = pds::bench::bench(&format!("fwht p={p} x64cols"), 2, 20, || {
            for c in cols.iter_mut() {
                fwht_inplace(c);
            }
            cols[0][0]
        });
        let bytes = (64 * p * 8) as f64;
        let flops = (64 * p) as f64 * (p as f64).log2();
        let gbs = bytes * 2.0 / r.median_s / 1e9;
        println!("   -> {:.2} GB/s streamed, {:.2} GFLOP/s", gbs, flops / r.median_s / 1e9);
        entries.push(Entry { result: r, metric: "GB/s", value: gbs });
    }

    // 2) mask sampling: O(p)-reset reference vs the O(m) IndexSampler at
    //    the gamma=0.05, p=4096 point where the reset dominates
    {
        let (p, m) = (4096usize, 205usize);
        let mut out = vec![0u32; m];
        let mut perm = vec![0u32; p];
        let mut rng = Pcg64::seed(11);
        let r = pds::bench::bench("mask sample reference (p=4096,m=205) x1k", 2, 20, || {
            for _ in 0..1000 {
                sample_indices(&mut rng, p, &mut out, &mut perm);
            }
            out[0]
        });
        let masks = 1000.0 / r.median_s / 1e6;
        println!("   -> {masks:.2} M masks/s (O(p) reset)");
        entries.push(Entry { result: r, metric: "M masks/s", value: masks });

        let mut sampler = IndexSampler::new(p);
        let mut rng = Pcg64::seed(11);
        let r = pds::bench::bench("mask sample O(m) sampler (p=4096,m=205) x1k", 2, 20, || {
            for _ in 0..1000 {
                sampler.sample(&mut rng, &mut out);
            }
            out[0]
        });
        let masks = 1000.0 / r.median_s / 1e6;
        println!("   -> {masks:.2} M masks/s (O(m) epoch overlay)");
        entries.push(Entry { result: r, metric: "M masks/s", value: masks });
    }

    // 3) masked assignment (the kmeans hot loop), thread scaling
    let d = digits(20_000, DigitConfig::default());
    let cfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 2 };
    let sp = Sparsifier::new(784, cfg).unwrap();
    let chunk = sp.compress_chunk(&d.data, 0).unwrap();
    let mut rng = Pcg64::seed(3);
    let centers = sp.precondition_dense(&kmeans_pp_dense(&d.data, 3, &mut rng));
    let gathers = (20_000 * chunk.m() * 3) as f64;
    for workers in [1usize, 2, 4] {
        let mut ids = vec![0u32; chunk.n()];
        let mut dist = vec![0.0f64; chunk.n()];
        let r = pds::bench::bench(
            &format!("assign native (n=20k,m={},K=3) w={workers}", chunk.m()),
            2,
            20,
            || {
                NativeAssigner
                    .assign_into(&chunk, &centers, workers, &mut ids, &mut dist)
                    .unwrap();
                dist.iter().sum::<f64>()
            },
        );
        let rate = gathers / r.median_s / 1e6;
        println!("   -> {rate:.1} M masked-gathers/s");
        entries.push(Entry { result: r, metric: "M masked-gathers/s", value: rate });
    }

    // 4) covariance scatter accumulation, thread scaling
    let mut rng = Pcg64::seed(5);
    let x = Mat::from_fn(256, 2560, |_, _| rng.normal());
    let cfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 7 };
    let sp = Sparsifier::new(256, cfg).unwrap();
    let chunk = sp.compress_chunk(&x, 0).unwrap();
    let m = sp.m();
    let scatters = 2560.0 * (m * m) as f64 / 2.0; // lower triangle only
    for workers in [1usize, 2, 4] {
        let r = pds::bench::bench(
            &format!("cov accumulate (p=256,n=2560,m={m}) w={workers}"),
            1,
            10,
            || {
                let mut est = CovarianceEstimator::new(sp.p(), sp.m()).with_workers(workers);
                est.accumulate(&chunk);
                est.n()
            },
        );
        let rate = scatters / r.median_s / 1e6;
        println!("   -> {rate:.1} M scatter-madds/s");
        entries.push(Entry { result: r, metric: "M scatter-madds/s", value: rate });
    }

    // 5) PCA solver comparison at p = 2^12..2^14: the p×p-materializing
    //    covariance path (scatter + estimate + subspace iteration) vs the
    //    covariance-free block-Krylov path on the same chunk. Matched
    //    iteration budgets so the comparison isolates the data structure.
    //    The covariance arm allocates O(p²) — ~6 GB transient at p=16384
    //    (accumulator + two estimate copies) — so that one size is gated
    //    behind PDS_BENCH_FULL=1; the krylov arm runs everywhere in
    //    O(p·(k+4)) on top of the ~5 MB chunk.
    pds::bench::section("pca solver: covariance (p x p) vs krylov (covariance-free)");
    const SOLVER_K: usize = 10;
    const SOLVER_ITERS: usize = 4;
    let full = std::env::var("PDS_BENCH_FULL").is_ok();
    for p in [4096usize, 8192, 16384] {
        let n = 512usize;
        let m = p / 20; // gamma = 0.05
        let chunk = sparse_chunk(p, m, n, 0, 0xC0FFEE ^ p as u64);
        if p < 16384 || full {
            let r = pds::bench::bench(
                &format!("pca solve covariance p={p} (n={n},m={m},k={SOLVER_K})"),
                0,
                3,
                || {
                    let mut est = CovarianceEstimator::new(p, m);
                    est.accumulate(&chunk);
                    let c = est.estimate();
                    let (vals, _) = pds::linalg::sym_eig_topk(&c, SOLVER_K, SOLVER_ITERS, 1);
                    vals[0]
                },
            );
            let ms = r.median_s * 1e3;
            println!("   -> {ms:.1} ms/solve, holds a {p}x{p} f64 matrix");
            entries.push(Entry { result: r, metric: "ms/solve", value: ms });
        } else {
            println!(
                "bench pca solve covariance p={p}: skipped (O(p^2) = {:.1} GB transient; \
                 set PDS_BENCH_FULL=1 to run)",
                3.0 * (p * p * 8) as f64 / 1e9
            );
        }
        for workers in [1usize, 4] {
            let chunks = [chunk.clone()];
            let r = pds::bench::bench(
                &format!("pca solve krylov p={p} (n={n},m={m},k={SOLVER_K}) w={workers}"),
                0,
                3,
                || {
                    let mut op = SparseCovOp::new(&chunks, workers).unwrap();
                    let pca =
                        Pca::from_sparse_operator(&mut op, SOLVER_K, SOLVER_ITERS, 1).unwrap();
                    pca.eigenvalues[0]
                },
            );
            let ms = r.median_s * 1e3;
            println!("   -> {ms:.1} ms/solve, no p x p allocation");
            entries.push(Entry { result: r, metric: "ms/solve", value: ms });
        }
    }

    // 6) K-means solver comparison: in-memory chunk fit vs the
    //    source-driven streaming fit (CenterStep folding budget-sized
    //    chunks — the exact shape a memory-budgeted store reader hands
    //    out, minus disk noise). Both run the same seeding + Lloyd
    //    schedule and produce bitwise identical fits; the delta is pure
    //    per-chunk fold overhead. Reported as ms per Lloyd iteration.
    pds::bench::section("kmeans solver: in-memory fit vs streaming CenterStep fit");
    {
        use pds::kmeans::{KmeansOpts, SparsifiedKmeans};
        use pds::sparse::SparseVecSource;
        const KM_K: usize = 8;
        const KM_ITERS: usize = 3;
        for p in [4096usize, 8192] {
            let n = 4096usize;
            let mut rng = Pcg64::seed(0xBEEF ^ p as u64);
            let x = Mat::from_fn(p, n, |_, _| rng.normal());
            let cfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 3 };
            let sp = Sparsifier::new(p, cfg).unwrap();
            let whole = sp.compress_chunk(&x, 0).unwrap();
            // 512-column pieces ≈ a few-MB reader budget at this (p, m)
            let mut pieces = Vec::new();
            let mut a = 0usize;
            while a < n {
                let b = (a + 512).min(n);
                pieces.push(sp.compress_chunk(&x.col_range(a, b), a).unwrap());
                a = b;
            }
            let opts =
                KmeansOpts { n_init: 1, max_iters: KM_ITERS, tol_frac: 0.0, seed: 1 };
            for workers in [1usize, 2, 4] {
                let chunks = [whole.clone()];
                let r = pds::bench::bench(
                    &format!("kmeans inmemory p={p} (n={n},K={KM_K}) w={workers}"),
                    0,
                    3,
                    || {
                        let sk = SparsifiedKmeans::new(cfg, KM_K, opts).with_workers(workers);
                        let m = sk.fit_chunks(&sp, &chunks, &NativeAssigner).unwrap();
                        m.result.objective
                    },
                );
                let ms = r.median_s * 1e3 / KM_ITERS as f64;
                println!("   -> {ms:.1} ms/iteration (in-memory)");
                entries.push(Entry { result: r, metric: "ms/iter", value: ms });

                let r = pds::bench::bench(
                    &format!("kmeans stream p={p} (n={n},K={KM_K},chunk=512) w={workers}"),
                    0,
                    3,
                    || {
                        let mut src = SparseVecSource::new(pieces.clone()).unwrap();
                        let sk = SparsifiedKmeans::new(cfg, KM_K, opts).with_workers(workers);
                        let (m, _passes) =
                            sk.fit_source(&sp, &mut src, &NativeAssigner, true).unwrap();
                        m.result.objective
                    },
                );
                let ms = r.median_s * 1e3 / KM_ITERS as f64;
                println!("   -> {ms:.1} ms/iteration (streaming)");
                entries.push(Entry { result: r, metric: "ms/iter", value: ms });
            }
        }
    }

    if let Err(e) = write_json(&entries) {
        eprintln!("warning: could not write BENCH_hotpaths.json: {e}");
    }
}

/// Emit the machine-readable perf log at the repository root (one dir
/// above the crate).
fn write_json(entries: &[Entry]) -> std::io::Result<()> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| std::path::PathBuf::from("."));
    let path = root.join("BENCH_hotpaths.json");
    let mut body = String::new();
    body.push_str("{\n  \"bench\": \"perf_hotpaths\",\n");
    body.push_str("  \"source\": \"cargo bench --bench perf_hotpaths\",\n");
    body.push_str("  \"results\": [\n");
    for (i, e) in entries.iter().enumerate() {
        body.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"median_s\": {:e}, \"mad_s\": {:e}, \
             \"min_s\": {:e}, \"metric\": \"{}\", \"value\": {:.3}}}{}\n",
            e.result.name,
            e.result.iters,
            e.result.median_s,
            e.result.mad_s,
            e.result.min_s,
            e.metric,
            e.value,
            if i + 1 < entries.len() { "," } else { "" }
        ));
    }
    body.push_str("  ]\n}\n");
    let mut f = std::fs::File::create(&path)?;
    f.write_all(body.as_bytes())?;
    println!("\nwrote {}", path.display());
    Ok(())
}
