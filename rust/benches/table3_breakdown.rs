//! Bench: regenerate Table III (timing breakdown at gamma=0.05).
use pds::cli::Args;
fn main() {
    pds::bench::section("Table III: timing breakdown, streaming digits");
    let args = Args::parse(&["--n".into(), "20000".into()]).unwrap();
    pds::experiments::fig10_table3::run_table3(&args).unwrap();
}
