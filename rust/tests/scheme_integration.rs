//! End-to-end coverage of the pluggable sampling-scheme layer: scheme
//! selection through `FitPlan`, byte-identity of the default
//! (preconditioned-uniform) scheme, hybrid store round trips through the
//! v2 manifest, and scheme-matched estimator calibration on store-backed
//! fits.

use pds::coordinator::{FitPlan, MatSource, Solver, StreamConfig};
use pds::error::Error;
use pds::linalg::Mat;
use pds::rng::Pcg64;
use pds::sampling::{Scheme, Sparsifier, SparsifyConfig};
use pds::sparse::{SparseChunkSource, SparseVecSource};
use pds::store::SparseStoreReader;
use pds::transform::TransformKind;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let p = std::env::temp_dir().join(format!("pds_scheme_it_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

fn spiked(p: usize, n: usize, seed: u64) -> pds::data::Dataset {
    let mut rng = Pcg64::seed(seed);
    pds::data::spiked(p, n, &[8.0, 4.0], false, &mut rng)
}

/// `pds fit --scheme precond` contract: a store written with the
/// explicit precond scheme is byte-identical, file for file, to one
/// written through the pre-scheme default path for matched seeds.
#[test]
fn precond_scheme_store_is_byte_identical_to_default() {
    let d = spiked(32, 150, 3);
    let scfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 7 };

    let dir_default = tmpdir("default");
    let mut src = MatSource::new(&d.data, 64);
    FitPlan::compress()
        .stream(&mut src, scfg)
        .store_dir(&dir_default)
        .shard_cols(40)
        .run()
        .unwrap();

    let dir_explicit = tmpdir("explicit");
    let mut src2 = MatSource::new(&d.data, 64);
    FitPlan::compress()
        .stream(&mut src2, scfg)
        .scheme(Scheme::Precond)
        .store_dir(&dir_explicit)
        .shard_cols(40)
        .run()
        .unwrap();

    let mut names_a: Vec<_> = std::fs::read_dir(&dir_default)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names_a.sort();
    for name in &names_a {
        let a = std::fs::read(dir_default.join(name)).unwrap();
        let b = std::fs::read(dir_explicit.join(name)).unwrap();
        assert_eq!(a, b, "file {name} differs between default and explicit precond scheme");
    }
    // the recorded scheme is precond, and a store fit reproduces the
    // streaming fit bit for bit
    let mut reader = SparseStoreReader::open(&dir_default).unwrap();
    assert_eq!(reader.manifest().scheme, Scheme::Precond);
    assert!(reader.manifest().preconditioned);
    let from_store = FitPlan::pca().store(&mut reader).topk(2).run().unwrap();
    let mut src3 = MatSource::new(&d.data, 64);
    let from_stream = FitPlan::pca().stream(&mut src3, scfg).topk(2).run().unwrap();
    let (a, b) = (from_store.pca_fit().unwrap(), from_stream.pca_fit().unwrap());
    for (x, y) in a.mean.iter().zip(&b.mean) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in a.pca.components.as_slice().iter().zip(b.pca.components.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    std::fs::remove_dir_all(&dir_default).ok();
    std::fs::remove_dir_all(&dir_explicit).ok();
}

/// Hybrid store round trip: the manifest records the scheme (v2), the
/// reader rebuilds a weighted sparsifier, chunks (with duplicate slots)
/// survive verification, and the store-backed PCA is bit-identical to
/// the in-memory fit of the same chunks under the weighted calibration.
#[test]
fn hybrid_store_roundtrips_and_restores_the_scheme() {
    let d = spiked(24, 200, 9); // pads to 32 under Hadamard
    let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 21 };
    let dir = tmpdir("hybrid");
    let mut src = MatSource::new(&d.data, 64);
    let report = FitPlan::compress()
        .stream(&mut src, scfg)
        .scheme(Scheme::Hybrid)
        .store_dir(&dir)
        .shard_cols(33) // awkward stride: shards cut inside chunks
        .run()
        .unwrap();
    let manifest = report.store_manifest().unwrap();
    assert_eq!(manifest.version, 2);
    assert_eq!(manifest.scheme, Scheme::Hybrid);
    assert!(!manifest.preconditioned);
    assert_eq!(manifest.n, 200);

    // reader: scheme restored, chunks verified with the weighted check
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let sp = reader.sparsifier().unwrap();
    assert_eq!(sp.scheme(), Scheme::Hybrid);
    assert!(sp.weighted());
    let mut chunks = Vec::new();
    let mut cols = 0usize;
    while let Some(c) = SparseChunkSource::next_chunk(&mut reader).unwrap() {
        c.validate_weighted().unwrap();
        cols += c.n();
        chunks.push(c);
    }
    assert_eq!(cols, 200);

    // store bytes are exact: the read-back chunks equal a direct
    // compression, slot for slot
    let direct = sp.compress_chunk(&d.data, 0).unwrap();
    let mut col = 0usize;
    for c in &chunks {
        for i in 0..c.n() {
            assert_eq!(c.col_indices(i), direct.col_indices(col + i));
            for (a, b) in c.col_values(i).iter().zip(direct.col_values(col + i)) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        col += c.n();
    }

    // store-backed weighted PCA == in-memory weighted PCA, bit for bit,
    // on both solvers
    for solver in [Solver::Covariance, Solver::Krylov] {
        SparseChunkSource::reset(&mut reader).unwrap();
        let from_store =
            FitPlan::pca().store(&mut reader).topk(2).solver(solver).run().unwrap();
        let mut mem = SparseVecSource::new(chunks.clone()).unwrap();
        let in_memory = FitPlan::pca()
            .source(&mut mem, &sp, false)
            .topk(2)
            .solver(solver)
            .run()
            .unwrap();
        let (a, b) = (from_store.pca_fit().unwrap(), in_memory.pca_fit().unwrap());
        for (x, y) in a.mean.iter().zip(&b.mean) {
            assert_eq!(x.to_bits(), y.to_bits(), "mean, {solver:?}");
        }
        for (x, y) in a.pca.components.as_slice().iter().zip(b.pca.components.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits(), "components, {solver:?}");
        }
        assert_eq!(from_store.raw_passes, 0);
    }

    // an explicitly requested scheme that contradicts the store's
    // recorded one fails the plan instead of silently fitting the
    // wrong comparison arm
    SparseChunkSource::reset(&mut reader).unwrap();
    let err = FitPlan::pca().store(&mut reader).scheme(Scheme::Precond).topk(2).run();
    assert!(matches!(err, Err(Error::Invalid(_))));
    // asserting the matching scheme is fine
    SparseChunkSource::reset(&mut reader).unwrap();
    assert!(FitPlan::pca().store(&mut reader).scheme(Scheme::Hybrid).topk(2).run().is_ok());

    // K-means from the hybrid store runs on both solvers and agrees
    // with itself bit for bit (inmemory vs stream)
    let opts = pds::kmeans::KmeansOpts { n_init: 2, ..Default::default() };
    SparseChunkSource::reset(&mut reader).unwrap();
    let km_mem = FitPlan::kmeans().store(&mut reader).k(3).kmeans_opts(opts).run().unwrap();
    SparseChunkSource::reset(&mut reader).unwrap();
    let km_stream = FitPlan::kmeans()
        .store(&mut reader)
        .k(3)
        .kmeans_opts(opts)
        .solver(Solver::Stream)
        .run()
        .unwrap();
    let (ma, mb) = (km_mem.kmeans_model().unwrap(), km_stream.kmeans_model().unwrap());
    assert_eq!(ma.result.assign, mb.result.assign);
    assert_eq!(ma.result.objective.to_bits(), mb.result.objective.to_bits());
    for (x, y) in ma.result.centers.as_slice().iter().zip(mb.result.centers.as_slice()) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The hybrid covariance estimate through the full plan converges to the
/// raw-data covariance as n grows — the end-to-end face of the weighted
/// calibration (the exact Monte-Carlo unbiasedness property lives in
/// `estimators::covariance`).
#[test]
fn hybrid_plan_covariance_tracks_the_raw_covariance() {
    let p = 16usize;
    let n = 30_000usize;
    let mut rng = Pcg64::seed(41);
    let x = Mat::from_fn(p, n, |_, _| rng.normal());
    let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 4 };
    let mut src = MatSource::new(&x, 4096);
    let report = FitPlan::pca()
        .stream(&mut src, scfg)
        .scheme(Scheme::Hybrid)
        .topk(3)
        .stream_config(StreamConfig { workers: 2, ..Default::default() })
        .run()
        .unwrap();
    let fit = report.pca_fit().unwrap();
    let chat = fit.covariance.as_ref().expect("covariance solver materializes");
    let cemp = x.syrk().scaled(1.0 / n as f64);
    let err = chat.sub(&cemp).max_abs();
    // heavy averaging: the unbiased weighted estimate concentrates; a
    // mis-calibrated (uniform-constant) estimate would be off by ~4x on
    // the off-diagonals
    assert!(err < 0.15, "|Chat - Cemp|_max = {err}");
}

/// Sparse-source plans take the calibration from the sparsifier the
/// caller passes — a hybrid sparsifier with uniform chunks (or vice
/// versa) is the caller's bug, but shape mismatches surface as errors.
#[test]
fn sparse_source_plan_checks_shapes_and_runs_hybrid() {
    let d = spiked(32, 300, 17);
    let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 6 };
    let sp = Sparsifier::with_scheme(32, scfg, Scheme::Hybrid).unwrap();
    let chunk = sp.compress_chunk(&d.data, 0).unwrap();
    let mut src = SparseVecSource::new(vec![chunk]).unwrap();
    let report = FitPlan::pca().source(&mut src, &sp, false).topk(2).run().unwrap();
    assert!(report.pca_fit().unwrap().mean.iter().all(|v| v.is_finite()));

    // mismatched sparsifier shape is rejected
    let other = Sparsifier::with_scheme(
        64,
        SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 6 },
        Scheme::Hybrid,
    )
    .unwrap();
    let mut src2 = SparseVecSource::new(vec![sp.compress_chunk(&d.data, 0).unwrap()]).unwrap();
    let err = FitPlan::pca().source(&mut src2, &other, false).topk(2).run();
    assert!(matches!(err, Err(Error::Invalid(_))));
}
