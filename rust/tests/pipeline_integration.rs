//! Cross-module integration: streaming pipeline ↔ estimators ↔ K-means ↔
//! out-of-core store, plus end-to-end statistical sanity (no artifacts
//! required — pure native engine). Every fit routes through the
//! `FitPlan` session API.

use pds::coordinator::{
    compress_stream, ChunkSource, FitPlan, MatSource, Solver, StoreSource, StreamConfig,
};
use pds::data::{digits, ChunkStore, ChunkStoreReader, DigitConfig, DigitStream};
use pds::estimators::{HkAccumulator, SparseMeanEstimator};
use pds::kmeans::KmeansOpts;
use pds::metrics::clustering_accuracy;
use pds::pca::{explained_variance, recovered_components};
use pds::rng::Pcg64;
use pds::sampling::{Sparsifier, SparsifyConfig};
use pds::store::SparseStoreReader;
use pds::testing::prop::forall;
use pds::transform::TransformKind;

#[test]
fn digits_cluster_via_streaming_pipeline() {
    let d = digits(2000, DigitConfig { seed: 3, ..Default::default() });
    let scfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 9 };
    let mut src = MatSource::new(&d.data, 256);
    let report = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(3)
        .kmeans_opts(KmeansOpts { n_init: 8, ..Default::default() })
        .stream_config(StreamConfig { workers: 2, ..Default::default() })
        .run()
        .unwrap();
    let model = report.kmeans_model().expect("kmeans plan");
    let acc = clustering_accuracy(&model.result.assign, &d.labels, 3);
    assert!(acc > 0.85, "digit accuracy at gamma=0.05: {acc}");
    assert_eq!(report.n, 2000);
    // centers live in the original 784-dim space (padding dropped)
    assert_eq!(model.result.centers.rows(), 784);
    // one Eq. 43 bound per Lloyd iteration
    assert_eq!(report.center_bound.len(), report.iterations);
}

#[test]
fn out_of_core_roundtrip_matches_in_memory() {
    let d = digits(400, DigitConfig { seed: 5, ..Default::default() });
    let path = std::env::temp_dir().join(format!("pds_it_store_{}", std::process::id()));
    {
        let mut store = ChunkStore::create(&path, 784, 128).unwrap();
        let mut start = 0;
        while start < 400 {
            let end = (start + 128).min(400);
            store.append(&d.data.col_range(start, end)).unwrap();
            start = end;
        }
        store.finish().unwrap();
    }
    let scfg = SparsifyConfig { gamma: 0.08, transform: TransformKind::Hadamard, seed: 11 };
    let opts = KmeansOpts { n_init: 3, ..Default::default() };

    let mut mem_src = MatSource::new(&d.data, 128);
    let mem = FitPlan::kmeans()
        .stream(&mut mem_src, scfg)
        .k(3)
        .kmeans_opts(opts)
        .run()
        .unwrap();

    // f32 storage introduces tiny value differences; the *structure* of
    // the clustering must survive the disk roundtrip.
    let mut disk_src = StoreSource::new(ChunkStoreReader::open(&path).unwrap());
    let disk = FitPlan::kmeans()
        .stream(&mut disk_src, scfg)
        .k(3)
        .kmeans_opts(opts)
        .run()
        .unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(disk.n, 400);
    let mem_assign = &mem.kmeans_model().unwrap().result.assign;
    let disk_assign = &disk.kmeans_model().unwrap().result.assign;
    // identical up to label permutation; compare via accuracy metric
    let cross = clustering_accuracy(mem_assign, disk_assign, 3);
    assert!(cross > 0.99, "disk vs memory clustering agreement {cross}");
}

#[test]
fn two_pass_plan_beats_one_pass_on_noisy_digits() {
    let d = digits(1200, DigitConfig { seed: 7, noise: 0.25, ..Default::default() });
    let scfg = SparsifyConfig { gamma: 0.02, transform: TransformKind::Hadamard, seed: 13 };
    let opts = KmeansOpts { n_init: 3, ..Default::default() };
    let mut src = MatSource::new(&d.data, 256);
    let one = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(3)
        .kmeans_opts(opts)
        .run()
        .unwrap();
    src.reset().unwrap();
    let two = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(3)
        .kmeans_opts(opts)
        .two_pass(true)
        .run()
        .unwrap();
    assert_eq!(two.raw_passes, 2);
    assert!(two.timer.get("pass2") > 0.0);
    let a1 = clustering_accuracy(&one.kmeans_model().unwrap().result.assign, &d.labels, 3);
    let a2 = clustering_accuracy(&two.refined().expect("refinement ran").assign, &d.labels, 3);
    assert!(a2 >= a1 - 0.01, "two-pass {a2} vs one-pass {a1}");
}

#[test]
fn streaming_pca_mean_matches_direct_estimator() {
    let mut rng = Pcg64::seed(17);
    let d = pds::data::spiked(64, 3000, &[6.0, 3.0], false, &mut rng);
    let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 19 };
    let mut src = MatSource::new(&d.data, 500);
    let report = FitPlan::pca().stream(&mut src, scfg).topk(2).run().unwrap();
    assert_eq!(report.n, 3000);
    let fit = report.pca_fit().expect("pca plan");
    // direct (single-chunk) estimator must agree exactly: same masks
    let sp = Sparsifier::new(64, scfg).unwrap();
    let chunk = sp.compress_chunk(&d.data, 0).unwrap();
    let mut mean = SparseMeanEstimator::new(sp.p(), sp.m());
    mean.accumulate(&chunk);
    let direct_pre = pds::linalg::Mat::from_vec(sp.p(), 1, mean.estimate()).unwrap();
    let direct = sp.unmix(&direct_pre);
    for i in 0..64 {
        assert!((fit.mean[i] - direct.get(i, 0)).abs() < 1e-9);
    }
}

#[test]
fn both_pca_solvers_recover_the_same_digit_pcs() {
    // acceptance: on the digits dataset the covariance solver and the
    // covariance-free krylov solver find the same top PCs — matched
    // one-to-one with inner product >= 0.95 per component
    let d = digits(1500, DigitConfig { seed: 11, ..Default::default() });
    let scfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 17 };
    let mut src = MatSource::new(&d.data, 256);
    let cov = FitPlan::pca().stream(&mut src, scfg).topk(3).run().unwrap();
    let mut src2 = MatSource::new(&d.data, 256);
    let kry = FitPlan::pca()
        .stream(&mut src2, scfg)
        .topk(3)
        .solver(Solver::Krylov)
        .run()
        .unwrap();
    assert_eq!(kry.raw_passes, 1);
    let covf = cov.pca_fit().unwrap();
    let kryf = kry.pca_fit().unwrap();
    assert_eq!(kryf.pca.components.rows(), 784, "components live in the original domain");
    assert_eq!(
        recovered_components(&kryf.pca.components, &covf.pca.components, 0.95),
        3,
        "solvers disagree on the digit PCs"
    );
    // the shared mean-estimator path is bit-identical
    for (a, b) in kryf.mean.iter().zip(&covf.mean) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn krylov_pca_from_store_matches_streaming_and_is_invariant() {
    // compress-to-store -> covariance-free fit: explained variance must
    // match the streaming covariance solver, the fit must be bitwise
    // invariant to worker count and to the reader memory budget, and it
    // must report zero raw-data passes
    let mut rng = Pcg64::seed(41);
    let n = 1200usize;
    let d = pds::data::spiked(64, n, &[8.0, 5.0, 3.0], false, &mut rng);
    let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 6 };
    let stream = StreamConfig { workers: 2, chunk_cols: 128, ..Default::default() };

    let mut src = MatSource::new(&d.data, 128);
    let cov = FitPlan::pca().stream(&mut src, scfg).topk(3).stream_config(stream).run().unwrap();

    let dir = std::env::temp_dir().join(format!("pds_it_krylov_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut src2 = MatSource::new(&d.data, 128);
    FitPlan::compress()
        .stream(&mut src2, scfg)
        .store_dir(&dir)
        .shard_cols(97)
        .stream_config(stream)
        .run()
        .unwrap();

    let c_full = d.data.syrk().scaled(1.0 / n as f64);
    let mut store = SparseStoreReader::open(&dir).unwrap();
    let base = FitPlan::pca()
        .store(&mut store)
        .topk(3)
        .solver(Solver::Krylov)
        .run()
        .unwrap();
    assert_eq!(base.raw_passes, 0, "store-backed krylov fit reads no raw data");
    assert_eq!(base.n, n);
    let basef = base.pca_fit().unwrap();
    let ev_cov = explained_variance(&cov.pca_fit().unwrap().pca.components, &c_full);
    let ev_kry = explained_variance(&basef.pca.components, &c_full);
    assert!(
        (ev_cov - ev_kry).abs() < 1e-3,
        "explained variance: covariance {ev_cov} vs krylov {ev_kry}"
    );
    assert_eq!(
        recovered_components(&basef.pca.components, &cov.pca_fit().unwrap().pca.components, 0.95),
        3
    );

    // worker count and memory budget may change speed, never bits
    for (workers, budget_bytes) in [(2usize, 0usize), (4, 64 * 1024), (1, 4096)] {
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        if budget_bytes > 0 {
            reader = reader.with_memory_budget(budget_bytes);
        }
        let got = FitPlan::pca()
            .store(&mut reader)
            .topk(3)
            .solver(Solver::Krylov)
            .workers(workers)
            .run()
            .unwrap();
        assert_eq!(got.raw_passes, 0);
        let gotf = got.pca_fit().unwrap();
        for (a, b) in gotf
            .pca
            .components
            .as_slice()
            .iter()
            .zip(basef.pca.components.as_slice())
        {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "components, workers={workers} budget={budget_bytes}"
            );
        }
        for (a, b) in gotf.pca.eigenvalues.iter().zip(&basef.pca.eigenvalues) {
            assert_eq!(a.to_bits(), b.to_bits(), "eigenvalues");
        }
        for (a, b) in gotf.mean.iter().zip(&basef.mean) {
            assert_eq!(a.to_bits(), b.to_bits(), "mean");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn streaming_kmeans_from_store_is_bitwise_identical_out_of_core() {
    // the PR's acceptance path: `--task kmeans --solver stream` on a
    // store larger than the reader budget — 0 raw passes, one sparse
    // pass per Lloyd iteration, and bitwise identical centers /
    // assignments / objective to the in-memory path at workers {1,2,4}
    // and across reader memory budgets.
    let mut rng = Pcg64::seed(73);
    let d = pds::data::gaussian_blobs(64, 1500, 4, 0.15, &mut rng);
    let scfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 21 };
    let opts = KmeansOpts { n_init: 2, ..Default::default() };
    let stream = StreamConfig { workers: 2, chunk_cols: 128, ..Default::default() };

    // reference: the in-memory streaming path
    let mut src = MatSource::new(&d.data, 128);
    let direct = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(4)
        .kmeans_opts(opts)
        .stream_config(stream)
        .run()
        .unwrap();
    assert_eq!(direct.raw_passes, 1, "stream fit pays exactly one raw pass");
    assert_eq!(direct.sparse_passes, 1);
    let dm = direct.kmeans_model().unwrap();

    // compress once (shard size != chunk size on purpose), then fit
    // out-of-core with budgets far below the compressed size
    let dir = std::env::temp_dir().join(format!("pds_it_stream_km_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut src2 = MatSource::new(&d.data, 128);
    let creport = FitPlan::compress()
        .stream(&mut src2, scfg)
        .store_dir(&dir)
        .shard_cols(190)
        .stream_config(stream)
        .run()
        .unwrap();
    let payload = creport.store_manifest().unwrap().payload_bytes();

    for workers in [1usize, 2, 4] {
        // budget 0 = whole shards; the others are a small fraction of the
        // compressed payload, forcing many chunks per pass
        for budget_bytes in [0usize, payload / 20, payload / 7] {
            let mut reader = SparseStoreReader::open(&dir).unwrap();
            if budget_bytes > 0 {
                reader = reader.with_memory_budget(budget_bytes);
            }
            let got = FitPlan::kmeans()
                .store(&mut reader)
                .k(4)
                .kmeans_opts(opts)
                .solver(Solver::Stream)
                .workers(workers)
                .run()
                .unwrap();
            assert_eq!(got.raw_passes, 0, "store fit reads no raw data");
            // one seeding + d2 pass set per restart plus one pass per
            // Lloyd iteration — at minimum iterations many passes
            assert!(
                got.sparse_passes > got.iterations,
                "sparse passes {} vs iterations {}",
                got.sparse_passes,
                got.iterations
            );
            let gm = got.kmeans_model().unwrap();
            assert_eq!(gm.result.assign, dm.result.assign, "w={workers} b={budget_bytes}");
            assert_eq!(
                gm.result.objective.to_bits(),
                dm.result.objective.to_bits(),
                "objective, w={workers} b={budget_bytes}"
            );
            assert_eq!(gm.result.iterations, dm.result.iterations);
            for (a, b) in gm
                .result
                .centers
                .as_slice()
                .iter()
                .zip(dm.result.centers.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "centers, w={workers} b={budget_bytes}");
            }
            for (a, b) in got.center_bound.iter().zip(&direct.center_bound) {
                assert_eq!(a.to_bits(), b.to_bits(), "bounds, w={workers} b={budget_bytes}");
            }
        }
    }

    // the in-memory store solver agrees too (collect + iterate)
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let inmem = FitPlan::kmeans()
        .store(&mut reader)
        .k(4)
        .kmeans_opts(opts)
        .run()
        .unwrap();
    assert_eq!(inmem.raw_passes, 0);
    assert_eq!(inmem.sparse_passes, 1);
    let im = inmem.kmeans_model().unwrap();
    assert_eq!(im.result.assign, dm.result.assign);
    assert_eq!(im.result.objective.to_bits(), dm.result.objective.to_bits());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn restarts_are_deterministic_across_worker_counts() {
    // `--restarts N` contract end to end: a multi-restart plan picks the
    // same best model for every worker count
    let mut rng = Pcg64::seed(83);
    let d = pds::data::gaussian_blobs(32, 900, 3, 0.4, &mut rng);
    let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 31 };
    let mut base_src = MatSource::new(&d.data, 128);
    let base = FitPlan::kmeans()
        .stream(&mut base_src, scfg)
        .k(3)
        .restarts(5)
        .workers(1)
        .run()
        .unwrap();
    let bm = base.kmeans_model().unwrap();
    for workers in [2usize, 4] {
        let mut src = MatSource::new(&d.data, 128);
        let got = FitPlan::kmeans()
            .stream(&mut src, scfg)
            .k(3)
            .restarts(5)
            .workers(workers)
            .run()
            .unwrap();
        let gm = got.kmeans_model().unwrap();
        assert_eq!(gm.result.assign, bm.result.assign, "workers={workers}");
        assert_eq!(
            gm.result.objective.to_bits(),
            bm.result.objective.to_bits(),
            "workers={workers}"
        );
        for (a, b) in gm.result.centers.as_slice().iter().zip(bm.result.centers.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "workers={workers}");
        }
    }
}

#[test]
fn digit_stream_is_order_independent() {
    forall("digit_stream_order", 10, |g| {
        let seed = g.int(0, 1000) as u64;
        let stream = DigitStream::new(DigitConfig { seed, ..Default::default() });
        let idx = g.int(0, 5000) as usize;
        let a = stream.chunk(idx, 3);
        let b = stream.chunk(idx + 1, 1); // overlapping later read
        // column idx+1 must be identical whichever chunk produced it
        for i in 0..784 {
            assert_eq!(a.get(i, 1), b.get(i, 0));
        }
    });
}

#[test]
fn hk_accumulator_over_stream_matches_theorem7_shape() {
    let mut rng = Pcg64::seed(23);
    let x = pds::linalg::Mat::from_fn(128, 4000, |_, _| rng.normal());
    let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 29 };
    let sp = Sparsifier::new(128, scfg).unwrap();
    let mut acc = HkAccumulator::new(sp.p(), sp.m());
    let mut src = MatSource::new(&x, 512);
    let mut timer = pds::metrics::Timer::new();
    let mut fold = |c: pds::sparse::SparseChunk| -> pds::Result<()> {
        acc.accumulate(&c);
        Ok(())
    };
    compress_stream(&mut src, &sp, StreamConfig::default(), true, &mut fold, &mut timer)
        .unwrap();
    let dev = acc.deviation_norm();
    let bound = HkAccumulator::t_for_delta(sp.p(), sp.m(), 4000, 1e-3);
    assert!(dev <= bound, "H_k deviation {dev} exceeded Thm 7 bound {bound}");
}
