//! Cross-module integration: streaming pipeline ↔ estimators ↔ K-means ↔
//! out-of-core store, plus end-to-end statistical sanity (no artifacts
//! required — pure native engine).

use pds::coordinator::{
    run_compress_to_store, run_pca_krylov_from_store, run_pca_krylov_stream, run_pca_stream,
    run_sparsified_kmeans_stream, run_two_pass_stream, ChunkSource, MatSource, StoreSource,
    StreamConfig,
};
use pds::data::{digits, ChunkStore, ChunkStoreReader, DigitConfig, DigitStream};
use pds::estimators::{HkAccumulator, SparseMeanEstimator};
use pds::kmeans::{KmeansOpts, NativeAssigner};
use pds::metrics::clustering_accuracy;
use pds::pca::{explained_variance, recovered_components};
use pds::rng::Pcg64;
use pds::sampling::{Sparsifier, SparsifyConfig};
use pds::store::SparseStoreReader;
use pds::testing::prop::forall;
use pds::transform::TransformKind;

#[test]
fn digits_cluster_via_streaming_pipeline() {
    let d = digits(2000, DigitConfig { seed: 3, ..Default::default() });
    let scfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 9 };
    let mut src = MatSource::new(&d.data, 256);
    let (model, report) = run_sparsified_kmeans_stream(
        &mut src,
        scfg,
        3,
        KmeansOpts { n_init: 8, ..Default::default() },
        &NativeAssigner,
        StreamConfig { workers: 2, ..Default::default() },
        true,
    )
    .unwrap();
    let acc = clustering_accuracy(&model.result.assign, &d.labels, 3);
    assert!(acc > 0.85, "digit accuracy at gamma=0.05: {acc}");
    assert_eq!(report.n, 2000);
    // centers live in the original 784-dim space (padding dropped)
    assert_eq!(model.result.centers.rows(), 784);
}

#[test]
fn out_of_core_roundtrip_matches_in_memory() {
    let d = digits(400, DigitConfig { seed: 5, ..Default::default() });
    let path = std::env::temp_dir().join(format!("pds_it_store_{}", std::process::id()));
    {
        let mut store = ChunkStore::create(&path, 784, 128).unwrap();
        let mut start = 0;
        while start < 400 {
            let end = (start + 128).min(400);
            store.append(&d.data.col_range(start, end)).unwrap();
            start = end;
        }
        store.finish().unwrap();
    }
    let scfg = SparsifyConfig { gamma: 0.08, transform: TransformKind::Hadamard, seed: 11 };
    let opts = KmeansOpts { n_init: 3, ..Default::default() };

    let mut mem_src = MatSource::new(&d.data, 128);
    let (mem, _) = run_sparsified_kmeans_stream(
        &mut mem_src, scfg, 3, opts, &NativeAssigner, StreamConfig::default(), true,
    )
    .unwrap();

    // f32 storage introduces tiny value differences; the *structure* of
    // the clustering must survive the disk roundtrip.
    let mut disk_src = StoreSource::new(ChunkStoreReader::open(&path).unwrap());
    let (disk, report) = run_sparsified_kmeans_stream(
        &mut disk_src, scfg, 3, opts, &NativeAssigner, StreamConfig::default(), true,
    )
    .unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(report.n, 400);
    let agree = mem
        .result
        .assign
        .iter()
        .zip(&disk.result.assign)
        .filter(|(a, b)| a == b)
        .count();
    let frac = agree as f64 / 400.0;
    // identical up to label permutation; compare via accuracy metric
    let cross = clustering_accuracy(&mem.result.assign, &disk.result.assign, 3);
    assert!(cross > 0.99, "disk vs memory clustering agreement {cross} (raw {frac})");
}

#[test]
fn two_pass_stream_beats_one_pass_on_noisy_digits() {
    let d = digits(1200, DigitConfig { seed: 7, noise: 0.25, ..Default::default() });
    let scfg = SparsifyConfig { gamma: 0.02, transform: TransformKind::Hadamard, seed: 13 };
    let opts = KmeansOpts { n_init: 3, ..Default::default() };
    let mut src = MatSource::new(&d.data, 256);
    let (one, _) = run_sparsified_kmeans_stream(
        &mut src, scfg, 3, opts, &NativeAssigner, StreamConfig::default(), true,
    )
    .unwrap();
    src.reset().unwrap();
    let (two, report) =
        run_two_pass_stream(&mut src, scfg, 3, opts, &NativeAssigner, StreamConfig::default())
            .unwrap();
    assert_eq!(report.passes, 2);
    let a1 = clustering_accuracy(&one.result.assign, &d.labels, 3);
    let a2 = clustering_accuracy(&two.assign, &d.labels, 3);
    assert!(a2 >= a1 - 0.01, "two-pass {a2} vs one-pass {a1}");
}

#[test]
fn streaming_pca_mean_matches_direct_estimator() {
    let mut rng = Pcg64::seed(17);
    let d = pds::data::spiked(64, 3000, &[6.0, 3.0], false, &mut rng);
    let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 19 };
    let mut src = MatSource::new(&d.data, 500);
    let (pca_report, report) = run_pca_stream(&mut src, scfg, 2, StreamConfig::default()).unwrap();
    assert_eq!(report.n, 3000);
    // direct (single-chunk) estimator must agree exactly: same masks
    let sp = Sparsifier::new(64, scfg).unwrap();
    let chunk = sp.compress_chunk(&d.data, 0).unwrap();
    let mut mean = SparseMeanEstimator::new(sp.p(), sp.m());
    mean.accumulate(&chunk);
    let direct_pre = pds::linalg::Mat::from_vec(sp.p(), 1, mean.estimate()).unwrap();
    let direct = sp.unmix(&direct_pre);
    for i in 0..64 {
        assert!((pca_report.mean[i] - direct.get(i, 0)).abs() < 1e-9);
    }
}

#[test]
fn both_pca_solvers_recover_the_same_digit_pcs() {
    // acceptance: on the digits dataset the covariance solver and the
    // covariance-free krylov solver find the same top PCs — matched
    // one-to-one with inner product >= 0.95 per component
    let d = digits(1500, DigitConfig { seed: 11, ..Default::default() });
    let scfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 17 };
    let stream = StreamConfig::default();
    let mut src = MatSource::new(&d.data, 256);
    let (cov, _) = run_pca_stream(&mut src, scfg, 3, stream).unwrap();
    let mut src2 = MatSource::new(&d.data, 256);
    let (kry, report) = run_pca_krylov_stream(&mut src2, scfg, 3, stream).unwrap();
    assert_eq!(report.passes, 1);
    assert_eq!(kry.pca.components.rows(), 784, "components live in the original domain");
    assert_eq!(
        recovered_components(&kry.pca.components, &cov.pca.components, 0.95),
        3,
        "solvers disagree on the digit PCs"
    );
    // the shared mean-estimator path is bit-identical
    for (a, b) in kry.mean.iter().zip(&cov.mean) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn krylov_pca_from_store_matches_streaming_and_is_invariant() {
    // compress-to-store -> covariance-free fit: explained variance must
    // match the streaming covariance solver, the fit must be bitwise
    // invariant to worker count and to the reader memory budget, and it
    // must report zero raw-data passes
    let mut rng = Pcg64::seed(41);
    let n = 1200usize;
    let d = pds::data::spiked(64, n, &[8.0, 5.0, 3.0], false, &mut rng);
    let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 6 };
    let stream = StreamConfig { workers: 2, chunk_cols: 128, ..Default::default() };

    let mut src = MatSource::new(&d.data, 128);
    let (cov, _) = run_pca_stream(&mut src, scfg, 3, stream).unwrap();

    let dir = std::env::temp_dir().join(format!("pds_it_krylov_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut src2 = MatSource::new(&d.data, 128);
    run_compress_to_store(&mut src2, scfg, &dir, 97, stream, true).unwrap();

    let c_full = d.data.syrk().scaled(1.0 / n as f64);
    let mut store = SparseStoreReader::open(&dir).unwrap();
    let (base, report) = run_pca_krylov_from_store(&mut store, 3, 1).unwrap();
    assert_eq!(report.passes, 0, "store-backed krylov fit reads no raw data");
    assert_eq!(report.n, n);
    let ev_cov = explained_variance(&cov.pca.components, &c_full);
    let ev_kry = explained_variance(&base.pca.components, &c_full);
    assert!(
        (ev_cov - ev_kry).abs() < 1e-3,
        "explained variance: covariance {ev_cov} vs krylov {ev_kry}"
    );
    assert_eq!(recovered_components(&base.pca.components, &cov.pca.components, 0.95), 3);

    // worker count and memory budget may change speed, never bits
    for (workers, budget_bytes) in [(2usize, 0usize), (4, 64 * 1024), (1, 4096)] {
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        if budget_bytes > 0 {
            reader = reader.with_memory_budget(budget_bytes);
        }
        let (got, rep) = run_pca_krylov_from_store(&mut reader, 3, workers).unwrap();
        assert_eq!(rep.passes, 0);
        for (a, b) in got
            .pca
            .components
            .as_slice()
            .iter()
            .zip(base.pca.components.as_slice())
        {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "components, workers={workers} budget={budget_bytes}"
            );
        }
        for (a, b) in got.pca.eigenvalues.iter().zip(&base.pca.eigenvalues) {
            assert_eq!(a.to_bits(), b.to_bits(), "eigenvalues");
        }
        for (a, b) in got.mean.iter().zip(&base.mean) {
            assert_eq!(a.to_bits(), b.to_bits(), "mean");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn digit_stream_is_order_independent() {
    forall("digit_stream_order", 10, |g| {
        let seed = g.int(0, 1000) as u64;
        let stream = DigitStream::new(DigitConfig { seed, ..Default::default() });
        let idx = g.int(0, 5000) as usize;
        let a = stream.chunk(idx, 3);
        let b = stream.chunk(idx + 1, 1); // overlapping later read
        // column idx+1 must be identical whichever chunk produced it
        for i in 0..784 {
            assert_eq!(a.get(i, 1), b.get(i, 0));
        }
    });
}

#[test]
fn hk_accumulator_over_stream_matches_theorem7_shape() {
    let mut rng = Pcg64::seed(23);
    let x = pds::linalg::Mat::from_fn(128, 4000, |_, _| rng.normal());
    let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 29 };
    let sp = Sparsifier::new(128, scfg).unwrap();
    let mut acc = HkAccumulator::new(sp.p(), sp.m());
    let mut src = MatSource::new(&x, 512);
    let mut timer = pds::metrics::Timer::new();
    let mut fold = |c: pds::sparse::SparseChunk| -> pds::Result<()> {
        acc.accumulate(&c);
        Ok(())
    };
    pds::coordinator::compress_stream(
        &mut src, &sp, StreamConfig::default(), true, &mut fold, &mut timer,
    )
    .unwrap();
    let dev = acc.deviation_norm();
    let bound = HkAccumulator::t_for_delta(sp.p(), sp.m(), 4000, 1e-3);
    assert!(dev <= bound, "H_k deviation {dev} exceeded Thm 7 bound {bound}");
}
