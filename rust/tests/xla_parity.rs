//! Integration: the PJRT runtime executing the AOT JAX/Pallas artifacts
//! must agree with the native Rust engine on every chunk op, and the full
//! sparsified K-means driver must work end-to-end on the Xla engine.
//!
//! Requires `make artifacts` (skips with a clear message otherwise).

use pds::coordinator::{FitPlan, MatSource, StreamConfig};
use pds::data::gaussian_blobs;
use pds::kmeans::{KmeansOpts, NativeAssigner, SparseAssigner};
use pds::linalg::Mat;
use pds::metrics::clustering_accuracy;
use pds::rng::Pcg64;
use pds::runtime::{artifact_dir, XlaEngine};
use pds::sampling::{Sparsifier, SparsifyConfig};
use pds::transform::TransformKind;

fn engine_or_skip() -> Option<XlaEngine> {
    if !artifact_dir().join("manifest.tsv").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(XlaEngine::new(None).expect("PJRT CPU client"))
}

/// Compressed chunk fixture at the artifact signature p=512, k=5.
fn fixture(n: usize, seed: u64) -> (Sparsifier, pds::sparse::SparseChunk, Mat, Vec<u32>) {
    let mut rng = Pcg64::seed(seed);
    let d = gaussian_blobs(512, n, 5, 0.1, &mut rng);
    let cfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed };
    let sp = Sparsifier::new(512, cfg).unwrap();
    let chunk = sp.compress_chunk(&d.data, 0).unwrap();
    let centers = sp.precondition_dense(&d.centers);
    (sp, chunk, centers, d.labels)
}

#[test]
fn assign_matches_native_engine() {
    let Some(engine) = engine_or_skip() else { return };
    // n = 300 exercises sub-batching (artifact b=256) + padding
    let (_sp, chunk, centers, _) = fixture(300, 11);
    let (a_native, obj_native) = NativeAssigner::new().assign(&chunk, &centers).unwrap();
    let (a_xla, obj_xla) = engine.assign(&chunk, &centers).unwrap();
    assert_eq!(a_native.len(), a_xla.len());
    let mismatches = a_native.iter().zip(&a_xla).filter(|(a, b)| a != b).count();
    // f32-vs-f64 rounding may flip genuinely ambiguous samples only
    assert!(
        mismatches <= a_native.len() / 100,
        "assignments diverge: {mismatches}/{}",
        a_native.len()
    );
    let rel = (obj_native - obj_xla).abs() / obj_native.max(1e-12);
    assert!(rel < 1e-3, "objective mismatch: native {obj_native} xla {obj_xla}");
}

#[test]
fn precondition_artifact_matches_native_ros() {
    let Some(engine) = engine_or_skip() else { return };
    let p = 512usize;
    let b = 256usize;
    let mut rng = Pcg64::seed(3);
    let x = Mat::from_fn(p, b, |_, _| rng.normal());
    let cfg = SparsifyConfig { gamma: 0.1, transform: TransformKind::Hadamard, seed: 21 };
    let sp = Sparsifier::new(p, cfg).unwrap();
    let y_native = sp.precondition_dense(&x);
    let signs: Vec<f32> = sp.ros().signs().iter().map(|&v| v as f32).collect();
    let y_xla = engine.precondition_chunk(&x.to_f32(), &signs, p).unwrap();
    let y_xla = Mat::from_f32(p, b, &y_xla).unwrap();
    let err = y_native.sub(&y_xla).max_abs();
    assert!(err < 1e-3, "ROS parity: max err {err}");
}

#[test]
fn cov_artifact_matches_native_gram() {
    let Some(engine) = engine_or_skip() else { return };
    let p = 512usize;
    let b = 256usize;
    let mut rng = Pcg64::seed(7);
    let w = Mat::from_fn(p, b, |i, j| if (i + j) % 7 == 0 { rng.normal() } else { 0.0 });
    let gram_native = w.syrk();
    let gram_xla = engine.cov_chunk(&w.to_f32(), p).unwrap();
    let gram_xla = Mat::from_f32(p, p, &gram_xla).unwrap();
    let denom = gram_native.max_abs().max(1.0);
    let err = gram_native.sub(&gram_xla).max_abs() / denom;
    assert!(err < 1e-4, "gram parity: rel err {err}");
}

#[test]
fn full_driver_runs_on_xla_engine() {
    let Some(engine) = engine_or_skip() else { return };
    let mut rng = Pcg64::seed(17);
    let d = gaussian_blobs(512, 600, 5, 0.05, &mut rng);
    let scfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 5 };
    let mut src = MatSource::new(&d.data, 256);
    let report = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(5)
        .kmeans_opts(KmeansOpts { n_init: 2, ..Default::default() })
        .assigner(&engine)
        .stream_config(StreamConfig::default())
        .run()
        .unwrap();
    assert_eq!(report.engine, "xla");
    let model = report.kmeans_model().expect("kmeans plan");
    let acc = clustering_accuracy(&model.result.assign, &d.labels, 5);
    assert!(acc > 0.9, "xla-engine clustering accuracy {acc}");
}

#[test]
fn digit_signature_artifacts_present() {
    let Some(engine) = engine_or_skip() else { return };
    let m = engine.manifest();
    // the DCT-preconditioner digit signature
    assert!(m.find("assign", 784, 256, 3).is_ok(), "missing digit assign artifact");
    assert!(m.find("precondition", 784, 256, 0).is_ok(), "missing digit precondition artifact");
    // the padded-FWHT signature the Rust coordinator actually runs (e2e)
    assert!(m.find("assign", 1024, 256, 3).is_ok(), "missing padded digit assign artifact");
}
