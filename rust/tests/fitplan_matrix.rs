//! Table-driven sweep of the `FitPlan` matrix: task × source × solver ×
//! scheme × precision. Every valid cell must fit, account for its raw /
//! sparse passes, and be bit-for-bit deterministic (two runs of the same
//! cell produce identical outputs).

use std::path::PathBuf;

use pds::coordinator::{FitPlan, MatSource, Solver, StreamConfig};
use pds::kmeans::KmeansOpts;
use pds::linalg::Mat;
use pds::rng::Pcg64;
use pds::sampling::{Scheme, SparsifyConfig};
use pds::sparse::Precision;
use pds::store::SparseStoreReader;
use pds::transform::TransformKind;

const P: usize = 32;
const N: usize = 240;
const K: usize = 3;
const TOPK: usize = 2;

#[derive(Clone, Copy, PartialEq)]
enum Src {
    Stream,
    Store,
}

struct Case {
    task: &'static str,
    src: Src,
    solver: Solver,
    scheme: Scheme,
    precision: Precision,
}

impl Case {
    fn label(&self) -> String {
        format!(
            "{} / {} / {} / {} / {}",
            self.task,
            match self.src {
                Src::Stream => "stream",
                Src::Store => "store",
            },
            self.solver.name(),
            self.scheme.name(),
            self.precision.name()
        )
    }
}

fn scfg() -> SparsifyConfig {
    SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 7 }
}

/// Compress the shared dataset once per (scheme, precision) cell.
fn build_store(data: &Mat, scheme: Scheme, precision: Precision) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "pds_matrix_{}_{}_{}",
        scheme.name(),
        precision.name(),
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let mut src = MatSource::new(data, 64);
    FitPlan::compress()
        .stream(&mut src, scfg())
        .scheme(scheme)
        .precision(precision)
        .store_dir(&dir)
        .shard_cols(70)
        .run()
        .unwrap();
    dir
}

/// Run one cell; returns (raw_passes, sparse_passes, output bits).
fn run_cell(case: &Case, data: &Mat, store_dir: &PathBuf) -> (usize, usize, Vec<u64>) {
    let opts = KmeansOpts { n_init: 2, ..Default::default() };
    let stream = StreamConfig { workers: 2, ..Default::default() };
    let report = match case.src {
        Src::Stream => {
            let mut src = MatSource::new(data, 64);
            let res = match case.task {
                "pca" => FitPlan::pca()
                    .stream(&mut src, scfg())
                    .scheme(case.scheme)
                    .precision(case.precision)
                    .topk(TOPK)
                    .solver(case.solver)
                    .stream_config(stream)
                    .run(),
                _ => FitPlan::kmeans()
                    .stream(&mut src, scfg())
                    .scheme(case.scheme)
                    .precision(case.precision)
                    .k(K)
                    .kmeans_opts(opts)
                    .solver(case.solver)
                    .stream_config(stream)
                    .run(),
            };
            res.unwrap_or_else(|e| panic!("{}: {e}", case.label()))
        }
        Src::Store => {
            let mut reader = SparseStoreReader::open(store_dir).unwrap();
            // explicit scheme/precision on a store plan assert against the
            // manifest — exercising the loud-mismatch contract's happy path
            let res = match case.task {
                "pca" => FitPlan::pca()
                    .store(&mut reader)
                    .precision(case.precision)
                    .topk(TOPK)
                    .solver(case.solver)
                    .run(),
                _ => FitPlan::kmeans()
                    .store(&mut reader)
                    .precision(case.precision)
                    .k(K)
                    .kmeans_opts(opts)
                    .solver(case.solver)
                    .run(),
            };
            res.unwrap_or_else(|e| panic!("{}: {e}", case.label()))
        }
    };

    assert_eq!(report.n, N, "{}", case.label());
    let bits: Vec<u64> = match case.task {
        "pca" => {
            let fit = report.pca_fit().expect("pca plan");
            assert_eq!(fit.pca.eigenvalues.len(), TOPK, "{}", case.label());
            for w in fit.pca.eigenvalues.windows(2) {
                assert!(w[0] >= w[1], "{}: eigenvalues not sorted", case.label());
            }
            fit.pca
                .eigenvalues
                .iter()
                .chain(&fit.mean)
                .map(|v| v.to_bits())
                .chain(fit.pca.components.as_slice().iter().map(|v| v.to_bits()))
                .collect()
        }
        _ => {
            let m = report.kmeans_model().expect("kmeans plan");
            assert_eq!(m.result.assign.len(), N, "{}", case.label());
            assert!(
                m.result.assign.iter().all(|&a| (a as usize) < K),
                "{}: label out of range",
                case.label()
            );
            std::iter::once(m.result.objective.to_bits())
                .chain(m.result.assign.iter().map(|&a| a as u64))
                .chain(m.result.centers.as_slice().iter().map(|v| v.to_bits()))
                .collect()
        }
    };
    (report.raw_passes, report.sparse_passes, bits)
}

#[test]
fn every_valid_fitplan_cell_fits_accounts_passes_and_is_deterministic() {
    let mut rng = Pcg64::seed(97);
    let d = pds::data::gaussian_blobs(P, N, K, 0.15, &mut rng);

    let schemes = [Scheme::Precond, Scheme::Uniform, Scheme::Hybrid];
    let precisions = [Precision::F64, Precision::F32];

    let mut total = 0usize;
    let mut store_dirs = Vec::new();
    for &scheme in &schemes {
        for &precision in &precisions {
            let store_dir = build_store(&d.data, scheme, precision);

            let mut cases = vec![
                // raw streams: compress inline; the stream K-means solver
                // needs a store (it re-reads every iteration) so it has
                // no stream-source cell
                Case { task: "pca", src: Src::Stream, solver: Solver::Covariance, scheme, precision },
                Case { task: "pca", src: Src::Stream, solver: Solver::Krylov, scheme, precision },
                Case { task: "kmeans", src: Src::Stream, solver: Solver::InMemory, scheme, precision },
                // store-backed: every solver family member
                Case { task: "pca", src: Src::Store, solver: Solver::Covariance, scheme, precision },
                Case { task: "pca", src: Src::Store, solver: Solver::Krylov, scheme, precision },
                Case { task: "kmeans", src: Src::Store, solver: Solver::InMemory, scheme, precision },
                Case { task: "kmeans", src: Src::Store, solver: Solver::Stream, scheme, precision },
                Case { task: "kmeans", src: Src::Store, solver: Solver::Coreset, scheme, precision },
            ];
            for case in cases.drain(..) {
                let (raw, sparse, bits) = run_cell(&case, &d.data, &store_dir);
                // pass accounting: a stream fit pays exactly one raw
                // pass, a store fit pays none
                match case.src {
                    Src::Stream => assert_eq!(raw, 1, "{}", case.label()),
                    Src::Store => assert_eq!(raw, 0, "{}", case.label()),
                }
                assert!(sparse >= 1, "{}", case.label());
                if case.solver == Solver::Coreset {
                    // one pass building coreset leaves + one assigning
                    assert_eq!(sparse, 2, "{}", case.label());
                }
                // bit-for-bit deterministic: a second run of the same
                // cell reproduces every output exactly
                let (raw2, sparse2, bits2) = run_cell(&case, &d.data, &store_dir);
                assert_eq!((raw2, sparse2), (raw, sparse), "{}", case.label());
                assert_eq!(bits2, bits, "{}: fit is not deterministic", case.label());
                total += 1;
            }
            store_dirs.push(store_dir);
        }
    }
    assert_eq!(total, 48, "matrix coverage shrank — update the table, don't drop cells");
    println!("fitplan matrix: {total} cells passed, each run twice for bit-identity");
    for dir in store_dirs {
        std::fs::remove_dir_all(&dir).ok();
    }
}
