//! Distributed-fit integration: N workers fit shard ranges of a sparse
//! store (possibly dealt across N directories via `split_store`) and a
//! coordinator merges the partials — bit-identical to the single-worker
//! fit at every partition count and merge order for exact f64 folds, and
//! within a documented inertia tolerance for the coreset solver.

use std::path::PathBuf;

use pds::coordinator::{FitPlan, MatSource, Solver, StreamConfig};
use pds::error::Error;
use pds::kmeans::KmeansOpts;
use pds::rng::Pcg64;
use pds::sampling::SparsifyConfig;
use pds::store::{split_store, SparseStoreReader};
use pds::transform::TransformKind;

fn tmpdir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("pds_dist_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// Compress `data` (p × n) into a fresh store with the given shard size.
fn build_store(name: &str, data: &pds::linalg::Mat, shard_cols: usize, seed: u64) -> PathBuf {
    let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed };
    let dir = tmpdir(name);
    let mut src = MatSource::new(data, 64);
    FitPlan::compress()
        .stream(&mut src, scfg)
        .store_dir(&dir)
        .shard_cols(shard_cols)
        .stream_config(StreamConfig { workers: 2, ..Default::default() })
        .run()
        .unwrap();
    dir
}

/// Everything a PCA fit computes, as raw bits.
fn pca_bits(report: &pds::coordinator::FitReport) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
    let fit = report.pca_fit().expect("pca plan");
    (
        fit.pca.eigenvalues.iter().map(|v| v.to_bits()).collect(),
        fit.pca.components.as_slice().iter().map(|v| v.to_bits()).collect(),
        fit.mean.iter().map(|v| v.to_bits()).collect(),
    )
}

/// Everything a K-means fit computes, as raw bits.
fn km_bits(report: &pds::coordinator::FitReport) -> (Vec<u32>, u64, Vec<u64>, Vec<u64>) {
    let m = report.kmeans_model().expect("kmeans plan");
    (
        m.result.assign.clone(),
        m.result.objective.to_bits(),
        m.result.centers.as_slice().iter().map(|v| v.to_bits()).collect(),
        report.center_bound.iter().map(|v| v.to_bits()).collect(),
    )
}

#[test]
fn partitioned_pca_is_invariant_across_partitions_directories_and_merge_orders() {
    let mut rng = Pcg64::seed(51);
    let d = pds::data::spiked(32, 300, &[8.0, 4.0], false, &mut rng);
    let dir = build_store("pca", &d.data, 50, 5); // 6 shards

    // reference: the one-worker distributed fit
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let base = FitPlan::pca().store(&mut reader).topk(2).partition(1).run().unwrap();
    assert_eq!(base.raw_passes, 0, "distributed fit reads no raw data");
    assert_eq!(base.n, 300);
    let want = pca_bits(&base);

    // every partition count folds the same per-shard subtotals in the
    // same global shard order — bitwise identical
    for parts in [2usize, 3, 6] {
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        let got = FitPlan::pca().store(&mut reader).topk(2).partition(parts).run().unwrap();
        assert_eq!(pca_bits(&got), want, "partition({parts})");
    }

    // worker artifacts round-trip through files and merge in any order
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let artifacts = FitPlan::pca().store(&mut reader).topk(2).partition(3).partials().unwrap();
    assert_eq!(artifacts.len(), 3);
    let art_dir = tmpdir("pca_artifacts");
    std::fs::create_dir_all(&art_dir).unwrap();
    let mut from_disk = Vec::new();
    for (i, bytes) in artifacts.iter().enumerate() {
        let path = art_dir.join(format!("partial-{i:05}.pdsp"));
        std::fs::write(&path, bytes).unwrap();
        from_disk.push(std::fs::read(&path).unwrap());
    }
    for rot in 0..from_disk.len() {
        let mut order = from_disk.clone();
        order.rotate_left(rot);
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        let merged = FitPlan::pca().store(&mut reader).topk(2).merge_partials(&order).unwrap();
        assert_eq!(merged.raw_passes, 0);
        assert_eq!(pca_bits(&merged), want, "merge order rotated by {rot}");
    }

    // the real N-directory story: deal the store across 3 directories,
    // let each "worker" fit only its own piece, merge on the full store
    let pieces = vec![tmpdir("pca_w0"), tmpdir("pca_w1"), tmpdir("pca_w2")];
    split_store(&dir, &pieces).unwrap();
    let mut worker_artifacts = Vec::new();
    for piece in &pieces {
        let mut piece_reader = SparseStoreReader::open(piece).unwrap();
        let mut arts = FitPlan::pca().store(&mut piece_reader).topk(2).partials().unwrap();
        assert_eq!(arts.len(), 1, "one artifact per worker directory");
        worker_artifacts.append(&mut arts);
    }
    worker_artifacts.reverse(); // coordinator receives them in any order
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let merged = FitPlan::pca()
        .store(&mut reader)
        .topk(2)
        .merge_partials(&worker_artifacts)
        .unwrap();
    assert_eq!(pca_bits(&merged), want, "3-directory split-fit-merge");

    // an incomplete worker set is refused, not silently wrong
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    match FitPlan::pca().store(&mut reader).topk(2).merge_partials(&worker_artifacts[..2]) {
        Err(Error::Invalid(msg)) => assert!(msg.contains("cover"), "{msg}"),
        other => panic!("expected Invalid for missing worker, got {:?}", other.map(|_| ())),
    }

    for p in pieces.iter().chain([&dir, &art_dir]) {
        std::fs::remove_dir_all(p).ok();
    }
}

#[test]
fn partitioned_lloyd_kmeans_is_bit_identical_for_every_partition_count() {
    let mut rng = Pcg64::seed(61);
    let d = pds::data::gaussian_blobs(32, 420, 4, 0.2, &mut rng);
    let dir = build_store("lloyd", &d.data, 70, 9); // 6 shards
    let opts = KmeansOpts { n_init: 2, ..Default::default() };

    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let base = FitPlan::kmeans()
        .store(&mut reader)
        .k(4)
        .kmeans_opts(opts)
        .partition(1)
        .run()
        .unwrap();
    assert_eq!(base.raw_passes, 0);
    assert_eq!(base.n, 420);
    assert_eq!(base.center_bound.len(), base.iterations, "one Eq. 43 bound per iteration");
    let want = km_bits(&base);

    for parts in [2usize, 4] {
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        let got = FitPlan::kmeans()
            .store(&mut reader)
            .k(4)
            .kmeans_opts(opts)
            .partition(parts)
            .run()
            .unwrap();
        assert_eq!(got.iterations, base.iterations, "partition({parts})");
        assert_eq!(km_bits(&got), want, "partition({parts})");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn coreset_kmeans_meets_tolerance_and_merges_across_directories() {
    let mut rng = Pcg64::seed(71);
    let d = pds::data::gaussian_blobs(32, 600, 4, 0.1, &mut rng);
    let dir = build_store("coreset", &d.data, 100, 13); // 6 shards
    let opts = KmeansOpts { n_init: 4, ..Default::default() };

    // exact reference: full-store Lloyd on the same sparsified data
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let exact = FitPlan::kmeans().store(&mut reader).k(4).kmeans_opts(opts).run().unwrap();
    let exact_obj = exact.kmeans_model().unwrap().result.objective;

    // coreset solver: documented accuracy contract vs full-store Lloyd
    // (EXPERIMENTS.md §Distributed merge: inertia within 1.5× + eps)
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let approx = FitPlan::kmeans()
        .store(&mut reader)
        .k(4)
        .kmeans_opts(opts)
        .solver(Solver::Coreset)
        .coreset_size(128)
        .partition(2)
        .run()
        .unwrap();
    assert_eq!(approx.raw_passes, 0);
    assert_eq!(approx.n, 600);
    let approx_obj = approx.kmeans_model().unwrap().result.objective;
    assert!(
        approx_obj <= exact_obj * 1.5 + 1e-9,
        "coreset inertia {approx_obj} vs Lloyd {exact_obj}"
    );
    // the coreset centers don't come from the Eq. 39 estimator, so no
    // center-error guarantee is claimed
    assert!(approx.center_bound.iter().all(|b| b.is_nan()));
    let want = km_bits(&approx);

    // same fit from 2 worker directories, artifacts merged in reverse
    let pieces = vec![tmpdir("coreset_w0"), tmpdir("coreset_w1")];
    split_store(&dir, &pieces).unwrap();
    let mut worker_artifacts = Vec::new();
    for piece in &pieces {
        let mut piece_reader = SparseStoreReader::open(piece).unwrap();
        let mut arts = FitPlan::kmeans()
            .store(&mut piece_reader)
            .k(4)
            .kmeans_opts(opts)
            .solver(Solver::Coreset)
            .coreset_size(128)
            .partials()
            .unwrap();
        assert_eq!(arts.len(), 1);
        worker_artifacts.append(&mut arts);
    }
    worker_artifacts.reverse();
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let merged = FitPlan::kmeans()
        .store(&mut reader)
        .k(4)
        .kmeans_opts(opts)
        .solver(Solver::Coreset)
        .coreset_size(128)
        .merge_partials(&worker_artifacts)
        .unwrap();
    assert_eq!(km_bits(&merged), want, "2-directory coreset split-fit-merge");

    for p in pieces.iter().chain([&dir]) {
        std::fs::remove_dir_all(p).ok();
    }
}

#[test]
fn damaged_partial_artifacts_are_typed_errors_never_panics() {
    let mut rng = Pcg64::seed(81);
    let d = pds::data::spiked(16, 120, &[5.0], false, &mut rng);
    let dir = build_store("damage", &d.data, 30, 17); // 4 shards
    let mut reader = SparseStoreReader::open(&dir).unwrap();
    let artifacts =
        FitPlan::pca().store(&mut reader).topk(1).partition(2).partials().unwrap();
    assert_eq!(artifacts.len(), 2);

    let merge = |arts: &[Vec<u8>]| {
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        FitPlan::pca()
            .store(&mut reader)
            .topk(1)
            .merge_partials(arts)
            .map(|_| ())
    };

    // a flipped payload byte fails the envelope checksum
    let mut flipped = artifacts.clone();
    let mid = flipped[1].len() / 2;
    flipped[1][mid] ^= 0x40;
    assert!(matches!(merge(&flipped), Err(Error::Corrupt(_))));

    // truncation at any point is Corrupt, never a panic
    for cut in [0usize, 3, 19, artifacts[0].len() - 1] {
        let cut_arts = vec![artifacts[0][..cut].to_vec(), artifacts[1].clone()];
        assert!(matches!(merge(&cut_arts), Err(Error::Corrupt(_))), "cut at {cut}");
    }

    // artifacts from a differently-sharded store don't cover this one
    let other = build_store("damage_other", &d.data, 60, 17); // 2 shards
    let mut other_reader = SparseStoreReader::open(&other).unwrap();
    let other_arts =
        FitPlan::pca().store(&mut other_reader).topk(1).partials().unwrap();
    assert!(matches!(merge(&other_arts), Err(Error::Invalid(_))));

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&other).ok();
}
