//! End-to-end tests driving the real `pds serve` binary in pipe mode:
//! a full ingest → refresh → query/query_batch session with a clean
//! shutdown, a SIGKILL mid-stream (the store must reopen CRC-clean at
//! the last checkpoint), a warm restart (a respawned daemon must answer
//! its first query from the persisted snapshot at the pre-kill version
//! and keep the version monotone), and a SIGTERM (the signal watcher
//! must finalize the store, partial shard included, before exiting).

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::process::{Child, ChildStdout, Command, Stdio};

use pds::rng::Pcg64;
use pds::serve::json::Json;
use pds::store::SparseStoreReader;

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("pds_pipe_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// One serve session over the child's stdin/stdout pipes.
struct Session {
    child: Child,
    out: BufReader<ChildStdout>,
}

impl Session {
    fn spawn(dir: &PathBuf, task: &str, p: usize) -> Session {
        let mut child = Command::new(env!("CARGO_BIN_EXE_pds"))
            .args([
                "serve",
                "--store",
                dir.to_str().unwrap(),
                "--task",
                task,
                "--p",
                &p.to_string(),
                "--shard-cols",
                "8",
                "--k",
                "2",
                // refresh only when asked: no background cycle racing the test
                "--refresh-ms",
                "3600000",
                "--timeout-ms",
                "60000",
            ])
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn pds serve");
        let out = BufReader::new(child.stdout.take().unwrap());
        Session { child, out }
    }

    /// Send one request line, read the one response line.
    fn request(&mut self, line: &str) -> Json {
        let stdin = self.child.stdin.as_mut().unwrap();
        stdin.write_all(line.as_bytes()).unwrap();
        stdin.write_all(b"\n").unwrap();
        stdin.flush().unwrap();
        let mut resp = String::new();
        self.out.read_line(&mut resp).expect("read response");
        assert!(!resp.is_empty(), "daemon closed the pipe on {line:?}");
        Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}"))
    }

    fn expect_ok(&mut self, line: &str) -> Json {
        let resp = self.request(line);
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{line} -> {resp:?}");
        resp
    }
}

fn batch_line(p: usize, n: usize, seed: u64) -> String {
    let mut rng = Pcg64::seed(seed);
    let rows: Vec<String> = (0..n)
        .map(|_| {
            let vals: Vec<String> = (0..p).map(|_| format!("{:.6}", rng.normal())).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    format!("{{\"cmd\":\"ingest\",\"samples\":[{}]}}", rows.join(","))
}

fn query_line(p: usize, seed: u64) -> String {
    let mut rng = Pcg64::seed(seed);
    let vals: Vec<String> = (0..p).map(|_| format!("{:.6}", rng.normal())).collect();
    format!("{{\"cmd\":\"query\",\"sample\":[{}]}}", vals.join(","))
}

fn query_batch_line(p: usize, seeds: &[u64]) -> String {
    let rows: Vec<String> = seeds
        .iter()
        .map(|&seed| {
            let mut rng = Pcg64::seed(seed);
            let vals: Vec<String> = (0..p).map(|_| format!("{:.6}", rng.normal())).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    format!("{{\"cmd\":\"query_batch\",\"samples\":[{}]}}", rows.join(","))
}

/// CRC-verified readback; returns total columns.
fn verified_cols(dir: &PathBuf) -> usize {
    let mut reader = SparseStoreReader::open(dir).unwrap().with_verify(true);
    let mut cols = 0;
    while let Some(chunk) = reader.next_chunk().unwrap() {
        cols += chunk.n();
    }
    cols
}

#[test]
fn pipe_session_full_lifecycle() {
    let dir = tmp("lifecycle");
    let p = 16;
    let mut s = Session::spawn(&dir, "pca", p);

    for seed in 0..3 {
        s.expect_ok(&batch_line(p, 8, seed));
    }
    let flush = s.expect_ok(r#"{"cmd":"flush"}"#);
    assert_eq!(flush.get("durable_cols").and_then(Json::as_f64), Some(24.0));

    let refresh = s.expect_ok(r#"{"cmd":"refresh"}"#);
    let version = refresh.get("model_version").and_then(Json::as_f64).unwrap();
    assert!(version >= 1.0);

    let query = s.expect_ok(&query_line(p, 42));
    assert_eq!(query.get("model_version").and_then(Json::as_f64), Some(version));
    assert_eq!(query.get("stale").and_then(Json::as_bool), Some(false));
    assert!(query.get("coords").and_then(Json::as_arr).is_some_and(|c| !c.is_empty()));

    // a query_batch answers every sample from one snapshot, in order,
    // bit-identical to the single-query path
    let qb = s.expect_ok(&query_batch_line(p, &[42, 43]));
    assert_eq!(qb.get("model_version").and_then(Json::as_f64), Some(version));
    let results = qb.get("results").and_then(Json::as_arr).expect("results array");
    assert_eq!(results.len(), 2);
    assert_eq!(results[0].get("coords"), query.get("coords"));

    let stats = s.expect_ok(r#"{"cmd":"stats"}"#);
    assert!(stats.get("metrics").is_some(), "stats must embed the metrics registry");

    s.expect_ok(r#"{"cmd":"shutdown"}"#);
    let status = s.child.wait().unwrap();
    assert!(status.success(), "clean shutdown must exit 0: {status:?}");

    // the finalized store holds every ingested column, CRC-clean
    assert_eq!(verified_cols(&dir), 24);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sigkill_mid_stream_leaves_checkpointed_store() {
    let dir = tmp("sigkill");
    let p = 16;
    let mut s = Session::spawn(&dir, "kmeans", p);

    // 16 columns = 2 complete shards at --shard-cols 8, both checkpointed
    s.expect_ok(&batch_line(p, 8, 0));
    s.expect_ok(&batch_line(p, 8, 1));
    let flush = s.expect_ok(r#"{"cmd":"flush"}"#);
    assert_eq!(flush.get("durable_cols").and_then(Json::as_f64), Some(16.0));

    s.child.kill().unwrap(); // SIGKILL: no cleanup of any kind runs
    let _ = s.child.wait();

    // the last checkpoint manifest is the recovery point, CRC-clean
    let reader = SparseStoreReader::open(&dir).unwrap();
    assert_eq!(reader.manifest().n, 16);
    assert_eq!(verified_cols(&dir), 16);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_restart_serves_persisted_snapshot() {
    let dir = tmp("warmrestart");
    let p = 16;
    let mut s = Session::spawn(&dir, "pca", p);

    // two complete shards, refreshed once: the refresh persists the
    // snapshot artifact next to the checkpointed manifest
    s.expect_ok(&batch_line(p, 8, 0));
    s.expect_ok(&batch_line(p, 8, 1));
    let flush = s.expect_ok(r#"{"cmd":"flush"}"#);
    assert_eq!(flush.get("durable_cols").and_then(Json::as_f64), Some(16.0));
    let refresh = s.expect_ok(r#"{"cmd":"refresh"}"#);
    let version = refresh.get("model_version").and_then(Json::as_f64).unwrap();
    assert!(version >= 1.0);

    s.child.kill().unwrap(); // SIGKILL: the warm start must not need a clean exit
    let _ = s.child.wait();

    // restart on the same directory: the very first query — before any
    // ingest or refresh — answers from the persisted snapshot at its
    // pre-kill version
    let mut s = Session::spawn(&dir, "pca", p);
    let query = s.expect_ok(&query_line(p, 42));
    assert_eq!(query.get("model_version").and_then(Json::as_f64), Some(version));
    assert!(query.get("coords").and_then(Json::as_arr).is_some_and(|c| !c.is_empty()));

    // ingest resumes at the checkpoint, and the next refresh keeps the
    // version monotone across the restart
    s.expect_ok(&batch_line(p, 8, 2));
    let flush = s.expect_ok(r#"{"cmd":"flush"}"#);
    assert_eq!(flush.get("durable_cols").and_then(Json::as_f64), Some(24.0));
    let refresh = s.expect_ok(r#"{"cmd":"refresh"}"#);
    assert_eq!(refresh.get("model_version").and_then(Json::as_f64), Some(version + 1.0));

    s.expect_ok(r#"{"cmd":"shutdown"}"#);
    let status = s.child.wait().unwrap();
    assert!(status.success(), "clean shutdown must exit 0: {status:?}");
    assert_eq!(verified_cols(&dir), 24);
    let _ = std::fs::remove_dir_all(&dir);
}

#[cfg(unix)]
#[test]
fn sigterm_finalizes_the_store_before_exit() {
    let dir = tmp("sigterm");
    let p = 16;
    let mut s = Session::spawn(&dir, "pca", p);

    // 12 columns: one complete shard plus a 4-column partial that only
    // the graceful path (writer.finish) can make durable
    s.expect_ok(&batch_line(p, 8, 0));
    s.expect_ok(&batch_line(p, 4, 1));
    s.expect_ok(r#"{"cmd":"flush"}"#);

    let kill = Command::new("kill")
        .args(["-TERM", &s.child.id().to_string()])
        .status()
        .expect("send SIGTERM");
    assert!(kill.success());
    let status = s.child.wait().unwrap();
    assert!(status.success(), "SIGTERM path must exit 0: {status:?}");

    let reader = SparseStoreReader::open(&dir).unwrap();
    assert_eq!(reader.manifest().n, 12, "the partial shard must be finalized");
    assert_eq!(verified_cols(&dir), 12);
    let _ = std::fs::remove_dir_all(&dir);
}
