//! In-process integration tests for the `pds serve` daemon: concurrent
//! queries during refresh, graceful degradation (stale snapshots after a
//! failed refresh, typed backpressure under a full queue), and the
//! request-validation surface.

use std::path::PathBuf;
use std::time::Duration;

use pds::rng::Pcg64;
use pds::serve::json::Json;
use pds::serve::{Daemon, ServeConfig, ServeTask};
use pds::store::SparseStoreReader;

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("pds_serve_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&p);
    p
}

/// A small daemon config: tiny shards (checkpoint often), refresh only
/// on explicit request (the interval is effectively "never"), generous
/// request timeout so CI jitter can't fail a blocking call.
fn small_cfg(dir: &PathBuf, task: ServeTask, p: usize) -> ServeConfig {
    let mut cfg = ServeConfig::new(dir.clone(), task, p);
    cfg.shard_cols = 8;
    cfg.refresh_interval = Duration::from_secs(3600);
    cfg.request_timeout = Duration::from_secs(60);
    cfg
}

/// An `ingest` request line with `n` deterministic Gaussian samples.
fn batch_line(p: usize, n: usize, seed: u64) -> String {
    let mut rng = Pcg64::seed(seed);
    let rows: Vec<String> = (0..n)
        .map(|_| {
            let vals: Vec<String> = (0..p).map(|_| format!("{:.6}", rng.normal())).collect();
            format!("[{}]", vals.join(","))
        })
        .collect();
    format!("{{\"cmd\":\"ingest\",\"samples\":[{}]}}", rows.join(","))
}

fn query_line(p: usize, seed: u64) -> String {
    let mut rng = Pcg64::seed(seed);
    let vals: Vec<String> = (0..p).map(|_| format!("{:.6}", rng.normal())).collect();
    format!("{{\"cmd\":\"query\",\"sample\":[{}]}}", vals.join(","))
}

fn field(resp: &str, name: &str) -> Json {
    Json::parse(resp)
        .unwrap_or_else(|e| panic!("unparseable response {resp:?}: {e}"))
        .get(name)
        .cloned()
        .unwrap_or(Json::Null)
}

fn is_ok(resp: &str) -> bool {
    field(resp, "ok").as_bool() == Some(true)
}

fn num(resp: &str, name: &str) -> f64 {
    field(resp, name).as_f64().unwrap_or_else(|| panic!("no numeric {name:?} in {resp}"))
}

fn code(resp: &str) -> String {
    field(resp, "code").as_str().unwrap_or("").to_string()
}

/// The tentpole acceptance path: ingest, refresh, then hammer the query
/// lane from several threads *while* a refresh publishes a new version.
/// Every response must be coherent — a published version, never stale,
/// never a half-written model.
#[test]
fn queries_stay_consistent_during_concurrent_refresh() {
    let dir = tmp("pca_versions");
    let p = 16;
    let daemon = Daemon::start(small_cfg(&dir, ServeTask::Pca, p)).unwrap();
    let client = daemon.client();

    for seed in 0..3 {
        let resp = client.handle_line(&batch_line(p, 8, seed)).0;
        assert!(is_ok(&resp), "ingest failed: {resp}");
    }
    let flush = client.handle_line(r#"{"cmd":"flush"}"#).0;
    assert!(is_ok(&flush), "flush failed: {flush}");
    assert_eq!(num(&flush, "durable_cols") as usize, 24, "3 full shards must be durable");

    let refresh = client.handle_line(r#"{"cmd":"refresh"}"#).0;
    assert!(is_ok(&refresh), "refresh failed: {refresh}");
    let v1 = num(&refresh, "model_version") as u64;
    assert!(v1 >= 1);

    // new data for the second refresh to fold
    let resp = client.handle_line(&batch_line(p, 8, 99)).0;
    assert!(is_ok(&resp), "ingest failed: {resp}");
    let flush = client.handle_line(r#"{"cmd":"flush"}"#).0;
    assert!(is_ok(&flush), "flush failed: {flush}");

    // query threads race the refresh below
    let handles: Vec<_> = (0..4)
        .map(|t| {
            let c = daemon.client();
            std::thread::spawn(move || {
                for i in 0..50u64 {
                    let resp = c.handle_line(&query_line(p, 1000 + t * 100 + i)).0;
                    assert!(is_ok(&resp), "query failed mid-refresh: {resp}");
                    let v = num(&resp, "model_version") as u64;
                    assert!(v == v1 || v == v1 + 1, "incoherent version {v} (v1={v1})");
                    assert_eq!(field(&resp, "stale").as_bool(), Some(false));
                    let coords = field(&resp, "coords");
                    assert!(coords.as_arr().is_some_and(|c| !c.is_empty()));
                }
            })
        })
        .collect();
    let refresh = client.handle_line(r#"{"cmd":"refresh"}"#).0;
    assert!(is_ok(&refresh), "second refresh failed: {refresh}");
    assert_eq!(num(&refresh, "model_version") as u64, v1 + 1);
    for h in handles {
        h.join().unwrap();
    }

    // after the swap, every query sees the new version
    let resp = client.handle_line(&query_line(p, 7)).0;
    assert_eq!(num(&resp, "model_version") as u64, v1 + 1);

    drop(client);
    let (manifest, stats) = daemon.shutdown();
    let manifest = manifest.expect("graceful shutdown finalizes the store");
    assert_eq!(manifest.n, 32);
    assert!(stats.contains("\"requests\""), "metrics dump missing: {stats}");

    // the finalized store passes a full CRC-verified readback
    let mut reader = SparseStoreReader::open(&dir).unwrap().with_verify(true);
    let mut cols = 0;
    while let Some(chunk) = reader.next_chunk().unwrap() {
        cols += chunk.n();
    }
    assert_eq!(cols, 32);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Degraded mode: a refresh that fails (here: a shard file goes missing
/// mid-cycle) must keep the previous snapshot live with `stale: true`,
/// and a later successful refresh must clear the flag and bump the
/// version — the failed cycle's shards are retried, not lost.
#[test]
fn failed_refresh_serves_stale_snapshot_and_recovers() {
    let dir = tmp("kmeans_stale");
    let p = 16;
    let mut cfg = small_cfg(&dir, ServeTask::Kmeans, p);
    cfg.k = 2;
    let daemon = Daemon::start(cfg).unwrap();
    let client = daemon.client();

    for seed in 0..2 {
        assert!(is_ok(&client.handle_line(&batch_line(p, 8, seed)).0));
    }
    assert!(is_ok(&client.handle_line(r#"{"cmd":"flush"}"#).0));
    let refresh = client.handle_line(r#"{"cmd":"refresh"}"#).0;
    assert!(is_ok(&refresh), "first refresh failed: {refresh}");
    let v1 = num(&refresh, "model_version") as u64;

    // a new durable shard, whose file we then hide to break the refit
    assert!(is_ok(&client.handle_line(&batch_line(p, 8, 50)).0));
    assert!(is_ok(&client.handle_line(r#"{"cmd":"flush"}"#).0));
    let mut shards: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "pdsb"))
        .collect();
    shards.sort();
    let newest = shards.last().unwrap().clone();
    let hidden = newest.with_extension("pdsb.bak");
    std::fs::rename(&newest, &hidden).unwrap();

    let failed = client.handle_line(r#"{"cmd":"refresh"}"#).0;
    assert!(!is_ok(&failed), "refresh over a missing shard must fail: {failed}");
    assert_eq!(code(&failed), "internal");
    assert!(
        field(&failed, "error").as_str().unwrap().contains("previous snapshot"),
        "error must say the old model still serves: {failed}"
    );

    // degraded but alive: the v1 model answers, marked stale
    let resp = client.handle_line(&query_line(p, 3)).0;
    assert!(is_ok(&resp), "stale-mode query failed: {resp}");
    assert_eq!(num(&resp, "model_version") as u64, v1);
    assert_eq!(field(&resp, "stale").as_bool(), Some(true));
    let stats = client.handle_line(r#"{"cmd":"stats"}"#).0;
    assert_eq!(field(&stats, "stale").as_bool(), Some(true));

    // restore the shard: the retried refresh folds it and clears stale
    std::fs::rename(&hidden, &newest).unwrap();
    let recovered = client.handle_line(r#"{"cmd":"refresh"}"#).0;
    assert!(is_ok(&recovered), "recovery refresh failed: {recovered}");
    assert_eq!(num(&recovered, "model_version") as u64, v1 + 1);
    let resp = client.handle_line(&query_line(p, 4)).0;
    assert_eq!(field(&resp, "stale").as_bool(), Some(false));
    assert_eq!(num(&resp, "model_version") as u64, v1 + 1);

    drop(client);
    let (manifest, _) = daemon.shutdown();
    assert_eq!(manifest.unwrap().n, 24);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Backpressure and validation: a full bounded queue is a typed
/// `backpressure` error (nothing enqueued, nothing lost), malformed
/// lines are `bad_request`, querying before any model is `no_model`,
/// and everything the daemon did accept is durable after a flush.
#[test]
fn full_queue_is_typed_backpressure_not_loss() {
    let dir = tmp("backpressure");
    let p = 64;
    let mut cfg = small_cfg(&dir, ServeTask::Pca, p);
    // depth-1 queue + one checkpoint (fsync) per batch: the worker is
    // deliberately much slower than the handler's try_send
    cfg.queue_batches = 1;
    cfg.shard_cols = 64;
    let daemon = Daemon::start(cfg).unwrap();
    let client = daemon.client();

    let resp = client.handle_line(&query_line(p, 0)).0;
    assert_eq!(code(&resp), "no_model");
    let resp = client.handle_line("this is not json").0;
    assert_eq!(code(&resp), "bad_request");
    let resp = client.handle_line(r#"{"cmd":"ingest","samples":[[1,2]]}"#).0;
    assert_eq!(code(&resp), "bad_request", "dimension mismatch must be typed: {resp}");

    let line = batch_line(p, 64, 0);
    let mut accepted = 0u64;
    let mut rejected = 0u64;
    for _ in 0..200 {
        let resp = client.handle_line(&line).0;
        if is_ok(&resp) {
            accepted += 1;
        } else {
            assert_eq!(code(&resp), "backpressure", "only typed backpressure: {resp}");
            rejected += 1;
        }
    }
    assert!(rejected > 0, "a depth-1 queue must reject under a 200-batch flood");
    assert!(accepted > 0);

    let flush = client.handle_line(r#"{"cmd":"flush"}"#).0;
    assert!(is_ok(&flush), "flush failed: {flush}");
    assert_eq!(num(&flush, "total_cols") as u64, accepted * 64, "accepted batches all absorbed");

    drop(client);
    let (manifest, stats) = daemon.shutdown();
    assert_eq!(manifest.unwrap().n as u64, accepted * 64);
    let parsed = Json::parse(&stats).unwrap();
    let metric_rejections =
        parsed.get("backpressure_rejections").and_then(Json::as_f64).unwrap() as u64;
    assert_eq!(metric_rejections, rejected);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Config validation is a typed error, not a wedged daemon.
#[test]
fn zero_depth_queue_is_rejected_at_start() {
    let dir = tmp("zero_queue");
    let mut cfg = small_cfg(&dir, ServeTask::Pca, 16);
    cfg.queue_batches = 0;
    assert!(Daemon::start(cfg).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
