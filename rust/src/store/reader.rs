//! Shard reader: streams a sparse store back as [`SparseChunk`]s with a
//! configurable memory budget, per-shard checksum verification, and
//! resume-at-any-column support. Implements
//! [`SparseChunkSource`](crate::sparse::SparseChunkSource), so every
//! estimator and the K-means drivers consume stored data exactly as they
//! consume freshly compressed chunks.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use crate::sparse::SparseChunkSource;
use crate::error::{corrupt, invalid, Error, Result};
use crate::sampling::Sparsifier;
use crate::sparse::{Precision, SparseChunk};

use super::manifest::StoreManifest;
use super::{Crc32, SHARD_HEADER_LEN, SHARD_MAGIC, SHARD_VERSION, SHARD_VERSION_F32};

/// Streaming reader over a completed sparse store.
///
/// Reads shards in global column order, returning at most
/// `chunk_cols` columns per [`next_chunk`](Self::next_chunk) (set via
/// [`with_memory_budget`](Self::with_memory_budget); default: whole
/// shards). Each shard's CRC-32 is verified against the manifest the
/// first time the shard is opened in a pass; corruption surfaces as
/// [`Error::Corrupt`], never a panic.
///
/// # Example
///
/// ```
/// use pds::linalg::Mat;
/// use pds::rng::Pcg64;
/// use pds::sampling::{Sparsifier, SparsifyConfig};
/// use pds::store::{SparseStoreReader, SparseStoreWriter};
/// use pds::transform::TransformKind;
///
/// let dir = std::env::temp_dir().join(format!("pds_doc_reader_{}", std::process::id()));
/// # let _ = std::fs::remove_dir_all(&dir);
/// let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 9 };
/// let sp = Sparsifier::new(8, cfg)?;
/// let mut rng = Pcg64::seed(2);
/// let x = Mat::from_fn(8, 7, |_, _| rng.normal());
/// let mut writer = SparseStoreWriter::create(&dir, &sp, cfg, true, 4)?;
/// writer.append(sp.compress_chunk(&x, 0)?)?;
/// writer.finish()?;
///
/// // memory-budgeted streaming: at most ~1 column in RAM per chunk here
/// let mut reader = SparseStoreReader::open(&dir)?.with_memory_budget(64);
/// let mut seen = 0;
/// while let Some(chunk) = reader.next_chunk()? {
///     seen += chunk.n();
/// }
/// assert_eq!(seen, 7);
///
/// // resumable: restart a pass from column 5
/// reader.seek_to_col(5)?;
/// assert_eq!(reader.next_chunk()?.unwrap().start_col(), 5);
/// std::fs::remove_dir_all(&dir)?;
/// # Ok::<(), pds::Error>(())
/// ```
pub struct SparseStoreReader {
    dir: PathBuf,
    manifest: StoreManifest,
    /// Index of the shard the cursor is in.
    shard: usize,
    /// Columns of that shard already consumed.
    col_in_shard: usize,
    /// Open handle on the current shard (checksum already verified).
    handle: Option<File>,
    /// Max columns per returned chunk.
    chunk_cols: usize,
    /// Verify shard checksums on open (and chunk structure on read).
    verify: bool,
}

impl SparseStoreReader {
    /// Open a completed store (requires `manifest.pdsm`; a writer that
    /// never finished leaves none).
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = StoreManifest::load(dir)?;
        let chunk_cols = manifest.shard_cols.max(1);
        Ok(SparseStoreReader {
            dir: dir.to_path_buf(),
            manifest,
            shard: 0,
            col_in_shard: 0,
            handle: None,
            chunk_cols,
            verify: true,
        })
    }

    /// Cap the heap held by any returned chunk to roughly `bytes`
    /// (12 bytes per kept entry), never below one column. Shards larger
    /// than the budget are streamed in column slices.
    ///
    /// This bounds what the *reader* hands out per call; a consumer that
    /// retains chunks (e.g. the K-means fit, which iterates over all
    /// samples) still accumulates the full compressed size. The budget
    /// is sized on the **in-RAM** chunk — whose values are always `f64`
    /// regardless of the store's precision — not the (possibly smaller)
    /// on-disk bytes.
    pub fn with_memory_budget(mut self, bytes: usize) -> Self {
        let per_col = (self.manifest.m * 12).max(1);
        self.chunk_cols = (bytes / per_col).max(1);
        self
    }

    /// Enable/disable checksum + structural verification (on by default;
    /// turning it off skips the extra read pass per shard).
    pub fn with_verify(mut self, verify: bool) -> Self {
        self.verify = verify;
        self
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &StoreManifest {
        &self.manifest
    }

    /// Rebuild the [`Sparsifier`] this store was written with — including
    /// its element-sampling scheme, so downstream consumers pick the
    /// matching estimator calibration — and check it against the
    /// manifest's recorded shape.
    pub fn sparsifier(&self) -> Result<Sparsifier> {
        let sp = Sparsifier::with_scheme(
            self.manifest.p_orig,
            self.manifest.sparsify_config(),
            self.manifest.scheme,
        )?;
        if sp.p() != self.manifest.p || sp.m() != self.manifest.m {
            return corrupt(format!(
                "manifest inconsistent: config rebuilds to p={} m={}, manifest records p={} m={}",
                sp.p(),
                sp.m(),
                self.manifest.p,
                self.manifest.m
            ));
        }
        Ok(sp)
    }

    /// Global column index the next [`next_chunk`](Self::next_chunk) will
    /// start at (`n` when the pass is exhausted).
    pub fn position(&self) -> usize {
        match self.manifest.shards.get(self.shard) {
            Some(s) => s.start_col + self.col_in_shard,
            None => self.manifest.end_col(),
        }
    }

    /// Resume a pass at global column `col` (within the store's column
    /// range — `[0, n]` for a whole store, the group piece's global range
    /// for a split piece; the range's end positions at end-of-pass). This
    /// is the crash-resume hook: a
    /// consumer that checkpoints [`position`](Self::position) can
    /// continue without rereading earlier shards.
    pub fn seek_to_col(&mut self, col: usize) -> Result<()> {
        self.handle = None;
        if col == self.manifest.end_col() {
            self.shard = self.manifest.shards.len();
            self.col_in_shard = 0;
            return Ok(());
        }
        let Some(idx) = self.manifest.shard_for_col(col) else {
            return invalid(format!(
                "seek_to_col: column {col} out of range (store holds columns [{}, {}))",
                self.manifest.start_col(),
                self.manifest.end_col()
            ));
        };
        self.shard = idx;
        self.col_in_shard = col - self.manifest.shards[idx].start_col;
        Ok(())
    }

    /// Restart from column 0 (a fresh pass).
    pub fn rewind(&mut self) {
        self.shard = 0;
        self.col_in_shard = 0;
        self.handle = None;
    }

    /// Pull the next chunk (at most the memory budget's worth of
    /// columns); `None` ends the pass.
    pub fn next_chunk(&mut self) -> Result<Option<SparseChunk>> {
        loop {
            if self.shard >= self.manifest.shards.len() {
                return Ok(None);
            }
            let (n_cols, start_col) = {
                let e = &self.manifest.shards[self.shard];
                (e.n_cols, e.start_col)
            };
            if self.col_in_shard >= n_cols {
                self.shard += 1;
                self.col_in_shard = 0;
                self.handle = None;
                continue;
            }
            if self.handle.is_none() {
                self.open_shard()?;
            }
            let m = self.manifest.m;
            let vb = self.manifest.precision.val_bytes();
            let a = self.col_in_shard;
            let b = (a + self.chunk_cols).min(n_cols);
            let cols = b - a;
            let Some(f) = self.handle.as_mut() else {
                // unreachable: open_shard() just populated the handle,
                // but a typed error beats a panic if that ever changes
                return corrupt(format!("shard {}: handle lost after open", self.shard));
            };
            // indices block, then values block (two seeks because the
            // blocks are contiguous per shard, not interleaved)
            f.seek(SeekFrom::Start(crate::convert::usize_to_u64(SHARD_HEADER_LEN + a * m * 4)))?;
            let mut ibuf = vec![0u8; cols * m * 4];
            f.read_exact(&mut ibuf)?;
            f.seek(SeekFrom::Start(crate::convert::usize_to_u64(
                SHARD_HEADER_LEN + n_cols * m * 4 + a * m * vb,
            )))?;
            let mut vbuf = vec![0u8; cols * m * vb];
            f.read_exact(&mut vbuf)?;
            let indices: Vec<u32> = ibuf
                .chunks_exact(4)
                .map(|q| u32::from_le_bytes([q[0], q[1], q[2], q[3]]))
                .collect();
            // decode to the chunk's in-RAM f64 values; the f32 → f64
            // widening is exact, so every downstream fold runs the same
            // f64 kernels whatever the store precision
            let values: Vec<f64> = match self.manifest.precision {
                Precision::F64 => vbuf
                    .chunks_exact(8)
                    .map(|q| {
                        f64::from_le_bytes([q[0], q[1], q[2], q[3], q[4], q[5], q[6], q[7]])
                    })
                    .collect(),
                Precision::F32 => vbuf
                    .chunks_exact(4)
                    .map(|q| f64::from(f32::from_le_bytes([q[0], q[1], q[2], q[3]])))
                    .collect(),
            };
            self.col_in_shard = b;
            let chunk = SparseChunk::from_raw(self.manifest.p, m, cols, indices, values, start_col + a)?
                .with_precision(self.manifest.precision);
            if self.verify {
                // weighted schemes legally repeat indices (one slot per
                // with-replacement draw); uniform schemes must be
                // strictly sorted
                let structural = if self.manifest.scheme.weighted() {
                    chunk.validate_weighted()
                } else {
                    chunk.validate()
                };
                if let Err(e) = structural {
                    return corrupt(format!("shard {}: invalid chunk structure ({e})", self.shard));
                }
            }
            return Ok(Some(chunk));
        }
    }

    /// Open the current shard: length check, optional CRC pass, header
    /// validation against the manifest.
    fn open_shard(&mut self) -> Result<()> {
        let entry = &self.manifest.shards[self.shard];
        let path = self.dir.join(&entry.file);
        let m = self.manifest.m;
        let per_entry = 4 + self.manifest.precision.val_bytes();
        let expected_len =
            crate::convert::usize_to_u64(SHARD_HEADER_LEN + entry.n_cols * m * per_entry);
        let meta = std::fs::metadata(&path).map_err(|e| {
            Error::Corrupt(format!("{}: missing shard file ({e})", path.display()))
        })?;
        if meta.len() != expected_len {
            return corrupt(format!(
                "{}: truncated or oversized shard ({} bytes, expected {expected_len})",
                path.display(),
                meta.len()
            ));
        }
        let mut f = File::open(&path)?;
        if self.verify {
            let mut crc = Crc32::new();
            let mut buf = vec![0u8; 64 * 1024];
            loop {
                let got = f.read(&mut buf)?;
                if got == 0 {
                    break;
                }
                crc.update(&buf[..got]);
            }
            if crc.finish() != entry.crc32 {
                return corrupt(format!(
                    "{}: checksum mismatch (computed {:08x}, manifest {:08x})",
                    path.display(),
                    crc.finish(),
                    entry.crc32
                ));
            }
            f.seek(SeekFrom::Start(0))?;
        }
        let mut header = [0u8; SHARD_HEADER_LEN];
        f.read_exact(&mut header)?;
        if &header[0..4] != SHARD_MAGIC {
            return corrupt(format!("{}: bad shard magic", path.display()));
        }
        let u32_at = |off: usize| u32::from_le_bytes([header[off], header[off + 1], header[off + 2], header[off + 3]]);
        let version = u32_at(4);
        let expected_version = match self.manifest.precision {
            Precision::F64 => SHARD_VERSION,
            Precision::F32 => SHARD_VERSION_F32,
        };
        if version != expected_version {
            return corrupt(format!(
                "{}: shard version {version} does not match the manifest's {} precision \
                 (expected {expected_version})",
                path.display(),
                self.manifest.precision.name()
            ));
        }
        let (hp, hm, hn) = (
            crate::convert::u32_to_usize(u32_at(8)),
            crate::convert::u32_to_usize(u32_at(12)),
            crate::convert::u32_to_usize(u32_at(16)),
        );
        let hstart_raw = u64::from_le_bytes([
            header[20], header[21], header[22], header[23], header[24], header[25], header[26],
            header[27],
        ]);
        // a start_col past usize::MAX cannot index any in-RAM store on
        // this target: typed Corrupt, not a silent wrap
        let hstart = crate::convert::u64_to_usize(hstart_raw, "shard header start_col")?;
        if hp != self.manifest.p
            || hm != m
            || hn != entry.n_cols
            || hstart != entry.start_col
        {
            return corrupt(format!(
                "{}: shard header (p={hp} m={hm} n={hn} start={hstart}) disagrees with manifest \
                 (p={} m={m} n={} start={})",
                path.display(),
                self.manifest.p,
                entry.n_cols,
                entry.start_col
            ));
        }
        self.handle = Some(f);
        Ok(())
    }
}

impl SparseChunkSource for SparseStoreReader {
    fn p(&self) -> usize {
        self.manifest.p
    }

    fn m(&self) -> usize {
        self.manifest.m
    }

    fn n_hint(&self) -> Option<usize> {
        Some(self.manifest.n)
    }

    fn next_chunk(&mut self) -> Result<Option<SparseChunk>> {
        SparseStoreReader::next_chunk(self)
    }

    fn reset(&mut self) -> Result<()> {
        self.rewind();
        Ok(())
    }

    fn precision(&self) -> Precision {
        self.manifest.precision
    }
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;
    use crate::coordinator::{compress_stream, MatSource, StreamConfig};
    use crate::error::Error;
    use crate::linalg::Mat;
    use crate::metrics::Timer;
    use crate::rng::Pcg64;
    use crate::sampling::SparsifyConfig;
    use crate::store::{SparseStoreWriter, MANIFEST_FILE};
    use crate::testing::prop::forall;
    use crate::transform::TransformKind;

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("pds_store_mod_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    /// Compress `x` through the full pipeline into a store at `dir`.
    fn write_store(
        dir: &PathBuf,
        x: &Mat,
        scfg: SparsifyConfig,
        chunk_cols: usize,
        shard_cols: usize,
        workers: usize,
    ) -> StoreManifest {
        let sp = Sparsifier::new(x.rows(), scfg).unwrap();
        let mut writer =
            SparseStoreWriter::create(dir, &sp, scfg, true, shard_cols).unwrap();
        let mut src = MatSource::new(x, chunk_cols);
        let mut timer = Timer::new();
        let cfg = StreamConfig { workers, queue_depth: 2, chunk_cols, ..Default::default() };
        let mut sink = |c: SparseChunk| writer.append(c);
        compress_stream(&mut src, &sp, cfg, true, &mut sink, &mut timer).unwrap();
        writer.finish().unwrap()
    }

    /// Every file in `dir`, as (name, bytes), sorted by name.
    fn dir_bytes(dir: &PathBuf) -> Vec<(String, Vec<u8>)> {
        let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| {
                let e = e.unwrap();
                (
                    e.file_name().to_string_lossy().into_owned(),
                    std::fs::read(e.path()).unwrap(),
                )
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    #[test]
    fn roundtrip_property_random_shapes_and_budgets() {
        forall("store_roundtrip", 12, |g| {
            let p = 1usize << g.int(3, 6); // 8..64
            let n = g.int(5, 120) as usize;
            let gamma = g.float(0.1, 0.8);
            let chunk_cols = g.int(1, 40) as usize;
            let shard_cols = g.int(1, 50) as usize;
            let seed = g.int(0, 1 << 30) as u64;
            let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
            let mut rng = Pcg64::seed(seed ^ 0xABCD);
            let x = Mat::from_fn(p, n, |_, _| rng.normal());
            let sp = Sparsifier::new(p, scfg).unwrap();
            let direct = sp.compress_chunk(&x, 0).unwrap();

            let dir = tmpdir(&format!("prop_{}", g.case));
            let manifest = write_store(&dir, &x, scfg, chunk_cols, shard_cols, 1);
            assert_eq!(manifest.n, n);
            assert_eq!(manifest.m, sp.m());

            // read back under a random memory budget, compare bit-exactly
            let budget_cols = g.int(1, 30) as usize;
            let mut reader = SparseStoreReader::open(&dir)
                .unwrap()
                .with_memory_budget(budget_cols * sp.m() * 12);
            let mut col = 0usize;
            while let Some(chunk) = reader.next_chunk().unwrap() {
                assert_eq!(chunk.start_col(), col);
                for i in 0..chunk.n() {
                    assert_eq!(chunk.col_indices(i), direct.col_indices(col + i));
                    let got = chunk.col_values(i);
                    let want = direct.col_values(col + i);
                    for (a, b) in got.iter().zip(want) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
                col += chunk.n();
            }
            assert_eq!(col, n);
            std::fs::remove_dir_all(&dir).ok();
        });
    }

    #[test]
    fn store_bytes_are_worker_count_invariant() {
        let p = 32;
        let n = 157; // awkward: not a multiple of chunk or shard size
        let scfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 9 };
        let mut rng = Pcg64::seed(4);
        let x = Mat::from_fn(p, n, |_, _| rng.normal());
        let dir1 = tmpdir("workers1");
        let dir4 = tmpdir("workers4");
        write_store(&dir1, &x, scfg, 13, 29, 1);
        write_store(&dir4, &x, scfg, 13, 29, 4);
        let a = dir_bytes(&dir1);
        let b = dir_bytes(&dir4);
        assert_eq!(a.len(), b.len());
        for ((na, ba), (nb, bb)) in a.iter().zip(&b) {
            assert_eq!(na, nb);
            assert_eq!(ba, bb, "file {na} differs between worker counts");
        }
        std::fs::remove_dir_all(&dir1).ok();
        std::fs::remove_dir_all(&dir4).ok();
    }

    fn small_store(name: &str) -> (PathBuf, StoreManifest) {
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 2 };
        let mut rng = Pcg64::seed(8);
        let x = Mat::from_fn(16, 25, |_, _| rng.normal());
        let dir = tmpdir(name);
        let manifest = write_store(&dir, &x, scfg, 7, 10, 1);
        (dir, manifest)
    }

    fn read_all(reader: &mut SparseStoreReader) -> Result<usize> {
        let mut cols = 0;
        while let Some(c) = reader.next_chunk()? {
            cols += c.n();
        }
        Ok(cols)
    }

    #[test]
    fn truncated_shard_is_a_typed_error() {
        let (dir, manifest) = small_store("truncated");
        let shard = dir.join(&manifest.shards[1].file);
        let bytes = std::fs::read(&shard).unwrap();
        std::fs::write(&shard, &bytes[..bytes.len() - 5]).unwrap();
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        match read_all(&mut reader) {
            Err(Error::Corrupt(msg)) => assert!(msg.contains("truncated"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn flipped_byte_fails_the_checksum() {
        let (dir, manifest) = small_store("badcrc");
        let shard = dir.join(&manifest.shards[0].file);
        let mut bytes = std::fs::read(&shard).unwrap();
        let at = bytes.len() - 3; // deep in the values block
        bytes[at] ^= 0x40;
        std::fs::write(&shard, &bytes).unwrap();
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        match read_all(&mut reader) {
            Err(Error::Corrupt(msg)) => assert!(msg.contains("checksum"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        // with verification off the corruption goes undetected (documented
        // trade-off) but still reads without panicking
        let mut unchecked = SparseStoreReader::open(&dir).unwrap().with_verify(false);
        assert_eq!(read_all(&mut unchecked).unwrap(), 25);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tampered_header_start_col_is_a_typed_error() {
        // regression: the header start_col used to flow through bare
        // casts; a disagreement with the manifest must surface as a
        // typed Corrupt even with the CRC pass disabled, never a panic
        // or a silently misplaced chunk
        let (dir, manifest) = small_store("tampered-start");
        let shard = dir.join(&manifest.shards[1].file);
        let mut bytes = std::fs::read(&shard).unwrap();
        bytes[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&shard, &bytes).unwrap();
        let mut reader = SparseStoreReader::open(&dir).unwrap().with_verify(false);
        match read_all(&mut reader) {
            Err(Error::Corrupt(msg)) => assert!(
                msg.contains("start_col") || msg.contains("disagrees"),
                "{msg}"
            ),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tampered_header_version_is_a_typed_error() {
        let (dir, manifest) = small_store("tampered-version");
        let shard = dir.join(&manifest.shards[0].file);
        let mut bytes = std::fs::read(&shard).unwrap();
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&shard, &bytes).unwrap();
        let mut reader = SparseStoreReader::open(&dir).unwrap().with_verify(false);
        match read_all(&mut reader) {
            Err(Error::Corrupt(msg)) => assert!(msg.contains("version"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn tampered_manifest_m_is_a_typed_error() {
        let (dir, _) = small_store("badm");
        let mpath = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&mpath).unwrap();
        // m = 8 for p=16, gamma=0.5; shard sizes stop matching under m=7
        std::fs::write(&mpath, text.replace("m = 8", "m = 7")).unwrap();
        match SparseStoreReader::open(&dir) {
            Ok(mut reader) => match read_all(&mut reader) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("expected Corrupt, got {other:?}"),
            },
            Err(Error::Corrupt(_)) => {}
            Err(other) => panic!("expected Corrupt, got {other}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_shard_file_is_a_typed_error() {
        let (dir, manifest) = small_store("missing");
        std::fs::remove_file(dir.join(&manifest.shards[2].file)).unwrap();
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        match read_all(&mut reader) {
            Err(Error::Corrupt(msg)) => assert!(msg.contains("missing"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unfinished_store_is_invisible_to_readers() {
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 2 };
        let sp = Sparsifier::new(16, scfg).unwrap();
        let dir = tmpdir("unfinished");
        let mut rng = Pcg64::seed(1);
        let x = Mat::from_fn(16, 12, |_, _| rng.normal());
        let mut writer = SparseStoreWriter::create(&dir, &sp, scfg, true, 4).unwrap();
        writer.append(sp.compress_chunk(&x, 0).unwrap()).unwrap();
        // no finish(): shards exist, manifest does not
        assert!(SparseStoreReader::open(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gap_in_stream_fails_finish() {
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 3 };
        let sp = Sparsifier::new(8, scfg).unwrap();
        let dir = tmpdir("gap");
        let mut rng = Pcg64::seed(2);
        let x = Mat::from_fn(8, 10, |_, _| rng.normal());
        let mut writer = SparseStoreWriter::create(&dir, &sp, scfg, true, 4).unwrap();
        // append columns 5.. but never 0..5
        writer
            .append(sp.compress_chunk(&x.col_range(5, 10), 5).unwrap())
            .unwrap();
        match writer.finish() {
            Err(Error::Invalid(msg)) => assert!(msg.contains("gap"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn out_of_order_appends_reorder_deterministically() {
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 4 };
        let sp = Sparsifier::new(8, scfg).unwrap();
        let mut rng = Pcg64::seed(3);
        let x = Mat::from_fn(8, 20, |_, _| rng.normal());
        let c0 = sp.compress_chunk(&x.col_range(0, 6), 0).unwrap();
        let c1 = sp.compress_chunk(&x.col_range(6, 13), 6).unwrap();
        let c2 = sp.compress_chunk(&x.col_range(13, 20), 13).unwrap();

        let dir_fwd = tmpdir("order_fwd");
        let mut w = SparseStoreWriter::create(&dir_fwd, &sp, scfg, true, 9).unwrap();
        for c in [c0.clone(), c1.clone(), c2.clone()] {
            w.append(c).unwrap();
        }
        w.finish().unwrap();

        let dir_rev = tmpdir("order_rev");
        let mut w = SparseStoreWriter::create(&dir_rev, &sp, scfg, true, 9).unwrap();
        for c in [c2, c0, c1] {
            w.append(c).unwrap();
        }
        w.finish().unwrap();

        assert_eq!(dir_bytes(&dir_fwd), dir_bytes(&dir_rev));
        std::fs::remove_dir_all(&dir_fwd).ok();
        std::fs::remove_dir_all(&dir_rev).ok();
    }

    #[test]
    fn writer_rejects_overlap_duplicate_and_bad_shape() {
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 5 };
        let sp = Sparsifier::new(8, scfg).unwrap();
        let dir = tmpdir("rejects");
        let mut rng = Pcg64::seed(5);
        let x = Mat::from_fn(8, 10, |_, _| rng.normal());
        let mut writer = SparseStoreWriter::create(&dir, &sp, scfg, true, 16).unwrap();
        writer.append(sp.compress_chunk(&x.col_range(0, 6), 0).unwrap()).unwrap();
        // overlap: starts inside already-written data
        let overlap = sp.compress_chunk(&x.col_range(3, 8), 3).unwrap();
        assert!(matches!(writer.append(overlap), Err(Error::Invalid(_))));
        // duplicate pending start
        let ahead = sp.compress_chunk(&x.col_range(8, 10), 8).unwrap();
        writer.append(ahead.clone()).unwrap();
        assert!(matches!(writer.append(ahead), Err(Error::Invalid(_))));
        // range overlap with a parked chunk (would otherwise surface as a
        // misleading gap error at finish)
        let into_parked = sp.compress_chunk(&x.col_range(6, 9), 6).unwrap();
        match writer.append(into_parked) {
            Err(Error::Invalid(msg)) => assert!(msg.contains("overlaps pending"), "{msg}"),
            other => panic!("expected Invalid overlap, got {other:?}"),
        }
        // wrong shape
        let other = Sparsifier::new(
            16,
            SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 5 },
        )
        .unwrap();
        let bad = other.compress_chunk(&Mat::zeros(16, 2), 6).unwrap();
        assert!(matches!(writer.append(bad), Err(Error::Shape(_))));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn create_refuses_to_clobber_a_finished_store() {
        let (dir, _) = small_store("clobber");
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 2 };
        let sp = Sparsifier::new(16, scfg).unwrap();
        assert!(matches!(
            SparseStoreWriter::create(&dir, &sp, scfg, true, 4),
            Err(Error::Invalid(_))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn seek_and_position_resume_mid_pass() {
        let (dir, _) = small_store("resume");
        let mut full = SparseStoreReader::open(&dir).unwrap();
        let mut all: Vec<(Vec<u32>, Vec<u64>)> = Vec::new();
        while let Some(c) = full.next_chunk().unwrap() {
            for i in 0..c.n() {
                all.push((
                    c.col_indices(i).to_vec(),
                    c.col_values(i).iter().map(|v| v.to_bits()).collect(),
                ));
            }
        }
        assert_eq!(all.len(), 25);
        assert_eq!(full.position(), 25);

        // resume at an arbitrary column, mid-shard
        let mut resumed = SparseStoreReader::open(&dir).unwrap();
        resumed.seek_to_col(13).unwrap();
        assert_eq!(resumed.position(), 13);
        let mut col = 13usize;
        while let Some(c) = resumed.next_chunk().unwrap() {
            assert_eq!(c.start_col(), col);
            for i in 0..c.n() {
                assert_eq!(c.col_indices(i), &all[col + i].0[..]);
            }
            col += c.n();
        }
        assert_eq!(col, 25);
        // seek to the very end is legal; past it is not
        resumed.seek_to_col(25).unwrap();
        assert!(resumed.next_chunk().unwrap().is_none());
        assert!(resumed.seek_to_col(26).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn f64_store_emits_v2_manifest_and_v1_shards() {
        // the precision axis must not disturb f64 stores: lowest capable
        // version on disk, no precision key, 8-byte values, f64 chunks
        let (dir, manifest) = small_store("f64_compat");
        assert_eq!(manifest.version, 2);
        assert_eq!(manifest.precision, Precision::F64);
        let text = std::fs::read_to_string(dir.join(MANIFEST_FILE)).unwrap();
        assert!(!text.contains("precision"), "{text}");
        let shard = std::fs::read(dir.join(&manifest.shards[0].file)).unwrap();
        assert_eq!(u32::from_le_bytes([shard[4], shard[5], shard[6], shard[7]]), 1);
        assert_eq!(shard.len(), SHARD_HEADER_LEN + 10 * manifest.m * 12);
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        assert_eq!(SparseChunkSource::precision(&reader), Precision::F64);
        let c = reader.next_chunk().unwrap().unwrap();
        assert_eq!(c.precision(), Precision::F64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn f32_store_roundtrips_quantized_values() {
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 12 };
        let sp = Sparsifier::new(16, scfg).unwrap();
        let mut rng = Pcg64::seed(13);
        let x = Mat::from_fn(16, 25, |_, _| rng.normal());
        let direct = sp.compress_chunk(&x, 0).unwrap();
        let dir = tmpdir("f32_roundtrip");
        let mut writer = SparseStoreWriter::create(&dir, &sp, scfg, true, 10)
            .unwrap()
            .with_precision(Precision::F32);
        writer.append(direct.clone()).unwrap();
        let manifest = writer.finish().unwrap();

        // v3 manifest + v2 shards, value block at 4 bytes/entry
        assert_eq!(manifest.version, 3);
        assert_eq!(manifest.precision, Precision::F32);
        assert_eq!(manifest.payload_bytes(), (25 * manifest.m * 8) as u64);
        let shard = std::fs::read(dir.join(&manifest.shards[0].file)).unwrap();
        assert_eq!(u32::from_le_bytes([shard[4], shard[5], shard[6], shard[7]]), 2);
        assert_eq!(shard.len(), SHARD_HEADER_LEN + 10 * manifest.m * 8);

        // read back (under a budget, to cross the value-seek path):
        // indices bit-exact, values exactly the f32 quantization of the
        // originals, chunk marked f32
        let want = direct.clone().with_precision(Precision::F32);
        let mut reader = SparseStoreReader::open(&dir)
            .unwrap()
            .with_memory_budget(4 * manifest.m * 12);
        assert_eq!(SparseChunkSource::precision(&reader), Precision::F32);
        let mut col = 0usize;
        while let Some(chunk) = reader.next_chunk().unwrap() {
            assert_eq!(chunk.precision(), Precision::F32);
            assert_eq!(chunk.start_col(), col);
            for i in 0..chunk.n() {
                assert_eq!(chunk.col_indices(i), want.col_indices(col + i));
                for (a, b) in chunk.col_values(i).iter().zip(want.col_values(col + i)) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            col += chunk.n();
        }
        assert_eq!(col, 25);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_store_roundtrips() {
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 6 };
        let sp = Sparsifier::new(8, scfg).unwrap();
        let dir = tmpdir("empty");
        let writer = SparseStoreWriter::create(&dir, &sp, scfg, true, 4).unwrap();
        let manifest = writer.finish().unwrap();
        assert_eq!(manifest.n, 0);
        assert!(manifest.shards.is_empty());
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        assert!(reader.next_chunk().unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
