//! Split a sparse store into shard-group pieces and re-join them.
//!
//! A store is a directory of immutable, globally-indexed shard files plus
//! a manifest, so distribution is pure bookkeeping: [`split_store`] deals
//! a contiguous run of shards to each destination directory (shard files
//! copied **byte-identical**, checksum-verified in transit) and writes
//! each piece a v4 manifest whose `group` key records where the piece
//! sits in the whole; [`join_stores`] verifies the pieces form exactly
//! one whole store and reassembles it — byte-identical to the store that
//! was split. Each piece is a complete, independently readable store
//! ([`SparseStoreReader`](super::SparseStoreReader) streams it over its
//! own global column range), which is what lets N workers fit their
//! shard ranges from N directories and merge the partials
//! ([`distributed`](crate::distributed)).

use std::fs::File;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::error::{corrupt, invalid, Error, Result};

use super::manifest::{ShardGroup, StoreManifest, MANIFEST_FILE};
use super::Crc32;

/// Copy one shard file, verifying its CRC-32 against the manifest entry
/// in transit (a damaged source surfaces here, not at first read).
fn copy_shard_checked(src: &Path, dest: &Path, want_crc: u32) -> Result<()> {
    let mut from = File::open(src)
        .map_err(|e| Error::Corrupt(format!("{}: missing shard file ({e})", src.display())))?;
    let mut to = File::create(dest)?;
    let mut crc = Crc32::new();
    let mut buf = vec![0u8; 64 * 1024];
    loop {
        let got = from.read(&mut buf)?;
        if got == 0 {
            break;
        }
        crc.update(&buf[..got]);
        to.write_all(&buf[..got])?;
    }
    to.sync_all()?;
    if crc.finish() != want_crc {
        return corrupt(format!(
            "{}: checksum mismatch while copying (computed {:08x}, manifest {want_crc:08x})",
            src.display(),
            crc.finish()
        ));
    }
    Ok(())
}

/// Refuse to write into a directory that already holds a finished store.
fn ensure_fresh_dir(dir: &Path) -> Result<()> {
    if dir.join(MANIFEST_FILE).exists() {
        return invalid(format!(
            "{}: refusing to overwrite an existing store",
            dir.display()
        ));
    }
    std::fs::create_dir_all(dir)?;
    Ok(())
}

/// Split the store at `src` into `dests.len()` shard-group pieces, one
/// per destination directory, dealing the shard table into contiguous
/// near-equal runs. Shard files are copied byte-identical (and
/// checksum-verified in transit); each piece gets a manifest whose
/// `group` key records its place, so [`join_stores`] — or any reader —
/// can tell the pieces apart and put them back together. Returns the
/// piece manifests in group order.
///
/// Splitting into one piece degenerates to a verified copy of the store.
pub fn split_store(src: &Path, dests: &[PathBuf]) -> Result<Vec<StoreManifest>> {
    let manifest = StoreManifest::load(src)?;
    if !manifest.group.is_standalone() {
        return invalid(format!(
            "{}: already a shard-group piece ({} of {}); join before re-splitting",
            src.display(),
            manifest.group.index,
            manifest.group.count
        ));
    }
    let k = dests.len();
    if k == 0 {
        return invalid("split_store: need at least one destination");
    }
    if k > manifest.shards.len() {
        return invalid(format!(
            "cannot split {} shards into {k} groups (each piece needs at least one shard)",
            manifest.shards.len()
        ));
    }
    for dest in dests {
        ensure_fresh_dir(dest)?;
    }
    // deal the shard table into contiguous near-equal runs
    let base = manifest.shards.len() / k;
    let rem = manifest.shards.len() % k;
    let mut out = Vec::with_capacity(k);
    let mut next = 0usize;
    for (i, dest) in dests.iter().enumerate() {
        let take = base + usize::from(i < rem);
        let shards = manifest.shards[next..next + take].to_vec();
        next += take;
        for s in &shards {
            copy_shard_checked(&src.join(&s.file), &dest.join(&s.file), s.crc32)?;
        }
        let n: usize = shards.iter().map(|s| s.n_cols).sum();
        let group = if k == 1 {
            ShardGroup::standalone(manifest.n)
        } else {
            ShardGroup {
                index: i,
                count: k,
                start_col: shards[0].start_col,
                total_n: manifest.n,
            }
        };
        let piece = StoreManifest {
            // groups need v4; a single-piece "split" is just a copy and
            // keeps the source's (lowest capable) version
            version: if k == 1 { manifest.version } else { 4 },
            n,
            group,
            shards,
            ..manifest.clone()
        };
        piece.validate()?;
        piece.write_atomic(dest)?;
        out.push(piece);
    }
    Ok(out)
}

/// Re-join shard-group pieces into one whole store at `dest`. The pieces
/// may be given in any order; they must share a configuration and form
/// exactly one complete group (every index present once, columns
/// contiguous from 0 to the group total). Shard files are copied
/// byte-identical and checksum-verified, and the joined manifest is
/// written at the store's lowest capable version — so joining what
/// [`split_store`] produced reconstructs the original store
/// byte-for-byte.
pub fn join_stores(srcs: &[PathBuf], dest: &Path) -> Result<StoreManifest> {
    if srcs.is_empty() {
        return invalid("join_stores: need at least one source");
    }
    let mut pieces: Vec<(PathBuf, StoreManifest)> = Vec::with_capacity(srcs.len());
    for src in srcs {
        pieces.push((src.clone(), StoreManifest::load(src)?));
    }
    let first = &pieces[0].1;
    for (dir, m) in &pieces[1..] {
        let same = m.p == first.p
            && m.p_orig == first.p_orig
            && m.m == first.m
            && m.gamma.to_bits() == first.gamma.to_bits()
            && m.transform == first.transform
            && m.seed == first.seed
            && m.preconditioned == first.preconditioned
            && m.scheme == first.scheme
            && m.precision == first.precision
            && m.shard_cols == first.shard_cols;
        if !same {
            return invalid(format!(
                "{}: store configuration differs from {} (cannot join stores that were \
                 not split from the same store)",
                dir.display(),
                pieces[0].0.display()
            ));
        }
        if m.group.count != first.group.count || m.group.total_n != first.group.total_n {
            return invalid(format!(
                "{}: group shape {} of {} ({} cols) differs from {} of {} ({} cols)",
                dir.display(),
                m.group.index,
                m.group.count,
                m.group.total_n,
                first.group.index,
                first.group.count,
                first.group.total_n
            ));
        }
    }
    if pieces.len() != first.group.count {
        return invalid(format!(
            "join_stores: got {} pieces of a {}-piece group",
            pieces.len(),
            first.group.count
        ));
    }
    pieces.sort_by_key(|(_, m)| m.group.index);
    let mut expected_start = 0usize;
    for (i, (dir, m)) in pieces.iter().enumerate() {
        if m.group.index != i {
            return invalid(format!(
                "join_stores: group piece {i} is {} (duplicate or missing piece)",
                if m.group.index < i { "duplicated" } else { "missing" }
            ));
        }
        if m.group.start_col != expected_start {
            return invalid(format!(
                "{}: piece {i} starts at column {} (expected {expected_start})",
                dir.display(),
                m.group.start_col
            ));
        }
        expected_start += m.n;
    }
    if expected_start != first.group.total_n {
        return invalid(format!(
            "join_stores: pieces cover {expected_start} cols but the group holds {}",
            first.group.total_n
        ));
    }
    ensure_fresh_dir(dest)?;
    let mut shards = Vec::new();
    for (dir, m) in &pieces {
        for s in &m.shards {
            copy_shard_checked(&dir.join(&s.file), &dest.join(&s.file), s.crc32)?;
            shards.push(s.clone());
        }
    }
    let joined = StoreManifest {
        // lowest capable version, matching what the writer would emit —
        // join(split(store)) is byte-identical to the original store
        version: if first.precision == crate::sparse::Precision::F32 { 3 } else { 2 },
        n: first.group.total_n,
        group: ShardGroup::standalone(first.group.total_n),
        shards,
        ..first.clone()
    };
    joined.validate()?;
    joined.write_atomic(dest)?;
    Ok(joined)
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::sampling::{Sparsifier, SparsifyConfig};
    use crate::store::{SparseStoreReader, SparseStoreWriter};
    use crate::transform::TransformKind;

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("pds_group_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    /// A finished 3-shard store (25 columns, shard_cols = 10).
    fn build_store(name: &str, seed: u64) -> PathBuf {
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed };
        let sp = Sparsifier::new(16, scfg).unwrap();
        let mut rng = Pcg64::seed(seed ^ 0x5EED);
        let x = Mat::from_fn(16, 25, |_, _| rng.normal());
        let dir = tmpdir(name);
        let mut writer = SparseStoreWriter::create(&dir, &sp, scfg, true, 10).unwrap();
        writer.append(sp.compress_chunk(&x, 0).unwrap()).unwrap();
        writer.finish().unwrap();
        dir
    }

    fn dir_bytes(dir: &Path) -> Vec<(String, Vec<u8>)> {
        let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| {
                let e = e.unwrap();
                (e.file_name().to_string_lossy().into_owned(), std::fs::read(e.path()).unwrap())
            })
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    #[test]
    fn split_join_round_trip_is_byte_identical() {
        let src = build_store("roundtrip", 7);
        let original = dir_bytes(&src);
        for k in 1..=3usize {
            let dests: Vec<PathBuf> =
                (0..k).map(|i| tmpdir(&format!("rt_{k}_piece{i}"))).collect();
            let pieces = split_store(&src, &dests).unwrap();
            assert_eq!(pieces.len(), k);
            // every piece is a complete, readable store over its range
            let mut covered = 0usize;
            for (dest, piece) in dests.iter().zip(&pieces) {
                let mut reader = SparseStoreReader::open(dest).unwrap();
                assert_eq!(reader.manifest().group, piece.group);
                let mut col = piece.start_col();
                while let Some(c) = reader.next_chunk().unwrap() {
                    assert_eq!(c.start_col(), col);
                    col += c.n();
                }
                assert_eq!(col, piece.end_col());
                covered += piece.n;
            }
            assert_eq!(covered, 25);

            // join (in scrambled order) reconstructs the original bytes
            let mut scrambled = dests.clone();
            scrambled.reverse();
            let joined = tmpdir(&format!("rt_{k}_joined"));
            let manifest = join_stores(&scrambled, &joined).unwrap();
            assert_eq!(manifest.n, 25);
            assert!(manifest.group.is_standalone());
            assert_eq!(dir_bytes(&joined), original, "k = {k}");

            for d in dests.iter().chain([&joined]) {
                std::fs::remove_dir_all(d).ok();
            }
        }
        std::fs::remove_dir_all(&src).ok();
    }

    #[test]
    fn pieces_stream_bitwise_identical_columns() {
        let src = build_store("bitwise", 11);
        let mut whole = SparseStoreReader::open(&src).unwrap();
        let mut cols: Vec<(Vec<u32>, Vec<u64>)> = Vec::new();
        while let Some(c) = whole.next_chunk().unwrap() {
            for i in 0..c.n() {
                cols.push((
                    c.col_indices(i).to_vec(),
                    c.col_values(i).iter().map(|v| v.to_bits()).collect(),
                ));
            }
        }
        let dests = [tmpdir("bw_a"), tmpdir("bw_b")];
        split_store(&src, &dests.to_vec()).unwrap();
        for dest in &dests {
            let mut reader = SparseStoreReader::open(dest).unwrap();
            // a piece also honors seek within its own range
            let start = reader.manifest().start_col();
            reader.seek_to_col(start).unwrap();
            assert!(reader.seek_to_col(26).is_err());
            let mut col = start;
            while let Some(c) = reader.next_chunk().unwrap() {
                for i in 0..c.n() {
                    assert_eq!(c.col_indices(i), &cols[col + i].0[..]);
                    let bits: Vec<u64> = c.col_values(i).iter().map(|v| v.to_bits()).collect();
                    assert_eq!(bits, cols[col + i].1);
                }
                col += c.n();
            }
            assert_eq!(col, reader.manifest().end_col());
        }
        for d in dests.iter().chain([&src]) {
            std::fs::remove_dir_all(d).ok();
        }
    }

    #[test]
    fn join_rejects_wrong_piece_sets() {
        let src = build_store("wrongset", 3);
        let dests = vec![tmpdir("ws_a"), tmpdir("ws_b"), tmpdir("ws_c")];
        split_store(&src, &dests).unwrap();

        // missing piece
        let out = tmpdir("ws_missing");
        assert!(matches!(
            join_stores(&dests[..2].to_vec(), &out),
            Err(Error::Invalid(_))
        ));
        // duplicate piece
        let dup = vec![dests[0].clone(), dests[1].clone(), dests[1].clone()];
        assert!(matches!(join_stores(&dup, &out), Err(Error::Invalid(_))));

        // a piece from a different store (other seed ⇒ other config)
        let other_src = build_store("wrongset_other", 4);
        let other_dests = vec![tmpdir("ws_oa"), tmpdir("ws_ob"), tmpdir("ws_oc")];
        split_store(&other_src, &other_dests).unwrap();
        let mixed = vec![dests[0].clone(), dests[1].clone(), other_dests[2].clone()];
        match join_stores(&mixed, &out) {
            Err(Error::Invalid(msg)) => assert!(msg.contains("configuration"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }

        for d in dests.iter().chain(&other_dests).chain([&src, &other_src]) {
            std::fs::remove_dir_all(d).ok();
        }
    }

    #[test]
    fn copies_verify_checksums_and_refuse_to_clobber() {
        let src = build_store("ccorrupt", 5);
        // flip a byte deep in a shard: split must surface Corrupt
        let manifest = StoreManifest::load(&src).unwrap();
        let shard = src.join(&manifest.shards[1].file);
        let mut bytes = std::fs::read(&shard).unwrap();
        let last = bytes.len() - 3;
        bytes[last] ^= 0x20;
        std::fs::write(&shard, &bytes).unwrap();
        let dests = vec![tmpdir("cc_a"), tmpdir("cc_b")];
        match split_store(&src, &dests) {
            Err(Error::Corrupt(msg)) => assert!(msg.contains("checksum"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }
        for d in &dests {
            std::fs::remove_dir_all(d).ok();
        }

        // an intact store refuses to split onto an existing store, into
        // zero dests, or into more pieces than shards
        let good = build_store("cc_good", 6);
        let other = build_store("cc_other", 8);
        assert!(matches!(
            split_store(&good, &[other.clone()]),
            Err(Error::Invalid(_))
        ));
        assert!(matches!(split_store(&good, &[]), Err(Error::Invalid(_))));
        let many: Vec<PathBuf> = (0..4).map(|i| tmpdir(&format!("cc_many{i}"))).collect();
        assert!(matches!(split_store(&good, &many), Err(Error::Invalid(_))));

        // splitting a piece again is refused (join first)
        let halves = vec![tmpdir("cc_h0"), tmpdir("cc_h1")];
        split_store(&good, &halves).unwrap();
        let sub = vec![tmpdir("cc_s0")];
        match split_store(&halves[0], &sub) {
            Err(Error::Invalid(msg)) => assert!(msg.contains("already"), "{msg}"),
            other => panic!("expected Invalid, got {other:?}"),
        }
        for d in halves.iter().chain(&sub).chain([&src, &good, &other]) {
            std::fs::remove_dir_all(d).ok();
        }
    }
}
