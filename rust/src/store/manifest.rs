//! The sparse-store manifest: a small text file (`manifest.pdsm`) written
//! last — its presence is what marks a store complete. Line-oriented
//! `key = value` pairs plus one `shard = ...` line per shard, in index
//! order; `docs/FORMAT.md` is the normative spec.

use std::fs::File;
use std::io::Write;
use std::path::Path;

use crate::error::{corrupt, invalid, Error, Result};
use crate::sampling::{Scheme, SparsifyConfig};
use crate::sparse::Precision;
use crate::transform::TransformKind;

/// Manifest file name inside a store directory.
pub const MANIFEST_FILE: &str = "manifest.pdsm";

/// Current manifest schema version. Readers reject greater versions;
/// additive fields do not bump it (unknown keys are ignored on parse).
///
/// * v1 — the original schema (no `scheme` key; every store was
///   uniform-masked, preconditioned or not).
/// * v2 — adds the `scheme` key (`precond | uniform | hybrid`). The bump
///   is semantic, not just additive: `hybrid` shards store
///   importance-weighted with-replacement slots whose indices may
///   repeat, which a v1 reader would mis-validate and mis-estimate. v1
///   manifests are still read (the scheme is inferred from
///   `preconditioned`).
/// * v3 — adds the `precision` key (`f32 | f64`). `f32` stores serialize
///   shard value blocks as little-endian `f32` (shard version 2), which
///   a v2 reader would mis-parse — hence the bump. The writer emits the
///   **lowest capable** version: `f64` stores stay v2 (byte-identical to
///   pre-precision releases); a missing key on read means `f64`.
/// * v4 — adds the `group` key (`<index> <count> <start_col> <total_n>`):
///   the store is one contiguous piece of a larger logical store that was
///   [`split`](super::split_store) across directories. Shard entries keep
///   their **global** indices and start columns (shard files are copied
///   byte-identical), so a group piece's shard walk does not begin at
///   column 0 — which a v3 reader would reject as a gap; hence the bump.
///   Ungrouped stores omit the key and stay at their previous lowest
///   capable version.
const MANIFEST_VERSION: u32 = 4;

/// Per-shard record: boundaries in the global column order plus the
/// CRC-32 of the entire shard file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardEntry {
    /// Shard index (also encoded in the file name).
    pub index: usize,
    /// Global column index of the shard's first sample.
    pub start_col: usize,
    /// Samples in this shard.
    pub n_cols: usize,
    /// CRC-32 (IEEE) of the entire shard file, header included.
    pub crc32: u32,
    /// Shard file name, relative to the store directory.
    pub file: String,
}

/// Shard-group membership (v4): which contiguous piece of a split
/// logical store this manifest describes. Ungrouped stores carry the
/// [`standalone`](Self::standalone) value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardGroup {
    /// This piece's position among the group's pieces.
    pub index: usize,
    /// Total pieces in the group (`1` = a standalone store).
    pub count: usize,
    /// Global column index of this piece's first sample (shard entries
    /// keep global coordinates, so the piece's shard walk starts here).
    pub start_col: usize,
    /// Total samples across the whole logical store.
    pub total_n: usize,
}

impl ShardGroup {
    /// The group value of an ordinary, un-split store holding `n` samples.
    pub fn standalone(n: usize) -> Self {
        ShardGroup { index: 0, count: 1, start_col: 0, total_n: n }
    }

    /// Whether this is the whole logical store (not a split piece).
    pub fn is_standalone(&self) -> bool {
        self.count == 1
    }
}

/// Parsed sparse-store manifest — everything a reader needs to stream
/// the shards back and to rebuild the matching
/// [`Sparsifier`](crate::sampling::Sparsifier).
#[derive(Clone, Debug)]
pub struct StoreManifest {
    /// Manifest schema version (see `docs/FORMAT.md` §versioning).
    pub version: u32,
    /// Working (possibly padded) dimension — the `p` of every chunk.
    pub p: usize,
    /// Original data dimension before Hadamard padding.
    pub p_orig: usize,
    /// Kept entries per sample.
    pub m: usize,
    /// Total samples across all shards.
    pub n: usize,
    /// Configured compression factor γ (exact, shortest-round-trip text).
    pub gamma: f64,
    /// Orthonormal transform of the ROS preconditioner.
    pub transform: TransformKind,
    /// Root seed of the sign diagonal and all sampling masks.
    pub seed: u64,
    /// Whether ROS preconditioning was applied (false = the paper's
    /// no-precondition ablation arm; centers must not be unmixed).
    pub preconditioned: bool,
    /// The element-sampling scheme the chunks were produced with
    /// (v2 key; inferred from `preconditioned` for v1 manifests).
    /// Consumers use it to rebuild the matching sparsifier and to select
    /// the estimator calibration (`Scheme::Hybrid` stores weighted
    /// with-replacement slots).
    pub scheme: Scheme,
    /// Storage precision of the shard value blocks (v3 key; absent —
    /// and hence [`Precision::F64`] — in every earlier version).
    pub precision: Precision,
    /// Target columns per shard; every shard except the last holds
    /// exactly this many.
    pub shard_cols: usize,
    /// Shard-group membership (v4 key; [`ShardGroup::standalone`] when
    /// absent — every earlier version is a whole store).
    pub group: ShardGroup,
    /// Shard table in index order.
    pub shards: Vec<ShardEntry>,
}

impl StoreManifest {
    /// The sparsifier configuration this store was written with.
    pub fn sparsify_config(&self) -> SparsifyConfig {
        SparsifyConfig { gamma: self.gamma, transform: self.transform, seed: self.seed }
    }

    /// Compressed payload bytes across all shards (per kept entry: a
    /// 4-byte `u32` index plus a 4- or 8-byte value depending on
    /// [`precision`](Self::precision)), excluding headers.
    pub fn payload_bytes(&self) -> u64 {
        crate::convert::usize_to_u64(self.n)
            * crate::convert::usize_to_u64(self.m)
            * (4 + crate::convert::usize_to_u64(self.precision.val_bytes()))
    }

    /// Global column index of this store's first sample (`0` unless the
    /// store is a split-group piece).
    pub fn start_col(&self) -> usize {
        self.group.start_col
    }

    /// One past the global column index of this store's last sample.
    pub fn end_col(&self) -> usize {
        self.group.start_col + self.n
    }

    /// Position (into [`shards`](Self::shards)) of the shard containing
    /// global column `col`.
    pub fn shard_for_col(&self, col: usize) -> Option<usize> {
        if col < self.start_col() || col >= self.end_col() || self.shard_cols == 0 {
            return None;
        }
        // fixed stride: every shard but the last holds exactly shard_cols,
        // and a group piece's first shard is stride-aligned (validated)
        let idx = col / self.shard_cols - self.group.start_col / self.shard_cols;
        if idx < self.shards.len() {
            Some(idx)
        } else {
            None
        }
    }

    /// Serialize to the manifest text format.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        out.push_str("# pds sparse store manifest — see docs/FORMAT.md\n");
        out.push_str("format = pdss\n");
        out.push_str(&format!("version = {}\n", self.version));
        out.push_str(&format!("p = {}\n", self.p));
        out.push_str(&format!("p_orig = {}\n", self.p_orig));
        out.push_str(&format!("m = {}\n", self.m));
        out.push_str(&format!("n = {}\n", self.n));
        out.push_str(&format!("gamma = {:?}\n", self.gamma));
        out.push_str(&format!("transform = {}\n", self.transform.name()));
        out.push_str(&format!("seed = {}\n", self.seed));
        out.push_str(&format!("preconditioned = {}\n", self.preconditioned));
        out.push_str(&format!("scheme = {}\n", self.scheme.name()));
        if self.version >= 3 {
            // the key exists from v3 on; emitting it under v2 would
            // break the byte-identity of f64 stores with old releases
            out.push_str(&format!("precision = {}\n", self.precision.name()));
        }
        out.push_str(&format!("shard_cols = {}\n", self.shard_cols));
        if self.version >= 4 {
            // the key exists from v4 on; a v3-or-earlier store is always
            // a whole (standalone) store and stays byte-identical
            let g = &self.group;
            out.push_str(&format!(
                "group = {} {} {} {}\n",
                g.index, g.count, g.start_col, g.total_n
            ));
        }
        out.push_str(&format!("shard_count = {}\n", self.shards.len()));
        for s in &self.shards {
            out.push_str(&format!(
                "shard = {} {} {} {:08x} {}\n",
                s.index, s.start_col, s.n_cols, s.crc32, s.file
            ));
        }
        out
    }

    /// Parse manifest text, then [`validate`](Self::validate).
    pub fn parse(text: &str) -> Result<StoreManifest> {
        let mut kv: Vec<(String, String)> = Vec::new();
        let mut shards: Vec<ShardEntry> = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return corrupt(format!("manifest line {}: no `=`", lineno + 1));
            };
            let (key, value) = (key.trim(), value.trim());
            if key == "shard" {
                shards.push(parse_shard_line(value, lineno + 1)?);
            } else {
                kv.push((key.to_string(), value.to_string()));
            }
        }
        if lookup(&kv, "format")? != "pdss" {
            return corrupt("manifest: format is not `pdss`");
        }
        let version = lookup_u32(&kv, "version")?;
        if version > MANIFEST_VERSION {
            return corrupt(format!(
                "manifest version {version} is newer than supported {MANIFEST_VERSION}"
            ));
        }
        let gamma_text = lookup(&kv, "gamma")?;
        let gamma: f64 = gamma_text
            .parse()
            .map_err(|_| Error::Corrupt(format!("manifest: bad gamma {gamma_text:?}")))?;
        let tname = lookup(&kv, "transform")?;
        let transform = TransformKind::from_name(tname)
            .ok_or_else(|| Error::Corrupt(format!("manifest: unknown transform {tname:?}")))?;
        let preconditioned = match lookup(&kv, "preconditioned")? {
            "true" => true,
            "false" => false,
            other => {
                return corrupt(format!("manifest: bad preconditioned flag {other:?}"));
            }
        };
        let scheme = match kv.iter().find(|(k, _)| k == "scheme") {
            Some((_, v)) => Scheme::parse(v)
                .map_err(|_| Error::Corrupt(format!("manifest: unknown scheme {v:?}")))?,
            // v1 manifests predate the scheme key: every store was
            // uniform-masked, with or without the ROS
            None if version < 2 => {
                if preconditioned {
                    Scheme::Precond
                } else {
                    Scheme::Uniform
                }
            }
            None => return corrupt("manifest: version >= 2 requires a scheme key"),
        };
        let precision = match kv.iter().find(|(k, _)| k == "precision") {
            Some((_, v)) => Precision::parse(v)
                .ok_or_else(|| Error::Corrupt(format!("manifest: unknown precision {v:?}")))?,
            // the key is optional at every version: pre-v3 stores (and
            // v3 writers that chose to omit it) are all f64
            None => Precision::F64,
        };
        let n = lookup_usize(&kv, "n")?;
        let group = match kv.iter().find(|(k, _)| k == "group") {
            Some((_, v)) => parse_group_value(v)?,
            // the key is optional at every version: its absence always
            // means "the whole store"
            None => ShardGroup::standalone(n),
        };
        let shard_count = lookup_usize(&kv, "shard_count")?;
        if shard_count != shards.len() {
            return corrupt(format!(
                "manifest: shard_count {} but {} shard lines",
                shard_count,
                shards.len()
            ));
        }
        let manifest = StoreManifest {
            version,
            // p, p_orig and m are encoded as little-endian u32 in every
            // shard header, so a wider manifest value cannot describe any
            // valid shard — checked conversion, not a silent truncation
            p: crate::convert::u32_to_usize(lookup_u32(&kv, "p")?),
            p_orig: crate::convert::u32_to_usize(lookup_u32(&kv, "p_orig")?),
            m: crate::convert::u32_to_usize(lookup_u32(&kv, "m")?),
            n,
            gamma,
            transform,
            seed: lookup_num(&kv, "seed")?,
            preconditioned,
            scheme,
            precision,
            shard_cols: lookup_usize(&kv, "shard_cols")?,
            group,
            shards,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Structural validation: shard table is contiguous, stride-aligned,
    /// and consistent with the scalar fields.
    pub fn validate(&self) -> Result<()> {
        if self.m == 0 || self.m > self.p {
            return corrupt(format!("manifest: m = {} out of range for p = {}", self.m, self.p));
        }
        if self.p_orig == 0 || self.p_orig > self.p {
            return corrupt(format!(
                "manifest: p_orig = {} out of range for p = {}",
                self.p_orig, self.p
            ));
        }
        if self.shard_cols == 0 {
            return corrupt("manifest: shard_cols = 0");
        }
        if self.precision == Precision::F32 && self.version < 3 {
            return corrupt(format!(
                "manifest: f32 precision requires version >= 3 (got {})",
                self.version
            ));
        }
        if self.scheme.preconditions() != self.preconditioned {
            return corrupt(format!(
                "manifest: scheme {} is inconsistent with preconditioned = {}",
                self.scheme.name(),
                self.preconditioned
            ));
        }
        let g = &self.group;
        if g.count == 0 || g.index >= g.count {
            return corrupt(format!(
                "manifest: group index {} out of range for count {}",
                g.index, g.count
            ));
        }
        if g.count > 1 && self.version < 4 {
            return corrupt(format!(
                "manifest: shard groups require version >= 4 (got {})",
                self.version
            ));
        }
        if g.count == 1 && (g.start_col != 0 || g.total_n != self.n) {
            return corrupt(format!(
                "manifest: standalone store claims group columns [{}, {}) of {}",
                g.start_col,
                g.start_col + self.n,
                g.total_n
            ));
        }
        if g.start_col % self.shard_cols != 0 {
            return corrupt(format!(
                "manifest: group start {} is not aligned to the shard stride {}",
                g.start_col, self.shard_cols
            ));
        }
        if g.index == 0 && g.start_col != 0 {
            return corrupt(format!("manifest: group piece 0 starts at column {}", g.start_col));
        }
        match g.start_col.checked_add(self.n) {
            Some(end) if end <= g.total_n => {
                if g.index + 1 == g.count && end != g.total_n {
                    return corrupt(format!(
                        "manifest: final group piece ends at {end} but the group holds {}",
                        g.total_n
                    ));
                }
            }
            _ => {
                return corrupt(format!(
                    "manifest: group piece columns [{}, {} + {}) exceed total_n = {}",
                    g.start_col, g.start_col, self.n, g.total_n
                ));
            }
        }
        let first_index = g.start_col / self.shard_cols;
        let mut expected_start = g.start_col;
        for (i, s) in self.shards.iter().enumerate() {
            if s.index != first_index + i {
                return corrupt(format!(
                    "manifest: shard {i} has index {} (expected {})",
                    s.index,
                    first_index + i
                ));
            }
            if s.start_col != expected_start {
                return corrupt(format!(
                    "manifest: shard {i} starts at {} (expected {expected_start})",
                    s.start_col
                ));
            }
            if s.n_cols == 0 || s.n_cols > self.shard_cols {
                return corrupt(format!(
                    "manifest: shard {i} holds {} cols (stride {})",
                    s.n_cols, self.shard_cols
                ));
            }
            if i + 1 < self.shards.len() && s.n_cols != self.shard_cols {
                return corrupt(format!(
                    "manifest: non-final shard {i} is short ({} < {})",
                    s.n_cols, self.shard_cols
                ));
            }
            expected_start += s.n_cols;
        }
        if expected_start != self.end_col() {
            return corrupt(format!(
                "manifest: shards cover {} cols but n = {}",
                expected_start - g.start_col,
                self.n
            ));
        }
        // a short final shard is only ever the *globally* last shard — a
        // group piece that ends mid-store must end on a full shard
        if let Some(last) = self.shards.last() {
            if last.n_cols != self.shard_cols && expected_start != g.total_n {
                return corrupt(format!(
                    "manifest: short shard {} ends at column {expected_start}, not at the \
                     group's total {}",
                    last.index, g.total_n
                ));
            }
        }
        Ok(())
    }

    /// Load and parse `<dir>/manifest.pdsm`.
    pub fn load(dir: &Path) -> Result<StoreManifest> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Invalid(format!("{}: cannot read sparse store manifest ({e})", path.display()))
        })?;
        Self::parse(&text)
    }

    /// Write the manifest atomically: temp file in `dir`, fsync, rename.
    /// Readers therefore only ever see a complete manifest.
    pub fn write_atomic(&self, dir: &Path) -> Result<()> {
        if self.version > MANIFEST_VERSION {
            return invalid(format!("cannot write manifest version {}", self.version));
        }
        let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
        {
            let mut f = File::create(&tmp)?;
            f.write_all(self.to_text().as_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, dir.join(MANIFEST_FILE))?;
        Ok(())
    }
}

/// Find a scalar key's value in the parsed key/value list.
fn lookup<'a>(kv: &'a [(String, String)], name: &str) -> Result<&'a str> {
    kv.iter()
        .find(|(k, _)| k == name)
        .map(|(_, v)| v.as_str())
        .ok_or_else(|| Error::Corrupt(format!("manifest: missing key {name:?}")))
}

/// [`lookup`], parsed as an unsigned integer.
fn lookup_num(kv: &[(String, String)], name: &str) -> Result<u64> {
    let v = lookup(kv, name)?;
    v.parse()
        .map_err(|_| Error::Corrupt(format!("manifest: bad integer {name} = {v:?}")))
}

/// [`lookup_num`], checked into `u32`. Used for the fields the shard
/// headers encode as `u32` (`p`, `m`, and kin) and for `version`: a
/// value past `u32::MAX` in a tampered manifest used to truncate
/// silently into a plausible small number (`2^32 + 2` read as version 2);
/// it is corruption and must surface as such.
fn lookup_u32(kv: &[(String, String)], name: &str) -> Result<u32> {
    let v = lookup_num(kv, name)?;
    u32::try_from(v)
        .map_err(|_| Error::Corrupt(format!("manifest: {name} = {v} out of range (max {})", u32::MAX)))
}

/// [`lookup_num`], checked into `usize` with the same corruption
/// contract as [`lookup_u32`] (relevant on 32-bit targets, and it keeps
/// every numeric field on the checked path).
fn lookup_usize(kv: &[(String, String)], name: &str) -> Result<usize> {
    let v = lookup_num(kv, name)?;
    usize::try_from(v)
        .map_err(|_| Error::Corrupt(format!("manifest: {name} = {v} out of range")))
}

/// Parse a `group = <index> <count> <start_col> <total_n>` value.
fn parse_group_value(value: &str) -> Result<ShardGroup> {
    let fields: Vec<&str> = value.split_whitespace().collect();
    if fields.len() != 4 {
        return corrupt(format!("manifest: group needs 4 fields, got {}", fields.len()));
    }
    let num = |s: &str, what: &str| -> Result<usize> {
        s.parse()
            .map_err(|_| Error::Corrupt(format!("manifest: bad group {what} {s:?}")))
    };
    Ok(ShardGroup {
        index: num(fields[0], "index")?,
        count: num(fields[1], "count")?,
        start_col: num(fields[2], "start_col")?,
        total_n: num(fields[3], "total_n")?,
    })
}

/// Parse one `shard = <index> <start_col> <n_cols> <crc32-hex> <file>`
/// value.
fn parse_shard_line(value: &str, lineno: usize) -> Result<ShardEntry> {
    let fields: Vec<&str> = value.split_whitespace().collect();
    if fields.len() != 5 {
        return corrupt(format!(
            "manifest line {lineno}: shard needs 5 fields, got {}",
            fields.len()
        ));
    }
    let num = |s: &str, what: &str| -> Result<usize> {
        s.parse()
            .map_err(|_| Error::Corrupt(format!("manifest line {lineno}: bad {what} {s:?}")))
    };
    Ok(ShardEntry {
        index: num(fields[0], "shard index")?,
        start_col: num(fields[1], "start_col")?,
        n_cols: num(fields[2], "n_cols")?,
        crc32: u32::from_str_radix(fields[3], 16)
            .map_err(|_| Error::Corrupt(format!("manifest line {lineno}: bad crc {:?}", fields[3])))?,
        file: fields[4].to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StoreManifest {
        StoreManifest {
            version: 2,
            p: 128,
            p_orig: 100,
            m: 32,
            n: 25,
            gamma: 0.25,
            transform: TransformKind::Hadamard,
            seed: 7,
            preconditioned: true,
            scheme: Scheme::Precond,
            precision: Precision::F64,
            shard_cols: 10,
            group: ShardGroup::standalone(25),
            shards: vec![
                ShardEntry {
                    index: 0,
                    start_col: 0,
                    n_cols: 10,
                    crc32: 0xDEAD_BEEF,
                    file: "shard-00000.pdsb".into(),
                },
                ShardEntry {
                    index: 1,
                    start_col: 10,
                    n_cols: 10,
                    crc32: 0x0000_0001,
                    file: "shard-00001.pdsb".into(),
                },
                ShardEntry {
                    index: 2,
                    start_col: 20,
                    n_cols: 5,
                    crc32: 0xFFFF_FFFF,
                    file: "shard-00002.pdsb".into(),
                },
            ],
        }
    }

    #[test]
    fn text_roundtrip_is_exact() {
        let m = sample();
        let parsed = StoreManifest::parse(&m.to_text()).unwrap();
        assert_eq!(parsed.p, m.p);
        assert_eq!(parsed.p_orig, m.p_orig);
        assert_eq!(parsed.m, m.m);
        assert_eq!(parsed.n, m.n);
        assert_eq!(parsed.gamma.to_bits(), m.gamma.to_bits());
        assert_eq!(parsed.transform, m.transform);
        assert_eq!(parsed.seed, m.seed);
        assert_eq!(parsed.preconditioned, m.preconditioned);
        assert_eq!(parsed.scheme, m.scheme);
        assert_eq!(parsed.shard_cols, m.shard_cols);
        assert_eq!(parsed.shards, m.shards);
    }

    #[test]
    fn v1_manifest_infers_scheme_from_preconditioned() {
        // a pre-scheme (v1) manifest parses, with the scheme inferred
        let strip = |m: StoreManifest, precond: bool| {
            let mut m = m;
            m.version = 1;
            m.preconditioned = precond;
            m.scheme = if precond { Scheme::Precond } else { Scheme::Uniform };
            let text: String = m
                .to_text()
                .lines()
                .filter(|l| !l.starts_with("scheme"))
                .map(|l| format!("{l}\n"))
                .collect();
            StoreManifest::parse(&text).unwrap()
        };
        assert_eq!(strip(sample(), true).scheme, Scheme::Precond);
        assert_eq!(strip(sample(), false).scheme, Scheme::Uniform);
        // v2 without a scheme key is corrupt, not inferred
        let text: String = sample()
            .to_text()
            .lines()
            .filter(|l| !l.starts_with("scheme"))
            .map(|l| format!("{l}\n"))
            .collect();
        assert!(matches!(StoreManifest::parse(&text), Err(Error::Corrupt(_))));
    }

    #[test]
    fn scheme_roundtrips_and_inconsistency_is_corrupt() {
        let mut hybrid = sample();
        hybrid.scheme = Scheme::Hybrid;
        hybrid.preconditioned = false;
        let parsed = StoreManifest::parse(&hybrid.to_text()).unwrap();
        assert_eq!(parsed.scheme, Scheme::Hybrid);
        assert!(!parsed.preconditioned);

        // scheme says preconditioned, flag says not — corrupt
        let mut bad = sample();
        bad.preconditioned = false; // scheme stays Precond
        assert!(matches!(bad.validate(), Err(Error::Corrupt(_))));
        assert!(StoreManifest::parse(&bad.to_text()).is_err());

        // unknown scheme name
        let text = sample().to_text().replace("scheme = precond", "scheme = mystery");
        assert!(matches!(StoreManifest::parse(&text), Err(Error::Corrupt(_))));
    }

    #[test]
    fn precision_key_roundtrips_and_defaults_to_f64() {
        // v2 manifest: no precision key emitted, parses as f64
        let v2 = sample();
        assert!(!v2.to_text().contains("precision"));
        assert_eq!(StoreManifest::parse(&v2.to_text()).unwrap().precision, Precision::F64);

        // v3 + f32 roundtrips
        let mut v3 = sample();
        v3.version = 3;
        v3.precision = Precision::F32;
        assert!(v3.to_text().contains("precision = f32"));
        let parsed = StoreManifest::parse(&v3.to_text()).unwrap();
        assert_eq!(parsed.precision, Precision::F32);
        assert_eq!(parsed.version, 3);
        assert_eq!(parsed.payload_bytes(), 25 * 32 * 8);
        assert_eq!(sample().payload_bytes(), 25 * 32 * 12);

        // v3 + f64 with the key stripped still parses (defaults f64)
        let mut v3f64 = sample();
        v3f64.version = 3;
        let text: String = v3f64
            .to_text()
            .lines()
            .filter(|l| !l.starts_with("precision"))
            .map(|l| format!("{l}\n"))
            .collect();
        assert_eq!(StoreManifest::parse(&text).unwrap().precision, Precision::F64);

        // f32 claimed under v2 is corrupt (a v2 reader would mis-parse
        // the 4-byte value blocks)
        let mut bad = sample();
        bad.precision = Precision::F32;
        assert!(matches!(bad.validate(), Err(Error::Corrupt(_))));

        // unknown precision name
        let mut v3bad = sample();
        v3bad.version = 3;
        let text = v3bad.to_text().replace("precision = f64", "precision = f16");
        assert!(matches!(StoreManifest::parse(&text), Err(Error::Corrupt(_))));
    }

    #[test]
    fn gamma_text_roundtrips_awkward_values() {
        for g in [0.1, 0.05, 1.0 / 3.0, 0.123456789012345] {
            let mut m = sample();
            m.gamma = g;
            let parsed = StoreManifest::parse(&m.to_text()).unwrap();
            assert_eq!(parsed.gamma.to_bits(), g.to_bits(), "gamma {g}");
        }
    }

    #[test]
    fn shard_for_col_uses_fixed_stride() {
        let m = sample();
        assert_eq!(m.shard_for_col(0), Some(0));
        assert_eq!(m.shard_for_col(9), Some(0));
        assert_eq!(m.shard_for_col(10), Some(1));
        assert_eq!(m.shard_for_col(24), Some(2));
        assert_eq!(m.shard_for_col(25), None);
    }

    #[test]
    fn validate_rejects_gaps_and_miscounts() {
        let mut gap = sample();
        gap.shards[1].start_col = 11;
        assert!(matches!(gap.validate(), Err(Error::Corrupt(_))));

        let mut short = sample();
        short.shards[0].n_cols = 9; // non-final short shard
        assert!(short.validate().is_err());

        let mut wrong_n = sample();
        wrong_n.n = 26;
        assert!(wrong_n.validate().is_err());

        let mut bad_m = sample();
        bad_m.m = 0;
        assert!(bad_m.validate().is_err());
    }

    #[test]
    fn parse_rejects_malformed_text() {
        assert!(StoreManifest::parse("format = pdss\nversion = 1\n").is_err()); // missing keys
        let mut text = sample().to_text();
        text = text.replace("format = pdss", "format = nope");
        assert!(matches!(StoreManifest::parse(&text), Err(Error::Corrupt(_))));
        let future = sample().to_text().replace("version = 2", "version = 99");
        assert!(StoreManifest::parse(&future).is_err());
        let badcount = sample().to_text().replace("shard_count = 3", "shard_count = 2");
        assert!(StoreManifest::parse(&badcount).is_err());
        let nocrc = sample().to_text().replace("deadbeef", "zzzz");
        assert!(StoreManifest::parse(&nocrc).is_err());
    }

    /// The `sample()` store split after its second shard: piece
    /// `which ∈ {0, 1}` of a two-piece group.
    fn group_piece(which: usize) -> StoreManifest {
        let mut m = sample();
        m.version = 4;
        if which == 0 {
            m.shards.truncate(2);
            m.n = 20;
            m.group = ShardGroup { index: 0, count: 2, start_col: 0, total_n: 25 };
        } else {
            m.shards.drain(..2);
            m.n = 5;
            m.group = ShardGroup { index: 1, count: 2, start_col: 20, total_n: 25 };
        }
        m
    }

    #[test]
    fn group_piece_roundtrips_with_global_coordinates() {
        for which in [0, 1] {
            let m = group_piece(which);
            m.validate().unwrap();
            let text = m.to_text();
            assert!(text.contains(&format!(
                "group = {} 2 {} 25",
                m.group.index, m.group.start_col
            )));
            let parsed = StoreManifest::parse(&text).unwrap();
            assert_eq!(parsed.group, m.group);
            assert_eq!(parsed.shards, m.shards);
        }
        // piece 1 serves exactly its own global column range
        let p1 = group_piece(1);
        assert_eq!((p1.start_col(), p1.end_col()), (20, 25));
        assert_eq!(p1.shard_for_col(19), None);
        assert_eq!(p1.shard_for_col(20), Some(0));
        assert_eq!(p1.shard_for_col(24), Some(0));
        assert_eq!(p1.shard_for_col(25), None);
        // pre-v4 manifests (no group key) are standalone
        assert_eq!(
            StoreManifest::parse(&sample().to_text()).unwrap().group,
            ShardGroup::standalone(25)
        );
        assert!(!sample().to_text().contains("group"));
    }

    #[test]
    fn group_validation_rejects_inconsistent_pieces() {
        // grouped store under a pre-group version
        let mut old = group_piece(1);
        old.version = 3;
        assert!(matches!(old.validate(), Err(Error::Corrupt(_))));

        // group start not aligned to the shard stride
        let mut misaligned = group_piece(1);
        misaligned.group.start_col = 15;
        assert!(misaligned.validate().is_err());

        // piece 0 must start at column 0
        let mut bad_first = group_piece(0);
        bad_first.group = ShardGroup { index: 0, count: 2, start_col: 20, total_n: 45 };
        assert!(bad_first.validate().is_err());

        // final piece must end at the group total
        let mut short_total = group_piece(1);
        short_total.group.total_n = 30;
        assert!(short_total.validate().is_err());

        // a short shard that is not globally last
        let mut mid_short = group_piece(0);
        mid_short.shards.truncate(1);
        mid_short.shards[0].n_cols = 9;
        mid_short.n = 9;
        match mid_short.validate() {
            Err(Error::Corrupt(msg)) => assert!(msg.contains("short shard"), "{msg}"),
            other => panic!("expected Corrupt, got {other:?}"),
        }

        // index out of range / zero count
        let mut bad_index = group_piece(1);
        bad_index.group.index = 2;
        assert!(bad_index.validate().is_err());

        // standalone manifests must not claim partial coverage
        let mut lying = sample();
        lying.group.total_n = 40;
        assert!(lying.validate().is_err());

        // malformed group lines are corrupt, not panics
        let text = group_piece(1).to_text().replace("group = 1 2 20 25", "group = 1 2 20");
        assert!(matches!(StoreManifest::parse(&text), Err(Error::Corrupt(_))));
        let text = group_piece(1).to_text().replace("group = 1 2 20 25", "group = 1 2 x 25");
        assert!(matches!(StoreManifest::parse(&text), Err(Error::Corrupt(_))));
    }

    #[test]
    fn unknown_keys_are_ignored_for_forward_compat() {
        let mut text = sample().to_text();
        text.push_str("future_extension = whatever\n");
        assert!(StoreManifest::parse(&text).is_ok());
    }

    /// Replace one `key = old` scalar line of a manifest text with a raw
    /// value, asserting the key was present.
    fn with_value(text: &str, key: &str, value: &str) -> String {
        let needle = format!("{key} = ");
        let mut hit = false;
        let out: String = text
            .lines()
            .map(|l| {
                if l.starts_with(&needle) {
                    hit = true;
                    format!("{key} = {value}\n")
                } else {
                    format!("{l}\n")
                }
            })
            .collect();
        assert!(hit, "no line for key {key}");
        out
    }

    #[test]
    fn parse_rejects_out_of_range_numerics() {
        // `2^32 + 2` used to truncate to version 2 and parse cleanly;
        // every u32-backed field must surface Error::Corrupt instead
        let overwide = (u64::from(u32::MAX) + 3).to_string();
        for key in ["version", "p", "p_orig", "m"] {
            let text = with_value(&sample().to_text(), key, &overwide);
            match StoreManifest::parse(&text) {
                Err(Error::Corrupt(msg)) => {
                    assert!(msg.contains("out of range"), "{key}: {msg}")
                }
                other => panic!("{key} = {overwide}: expected Corrupt, got {other:?}"),
            }
        }
        // negatives never parse as any unsigned field
        for key in ["version", "p", "p_orig", "m", "n", "shard_cols", "shard_count", "seed"] {
            let text = with_value(&sample().to_text(), key, "-1");
            assert!(
                matches!(StoreManifest::parse(&text), Err(Error::Corrupt(_))),
                "{key} = -1 must be corrupt"
            );
        }
    }

    #[test]
    fn prop_out_of_range_numerics_never_parse() {
        use crate::testing::prop::forall;
        let keys = ["version", "p", "p_orig", "m", "n", "shard_cols", "shard_count"];
        forall("manifest out-of-range numerics are corrupt", 64, |g| {
            let key = *g.choose(&keys);
            let mut rng = g.rng();
            // uniform in [2^32, u64::MAX] — every draw is wider than any
            // field a valid store can hold (n/shard_cols values this
            // large fail shard-table validation on 64-bit targets)
            let span = u64::MAX - (1u64 << 32) + 1;
            let v = (1u64 << 32) + rng.next_u64() % span;
            let text = with_value(&sample().to_text(), key, &v.to_string());
            match StoreManifest::parse(&text) {
                Err(Error::Corrupt(_)) => {}
                other => panic!("{key} = {v}: expected Corrupt, got {other:?}"),
            }
        });
    }
}
