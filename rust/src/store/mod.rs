//! Sharded on-disk store for **sparsified** data — compress once, analyze
//! many.
//!
//! The paper's compression is a single streaming pass, but its output is
//! what you want to keep: at γ = m/p the sparse form is 12·γ bytes per
//! original 8-byte entry, and every downstream consumer (PCA, K-means,
//! mean/covariance estimation) runs off it without ever revisiting the
//! raw data (the Table IV out-of-core workflow). This module persists
//! that output as a directory of fixed-stride shards plus a small text
//! manifest, zarr-style:
//!
//! ```text
//! store/
//! ├── manifest.pdsm      # text manifest: p, m, n, config, shard table
//! ├── shard-00000.pdsb   # columns [0, shard_cols)
//! ├── shard-00001.pdsb   # columns [shard_cols, 2·shard_cols)
//! └── ...                # last shard may be short
//! ```
//!
//! Each shard serializes a [`SparseChunk`](crate::sparse::SparseChunk)
//! verbatim (little-endian `u32` indices block, then `f64` values block,
//! both in the chunk's fixed-stride layout), so a round trip is
//! **bit-exact** and — because shard contents depend only on the global
//! column order — the files are byte-identical for every compress worker
//! count. Per-shard CRC-32 checksums live in the manifest; the manifest
//! is written last (temp file + rename), so a crashed writer never leaves
//! a store a reader would accept. `docs/FORMAT.md` specifies the exact
//! bytes.
//!
//! * [`SparseStoreWriter`] — append [`SparseChunk`](crate::sparse::SparseChunk)s
//!   (in any order within the pipeline's bounded reorder window) during
//!   a `compress_stream` pass; atomic finish.
//! * [`SparseStoreReader`] — memory-budgeted, resumable reads;
//!   implements [`SparseChunkSource`](crate::sparse::SparseChunkSource)
//!   so the estimators and K-means consume stored data unchanged.
//! * [`StoreManifest`] — the parsed manifest (shard table + the
//!   [`SparsifyConfig`](crate::sampling::SparsifyConfig) needed to rebuild
//!   the matching [`Sparsifier`](crate::sampling::Sparsifier) for center /
//!   component unmixing).
//! * [`split_store`] / [`join_stores`] — deal a store's shards across
//!   directories as shard-group pieces (v4 manifests, shard files
//!   byte-identical) and re-join them; each piece reads as a complete
//!   store over its own global column range, which is the on-disk side
//!   of the [`distributed`](crate::distributed) partitioned fit.

mod group;
mod manifest;
mod reader;
mod writer;

pub use group::{join_stores, split_store};
pub use manifest::{ShardEntry, ShardGroup, StoreManifest, MANIFEST_FILE};
pub use reader::SparseStoreReader;
pub use writer::SparseStoreWriter;

/// Magic bytes opening every shard file.
pub(crate) const SHARD_MAGIC: &[u8; 4] = b"PDSS";

/// Shard format version for `f64` value blocks (header field; the
/// original and still-default layout — `f64` stores are byte-identical
/// to every pre-`Precision` release).
pub(crate) const SHARD_VERSION: u32 = 1;

/// Shard format version for `f32` value blocks: same header and index
/// block, values serialized as little-endian `f32` (4 bytes/entry).
pub(crate) const SHARD_VERSION_F32: u32 = 2;

/// Fixed shard header length in bytes: magic + version + p + m + n_cols
/// (4 × u32 + the 4-byte magic) + start_col (u64).
pub(crate) const SHARD_HEADER_LEN: usize = 4 + 4 + 4 + 4 + 4 + 8;

/// File name of shard `index` (`shard-00042.pdsb`).
pub(crate) fn shard_file_name(index: usize) -> String {
    format!("shard-{index:05}.pdsb")
}

/// CRC-32 (IEEE 802.3, reflected, polynomial `0xEDB88320`) lookup table,
/// built at compile time.
const CRC32_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        // lint:allow(lossy-cast) — i < 256 by the loop bound; const
        // context, so the checked convert helpers are unavailable
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// Incremental CRC-32 (IEEE 802.3) — the per-shard checksum recorded in
/// the manifest. Matches the ubiquitous zlib/`cksum -o 3` definition so
/// stores can be verified with standard tools.
#[derive(Clone, Debug)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Start a fresh checksum.
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = CRC32_TABLE[crate::convert::u32_to_usize((c ^ u32::from(b)) & 0xFF)] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Final checksum value (the object may keep accumulating afterwards;
    /// this just reports the current state).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE test vectors
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn crc32_incremental_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let whole = crc32(&data);
        let mut inc = Crc32::new();
        for part in data.chunks(377) {
            inc.update(part);
        }
        assert_eq!(inc.finish(), whole);
    }

    #[test]
    fn shard_names_sort_in_index_order() {
        assert_eq!(shard_file_name(0), "shard-00000.pdsb");
        assert_eq!(shard_file_name(12), "shard-00012.pdsb");
        assert!(shard_file_name(9) < shard_file_name(10));
    }
}
