//! Shard writer: turns a stream of [`SparseChunk`]s into the on-disk
//! store. Chunks may arrive out of stream order (the compress pipeline's
//! workers race); the writer reorders them through a bounded pending map,
//! so the emitted bytes depend only on the global column order — making
//! store files **byte-identical for every worker count**.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};

use crate::error::{invalid, shape_err, Error, Result};
use crate::sampling::{Scheme, Sparsifier, SparsifyConfig};
use crate::sparse::{Precision, SparseChunk};
use crate::transform::TransformKind;

use super::manifest::{ShardEntry, ShardGroup, StoreManifest, MANIFEST_FILE};
use super::{shard_file_name, Crc32, SHARD_MAGIC, SHARD_VERSION, SHARD_VERSION_F32};

/// Serialization block size (entries per `write_all`) — bounds the
/// scratch buffer while keeping syscalls large.
const WRITE_BLOCK: usize = 16 * 1024;

/// Streaming writer for a sharded sparse store.
///
/// Append [`SparseChunk`]s as they come off `compress_stream` (any order
/// within the pipeline's bounded in-flight window); every full
/// `shard_cols` columns are flushed to a `shard-NNNNN.pdsb` file with a
/// running CRC-32. [`finish`](Self::finish) flushes the tail shard and
/// writes the manifest atomically — a store is invisible to readers until
/// that final rename.
///
/// # Example
///
/// ```
/// use pds::linalg::Mat;
/// use pds::rng::Pcg64;
/// use pds::sampling::{Sparsifier, SparsifyConfig};
/// use pds::store::{SparseStoreReader, SparseStoreWriter};
/// use pds::transform::TransformKind;
///
/// let dir = std::env::temp_dir().join(format!("pds_doc_writer_{}", std::process::id()));
/// # let _ = std::fs::remove_dir_all(&dir);
/// let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 3 };
/// let sp = Sparsifier::new(16, cfg)?;
/// let mut rng = Pcg64::seed(1);
/// let x = Mat::from_fn(16, 12, |_, _| rng.normal());
///
/// // compress once ...
/// let mut writer = SparseStoreWriter::create(&dir, &sp, cfg, true, 5)?;
/// writer.append(sp.compress_chunk(&x, 0)?)?;
/// let manifest = writer.finish()?;
/// assert_eq!(manifest.n, 12);
/// assert_eq!(manifest.shards.len(), 3); // 5 + 5 + 2 columns
///
/// // ... analyze many: read back bit-exactly
/// let mut reader = SparseStoreReader::open(&dir)?;
/// let first = reader.next_chunk()?.unwrap();
/// assert_eq!(first.col_indices(0), sp.compress_chunk(&x, 0)?.col_indices(0));
/// std::fs::remove_dir_all(&dir)?;
/// # Ok::<(), pds::Error>(())
/// ```
pub struct SparseStoreWriter {
    dir: PathBuf,
    p: usize,
    p_orig: usize,
    m: usize,
    gamma: f64,
    transform: TransformKind,
    seed: u64,
    preconditioned: bool,
    /// Element-sampling scheme recorded in the manifest (derived from the
    /// sparsifier's scheme and the precondition flag at `create`).
    scheme: Scheme,
    /// Value-block storage precision. F64 (the default) produces stores
    /// byte-identical to pre-precision releases.
    precision: Precision,
    shard_cols: usize,
    /// Next global column the store is waiting for.
    next_col: usize,
    /// Reorder window: chunks that arrived ahead of `next_col`, keyed by
    /// `start_col`. Bounded by the compress pipeline's in-flight cap.
    pending: BTreeMap<usize, SparseChunk>,
    /// Fixed-stride buffers of the shard currently being filled.
    cur_indices: Vec<u32>,
    cur_values: Vec<f64>,
    /// Global column index of the current shard's first sample.
    cur_start: usize,
    shards: Vec<ShardEntry>,
}

impl SparseStoreWriter {
    /// Create the store directory (and parents) and start writing a store
    /// for the output of `sp`. Fails if `dir` already holds a completed
    /// store. `preconditioned` records whether chunks went through the
    /// ROS (false for the ablation arm) so readers unmix correctly; the
    /// manifest additionally records the *effective* sampling scheme
    /// (the sparsifier's scheme, downgraded from `precond` to `uniform`
    /// when `preconditioned` is false) so readers rebuild the matching
    /// sparsifier and estimator calibration.
    pub fn create(
        dir: &Path,
        sp: &Sparsifier,
        cfg: SparsifyConfig,
        preconditioned: bool,
        shard_cols: usize,
    ) -> Result<Self> {
        if shard_cols == 0 {
            return invalid("SparseStoreWriter: shard_cols must be positive");
        }
        std::fs::create_dir_all(dir)?;
        if dir.join(MANIFEST_FILE).exists() {
            return invalid(format!(
                "{}: a completed sparse store already exists here",
                dir.display()
            ));
        }
        // the recorded scheme is the *effective* selection law: a
        // preconditioned-uniform sparsifier run with the ROS disabled
        // produced plain uniform chunks
        let scheme = match (sp.scheme(), preconditioned) {
            (Scheme::Precond, false) => Scheme::Uniform,
            (s, _) => s,
        };
        let preconditioned = preconditioned && scheme.preconditions();
        Ok(SparseStoreWriter {
            dir: dir.to_path_buf(),
            p: sp.p(),
            p_orig: sp.p_orig(),
            m: sp.m(),
            gamma: cfg.gamma,
            transform: cfg.transform,
            seed: cfg.seed,
            preconditioned,
            scheme,
            precision: Precision::F64,
            shard_cols,
            next_col: 0,
            pending: BTreeMap::new(),
            cur_indices: Vec::new(),
            cur_values: Vec::new(),
            cur_start: 0,
            shards: Vec::new(),
        })
    }

    /// Select the value-block storage precision (builder; call before the
    /// first [`append`](Self::append)). [`Precision::F32`] halves the
    /// value bytes (manifest v3, shard v2) and quantizes each value once
    /// on absorb; [`Precision::F64`] — the default — keeps the store
    /// byte-identical to pre-precision releases (manifest v2, shard v1).
    pub fn with_precision(mut self, precision: Precision) -> Self {
        assert_eq!(
            self.next_col, 0,
            "with_precision must be called before the first append"
        );
        self.precision = precision;
        self
    }

    /// Resume appending to a live store that a previous process left at a
    /// durable checkpoint (the serve daemon's warm-restart path).
    ///
    /// The caller supplies the same configuration it would use for
    /// [`create`](Self::create); every recorded parameter — dimensions,
    /// gamma, transform, seed, scheme, precision, shard columns — must
    /// match the manifest, or resuming would silently splice two
    /// incompatible streams into one store. Each mismatch is a typed
    /// [`Error::Invalid`]. Because checkpoints only ever publish whole
    /// shards, a resumable manifest's `n` must sit on a shard boundary;
    /// a store finalized with a partial tail shard (a completed
    /// `finish`) is rejected — it is a finished artifact, not a live
    /// store. The writer resumes with the cursor at column `n`, so the
    /// caller's chunk numbering must continue from there.
    pub fn reopen(
        dir: &Path,
        sp: &Sparsifier,
        cfg: SparsifyConfig,
        preconditioned: bool,
        shard_cols: usize,
        precision: Precision,
    ) -> Result<Self> {
        if shard_cols == 0 {
            return invalid("SparseStoreWriter: shard_cols must be positive");
        }
        let manifest = StoreManifest::load(dir)?;
        let scheme = match (sp.scheme(), preconditioned) {
            (Scheme::Precond, false) => Scheme::Uniform,
            (s, _) => s,
        };
        let preconditioned = preconditioned && scheme.preconditions();
        let mismatch = |what: &str| -> Result<Self> {
            invalid(format!(
                "{}: cannot resume this store: {what} differs from the manifest",
                dir.display()
            ))
        };
        if !manifest.group.is_standalone() {
            return invalid(format!(
                "{}: cannot resume a shard-group piece (only standalone stores)",
                dir.display()
            ));
        }
        if manifest.p != sp.p() || manifest.p_orig != sp.p_orig() {
            return mismatch("the sample dimension");
        }
        if manifest.m != sp.m() {
            return mismatch("the per-column sample count m");
        }
        if manifest.gamma != cfg.gamma {
            return mismatch("gamma");
        }
        if manifest.transform != cfg.transform {
            return mismatch("the transform");
        }
        if manifest.seed != cfg.seed {
            return mismatch("the seed");
        }
        if manifest.scheme != scheme || manifest.preconditioned != preconditioned {
            return mismatch("the sampling scheme");
        }
        if manifest.precision != precision {
            return mismatch("the value precision");
        }
        if manifest.shard_cols != shard_cols {
            return mismatch("shard_cols");
        }
        if manifest.n % shard_cols != 0 {
            return invalid(format!(
                "{}: cannot resume this store: n = {} is not a shard boundary; the store \
                 was finalized with a partial tail shard",
                dir.display(),
                manifest.n
            ));
        }
        Ok(SparseStoreWriter {
            dir: dir.to_path_buf(),
            p: manifest.p,
            p_orig: manifest.p_orig,
            m: manifest.m,
            gamma: manifest.gamma,
            transform: manifest.transform,
            seed: manifest.seed,
            preconditioned,
            scheme,
            precision,
            shard_cols,
            next_col: manifest.n,
            pending: BTreeMap::new(),
            cur_indices: Vec::new(),
            cur_values: Vec::new(),
            cur_start: manifest.n,
            shards: manifest.shards,
        })
    }

    /// Columns absorbed into shards (or the current shard buffer) so far.
    pub fn columns_written(&self) -> usize {
        self.next_col
    }

    /// Shards flushed (and fsynced) to disk so far. The current shard
    /// buffer's columns are not counted until it fills.
    pub fn completed_shards(&self) -> usize {
        self.shards.len()
    }

    /// Columns covered by the flushed shards — what a
    /// [`checkpoint`](Self::checkpoint) manifest would publish.
    pub fn columns_durable(&self) -> usize {
        self.cur_start
    }

    /// Durably publish the completed shards: write a manifest (atomic
    /// temp + fsync + rename, like [`finish`](Self::finish)) covering
    /// every fully flushed shard, while the writer keeps appending.
    ///
    /// This is the long-running-ingest crash-safety primitive: a process
    /// killed at any instant leaves either the previous checkpoint's
    /// manifest or this one — both valid, CRC-clean stores — never a
    /// torn manifest or one referencing unflushed bytes. Columns still
    /// in the shard buffer (and parked out-of-order chunks) are *not*
    /// covered; they become durable at the next shard boundary or at
    /// `finish`. Returns the columns published, or `Ok(None)` when no
    /// shard has completed yet (nothing worth publishing — an empty
    /// manifest would fail validation).
    pub fn checkpoint(&mut self) -> Result<Option<usize>> {
        if self.shards.is_empty() {
            return Ok(None);
        }
        let n = self.cur_start;
        let manifest = StoreManifest {
            version: self.manifest_version(),
            p: self.p,
            p_orig: self.p_orig,
            m: self.m,
            n,
            gamma: self.gamma,
            transform: self.transform,
            seed: self.seed,
            preconditioned: self.preconditioned,
            scheme: self.scheme,
            precision: self.precision,
            shard_cols: self.shard_cols,
            group: ShardGroup::standalone(n),
            shards: self.shards.clone(),
        };
        manifest.validate()?;
        manifest.write_atomic(&self.dir)?;
        Ok(Some(n))
    }

    /// Lowest capable manifest version for this writer's configuration:
    /// f64 stores stay v2 and remain byte-identical to pre-precision
    /// releases.
    fn manifest_version(&self) -> u32 {
        match self.precision {
            Precision::F64 => 2,
            Precision::F32 => 3,
        }
    }

    /// Append one compressed chunk. Chunks ahead of the stream cursor are
    /// parked until their predecessors arrive; chunks behind it are
    /// rejected (duplicate or overlapping ranges).
    pub fn append(&mut self, chunk: SparseChunk) -> Result<()> {
        if chunk.p() != self.p || chunk.m() != self.m {
            return shape_err(format!(
                "store append: chunk is {}x{} per column, store is {}x{}",
                chunk.p(),
                chunk.m(),
                self.p,
                self.m
            ));
        }
        if chunk.n() == 0 {
            return Ok(());
        }
        let start = chunk.start_col();
        let end = start + chunk.n();
        if start < self.next_col {
            return invalid(format!(
                "store append: chunk at column {start} overlaps already-written data \
                 (cursor {})",
                self.next_col
            ));
        }
        // reject range overlap against parked chunks up front, so a buggy
        // producer gets an overlap error here instead of a misleading
        // gap error at finish()
        if let Some((&ps, pc)) = self.pending.range(..start).next_back() {
            if ps + pc.n() > start {
                return invalid(format!(
                    "store append: chunk [{start}, {end}) overlaps pending chunk [{ps}, {})",
                    ps + pc.n()
                ));
            }
        }
        if let Some((&ns, nc)) = self.pending.range(start..).next() {
            if ns < end {
                return invalid(format!(
                    "store append: chunk [{start}, {end}) overlaps pending chunk [{ns}, {})",
                    ns + nc.n()
                ));
            }
        }
        self.pending.insert(start, chunk);
        // drain every chunk that is now contiguous with the cursor
        loop {
            let first = match self.pending.keys().next() {
                Some(&k) if k == self.next_col => k,
                _ => break,
            };
            let chunk = match self.pending.remove(&first) {
                Some(c) => c,
                // unreachable: the key was observed under this same
                // borrow — but a typed error beats a panic if the
                // drain logic ever changes
                None => return invalid(format!("store append: pending chunk at {first} vanished")),
            };
            self.absorb(&chunk)?;
        }
        Ok(())
    }

    /// Copy a contiguous chunk into the shard buffers, flushing every
    /// time the buffer reaches `shard_cols` columns.
    fn absorb(&mut self, chunk: &SparseChunk) -> Result<()> {
        let m = self.m;
        let n = chunk.n();
        let mut off = 0usize;
        while off < n {
            let room = self.shard_cols - self.cur_cols();
            let take = room.min(n - off);
            self.cur_indices
                .extend_from_slice(&chunk.indices()[off * m..(off + take) * m]);
            let vals = &chunk.values()[off * m..(off + take) * m];
            match self.precision {
                Precision::F64 => self.cur_values.extend_from_slice(vals),
                // quantize exactly once at absorb, so the buffered state
                // (and any future read-back) matches the disk bytes
                Precision::F32 => {
                    self.cur_values.extend(vals.iter().map(|&v| crate::convert::quantize_f32(v)));
                }
            }
            off += take;
            self.next_col += take;
            if self.cur_cols() == self.shard_cols {
                self.flush_shard()?;
            }
        }
        Ok(())
    }

    fn cur_cols(&self) -> usize {
        self.cur_indices.len() / self.m
    }

    /// Write the buffered shard to disk (header, indices block, values
    /// block), fsync it, and record its manifest entry.
    fn flush_shard(&mut self) -> Result<()> {
        let n_cols = self.cur_cols();
        if n_cols == 0 {
            return Ok(());
        }
        let index = self.shards.len();
        let file = shard_file_name(index);
        let path = self.dir.join(&file);
        let mut crc = Crc32::new();
        let mut out = BufWriter::new(File::create(&path)?);

        let shard_version = match self.precision {
            Precision::F64 => SHARD_VERSION,
            Precision::F32 => SHARD_VERSION_F32,
        };
        let mut header = Vec::with_capacity(super::SHARD_HEADER_LEN);
        header.extend_from_slice(SHARD_MAGIC);
        header.extend_from_slice(&shard_version.to_le_bytes());
        // the header encodes p/m/n_cols as u32: a store too wide for the
        // format must fail typed at flush, not truncate on disk
        header.extend_from_slice(&crate::convert::usize_to_u32(self.p, "store p")?.to_le_bytes());
        header.extend_from_slice(&crate::convert::usize_to_u32(self.m, "store m")?.to_le_bytes());
        header
            .extend_from_slice(&crate::convert::usize_to_u32(n_cols, "shard n_cols")?.to_le_bytes());
        header.extend_from_slice(&crate::convert::usize_to_u64(self.cur_start).to_le_bytes());
        crc.update(&header);
        out.write_all(&header)?;

        let mut buf = Vec::with_capacity(WRITE_BLOCK * 8);
        for block in self.cur_indices.chunks(WRITE_BLOCK) {
            buf.clear();
            for v in block {
                buf.extend_from_slice(&v.to_le_bytes());
            }
            crc.update(&buf);
            out.write_all(&buf)?;
        }
        for block in self.cur_values.chunks(WRITE_BLOCK) {
            buf.clear();
            match self.precision {
                Precision::F64 => {
                    for v in block {
                        buf.extend_from_slice(&v.to_bits().to_le_bytes());
                    }
                }
                // buffered values are already quantized (absorb), so the
                // narrowing cast here is exact
                Precision::F32 => {
                    for v in block {
                        buf.extend_from_slice(
                            &crate::convert::f64_to_f32(*v).to_bits().to_le_bytes(),
                        );
                    }
                }
            }
            crc.update(&buf);
            out.write_all(&buf)?;
        }
        out.flush()?;
        let f = out.into_inner().map_err(|e| Error::Io(e.into_error()))?;
        f.sync_all()?;

        self.shards.push(ShardEntry {
            index,
            start_col: self.cur_start,
            n_cols,
            crc32: crc.finish(),
            file,
        });
        self.cur_start += n_cols;
        self.cur_indices.clear();
        self.cur_values.clear();
        Ok(())
    }

    /// Flush the tail shard and write the manifest atomically. Fails —
    /// leaving no manifest, so the partial store stays invisible — if any
    /// parked chunk never had its predecessors appended.
    pub fn finish(mut self) -> Result<StoreManifest> {
        if let Some(&first) = self.pending.keys().next() {
            return invalid(format!(
                "store finish: columns {}..{first} were never appended (gap in the stream)",
                self.next_col
            ));
        }
        self.flush_shard()?;
        let manifest = StoreManifest {
            version: self.manifest_version(),
            p: self.p,
            p_orig: self.p_orig,
            m: self.m,
            n: self.next_col,
            gamma: self.gamma,
            transform: self.transform,
            seed: self.seed,
            preconditioned: self.preconditioned,
            scheme: self.scheme,
            precision: self.precision,
            shard_cols: self.shard_cols,
            group: ShardGroup::standalone(self.next_col),
            shards: std::mem::take(&mut self.shards),
        };
        manifest.validate()?;
        manifest.write_atomic(&self.dir)?;
        Ok(manifest)
    }
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::store::SparseStoreReader;

    fn tmpdir(name: &str) -> PathBuf {
        let p = std::env::temp_dir()
            .join(format!("pds_store_writer_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    fn scfg(seed: u64) -> SparsifyConfig {
        SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed }
    }

    #[test]
    fn reopen_resumes_at_the_checkpoint() {
        let dir = tmpdir("resume");
        let cfg = scfg(7);
        let sp = Sparsifier::new(16, cfg).unwrap();
        let mut rng = Pcg64::seed(11);
        let x = Mat::from_fn(16, 12, |_, _| rng.normal());
        let head_cols = Mat::from_fn(16, 8, |i, j| x.col(j)[i]);
        let tail_cols = Mat::from_fn(16, 4, |i, j| x.col(8 + j)[i]);

        // first process: two full shards, checkpoint, killed (dropped)
        let mut writer = SparseStoreWriter::create(&dir, &sp, cfg, true, 4).unwrap();
        let head = sp.compress_chunk(&head_cols, 0).unwrap();
        writer.append(head.clone()).unwrap();
        assert_eq!(writer.checkpoint().unwrap(), Some(8));
        drop(writer);

        // second process: resume and append the rest
        let mut writer = SparseStoreWriter::reopen(&dir, &sp, cfg, true, 4, Precision::F64)
            .unwrap();
        assert_eq!(writer.columns_written(), 8);
        assert_eq!(writer.columns_durable(), 8);
        writer.append(sp.compress_chunk(&tail_cols, 8).unwrap()).unwrap();
        let manifest = writer.finish().unwrap();
        assert_eq!(manifest.n, 12);
        assert_eq!(manifest.shards.len(), 3);

        // the resumed store reads back bit-exactly across the seam
        let mut reader = SparseStoreReader::open(&dir).unwrap();
        let chunk = reader.next_chunk().unwrap().unwrap();
        assert_eq!(chunk.col_indices(0), head.col_indices(0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_rejects_config_mismatches() {
        let dir = tmpdir("mismatch");
        let cfg = scfg(7);
        let sp = Sparsifier::new(16, cfg).unwrap();
        let mut rng = Pcg64::seed(11);
        let x = Mat::from_fn(16, 4, |_, _| rng.normal());
        let mut writer = SparseStoreWriter::create(&dir, &sp, cfg, true, 4).unwrap();
        writer.append(sp.compress_chunk(&x, 0).unwrap()).unwrap();
        writer.checkpoint().unwrap();
        drop(writer);

        // a different seed would splice two incompatible streams
        let other = scfg(8);
        let sp_other = Sparsifier::new(16, other).unwrap();
        assert!(matches!(
            SparseStoreWriter::reopen(&dir, &sp_other, other, true, 4, Precision::F64),
            Err(Error::Invalid(_))
        ));
        // so would a different precision or shard size
        assert!(matches!(
            SparseStoreWriter::reopen(&dir, &sp, cfg, true, 4, Precision::F32),
            Err(Error::Invalid(_))
        ));
        assert!(matches!(
            SparseStoreWriter::reopen(&dir, &sp, cfg, true, 8, Precision::F64),
            Err(Error::Invalid(_))
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopen_rejects_a_finished_partial_tail() {
        let dir = tmpdir("tail");
        let cfg = scfg(7);
        let sp = Sparsifier::new(16, cfg).unwrap();
        let mut rng = Pcg64::seed(11);
        let x = Mat::from_fn(16, 7, |_, _| rng.normal());
        let mut writer = SparseStoreWriter::create(&dir, &sp, cfg, true, 5).unwrap();
        writer.append(sp.compress_chunk(&x, 0).unwrap()).unwrap();
        writer.finish().unwrap(); // n = 7: not a shard boundary

        assert!(matches!(
            SparseStoreWriter::reopen(&dir, &sp, cfg, true, 5, Precision::F64),
            Err(Error::Invalid(_))
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
