//! # pds — Preconditioned Data Sparsification for Big Data
//!
//! A streaming data-sparsification pipeline reproducing Pourkamali-Anaraki &
//! Becker, *"Preconditioned Data Sparsification for Big Data with
//! Applications to PCA and K-means"* (IEEE TIT 2017).
//!
//! The compression scheme is two steps fused into a single pass over the
//! data (samples are columns of `X ∈ R^{p×n}`):
//!
//! 1. **Precondition** each sample with a randomized orthonormal system
//!    (ROS): `y_i = H D x_i` where `H` is a Hadamard/DCT transform and `D`
//!    a random ±1 diagonal (paper Eq. 1). This smooths large entries so
//!    uniform sampling becomes near-optimal (Theorem 1 / Corollary 2).
//! 2. **Sparsify**: keep exactly `m` of `p` entries of each `y_i`
//!    uniformly at random without replacement (an independent sampling
//!    matrix `R_i` per sample — the property that makes one-pass center
//!    and covariance estimation *consistent*).
//!
//! The element-selection law is pluggable ([`sampling::SamplingScheme`]):
//! besides the paper's preconditioned-uniform operator, the repo ships
//! the no-ROS uniform ablation and the hybrid-(ℓ1,ℓ2) importance-sampling
//! scheme of Kundu et al. (arXiv:1503.00547) — the "related sampling
//! approaches" the paper positions against — selected per fit with
//! `FitPlan::scheme` / `--scheme` and recorded in store manifests.
//!
//! Downstream consumers implemented here, matching the paper's evaluation:
//!
//! * [`estimators`] — unbiased sample-mean (Thm 4) and covariance (Thm 6)
//!   estimators with their concentration bounds, plus the `H_k`
//!   conditioning result (Thm 7).
//! * [`pca`] — principal components / explained variance, from the
//!   materialized covariance estimate (`Pca::from_covariance`) or
//!   covariance-free via randomized block-Krylov iteration on an
//!   implicit operator (`Pca::from_sparse_operator` over
//!   [`linalg::SymOp`] — no p×p allocation; select it with
//!   `FitPlan::pca().solver(Solver::Krylov)` to stream the operator from
//!   memory or from the sparse store).
//! * [`kmeans`] — standard K-means, k-means++ seeding, and **sparsified
//!   K-means** (Algorithm 1) with its two-pass refinement (Algorithm 2).
//! * [`baselines`] — feature extraction / feature selection
//!   (Boutsidis et al.) and uniform column sampling, for the paper's
//!   comparisons.
//! * [`coordinator`] — the L3 streaming orchestrator: chunked (optionally
//!   out-of-core) ingestion, sparsifier worker pool with bounded-channel
//!   backpressure, and the [`coordinator::FitPlan`] session API — the one
//!   builder every fit (PCA / K-means / compress, from a raw stream, an
//!   in-memory sparse source, or the persistent store) runs through.
//! * [`distributed`] — serializable, lawfully mergeable partial-fit
//!   state ([`distributed::PartialFit`]): per-shard mean / covariance /
//!   HK and Lloyd-update partials that N workers fit independently over
//!   disjoint shard ranges and a coordinator merges — bit-identically in
//!   every merge order and partition — plus the Barger–Feldman
//!   merge-and-reduce coreset tree (arXiv:1511.08990) behind
//!   `FitPlan::kmeans().solver(Solver::Coreset)` for bounded-memory
//!   streaming K-means.
//! * [`parallel`] — the fork/join execution layer under the hot paths:
//!   scoped threads over contiguous index ranges with deterministic
//!   in-order merge (K-means assignment/center accumulation and the
//!   covariance scatter partition their *output* space, so results are
//!   bitwise independent of the worker count).
//! * [`simd`] — explicit-SIMD kernels (AVX2/SSE2, runtime-dispatched
//!   with a scalar fallback) under the FWHT, assignment, and covariance
//!   scatter hot paths; every tier is bitwise identical in `f64`. The
//!   companion `f32` storage mode ([`sparse::Precision`]) halves chunk
//!   and store bytes while keeping all accumulation in `f64`.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas graphs
//!   (`artifacts/*.hlo.txt` built by `make artifacts`); the
//!   [`runtime::NativeEngine`] implements the same chunk ops in pure Rust
//!   and is the default engine.
//! * [`serve`] — the `pds serve` daemon: concurrent ingest (bounded
//!   queues, shard-boundary manifest checkpoints) + periodic
//!   incremental model refresh (PartialFit merges over new shards
//!   only) + lock-free queries from an `Arc`-swapped snapshot, with
//!   graceful degradation (stale-snapshot serving, typed backpressure)
//!   over newline-delimited JSON (stdin pipe or Unix socket).
//! * [`store`] — the persistent sharded store for sparsified data:
//!   compress once with `FitPlan::compress()`, then fit PCA / K-means any
//!   number of times from disk without touching the raw stream again —
//!   including fully out-of-core K-means via
//!   `FitPlan::kmeans().solver(Solver::Stream)` (`rust/ARCHITECTURE.md`
//!   maps the full pipeline, `docs/FORMAT.md` specifies the bytes).

#![warn(missing_docs)]
// CI runs `cargo clippy --all-targets -- -D warnings` (blocking); the
// style classes below are allowed crate-wide because they flag idioms
// this codebase uses deliberately, not defects:
#![allow(clippy::needless_range_loop)] // index loops mirror the paper's sums over (p, m, n, k)
#![allow(clippy::too_many_arguments)] // kernels take dims/strides explicitly, no config structs
#![allow(clippy::many_single_char_names)] // p, m, n, k, γ are the paper's own symbols
#![allow(clippy::excessive_precision)] // constants are quoted to full printed precision

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod convert;
pub mod coordinator;
pub mod data;
pub mod distributed;
pub mod error;
pub mod estimators;
pub mod experiments;
pub mod kmeans;
pub mod linalg;
pub mod metrics;
pub mod parallel;
pub mod pca;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod simd;
pub mod sparse;
pub mod store;
pub mod testing;
pub mod transform;

pub use error::{Error, Result};

/// Convenience re-exports of the types most programs touch.
pub mod prelude {
    pub use crate::coordinator::{
        ChunkSource, DenseChunk, FitOutcome, FitPlan, FitReport, Solver, StreamConfig,
    };
    pub use crate::sparse::{SparseChunkSource, SparseVecSource};
    pub use crate::distributed::PartialFit;
    pub use crate::error::{Error, Result};
    pub use crate::estimators::{CovarianceEstimator, SparseMeanEstimator};
    pub use crate::kmeans::{KmeansOpts, KmeansResult, SparsifiedKmeans};
    pub use crate::linalg::Mat;
    pub use crate::rng::Pcg64;
    pub use crate::sampling::{Scheme, Sparsifier, SparsifyConfig};
    pub use crate::sparse::{Precision, SparseChunk};
    pub use crate::store::{SparseStoreReader, SparseStoreWriter, StoreManifest};
    pub use crate::transform::{Ros, TransformKind};
}
