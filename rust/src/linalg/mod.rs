//! Dense linear algebra substrate (no external BLAS in this offline build).
//!
//! [`Mat`] is a column-major `f64` matrix — samples are columns throughout
//! the crate, matching the paper's `X ∈ R^{p×n}` convention. The hot
//! kernels (`matmul`, `syrk`) use an axpy-ordered loop that streams
//! contiguous columns; QR / symmetric-eig / randomized-SVD live in
//! submodules. [`krylov`](self) adds the operator-driven
//! ([`SymOp`]) block-Krylov top-k eigensolver, the covariance-free
//! counterpart of [`sym_eig_topk`].

mod chol;
mod eig;
mod krylov;
mod mat;
mod qr;
mod svd;

pub use chol::{cholesky, cholesky_solve};
pub use eig::{jacobi_eigh, spectral_norm_sym, sym_eig_topk};
pub use krylov::{block_krylov_topk, DenseSymOp, SymOp};
pub use mat::Mat;
pub use qr::{orthonormalize, qr_thin};
pub use svd::{leverage_scores, randomized_svd, Svd};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixtures::randmat;

    #[test]
    fn matmul_against_naive() {
        let a = randmat(7, 5, 1);
        let b = randmat(5, 9, 2);
        let c = a.matmul(&b);
        for i in 0..7 {
            for j in 0..9 {
                let mut s = 0.0;
                for k in 0..5 {
                    s += a.get(i, k) * b.get(k, j);
                }
                assert!((c.get(i, j) - s).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn matmul_transa_against_naive() {
        let a = randmat(6, 4, 3);
        let b = randmat(6, 3, 4);
        let c = a.matmul_transa(&b); // A^T B: (4,3)
        for i in 0..4 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..6 {
                    s += a.get(k, i) * b.get(k, j);
                }
                assert!((c.get(i, j) - s).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn syrk_matches_matmul() {
        let a = randmat(8, 5, 5);
        let g = a.syrk(); // A A^T
        let g2 = a.matmul(&a.transpose());
        for i in 0..8 {
            for j in 0..8 {
                assert!((g.get(i, j) - g2.get(i, j)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn norms() {
        let mut m = Mat::zeros(3, 2);
        m.set(0, 0, 3.0);
        m.set(1, 1, -4.0);
        assert!((m.frob_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.max_abs(), 4.0);
        assert!((m.max_col_norm() - 4.0).abs() < 1e-12);
        assert!((m.max_row_norm() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn qr_orthonormal_and_reconstructs() {
        let a = randmat(10, 4, 7);
        let (q, r) = qr_thin(&a);
        let qtq = q.matmul_transa(&q);
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((qtq.get(i, j) - want).abs() < 1e-10, "Q^T Q not I");
            }
        }
        let qr = q.matmul(&r);
        for i in 0..10 {
            for j in 0..4 {
                assert!((qr.get(i, j) - a.get(i, j)).abs() < 1e-10, "QR != A");
            }
        }
        // R upper-triangular
        for i in 1..4 {
            for j in 0..i {
                assert!(r.get(i, j).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn jacobi_recovers_known_spectrum() {
        // A = Q diag(5,2,1) Q^T for a random orthonormal Q
        let q0 = orthonormalize(&randmat(3, 3, 11));
        let lam = [5.0, 2.0, 1.0];
        let mut a = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += q0.get(i, k) * lam[k] * q0.get(j, k);
                }
                a.set(i, j, s);
            }
        }
        let (vals, vecs) = jacobi_eigh(&a);
        assert!((vals[0] - 5.0).abs() < 1e-9);
        assert!((vals[1] - 2.0).abs() < 1e-9);
        assert!((vals[2] - 1.0).abs() < 1e-9);
        // eigenvectors satisfy A v = lambda v
        for k in 0..3 {
            for i in 0..3 {
                let mut av = 0.0;
                for j in 0..3 {
                    av += a.get(i, j) * vecs.get(j, k);
                }
                assert!((av - vals[k] * vecs.get(i, k)).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn spectral_norm_sym_matches_jacobi() {
        let b = randmat(20, 20, 13);
        // symmetrize
        let mut a = Mat::zeros(20, 20);
        for i in 0..20 {
            for j in 0..20 {
                a.set(i, j, 0.5 * (b.get(i, j) + b.get(j, i)));
            }
        }
        let (vals, _) = jacobi_eigh(&a);
        let want = vals.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        let got = spectral_norm_sym(&a, 1e-10, 5000);
        assert!((got - want).abs() / want < 1e-6, "got {got} want {want}");
    }

    #[test]
    fn topk_eig_matches_jacobi_on_psd() {
        let x = randmat(30, 50, 17);
        let c = x.syrk().scaled(1.0 / 50.0);
        let (vals_full, vecs_full) = jacobi_eigh(&c);
        let (vals, vecs) = sym_eig_topk(&c, 5, 60, 31);
        for k in 0..5 {
            assert!(
                (vals[k] - vals_full[k]).abs() / vals_full[k].max(1e-12) < 1e-6,
                "eigenvalue {k}: {} vs {}",
                vals[k],
                vals_full[k]
            );
            // eigenvector up to sign
            let dot: f64 = (0..30).map(|i| vecs.get(i, k) * vecs_full.get(i, k)).sum();
            assert!(dot.abs() > 1.0 - 1e-6, "eigvec {k} dot {dot}");
        }
    }

    #[test]
    fn randomized_svd_rank_revealing() {
        // rank-3 matrix + tiny noise
        let u = orthonormalize(&randmat(40, 3, 19));
        let v = orthonormalize(&randmat(25, 3, 23));
        let mut a = Mat::zeros(40, 25);
        let s = [9.0, 4.0, 2.0];
        for i in 0..40 {
            for j in 0..25 {
                let mut val = 0.0;
                for k in 0..3 {
                    val += u.get(i, k) * s[k] * v.get(j, k);
                }
                a.set(i, j, val);
            }
        }
        let svd = randomized_svd(&a, 3, 8, 2, 29);
        for k in 0..3 {
            assert!((svd.singular_values[k] - s[k]).abs() < 1e-6, "{:?}", svd.singular_values);
            let dot: f64 = (0..40).map(|i| svd.u.get(i, k) * u.get(i, k)).sum();
            assert!(dot.abs() > 1.0 - 1e-8);
        }
    }
}
