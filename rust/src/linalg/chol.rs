//! Cholesky factorization and SPD solves (used for the Toeplitz covariance
//! of the Fig. 1 multivariate-t generator and the `Ω⁺` lift of the
//! feature-extraction baseline).

use super::Mat;
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ` (A symmetric
/// positive-definite).
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    if n != a.cols() {
        return Err(Error::Shape("cholesky: square input required".into()));
    }
    let mut l = Mat::zeros(n, n);
    for j in 0..n {
        let mut d = a.get(j, j);
        for k in 0..j {
            let v = l.get(j, k);
            d -= v * v;
        }
        if d <= 0.0 {
            return Err(Error::Numerical(format!("cholesky: not SPD at pivot {j} (d={d})")));
        }
        let dj = d.sqrt();
        l.set(j, j, dj);
        for i in (j + 1)..n {
            let mut s = a.get(i, j);
            for k in 0..j {
                s -= l.get(i, k) * l.get(j, k);
            }
            l.set(i, j, s / dj);
        }
    }
    Ok(l)
}

/// Solve `A x = b` for SPD `A` given its Cholesky factor `L`.
pub fn cholesky_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    debug_assert_eq!(b.len(), n);
    // forward: L y = b
    let mut y = b.to_vec();
    for i in 0..n {
        for k in 0..i {
            let lik = l.get(i, k);
            y[i] -= lik * y[k];
        }
        y[i] /= l.get(i, i);
    }
    // backward: Lᵀ x = y
    for i in (0..n).rev() {
        for k in (i + 1)..n {
            let lki = l.get(k, i);
            y[i] -= lki * y[k];
        }
        y[i] /= l.get(i, i);
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn factor_and_solve() {
        let mut rng = Pcg64::seed(31);
        let b = Mat::from_fn(6, 6, |_, _| rng.normal());
        let a = b.syrk().scaled(1.0).clone();
        let mut a = a;
        for i in 0..6 {
            a.add_at(i, i, 6.0); // well-conditioned SPD
        }
        let l = cholesky(&a).unwrap();
        // L Lᵀ = A
        let llt = l.matmul(&l.transpose());
        assert!((llt.sub(&a)).max_abs() < 1e-10);
        let rhs: Vec<f64> = (0..6).map(|i| i as f64 - 2.5).collect();
        let x = cholesky_solve(&l, &rhs);
        let ax = a.matvec(&x);
        for (got, want) in ax.iter().zip(&rhs) {
            assert!((got - want).abs() < 1e-9);
        }
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Mat::eye(3);
        a.set(2, 2, -1.0);
        assert!(cholesky(&a).is_err());
    }
}
