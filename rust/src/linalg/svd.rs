//! Randomized SVD (Halko–Martinsson–Tropp), the approximate-SVD substrate
//! the paper's *feature selection* baseline [36] needs for leverage scores.

use super::{jacobi_eigh, orthonormalize, Mat};
use crate::rng::Pcg64;

/// Truncated singular value decomposition `A ≈ U diag(s) Vᵀ`.
pub struct Svd {
    /// Left singular vectors, rows(A) × k.
    pub u: Mat,
    /// Singular values, descending.
    pub singular_values: Vec<f64>,
    /// Right singular vectors, cols(A) × k.
    pub v: Mat,
}

/// Randomized truncated SVD with `oversample` extra probe directions and
/// `power_iters` rounds of subspace iteration (2 is plenty for the
/// leverage-score use case; raise for slowly decaying spectra).
pub fn randomized_svd(a: &Mat, k: usize, oversample: usize, power_iters: usize, seed: u64) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    let l = (k + oversample).min(m).min(n);
    let mut rng = Pcg64::seed(seed);
    let g = Mat::from_fn(n, l, |_, _| rng.normal());
    let mut q = orthonormalize(&a.matmul(&g)); // m×l
    let at = a.transpose();
    for _ in 0..power_iters {
        q = orthonormalize(&at.matmul(&q)); // n×l
        q = orthonormalize(&a.matmul(&q)); // m×l
    }
    // B = Qᵀ A  (l×n); eig of B Bᵀ (l×l) gives singular pairs
    let b = q.matmul_transa(a); // Qᵀ A: (l×n)
    let bbt = b.matmul(&b.transpose()); // l×l
    let (vals, vecs) = jacobi_eigh(&bbt);
    let k = k.min(l);
    let mut s = Vec::with_capacity(k);
    let mut u = Mat::zeros(m, k);
    let mut v = Mat::zeros(n, k);
    let qu = q.matmul(&vecs); // m×l, left singular vectors of A
    for j in 0..k {
        let sigma = vals[j].max(0.0).sqrt();
        s.push(sigma);
        for i in 0..m {
            u.set(i, j, qu.get(i, j));
        }
        if sigma > 1e-300 {
            // v_j = Aᵀ u_j / sigma
            let uj: Vec<f64> = (0..m).map(|i| qu.get(i, j)).collect();
            let vj = a.matvec_transa(&uj);
            for i in 0..n {
                v.set(i, j, vj[i] / sigma);
            }
        }
    }
    Svd { u, singular_values: s, v }
}

/// Row leverage scores from the top-k left singular vectors:
/// `ℓ_j = (1/k) Σ_t U[j,t]²` (sums to 1). The feature-selection baseline
/// samples rows of `X` with these probabilities.
pub fn leverage_scores(u: &Mat, k: usize) -> Vec<f64> {
    let k = k.min(u.cols());
    let mut scores = vec![0.0; u.rows()];
    for t in 0..k {
        for j in 0..u.rows() {
            let v = u.get(j, t);
            scores[j] += v * v;
        }
    }
    let inv = 1.0 / k as f64;
    for s in &mut scores {
        *s *= inv;
    }
    scores
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leverage_scores_sum_to_one() {
        let mut rng = Pcg64::seed(1);
        let a = Mat::from_fn(20, 30, |_, _| rng.normal());
        let svd = randomized_svd(&a, 5, 5, 2, 3);
        let s = leverage_scores(&svd.u, 5);
        let total: f64 = s.iter().sum();
        assert!((total - 1.0).abs() < 1e-8, "sum={total}");
        assert!(s.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn singular_values_descending() {
        let mut rng = Pcg64::seed(5);
        let a = Mat::from_fn(15, 12, |_, _| rng.normal());
        let svd = randomized_svd(&a, 6, 4, 2, 7);
        for w in svd.singular_values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }
}
