//! Symmetric eigensolvers: cyclic Jacobi for small dense matrices, power
//! iteration for spectral norms of (possibly indefinite) symmetric error
//! matrices, and randomized subspace iteration for the top-k spectrum of
//! large PSD covariance estimates.

use super::{orthonormalize, Mat};
use crate::rng::Pcg64;

/// Full eigendecomposition of a symmetric matrix by cyclic Jacobi.
/// Returns `(eigenvalues desc, eigenvectors as columns)`. Intended for
/// small matrices (k×k projections, k ≲ 64); O(n³) per sweep.
pub fn jacobi_eigh(a: &Mat) -> (Vec<f64>, Mat) {
    let n = a.rows();
    assert_eq!(n, a.cols(), "jacobi_eigh: square input required");
    let mut m = a.clone();
    let mut v = Mat::eye(n);
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m.get(i, j) * m.get(i, j);
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + m.frob_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m.get(p, q);
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m.get(p, p);
                let aqq = m.get(q, q);
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p and q of m
                for k in 0..n {
                    let mkp = m.get(k, p);
                    let mkq = m.get(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.get(p, k);
                    let mqk = m.get(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v.get(k, p);
                    let vkq = v.get(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m.get(i, i), i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let vals: Vec<f64> = pairs.iter().map(|&(l, _)| l).collect();
    let mut vecs = Mat::zeros(n, n);
    for (newk, &(_, oldk)) in pairs.iter().enumerate() {
        for i in 0..n {
            vecs.set(i, newk, v.get(i, oldk));
        }
    }
    (vals, vecs)
}

/// Spectral norm (largest |eigenvalue|) of a symmetric matrix via power
/// iteration. Used for the error norms `‖Ĉ_n − C_emp‖₂` of Theorems 6/7 —
/// the matrices are symmetric but indefinite, and power iteration on `A`
/// converges to the dominant |λ| directly.
pub fn spectral_norm_sym(a: &Mat, tol: f64, max_iter: usize) -> f64 {
    let n = a.rows();
    assert_eq!(n, a.cols());
    if n == 0 {
        return 0.0;
    }
    let mut rng = Pcg64::seed(0x51EC ^ n as u64);
    let mut v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let mut lam_prev = 0.0f64;
    for _ in 0..max_iter {
        let mut w = a.matvec(&v);
        let nrm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm == 0.0 {
            return 0.0;
        }
        for x in &mut w {
            *x /= nrm;
        }
        // Rayleigh quotient
        let av = a.matvec(&w);
        let lam: f64 = w.iter().zip(&av).map(|(a, b)| a * b).sum();
        v = w;
        if (lam.abs() - lam_prev.abs()).abs() <= tol * lam.abs().max(1e-30) {
            return lam.abs();
        }
        lam_prev = lam;
    }
    lam_prev.abs()
}

/// Top-k eigenpairs of a symmetric PSD matrix via randomized subspace
/// iteration (Halko et al.): `Q ← orth(A Q)` repeated, then a k×k Jacobi
/// solve of `Qᵀ A Q`. Returns `(values desc, vectors p×k)`.
pub fn sym_eig_topk(a: &Mat, k: usize, iters: usize, seed: u64) -> (Vec<f64>, Mat) {
    let p = a.rows();
    assert_eq!(p, a.cols());
    let k = k.min(p);
    let over = (k + 4).min(p); // small oversampling
    let mut rng = Pcg64::seed(seed);
    let g = Mat::from_fn(p, over, |_, _| rng.normal());
    let mut q = orthonormalize(&a.matmul(&g));
    for _ in 0..iters {
        q = orthonormalize(&a.matmul(&q));
    }
    let small = q.matmul_transa(&a.matmul(&q)); // over×over symmetric
    let (vals, vecs) = jacobi_eigh(&small);
    let full = q.matmul(&vecs); // p×over
    let mut out = Mat::zeros(p, k);
    for j in 0..k {
        for i in 0..p {
            out.set(i, j, full.get(i, j));
        }
    }
    (vals[..k].to_vec(), out)
}
