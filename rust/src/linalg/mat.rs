//! Column-major dense `f64` matrix.

use crate::error::{shape_err, Result};

/// Column-major dense matrix. Column `j` is the contiguous slice
/// `data[j*rows .. (j+1)*rows]` — samples-as-columns is the layout of every
/// pipeline stage, so per-sample operations are contiguous.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from a generator `f(row, col)`. Evaluated column-major, so a
    /// stateful closure (e.g. an RNG) fills columns contiguously.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for j in 0..cols {
            for i in 0..rows {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing column-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return shape_err(format!("from_vec: {} != {rows}x{cols}", data.len()));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Row count.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Entry `(i, j)`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    /// Overwrite entry `(i, j)`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    /// Add `v` to entry `(i, j)`.
    #[inline]
    pub fn add_at(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] += v;
    }

    /// Contiguous column slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable contiguous column slice.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// The full column-major buffer.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable full column-major buffer.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Copy columns `[start, end)` into a new matrix.
    pub fn col_range(&self, start: usize, end: usize) -> Mat {
        assert!(start <= end && end <= self.cols);
        Mat {
            rows: self.rows,
            cols: end - start,
            data: self.data[start * self.rows..end * self.rows].to_vec(),
        }
    }

    /// Transpose (materialized).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            let c = self.col(j);
            for i in 0..self.rows {
                t.data[i * self.cols + j] = c[i];
            }
        }
        t
    }

    /// `C = self * b` — axpy-ordered (j,k) loop: both `self`'s and `C`'s
    /// columns stream contiguously.
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul inner dims");
        let mut c = Mat::zeros(self.rows, b.cols);
        for j in 0..b.cols {
            let bcol = b.col(j);
            let ccol = &mut c.data[j * self.rows..(j + 1) * self.rows];
            for (k, &bkj) in bcol.iter().enumerate() {
                if bkj == 0.0 {
                    continue;
                }
                let acol = &self.data[k * self.rows..(k + 1) * self.rows];
                for i in 0..self.rows {
                    ccol[i] += acol[i] * bkj;
                }
            }
        }
        c
    }

    /// `C = self^T * b` — dot-product formulation over contiguous columns.
    pub fn matmul_transa(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "matmul_transa inner dims");
        let mut c = Mat::zeros(self.cols, b.cols);
        for j in 0..b.cols {
            let bcol = b.col(j);
            for i in 0..self.cols {
                let acol = self.col(i);
                let mut s = 0.0;
                for k in 0..self.rows {
                    s += acol[k] * bcol[k];
                }
                c.data[j * self.cols + i] = s;
            }
        }
        c
    }

    /// Gram matrix `self * self^T` (p×p), exploiting symmetry.
    pub fn syrk(&self) -> Mat {
        let p = self.rows;
        let mut g = Mat::zeros(p, p);
        for jcol in 0..self.cols {
            let c = self.col(jcol);
            for j in 0..p {
                let cj = c[j];
                if cj == 0.0 {
                    continue;
                }
                let gcol = &mut g.data[j * p..(j + 1) * p];
                for i in j..p {
                    gcol[i] += c[i] * cj;
                }
            }
        }
        // mirror lower triangle into upper
        for j in 0..p {
            for i in (j + 1)..p {
                let v = g.data[j * p + i];
                g.data[i * p + j] = v;
            }
        }
        g
    }

    /// Matrix–vector product `self * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        let mut y = vec![0.0; self.rows];
        for (k, &xk) in x.iter().enumerate() {
            if xk == 0.0 {
                continue;
            }
            let col = self.col(k);
            for i in 0..self.rows {
                y[i] += col[i] * xk;
            }
        }
        y
    }

    /// `self^T * x`.
    pub fn matvec_transa(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        (0..self.cols)
            .map(|j| self.col(j).iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Returns `alpha * self`.
    pub fn scaled(&self, alpha: f64) -> Mat {
        let mut m = self.clone();
        for v in &mut m.data {
            *v *= alpha;
        }
        m
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Zero out all off-diagonal entries (the paper's `diag(·)` operator).
    pub fn diag_part(&self) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut d = Mat::zeros(self.rows, self.rows);
        for i in 0..self.rows {
            d.set(i, i, self.get(i, i));
        }
        d
    }

    /// The diagonal as a vector.
    pub fn diagonal(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self.get(i, i)).collect()
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// `‖X‖_max`: maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// `‖X‖_max-col = ‖X‖_{1→2}`: maximum column l2 norm.
    pub fn max_col_norm(&self) -> f64 {
        (0..self.cols)
            .map(|j| self.col(j).iter().map(|v| v * v).sum::<f64>().sqrt())
            .fold(0.0f64, f64::max)
    }

    /// `‖X‖_max-row = ‖X‖_{2→∞}`: maximum row l2 norm.
    pub fn max_row_norm(&self) -> f64 {
        let mut acc = vec![0.0f64; self.rows];
        for j in 0..self.cols {
            let c = self.col(j);
            for i in 0..self.rows {
                acc[i] += c[i] * c[i];
            }
        }
        acc.iter().fold(0.0f64, |m, &v| m.max(v)).sqrt()
    }

    /// Column means: `x̄ = (1/n) Σ x_i`.
    pub fn col_mean(&self) -> Vec<f64> {
        let mut mean = vec![0.0; self.rows];
        for j in 0..self.cols {
            let c = self.col(j);
            for i in 0..self.rows {
                mean[i] += c[i];
            }
        }
        let inv = 1.0 / self.cols as f64;
        for v in &mut mean {
            *v *= inv;
        }
        mean
    }

    /// Normalize every column to unit l2 norm (zero columns left as-is).
    pub fn normalize_columns(&mut self) {
        for j in 0..self.cols {
            let c = self.col_mut(j);
            let nrm = c.iter().map(|v| v * v).sum::<f64>().sqrt();
            if nrm > 0.0 {
                for v in c.iter_mut() {
                    *v /= nrm;
                }
            }
        }
    }

    /// Convert to an `f32` column-major buffer (runtime interop).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Build from an `f32` column-major buffer.
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Result<Self> {
        if data.len() != rows * cols {
            return shape_err(format!("from_f32: {} != {rows}x{cols}", data.len()));
        }
        Ok(Mat { rows, cols, data: data.iter().map(|&v| v as f64).collect() })
    }
}

/// Euclidean distance squared between two equal-length slices.
#[inline]
#[allow(dead_code)]
pub(crate) fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn col_major_layout() {
        let m = Mat::from_fn(2, 3, |i, j| (10 * i + j) as f64);
        assert_eq!(m.as_slice(), &[0.0, 10.0, 1.0, 11.0, 2.0, 12.0]);
        assert_eq!(m.col(1), &[1.0, 11.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Mat::from_fn(4, 6, |i, j| (i * 7 + j * 3) as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn diag_part_and_sub() {
        let m = Mat::from_fn(3, 3, |i, j| (i + j) as f64 + 1.0);
        let d = m.diag_part();
        assert_eq!(d.get(1, 1), 3.0);
        assert_eq!(d.get(0, 1), 0.0);
        let z = m.sub(&m);
        assert_eq!(z.frob_norm(), 0.0);
    }

    #[test]
    fn col_mean_and_normalize() {
        let mut m = Mat::from_vec(2, 2, vec![1.0, 0.0, 3.0, 4.0]).unwrap();
        assert_eq!(m.col_mean(), vec![2.0, 2.0]);
        m.normalize_columns();
        assert!((m.col(1).iter().map(|v| v * v).sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Mat::from_vec(2, 2, vec![0.0; 3]).is_err());
    }

    #[test]
    fn f32_roundtrip() {
        let m = Mat::from_fn(3, 2, |i, j| i as f64 - j as f64 * 0.5);
        let back = Mat::from_f32(3, 2, &m.to_f32()).unwrap();
        assert!((back.sub(&m)).max_abs() < 1e-6);
    }
}
