//! Thin Householder QR and orthonormalization (the building block of the
//! randomized SVD and subspace iteration).

use super::Mat;

/// Thin QR of `a` (rows ≥ cols): returns `(Q, R)` with `Q` rows×cols
/// orthonormal and `R` cols×cols upper-triangular, `a = Q R`.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let m = a.rows();
    let n = a.cols();
    assert!(m >= n, "qr_thin requires rows >= cols");
    // Householder working copy
    let mut h = a.clone();
    // store the n reflectors (v, beta)
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);
    let mut betas = Vec::with_capacity(n);
    for k in 0..n {
        // build reflector from h[k.., k]
        let col = h.col(k);
        let x = &col[k..];
        let alpha = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        let mut v = x.to_vec();
        if alpha == 0.0 {
            vs.push(v);
            betas.push(0.0);
            continue;
        }
        let sign = if v[0] >= 0.0 { 1.0 } else { -1.0 };
        v[0] += sign * alpha;
        let vnorm2: f64 = v.iter().map(|t| t * t).sum();
        let beta = if vnorm2 > 0.0 { 2.0 / vnorm2 } else { 0.0 };
        // apply reflector to remaining columns of h
        for j in k..n {
            let cj = h.col_mut(j);
            let dot: f64 = v.iter().zip(&cj[k..]).map(|(a, b)| a * b).sum();
            let s = beta * dot;
            for (vi, c) in v.iter().zip(cj[k..].iter_mut()) {
                *c -= s * vi;
            }
        }
        vs.push(v);
        betas.push(beta);
    }
    // R = upper triangle of h
    let mut r = Mat::zeros(n, n);
    for j in 0..n {
        for i in 0..=j {
            r.set(i, j, h.get(i, j));
        }
    }
    // Q = (I - b1 v1 v1^T) ... (I - bn vn vn^T) * [I; 0] — apply reflectors
    // in reverse to the thin identity.
    let mut q = Mat::zeros(m, n);
    for j in 0..n {
        q.set(j, j, 1.0);
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        let beta = betas[k];
        if beta == 0.0 {
            continue;
        }
        for j in 0..n {
            let cj = q.col_mut(j);
            let dot: f64 = v.iter().zip(&cj[k..]).map(|(a, b)| a * b).sum();
            let s = beta * dot;
            for (vi, c) in v.iter().zip(cj[k..].iter_mut()) {
                *c -= s * vi;
            }
        }
    }
    (q, r)
}

/// Orthonormal basis for the column space of `a` (just the Q factor).
pub fn orthonormalize(a: &Mat) -> Mat {
    qr_thin(a).0
}
