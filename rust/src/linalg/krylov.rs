//! Covariance-free top-k symmetric eigensolver: randomized block-Krylov /
//! subspace iteration driven by an abstract operator.
//!
//! [`sym_eig_topk`](super::sym_eig_topk) needs the p×p matrix in memory;
//! for the PCA arm that matrix is the estimated covariance, whose O(p²)
//! materialization dominates cost and memory once p grows. But subspace
//! iteration only ever touches the matrix through block products `A·Ω`,
//! so [`block_krylov_topk`] takes a [`SymOp`] — anything that can apply a
//! symmetric p×p operator to a thin p×b block — and computes the same
//! Rayleigh–Ritz approximation in O(p·b) working memory. The sparse
//! implementations (`estimators::SparseCovOp`, `coordinator`'s
//! store-streaming operator) evaluate the Theorem 6 covariance estimate's
//! action as `c₁·W(WᵀB) − c₂·diag∘B` directly from [`SparseChunk`]s,
//! never forming the estimate itself.
//!
//! [`SparseChunk`]: crate::sparse::SparseChunk

use crate::error::Result;
use crate::rng::Pcg64;

use super::{jacobi_eigh, orthonormalize, Mat};

/// A symmetric linear operator on `R^p`, presented through its action on
/// thin blocks. Implementations must be deterministic (same block in,
/// same bits out) — the solver's output is then a pure function of
/// `(operator, k, iters, seed)`.
///
/// `apply` takes `&mut self` so implementations may hold mutable
/// resources (a rewinding store reader, pass counters); mathematically
/// the operator must not change between calls.
pub trait SymOp {
    /// Operator dimension p (acts on `R^p`).
    fn dim(&self) -> usize;

    /// `A · block` for a `p × b` block; must return a `p × b` matrix.
    fn apply(&mut self, block: &Mat) -> Result<Mat>;
}

/// The trivial [`SymOp`]: a materialized symmetric matrix. Exists so the
/// solver can be pinned against [`jacobi_eigh`] /
/// [`sym_eig_topk`](super::sym_eig_topk) in tests and used on small
/// problems without a sparse source.
pub struct DenseSymOp<'a> {
    a: &'a Mat,
}

impl<'a> DenseSymOp<'a> {
    /// Wrap a symmetric matrix (square required; symmetry is the
    /// caller's contract, as everywhere else in [`eig`](super)).
    pub fn new(a: &'a Mat) -> Self {
        assert_eq!(a.rows(), a.cols(), "DenseSymOp: square input required");
        DenseSymOp { a }
    }
}

impl SymOp for DenseSymOp<'_> {
    fn dim(&self) -> usize {
        self.a.rows()
    }

    fn apply(&mut self, block: &Mat) -> Result<Mat> {
        Ok(self.a.matmul(block))
    }
}

/// Top-k eigenpairs of a symmetric operator via randomized block-Krylov
/// subspace iteration: `Q ← orth(A Q)` repeated `iters` times from a
/// seeded Gaussian start block (k + 4 oversampled columns), then a small
/// Jacobi solve of the Rayleigh quotient `Qᵀ A Q`. Returns
/// `(values desc, vectors p×k)`.
///
/// This is exactly the [`sym_eig_topk`](super::sym_eig_topk) schedule
/// with the matrix product abstracted behind [`SymOp::apply`]: for
/// [`DenseSymOp`] with the same `(k, iters, seed)` the two return
/// bit-identical results. Working memory is O(p·(k+4)) — no p×p
/// allocation anywhere — and the operator is applied `iters + 2` times.
pub fn block_krylov_topk(
    op: &mut dyn SymOp,
    k: usize,
    iters: usize,
    seed: u64,
) -> Result<(Vec<f64>, Mat)> {
    let p = op.dim();
    let k = k.min(p);
    let over = (k + 4).min(p); // small oversampling
    let mut rng = Pcg64::seed(seed);
    let g = Mat::from_fn(p, over, |_, _| rng.normal());
    let mut q = orthonormalize(&op.apply(&g)?);
    for _ in 0..iters {
        q = orthonormalize(&op.apply(&q)?);
    }
    let aq = op.apply(&q)?;
    let small = q.matmul_transa(&aq); // over×over symmetric
    let (vals, vecs) = jacobi_eigh(&small);
    let full = q.matmul(&vecs); // p×over
    Ok((vals[..k].to_vec(), full.col_range(0, k)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sym_eig_topk;
    use crate::testing::fixtures::{spiked_cov, sym_mat};
    use crate::testing::prop::forall;

    /// cos²θ_max between the column spans of two orthonormal p×k bases:
    /// the smallest eigenvalue of (U₁ᵀU₂)(U₁ᵀU₂)ᵀ.
    fn min_cos2_principal_angle(u1: &Mat, u2: &Mat) -> f64 {
        assert_eq!(u1.cols(), u2.cols());
        let m = u1.matmul_transa(u2); // k×k
        let mmt = m.syrk();
        let (vals, _) = jacobi_eigh(&mmt);
        *vals.last().unwrap()
    }

    #[test]
    fn dense_op_matches_sym_eig_topk_bitwise() {
        // same schedule, same RNG stream => identical bits
        let a = sym_mat(24, 3);
        let (v_ref, u_ref) = sym_eig_topk(&a, 5, 30, 11);
        let mut op = DenseSymOp::new(&a);
        let (v, u) = block_krylov_topk(&mut op, 5, 30, 11).unwrap();
        for (x, y) in v.iter().zip(&v_ref) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in u.as_slice().iter().zip(u_ref.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn krylov_matches_jacobi_on_separated_spectra() {
        // property: on symmetric matrices with a guaranteed eigengap the
        // solver reproduces the exact (Jacobi) top-k eigenpairs — values
        // to relative tolerance, vectors to subspace angle
        forall("krylov_vs_jacobi", 12, |g| {
            let p = g.int(8, 28) as usize;
            let k = g.int(1, 4) as usize;
            // descending spiked spectrum with gaps ≥ 1.5x
            let lambdas: Vec<f64> =
                (0..k).map(|t| 10.0 * 1.5f64.powi(-(t as i32)) + g.float(0.0, 0.3)).collect();
            let (c, _) = spiked_cov(p, &lambdas, g.int(0, 1 << 40) as u64);
            let (v_full, u_full) = jacobi_eigh(&c);
            let mut op = DenseSymOp::new(&c);
            let (v, u) = block_krylov_topk(&mut op, k, 40, g.int(0, 1 << 40) as u64).unwrap();
            for t in 0..k {
                let rel = (v[t] - v_full[t]).abs() / v_full[t].max(1e-12);
                assert!(rel < 1e-8, "case {}: eigenvalue {t}: {} vs {}", g.case, v[t], v_full[t]);
            }
            let u_ref = u_full.col_range(0, k);
            let cos2 = min_cos2_principal_angle(&u, &u_ref);
            assert!(cos2 > 1.0 - 1e-8, "case {}: subspace angle cos² {cos2}", g.case);
        });
    }

    #[test]
    fn k_clamped_to_dim() {
        let a = sym_mat(5, 7);
        let mut op = DenseSymOp::new(&a);
        let (vals, vecs) = block_krylov_topk(&mut op, 12, 30, 1).unwrap();
        assert_eq!(vals.len(), 5);
        assert_eq!((vecs.rows(), vecs.cols()), (5, 5));
    }
}
