//! PCA from the (estimated) covariance matrix, plus the paper's two PC
//! quality metrics: explained variance (Fig. 1) and recovered-PC count
//! (Table I, inner product ≥ 0.95).

use crate::error::Result;
use crate::linalg::{block_krylov_topk, sym_eig_topk, Mat, SymOp};

/// Subspace-iteration count used by [`Pca::from_covariance`] and, via
/// `coordinator::DEFAULT_KRYLOV_ITERS`, by the covariance-free drivers —
/// one constant so the two solvers always run matched iteration budgets
/// (the solver-comparison experiments and tests rely on this).
pub const DEFAULT_PCA_ITERS: usize = 30;

/// Principal components extracted from a symmetric covariance estimate.
pub struct Pca {
    /// Components as columns (p×k), unit-norm.
    pub components: Mat,
    /// Corresponding eigenvalues, descending.
    pub eigenvalues: Vec<f64>,
}

impl Pca {
    /// Top-`k` eigenpairs of a symmetric (estimated) covariance matrix via
    /// randomized subspace iteration.
    pub fn from_covariance(c: &Mat, k: usize, seed: u64) -> Pca {
        let (vals, vecs) = sym_eig_topk(c, k, DEFAULT_PCA_ITERS, seed);
        Pca { components: vecs, eigenvalues: vals }
    }

    /// Top-`k` eigenpairs of an *implicit* covariance operator via
    /// randomized block-Krylov iteration
    /// ([`block_krylov_topk`](crate::linalg::block_krylov_topk)) — the
    /// covariance-free PCA path. With a sparse operator
    /// ([`SparseCovOp`](crate::estimators::SparseCovOp), or the
    /// store-streaming operator behind
    /// `FitPlan::pca().solver(Solver::Krylov)`) this never materializes
    /// a p×p matrix: working memory is O(p·(k+4)) and the operator is
    /// applied `iters + 2` times.
    ///
    /// # Example
    ///
    /// ```
    /// use pds::estimators::SparseCovOp;
    /// use pds::linalg::Mat;
    /// use pds::pca::Pca;
    /// use pds::rng::Pcg64;
    /// use pds::sampling::{Sparsifier, SparsifyConfig};
    /// use pds::transform::TransformKind;
    ///
    /// let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 2 };
    /// let sp = Sparsifier::new(32, cfg)?;
    /// let mut rng = Pcg64::seed(5);
    /// let x = Mat::from_fn(32, 60, |_, _| rng.normal());
    /// let chunks = [sp.compress_chunk(&x, 0)?];
    ///
    /// // top-3 PCs of the Thm 6 estimate, no p×p matrix anywhere
    /// let mut op = SparseCovOp::new(&chunks, 1)?;
    /// let pca = Pca::from_sparse_operator(&mut op, 3, 30, cfg.seed)?;
    /// assert_eq!(pca.components.cols(), 3);
    /// assert!(pca.eigenvalues[0] >= pca.eigenvalues[2]);
    /// # Ok::<(), pds::Error>(())
    /// ```
    pub fn from_sparse_operator(
        op: &mut dyn SymOp,
        k: usize,
        iters: usize,
        seed: u64,
    ) -> Result<Pca> {
        let (vals, vecs) = block_krylov_topk(op, k, iters, seed)?;
        Ok(Pca { components: vecs, eigenvalues: vals })
    }

    /// Explained-variance fraction `tr(Ûᵀ C Û) / tr(C)` for this basis
    /// against a reference covariance (Fig. 1's metric; `C = X Xᵀ` up to a
    /// scale that cancels).
    pub fn explained_variance(&self, c_ref: &Mat) -> f64 {
        explained_variance(&self.components, c_ref)
    }
}

/// `tr(Ûᵀ C Û) / tr(C)` for any orthonormal basis `u` (p×k).
pub fn explained_variance(u: &Mat, c: &Mat) -> f64 {
    let p = c.rows();
    assert_eq!(u.rows(), p);
    let cu = c.matmul(u);
    let mut num = 0.0;
    for j in 0..u.cols() {
        let ucol = u.col(j);
        let ccol = cu.col(j);
        num += ucol.iter().zip(ccol).map(|(a, b)| a * b).sum::<f64>();
    }
    let tr: f64 = c.diagonal().iter().sum();
    if tr == 0.0 {
        0.0
    } else {
        num / tr
    }
}

/// Table I metric: number of estimated PCs whose best |inner product| with
/// the matching true PC exceeds `threshold` (0.95 in the paper). Greedy
/// one-to-one matching on |⟨û_i, u_j⟩|.
pub fn recovered_components(u_est: &Mat, u_true: &Mat, threshold: f64) -> usize {
    let ke = u_est.cols();
    let kt = u_true.cols();
    // |inner product| matrix
    let mut scores: Vec<(f64, usize, usize)> = Vec::with_capacity(ke * kt);
    for i in 0..ke {
        for j in 0..kt {
            let dot: f64 = u_est.col(i).iter().zip(u_true.col(j)).map(|(a, b)| a * b).sum();
            scores.push((dot.abs(), i, j));
        }
    }
    scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut used_e = vec![false; ke];
    let mut used_t = vec![false; kt];
    let mut count = 0;
    for (s, i, j) in scores {
        if s < threshold {
            break;
        }
        if !used_e[i] && !used_t[j] {
            used_e[i] = true;
            used_t[j] = true;
            count += 1;
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormalize;
    use crate::rng::Pcg64;
    use crate::testing::fixtures::spiked_cov;

    #[test]
    fn recovers_spiked_components() {
        let (c, u_true) = spiked_cov(40, &[10.0, 6.0, 3.0], 1);
        let pca = Pca::from_covariance(&c, 3, 7);
        assert_eq!(recovered_components(&pca.components, &u_true, 0.95), 3);
        assert!(pca.eigenvalues[0] > pca.eigenvalues[1]);
    }

    #[test]
    fn explained_variance_bounds() {
        let (c, u_true) = spiked_cov(30, &[5.0, 2.0], 3);
        let ev = explained_variance(&u_true, &c);
        assert!(ev > 0.9 && ev <= 1.0 + 1e-12, "ev={ev}");
        // a random basis explains less than the true one
        let mut rng = Pcg64::seed(9);
        let rand_u = orthonormalize(&Mat::from_fn(30, 2, |_, _| rng.normal()));
        assert!(explained_variance(&rand_u, &c) < ev);
    }

    #[test]
    fn recovered_count_zero_for_random_basis() {
        let (_, u_true) = spiked_cov(50, &[1.0, 1.0, 1.0], 5);
        let mut rng = Pcg64::seed(11);
        let u_est = orthonormalize(&Mat::from_fn(50, 3, |_, _| rng.normal()));
        assert_eq!(recovered_components(&u_est, &u_true, 0.95), 0);
    }

    #[test]
    fn sparse_operator_pca_matches_covariance_pca() {
        // both solvers target the same Thm 6 estimate; on a well-gapped
        // spiked workload they must find the same top components
        use crate::estimators::{CovarianceEstimator, SparseCovOp};
        use crate::sampling::{Sparsifier, SparsifyConfig};
        use crate::transform::TransformKind;
        let x = crate::testing::fixtures::spiked_data(64, 2000, &[10.0, 6.0, 3.0], 3);
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 9 };
        let sp = Sparsifier::new(64, cfg).unwrap();
        let chunk = sp.compress_chunk(&x, 0).unwrap();
        let mut est = CovarianceEstimator::new(sp.p(), sp.m());
        est.accumulate(&chunk);
        let dense = Pca::from_covariance(&est.estimate(), 3, 7);
        let chunks = [chunk];
        let mut op = SparseCovOp::new(&chunks, 2).unwrap();
        let kry = Pca::from_sparse_operator(&mut op, 3, 30, 7).unwrap();
        assert_eq!(recovered_components(&kry.components, &dense.components, 0.95), 3);
        for (a, b) in kry.eigenvalues.iter().zip(&dense.eigenvalues) {
            assert!((a - b).abs() / b.abs().max(1e-12) < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn recovered_matching_is_one_to_one() {
        // duplicate estimate columns may not double-count one true PC
        let (_, u_true) = spiked_cov(20, &[1.0], 13);
        let mut dup = Mat::zeros(20, 2);
        for i in 0..20 {
            dup.set(i, 0, u_true.get(i, 0));
            dup.set(i, 1, u_true.get(i, 0));
        }
        assert_eq!(recovered_components(&dup, &u_true, 0.95), 1);
    }
}
