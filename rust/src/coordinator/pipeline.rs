//! The compress pipeline: reader → bounded queue → sparsifier workers →
//! bounded queue → consumer, with per-phase timing.
//!
//! Backpressure: both queues are `sync_channel(queue_depth)` — a slow
//! consumer stalls the workers, stalled workers stall the reader, so at
//! most `2·queue_depth + workers + 1` dense chunks are in flight
//! regardless of stream length. That bound is what makes the out-of-core
//! runs (Table IV) possible in constant memory.

use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

use crate::error::{Error, Result};
use crate::metrics::Timer;
use crate::sampling::Sparsifier;
use crate::sparse::SparseChunk;

use super::{ChunkSource, DenseChunk, StreamConfig};

/// Sink for compressed chunks. Chunks may arrive out of stream order when
/// `workers > 1`; order-sensitive consumers sort on `start_col`.
pub trait SparseConsumer {
    /// Accept one compressed chunk.
    fn consume(&mut self, chunk: SparseChunk) -> Result<()>;
}

impl<F: FnMut(SparseChunk) -> Result<()>> SparseConsumer for F {
    fn consume(&mut self, chunk: SparseChunk) -> Result<()> {
        self(chunk)
    }
}

/// Run one compression pass over `source`, feeding `consumer`.
///
/// * `precondition = false` runs the no-ROS ablation arm.
/// * Phase timings are merged into `timer`: `load` (source I/O, reader
///   thread), `compress` (worker time: fused precondition+sample).
///
/// Returns the number of samples processed.
pub fn compress_stream(
    source: &mut dyn ChunkSource,
    sp: &Sparsifier,
    cfg: StreamConfig,
    precondition: bool,
    consumer: &mut dyn SparseConsumer,
    timer: &mut Timer,
) -> Result<usize> {
    let workers = cfg.workers.max(1);
    let (work_tx, work_rx) = mpsc::sync_channel::<DenseChunk>(cfg.queue_depth.max(1));
    let work_rx = Mutex::new(work_rx);
    let (out_tx, out_rx) = mpsc::sync_channel::<Result<SparseChunk>>(cfg.queue_depth.max(1));
    let shared_timer = Mutex::new(Timer::new());
    let mut total = 0usize;

    crossbeam_utils::thread::scope(|scope| -> Result<usize> {
        // Reader: pulls dense chunks, times the I/O, pushes to the work
        // queue. Dropping work_tx closes the queue.
        let reader_out = out_tx.clone();
        let reader = scope.spawn(|_| {
            let out_tx = reader_out;
            let mut load = 0.0f64;
            loop {
                let t0 = Instant::now();
                let next = source.next_chunk();
                load += t0.elapsed().as_secs_f64();
                match next {
                    Ok(Some(chunk)) => {
                        if work_tx.send(chunk).is_err() {
                            break; // workers gone (error path)
                        }
                    }
                    Ok(None) => break,
                    Err(e) => {
                        let _ = out_tx.send(Err(e));
                        break;
                    }
                }
            }
            drop(work_tx);
            shared_timer.lock().unwrap().add("load", load);
        });

        // Workers: fused precondition+sample per chunk.
        for _ in 0..workers {
            let out_tx = out_tx.clone();
            let work_rx = &work_rx;
            let sp_ref = sp;
            let st = &shared_timer;
            scope.spawn(move |_| {
                let mut busy = 0.0f64;
                loop {
                    let chunk = { work_rx.lock().unwrap().recv() };
                    let Ok(chunk) = chunk else { break };
                    let t0 = Instant::now();
                    let result = if precondition {
                        sp_ref.compress_chunk(&chunk.data, chunk.start_col)
                    } else {
                        sp_ref.compress_chunk_no_precondition(&chunk.data, chunk.start_col)
                    };
                    busy += t0.elapsed().as_secs_f64();
                    if out_tx.send(result).is_err() {
                        break;
                    }
                }
                st.lock().unwrap().add("compress", busy);
            });
        }
        drop(out_tx); // main keeps only out_rx; channel closes when workers finish

        // Consumer runs on the calling thread.
        let mut first_err: Option<Error> = None;
        for item in out_rx.iter() {
            match item {
                Ok(chunk) => {
                    if first_err.is_none() {
                        total += chunk.n();
                        if let Err(e) = consumer.consume(chunk) {
                            first_err = Some(e);
                            // keep draining so threads can finish
                        }
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        reader.join().expect("reader panicked");
        match first_err {
            Some(e) => Err(e),
            None => Ok(total),
        }
    })
    .map_err(|_| Error::Invalid("pipeline worker panicked".into()))?
    .map(|n| {
        timer.merge(&shared_timer.lock().unwrap());
        n
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MatSource;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;
    use crate::sampling::SparsifyConfig;
    use crate::transform::TransformKind;

    fn setup(n: usize) -> (Mat, Sparsifier) {
        let mut rng = Pcg64::seed(5);
        let x = Mat::from_fn(32, n, |_, _| rng.normal());
        let cfg = SparsifyConfig { gamma: 0.25, transform: TransformKind::Hadamard, seed: 9 };
        (x, Sparsifier::new(32, cfg).unwrap())
    }

    fn run(x: &Mat, sp: &Sparsifier, workers: usize) -> Vec<SparseChunk> {
        let mut src = MatSource::new(x, 7); // awkward chunk size on purpose
        let mut chunks: Vec<SparseChunk> = Vec::new();
        let mut timer = Timer::new();
        let cfg = StreamConfig { workers, queue_depth: 2, chunk_cols: 7, ..Default::default() };
        let mut push = |c: SparseChunk| -> Result<()> {
            chunks.push(c);
            Ok(())
        };
        let n = compress_stream(&mut src, sp, cfg, true, &mut push, &mut timer).unwrap();
        assert_eq!(n, x.cols());
        chunks.sort_by_key(|c| c.start_col());
        chunks
    }

    #[test]
    fn single_worker_matches_direct_compression() {
        let (x, sp) = setup(40);
        let chunks = run(&x, &sp, 1);
        let direct = sp.compress_chunk(&x, 0).unwrap();
        let mut col = 0;
        for ch in &chunks {
            for i in 0..ch.n() {
                assert_eq!(ch.col_indices(i), direct.col_indices(col));
                assert_eq!(ch.col_values(i), direct.col_values(col));
                col += 1;
            }
        }
        assert_eq!(col, 40);
    }

    #[test]
    fn multi_worker_same_output_any_scheduling() {
        let (x, sp) = setup(61);
        let a = run(&x, &sp, 1);
        let b = run(&x, &sp, 4);
        assert_eq!(a.len(), b.len());
        for (ca, cb) in a.iter().zip(&b) {
            assert_eq!(ca.start_col(), cb.start_col());
            for i in 0..ca.n() {
                assert_eq!(ca.col_indices(i), cb.col_indices(i));
                assert_eq!(ca.col_values(i), cb.col_values(i));
            }
        }
    }

    #[test]
    fn consumer_error_propagates() {
        let (x, sp) = setup(30);
        let mut src = MatSource::new(&x, 5);
        let mut timer = Timer::new();
        let mut failing = |_c: SparseChunk| -> Result<()> {
            Err(Error::Invalid("consumer rejected".into()))
        };
        let out = compress_stream(
            &mut src,
            &sp,
            StreamConfig::default(),
            true,
            &mut failing,
            &mut timer,
        );
        assert!(out.is_err());
    }

    #[test]
    fn timer_records_phases() {
        let (x, sp) = setup(50);
        let mut src = MatSource::new(&x, 10);
        let mut timer = Timer::new();
        let mut sink = |_c: SparseChunk| -> Result<()> { Ok(()) };
        compress_stream(&mut src, &sp, StreamConfig::default(), true, &mut sink, &mut timer)
            .unwrap();
        assert!(timer.get("compress") > 0.0);
        // load phase exists (may be ~0 for in-memory)
        assert!(timer.phases().iter().any(|(n, _)| n == "load"));
    }
}
