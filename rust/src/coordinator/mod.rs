//! L3 streaming coordinator.
//!
//! Owns the pipeline topology: a reader pulls dense chunks from a
//! [`ChunkSource`] (in-memory matrix, on-disk store, or generator),
//! bounded channels provide backpressure, a pool of sparsifier workers
//! runs the fused precondition+sample operator, and an accumulator folds
//! the resulting [`SparseChunk`](crate::sparse::SparseChunk)s into a
//! consumer (estimators, a collector for K-means, …). The public face is
//! the [`FitPlan`] session API.
//!
//! Design note: the spec'd stack calls for tokio, which is unavailable in
//! this offline build; `std::sync::mpsc::sync_channel` + scoped threads
//! provide the same bounded-queue backpressure semantics for this
//! CPU-bound pipeline (DESIGN.md §2).

mod driver;
mod krylov;
mod pipeline;
mod plan;

#[allow(deprecated)]
pub use driver::{
    run_compress_to_store, run_pca_from_store, run_pca_sparse, run_pca_stream,
    run_sparsified_kmeans_from_store, run_sparsified_kmeans_sparse,
    run_sparsified_kmeans_stream, run_two_pass_stream, PcaReport, PipelineReport,
};
#[allow(deprecated)]
pub use krylov::{
    run_pca_krylov_from_store, run_pca_krylov_sparse, run_pca_krylov_stream, KrylovPcaReport,
    SourceCovOp, DEFAULT_KRYLOV_ITERS,
};
pub use pipeline::{compress_stream, SparseConsumer};
pub use plan::{
    two_pass_refine_stream, FitOutcome, FitPlan, FitReport, PcaFit, Solver, Task,
    DEFAULT_CORESET_SIZE, DEFAULT_TOPK,
};
// Incremental-fit building blocks shared with the serve daemon's
// refresh loop (fold only new shards, merge into the running partial).
pub(crate) use plan::{coreset_partial_for_shards, pca_partial_for_shards, pca_report_from_partial};
// Re-exported from the data layer for compatibility: the sparse-source
// abstraction moved to `sparse::source` so estimators and K-means can
// stream sparsified data without depending on the coordinator.
pub use crate::sparse::{SparseChunkSource, SparseVecSource};

use crate::data::ChunkStoreReader;
use crate::error::Result;
use crate::linalg::Mat;

/// A dense chunk in flight: columns `[start_col, start_col + data.cols())`
/// of the logical stream.
pub struct DenseChunk {
    /// The chunk's columns (`p × cols`).
    pub data: Mat,
    /// Global index of the first column.
    pub start_col: usize,
}

/// Abstract chunked data source. Multi-pass algorithms call
/// [`reset`](ChunkSource::reset) between passes; one-pass algorithms
/// never do — the pass discipline of paper Table II is enforced by the
/// drivers and measured in `PipelineReport::passes`.
pub trait ChunkSource: Send {
    /// Ambient dimension p.
    fn p(&self) -> usize;
    /// Total samples if known.
    fn n_hint(&self) -> Option<usize>;
    /// Pull the next chunk; `None` ends the pass.
    fn next_chunk(&mut self) -> Result<Option<DenseChunk>>;
    /// Restart for another pass.
    fn reset(&mut self) -> Result<()>;
}

/// Streaming configuration.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    /// Sparsifier worker threads.
    pub workers: usize,
    /// Bounded-queue depth (chunks) between stages — the backpressure knob.
    pub queue_depth: usize,
    /// Columns per chunk when slicing in-memory matrices.
    pub chunk_cols: usize,
    /// Serial-fallback crossover for parallel K-means assignment: the
    /// assigner only fans out when every worker gets at least this many
    /// columns. `None` (the default) resolves at fit time — the
    /// `PDS_ASSIGN_COLS_PER_WORKER` env var if set, else the measured
    /// per-(precision, ISA) table. Any value is bitwise-safe; this only
    /// moves the serial/parallel break-even.
    pub assign_cols_per_worker: Option<usize>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            workers: 1,
            queue_depth: 4,
            chunk_cols: 256,
            assign_cols_per_worker: None,
        }
    }
}

/// In-memory matrix source (slices a `Mat` into chunks).
pub struct MatSource<'a> {
    mat: &'a Mat,
    chunk_cols: usize,
    cursor: usize,
}

impl<'a> MatSource<'a> {
    /// Slice `mat` into chunks of `chunk_cols` columns.
    pub fn new(mat: &'a Mat, chunk_cols: usize) -> Self {
        MatSource { mat, chunk_cols: chunk_cols.max(1), cursor: 0 }
    }
}

impl<'a> ChunkSource for MatSource<'a> {
    fn p(&self) -> usize {
        self.mat.rows()
    }

    fn n_hint(&self) -> Option<usize> {
        Some(self.mat.cols())
    }

    fn next_chunk(&mut self) -> Result<Option<DenseChunk>> {
        if self.cursor >= self.mat.cols() {
            return Ok(None);
        }
        let end = (self.cursor + self.chunk_cols).min(self.mat.cols());
        let chunk = DenseChunk { data: self.mat.col_range(self.cursor, end), start_col: self.cursor };
        self.cursor = end;
        Ok(Some(chunk))
    }

    fn reset(&mut self) -> Result<()> {
        self.cursor = 0;
        Ok(())
    }
}

/// Out-of-core source reading a [`ChunkStoreReader`] (Table IV workload).
pub struct StoreSource {
    reader: ChunkStoreReader,
}

impl StoreSource {
    /// Wrap an open dense-store reader.
    pub fn new(reader: ChunkStoreReader) -> Self {
        StoreSource { reader }
    }
}

impl ChunkSource for StoreSource {
    fn p(&self) -> usize {
        self.reader.p()
    }

    fn n_hint(&self) -> Option<usize> {
        Some(self.reader.n())
    }

    fn next_chunk(&mut self) -> Result<Option<DenseChunk>> {
        Ok(self.reader.next_chunk()?.map(|(data, start_col)| DenseChunk { data, start_col }))
    }

    fn reset(&mut self) -> Result<()> {
        self.reader.rewind()
    }
}

/// Generator source: streams synthetic chunks without materializing the
/// dataset (used to exercise true streaming at n beyond RAM).
pub struct GeneratorSource<F: FnMut(usize, usize) -> Mat + Send> {
    p: usize,
    n: usize,
    chunk_cols: usize,
    cursor: usize,
    /// `gen(start_col, cols) -> p×cols chunk`; must be deterministic in
    /// `start_col` so reset() replays identically.
    gen: F,
}

impl<F: FnMut(usize, usize) -> Mat + Send> GeneratorSource<F> {
    /// Stream `n` synthetic samples of dimension `p` from `gen`.
    pub fn new(p: usize, n: usize, chunk_cols: usize, gen: F) -> Self {
        GeneratorSource { p, n, chunk_cols: chunk_cols.max(1), cursor: 0, gen }
    }
}

impl<F: FnMut(usize, usize) -> Mat + Send> ChunkSource for GeneratorSource<F> {
    fn p(&self) -> usize {
        self.p
    }

    fn n_hint(&self) -> Option<usize> {
        Some(self.n)
    }

    fn next_chunk(&mut self) -> Result<Option<DenseChunk>> {
        if self.cursor >= self.n {
            return Ok(None);
        }
        let cols = (self.n - self.cursor).min(self.chunk_cols);
        let data = (self.gen)(self.cursor, cols);
        debug_assert_eq!(data.rows(), self.p);
        let chunk = DenseChunk { data, start_col: self.cursor };
        self.cursor += cols;
        Ok(Some(chunk))
    }

    fn reset(&mut self) -> Result<()> {
        self.cursor = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn mat_source_covers_everything_in_order() {
        let mut rng = Pcg64::seed(1);
        let x = Mat::from_fn(4, 10, |_, _| rng.normal());
        let mut src = MatSource::new(&x, 3);
        let mut seen = 0;
        let mut starts = Vec::new();
        while let Some(c) = src.next_chunk().unwrap() {
            starts.push(c.start_col);
            seen += c.data.cols();
        }
        assert_eq!(seen, 10);
        assert_eq!(starts, vec![0, 3, 6, 9]);
        // second pass after reset
        src.reset().unwrap();
        assert_eq!(src.next_chunk().unwrap().unwrap().start_col, 0);
    }

    #[test]
    fn generator_source_is_replayable() {
        let mut src = GeneratorSource::new(2, 5, 2, |start, cols| {
            Mat::from_fn(2, cols, |i, j| (start + j) as f64 * 10.0 + i as f64)
        });
        let mut pass1 = Vec::new();
        while let Some(c) = src.next_chunk().unwrap() {
            pass1.extend_from_slice(c.data.as_slice());
        }
        src.reset().unwrap();
        let mut pass2 = Vec::new();
        while let Some(c) = src.next_chunk().unwrap() {
            pass2.extend_from_slice(c.data.as_slice());
        }
        assert_eq!(pass1, pass2);
        assert_eq!(pass1.len(), 10);
    }
}
