//! Legacy high-level drivers — thin **deprecated** shims over
//! [`FitPlan`](super::FitPlan).
//!
//! The `run_{pca,sparsified_kmeans,two_pass,compress}_{stream,sparse,from_store}`
//! matrix predates the session API; every function here now just builds
//! the equivalent plan and unpacks its [`FitReport`](super::FitReport)
//! into the historical `(output, PipelineReport)` pair. New code uses
//! `FitPlan` directly — CI builds the crate with `-D deprecated` (plus a
//! grep allowlist pinning the callers to this module and `krylov.rs`), so
//! internal code cannot regrow on the shims.

use std::path::Path;

use crate::error::Result;
use crate::kmeans::{KmeansOpts, KmeansResult, SparseAssigner, SparsifiedModel};
use crate::linalg::Mat;
use crate::metrics::Timer;
use crate::pca::Pca;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::sparse::SparseChunkSource;
use crate::store::{SparseStoreReader, StoreManifest};

use super::plan::{FitOutcome, FitPlan, FitReport};
use super::{ChunkSource, StreamConfig};

/// Accounting for one driver run — the raw material of Tables III/IV.
/// Superseded by [`FitReport`](super::FitReport), which splits raw and
/// sparse pass counts and carries the per-iteration center-error bound.
#[derive(Debug)]
pub struct PipelineReport {
    /// Phase timings: `load`, `compress`, `kmeans` / `eig`, `pass2`.
    pub timer: Timer,
    /// Samples processed.
    pub n: usize,
    /// Passes over the raw data.
    pub passes: usize,
    /// Lloyd iterations (K-means drivers).
    pub iterations: usize,
    /// Assignment engine used.
    pub engine: &'static str,
}

/// PCA outputs of the covariance-solver drivers.
pub struct PcaReport {
    /// Unbiased sample-mean estimate (Thm 4), original-domain.
    pub mean: Vec<f64>,
    /// Unbiased covariance estimate `Ĉ_n` (Thm 6) in the *preconditioned*
    /// domain (PC directions are unmixed below).
    pub covariance: Mat,
    /// Top-k principal components, unmixed to the original domain.
    pub pca: Pca,
}

/// Split a [`FitReport`] into the legacy `(report, outcome)` shape.
fn legacy(report: FitReport) -> (PipelineReport, FitOutcome) {
    let FitReport { timer, n, raw_passes, iterations, engine, outcome, .. } = report;
    (PipelineReport { timer, n, passes: raw_passes, iterations, engine }, outcome)
}

fn legacy_kmeans(report: FitReport) -> (SparsifiedModel, PipelineReport) {
    let (rep, outcome) = legacy(report);
    match outcome {
        FitOutcome::Kmeans { model, .. } => (model, rep),
        _ => unreachable!("kmeans plan returns a kmeans outcome"),
    }
}

fn legacy_pca(report: FitReport) -> (PcaReport, PipelineReport) {
    let (rep, outcome) = legacy(report);
    match outcome {
        FitOutcome::Pca(fit) => (
            PcaReport {
                mean: fit.mean,
                covariance: fit.covariance.expect("covariance solver materializes the estimate"),
                pca: fit.pca,
            },
            rep,
        ),
        _ => unreachable!("pca plan returns a pca outcome"),
    }
}

/// One-pass sparsified K-means over a stream (Algorithm 1 at scale).
#[deprecated(
    note = "use FitPlan::kmeans().stream(source, scfg).k(k).kmeans_opts(opts)\
            .assigner(a).stream_config(stream).precondition(p).run()"
)]
pub fn run_sparsified_kmeans_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    stream: StreamConfig,
    precondition: bool,
) -> Result<(SparsifiedModel, PipelineReport)> {
    let report = FitPlan::kmeans()
        .stream(source, scfg)
        .k(k)
        .kmeans_opts(opts)
        .assigner(assigner)
        .stream_config(stream)
        .precondition(precondition)
        .run()?;
    Ok(legacy_kmeans(report))
}

/// Two-pass sparsified K-means over a stream (Algorithm 2 at scale).
#[deprecated(
    note = "use FitPlan::kmeans().stream(source, scfg).k(k).two_pass(true).run()"
)]
pub fn run_two_pass_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    stream: StreamConfig,
) -> Result<(KmeansResult, PipelineReport)> {
    let report = FitPlan::kmeans()
        .stream(source, scfg)
        .k(k)
        .kmeans_opts(opts)
        .assigner(assigner)
        .stream_config(stream)
        .two_pass(true)
        .run()?;
    let (rep, outcome) = legacy(report);
    match outcome {
        FitOutcome::Kmeans { refined: Some(result), .. } => Ok((result, rep)),
        _ => unreachable!("two-pass plan returns a refined outcome"),
    }
}

/// One-pass streaming PCA (covariance solver).
#[deprecated(note = "use FitPlan::pca().stream(source, scfg).topk(k).run()")]
pub fn run_pca_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    topk: usize,
    stream: StreamConfig,
) -> Result<(PcaReport, PipelineReport)> {
    let report = FitPlan::pca()
        .stream(source, scfg)
        .topk(topk)
        .stream_config(stream)
        .run()?;
    Ok(legacy_pca(report))
}

/// Compress a raw stream **once** into an on-disk sparse store at `dir`.
#[deprecated(
    note = "use FitPlan::compress().stream(source, scfg).store_dir(dir)\
            .shard_cols(c).run()"
)]
pub fn run_compress_to_store(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    dir: &Path,
    shard_cols: usize,
    stream: StreamConfig,
    precondition: bool,
) -> Result<(StoreManifest, PipelineReport)> {
    let report = FitPlan::compress()
        .stream(source, scfg)
        .store_dir(dir)
        .shard_cols(shard_cols)
        .stream_config(stream)
        .precondition(precondition)
        .run()?;
    let (rep, outcome) = legacy(report);
    match outcome {
        FitOutcome::Compressed(manifest) => Ok((manifest, rep)),
        _ => unreachable!("compress plan returns a manifest"),
    }
}

/// Sparsified K-means (Algorithm 1) over already-compressed chunks.
#[deprecated(
    note = "use FitPlan::kmeans().source(source, sp, unmix).k(k).workers(w).run()"
)]
pub fn run_sparsified_kmeans_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    workers: usize,
    unmix: bool,
) -> Result<(SparsifiedModel, PipelineReport)> {
    let report = FitPlan::kmeans()
        .source(source, sp, unmix)
        .k(k)
        .kmeans_opts(opts)
        .assigner(assigner)
        .workers(workers)
        .run()?;
    Ok(legacy_kmeans(report))
}

/// Sparsified K-means straight from a persistent store.
#[deprecated(note = "use FitPlan::kmeans().store(store).k(k).workers(w).run()")]
pub fn run_sparsified_kmeans_from_store(
    store: &mut SparseStoreReader,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    workers: usize,
) -> Result<(SparsifiedModel, PipelineReport)> {
    let report = FitPlan::kmeans()
        .store(store)
        .k(k)
        .kmeans_opts(opts)
        .assigner(assigner)
        .workers(workers)
        .run()?;
    Ok(legacy_kmeans(report))
}

/// One-pass PCA over already-compressed chunks (covariance solver).
#[deprecated(
    note = "use FitPlan::pca().source(source, sp, preconditioned).topk(k).workers(w).run()"
)]
pub fn run_pca_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    topk: usize,
    workers: usize,
    preconditioned: bool,
) -> Result<(PcaReport, PipelineReport)> {
    let report = FitPlan::pca()
        .source(source, sp, preconditioned)
        .topk(topk)
        .workers(workers)
        .run()?;
    Ok(legacy_pca(report))
}

/// Streaming PCA straight from a persistent store (covariance solver).
#[deprecated(note = "use FitPlan::pca().store(store).topk(k).workers(w).run()")]
pub fn run_pca_from_store(
    store: &mut SparseStoreReader,
    topk: usize,
    workers: usize,
) -> Result<(PcaReport, PipelineReport)> {
    let report = FitPlan::pca().store(store).topk(topk).workers(workers).run()?;
    Ok(legacy_pca(report))
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::super::{two_pass_refine_stream, MatSource, SparseVecSource};
    use super::*;
    use crate::data::gaussian_blobs;
    use crate::kmeans::{NativeAssigner, SparsifiedKmeans};
    use crate::rng::Pcg64;
    use crate::transform::TransformKind;

    fn bits_eq(a: &[f64], b: &[f64], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}");
        }
    }

    #[test]
    fn kmeans_stream_shim_matches_fitplan_bitwise() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(32, 300, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 4 };
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let stream = StreamConfig { workers: 2, ..Default::default() };

        let mut src = MatSource::new(&d.data, 64);
        let (model, report) = run_sparsified_kmeans_stream(
            &mut src, scfg, 3, opts, &NativeAssigner::new(), stream, true,
        )
        .unwrap();
        assert_eq!(report.n, 300);
        assert_eq!(report.passes, 1);

        let mut src2 = MatSource::new(&d.data, 64);
        let plan = FitPlan::kmeans()
            .stream(&mut src2, scfg)
            .k(3)
            .kmeans_opts(opts)
            .stream_config(stream)
            .run()
            .unwrap();
        let pm = plan.kmeans_model().unwrap();
        assert_eq!(model.result.assign, pm.result.assign);
        assert_eq!(model.result.objective.to_bits(), pm.result.objective.to_bits());
        bits_eq(model.result.centers.as_slice(), pm.result.centers.as_slice(), "centers");

        // ... and both match the direct dense fit (the original contract)
        let sk = SparsifiedKmeans::new(scfg, 3, opts);
        let direct = sk.fit_dense(&d.data).unwrap();
        assert_eq!(model.result.assign, direct.assign);
        assert!(model.result.centers.sub(&direct.centers).max_abs() < 1e-9);
    }

    #[test]
    fn two_pass_shim_matches_fitplan_and_refine_helper() {
        let mut rng = Pcg64::seed(3);
        let d = gaussian_blobs(64, 500, 3, 0.3, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.1, transform: TransformKind::Hadamard, seed: 7 };
        let opts = KmeansOpts { n_init: 3, ..Default::default() };

        let mut src = MatSource::new(&d.data, 128);
        let (two, report) =
            run_two_pass_stream(&mut src, scfg, 3, opts, &NativeAssigner::new(), StreamConfig::default())
                .unwrap();
        assert_eq!(report.passes, 2);
        assert!(report.timer.get("pass2") > 0.0);

        // equivalent: one-pass fit + the public refine helper
        let mut src2 = MatSource::new(&d.data, 128);
        let (model, _) = run_sparsified_kmeans_stream(
            &mut src2, scfg, 3, opts, &NativeAssigner::new(), StreamConfig::default(), true,
        )
        .unwrap();
        let (refined, _secs) = two_pass_refine_stream(&mut src2, &model, 3).unwrap();
        assert_eq!(two.assign, refined.assign);
        assert_eq!(two.objective.to_bits(), refined.objective.to_bits());
        bits_eq(two.centers.as_slice(), refined.centers.as_slice(), "refined centers");
    }

    #[test]
    fn pca_stream_shim_matches_fitplan_bitwise() {
        let mut rng = Pcg64::seed(5);
        let d = crate::data::spiked(32, 700, &[6.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 2 };
        let stream = StreamConfig { workers: 2, chunk_cols: 128, ..Default::default() };
        let mut src = MatSource::new(&d.data, 128);
        let (pca, report) = run_pca_stream(&mut src, scfg, 2, stream).unwrap();
        assert_eq!(report.passes, 1);
        let mut src2 = MatSource::new(&d.data, 128);
        let plan = FitPlan::pca().stream(&mut src2, scfg).topk(2).stream_config(stream).run().unwrap();
        let fit = plan.pca_fit().unwrap();
        bits_eq(&pca.mean, &fit.mean, "mean");
        bits_eq(pca.covariance.as_slice(), fit.covariance.as_ref().unwrap().as_slice(), "cov");
        bits_eq(pca.pca.components.as_slice(), fit.pca.components.as_slice(), "components");
    }

    #[test]
    fn sparse_and_store_shims_match_fitplan() {
        let mut rng = Pcg64::seed(17);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 5 };
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let sp = Sparsifier::new(32, scfg).unwrap();
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();

        let mut src = SparseVecSource::new(vec![chunk.clone()]).unwrap();
        let (model, report) = run_sparsified_kmeans_sparse(
            &mut src, &sp, 3, opts, &NativeAssigner::new(), 2, true,
        )
        .unwrap();
        assert_eq!(report.passes, 0, "sparse fit reads no raw data");

        let mut src2 = SparseVecSource::new(vec![chunk.clone()]).unwrap();
        let plan = FitPlan::kmeans()
            .source(&mut src2, &sp, true)
            .k(3)
            .kmeans_opts(opts)
            .workers(2)
            .run()
            .unwrap();
        let pm = plan.kmeans_model().unwrap();
        assert_eq!(plan.raw_passes, 0);
        assert_eq!(model.result.assign, pm.result.assign);
        bits_eq(model.result.centers.as_slice(), pm.result.centers.as_slice(), "centers");

        let mut src3 = SparseVecSource::new(vec![chunk]).unwrap();
        let (pca, preport) = run_pca_sparse(&mut src3, &sp, 2, 1, true).unwrap();
        assert_eq!(preport.passes, 0);
        assert_eq!(pca.pca.components.cols(), 2);
    }

    #[test]
    fn compress_shim_writes_an_identical_store() {
        let mut rng = Pcg64::seed(23);
        let d = gaussian_blobs(16, 200, 2, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 9 };
        let base = std::env::temp_dir()
            .join(format!("pds_shim_compress_{}", std::process::id()));
        let dir_a = base.join("shim");
        let dir_b = base.join("plan");
        let _ = std::fs::remove_dir_all(&base);

        let mut src = MatSource::new(&d.data, 64);
        let (manifest, report) =
            run_compress_to_store(&mut src, scfg, &dir_a, 50, StreamConfig::default(), true)
                .unwrap();
        assert_eq!(manifest.n, 200);
        assert_eq!(report.passes, 1);

        let mut src2 = MatSource::new(&d.data, 64);
        let plan = FitPlan::compress()
            .stream(&mut src2, scfg)
            .store_dir(&dir_b)
            .shard_cols(50)
            .run()
            .unwrap();
        assert_eq!(plan.store_manifest().unwrap().n, 200);

        // byte-identical stores
        let read_dir = |d: &std::path::Path| -> Vec<(String, Vec<u8>)> {
            let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(d)
                .unwrap()
                .map(|e| {
                    let e = e.unwrap();
                    (
                        e.file_name().to_string_lossy().into_owned(),
                        std::fs::read(e.path()).unwrap(),
                    )
                })
                .collect();
            out.sort_by(|a, b| a.0.cmp(&b.0));
            out
        };
        assert_eq!(read_dir(&dir_a), read_dir(&dir_b));

        // and the store shims match the plan's store fits
        let mut store = SparseStoreReader::open(&dir_a).unwrap();
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let (model, sreport) =
            run_sparsified_kmeans_from_store(&mut store, 2, opts, &NativeAssigner::new(), 1).unwrap();
        assert_eq!(sreport.passes, 0);
        let mut store2 = SparseStoreReader::open(&dir_b).unwrap();
        let plan = FitPlan::kmeans().store(&mut store2).k(2).kmeans_opts(opts).run().unwrap();
        let pm = plan.kmeans_model().unwrap();
        assert_eq!(model.result.assign, pm.result.assign);
        bits_eq(model.result.centers.as_slice(), pm.result.centers.as_slice(), "centers");

        store.rewind();
        let (pca, _) = run_pca_from_store(&mut store, 2, 1).unwrap();
        let mut store3 = SparseStoreReader::open(&dir_b).unwrap();
        let plan = FitPlan::pca().store(&mut store3).topk(2).run().unwrap();
        bits_eq(&pca.mean, &plan.pca_fit().unwrap().mean, "store pca mean");
        std::fs::remove_dir_all(&base).ok();
    }
}
