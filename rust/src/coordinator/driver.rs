//! High-level drivers: end-to-end runs combining the compress pipeline
//! with the estimators / K-means, with pass accounting and the timing
//! breakdowns of Tables III–V.
//!
//! Two families:
//!
//! * **Streaming** (`run_*_stream`) — compress the raw stream and fit in
//!   one go; the compressed data is transient.
//! * **Store-backed** — [`run_compress_to_store`] pays the compression
//!   pass once and persists the sparse form; [`run_pca_from_store`] /
//!   [`run_sparsified_kmeans_from_store`] then fit from disk with **zero
//!   raw-data passes** (`PipelineReport::passes` = 0) and are bit-exact
//!   matches of the streaming path on the same data.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use crate::error::{invalid, Result};
use crate::estimators::{CovarianceEstimator, SparseMeanEstimator};
use crate::kmeans::{
    assign_dense, KmeansOpts, KmeansResult, SparseAssigner, SparsifiedKmeans, SparsifiedModel,
};
use crate::linalg::Mat;
use crate::metrics::Timer;
use crate::pca::Pca;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::sparse::SparseChunk;
use crate::store::{SparseStoreReader, SparseStoreWriter, StoreManifest};

use super::{compress_stream, ChunkSource, SparseChunkSource, StreamConfig};

/// Accounting for one driver run — the raw material of Tables III/IV.
#[derive(Debug)]
pub struct PipelineReport {
    /// Phase timings: `load`, `compress`, `kmeans` / `eig`, `pass2`.
    pub timer: Timer,
    /// Samples processed.
    pub n: usize,
    /// Passes over the raw data.
    pub passes: usize,
    /// Lloyd iterations (K-means drivers).
    pub iterations: usize,
    /// Assignment engine used.
    pub engine: &'static str,
}

/// Target column count when coalescing stream chunks for a fit.
pub(crate) const FIT_COALESCE_COLS: usize = 8192;

/// Merge sorted, contiguous stream chunks into pieces of at least
/// `target_cols` columns (the tail piece may be smaller).
pub(crate) fn coalesce_chunks(
    chunks: Vec<SparseChunk>,
    target_cols: usize,
) -> Result<Vec<SparseChunk>> {
    let mut out = Vec::new();
    let mut group: Vec<SparseChunk> = Vec::new();
    let mut group_cols = 0usize;
    for c in chunks {
        group_cols += c.n();
        group.push(c);
        if group_cols >= target_cols {
            out.push(merge_group(&mut group)?);
            group_cols = 0;
        }
    }
    if !group.is_empty() {
        out.push(merge_group(&mut group)?);
    }
    Ok(out)
}

fn merge_group(group: &mut Vec<SparseChunk>) -> Result<SparseChunk> {
    let merged = if group.len() == 1 {
        group.pop().expect("non-empty group")
    } else {
        SparseChunk::concat(group)?
    };
    group.clear();
    Ok(merged)
}

/// One-pass sparsified K-means over a stream (Algorithm 1 at scale):
/// compress with backpressure (the compressed data — `γ·p·n` values — is
/// what's held in memory, never the raw stream), then iterate.
pub fn run_sparsified_kmeans_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    stream: StreamConfig,
    precondition: bool,
) -> Result<(SparsifiedModel, PipelineReport)> {
    let sp = Sparsifier::new(source.p(), scfg)?;
    let mut timer = Timer::new();
    let mut chunks: Vec<SparseChunk> = Vec::new();
    let mut collect = |c: SparseChunk| -> Result<()> {
        chunks.push(c);
        Ok(())
    };
    let n = compress_stream(source, &sp, stream, precondition, &mut collect, &mut timer)?;
    chunks.sort_by_key(|c| c.start_col());
    // coalesce the (often chunk_cols-sized) stream pieces so the parallel
    // assigner fans out over large column ranges instead of paying a
    // fork/join per tiny chunk; bitwise identical — the fit depends only
    // on the global column order
    let chunks = coalesce_chunks(chunks, FIT_COALESCE_COLS)?;
    // reuse the compress pool width for the fit: assignment and center
    // accumulation are bitwise worker-count-invariant, so this only
    // changes speed
    let sk = SparsifiedKmeans::new(scfg, k, opts).with_workers(stream.workers);
    let model = timer.time("kmeans", || sk.fit_chunks(&sp, &chunks, assigner))?;
    let iterations = model.result.iterations;
    Ok((
        model,
        PipelineReport { timer, n, passes: 1, iterations, engine: assigner.name() },
    ))
}

/// Two-pass sparsified K-means over a stream (Algorithm 2 at scale): run
/// the one-pass algorithm, then revisit the raw stream once to (a)
/// recompute centers as exact class means and (b) reassign against the
/// pass-1 center estimates in the original domain.
pub fn run_two_pass_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    stream: StreamConfig,
) -> Result<(KmeansResult, PipelineReport)> {
    let (model, mut report) = run_sparsified_kmeans_stream(
        source, scfg, k, opts, assigner, stream, true,
    )?;
    let result = two_pass_refine_stream(source, &model, k, &mut report)?;
    Ok((result, report))
}

/// The second pass of Algorithm 2, applied to an existing pass-1 model:
/// revisit the raw stream once to recompute exact class means and to
/// reassign against the pass-1 centers in the original domain.
pub fn two_pass_refine_stream(
    source: &mut dyn ChunkSource,
    model: &SparsifiedModel,
    k: usize,
    report: &mut PipelineReport,
) -> Result<KmeansResult> {
    let one = &model.result;
    let p = source.p();
    source.reset()?;
    let t0 = std::time::Instant::now();
    let mut sums = Mat::zeros(p, k);
    let mut counts = vec![0usize; k];
    let mut assign = vec![0u32; one.assign.len()];
    let mut objective = 0.0;
    while let Some(chunk) = source.next_chunk()? {
        // (a) exact class means under the pass-1 assignment
        for j in 0..chunk.data.cols() {
            let c = one.assign[chunk.start_col + j] as usize;
            counts[c] += 1;
            let col = chunk.data.col(j);
            let s = sums.col_mut(c);
            for i in 0..p {
                s[i] += col[i];
            }
        }
        // (b) reassignment against pass-1 centers, original domain
        let (a, obj) = assign_dense(&chunk.data, &one.centers);
        objective += obj;
        assign[chunk.start_col..chunk.start_col + a.len()].copy_from_slice(&a);
    }
    let mut centers = one.centers.clone();
    for c in 0..k {
        if counts[c] > 0 {
            let inv = 1.0 / counts[c] as f64;
            for v in centers.col_mut(c).iter_mut() {
                *v *= 0.0;
            }
            let (s, dst) = (sums.col(c), centers.col_mut(c));
            for i in 0..p {
                dst[i] = s[i] * inv;
            }
        }
    }
    report.timer.add("pass2", t0.elapsed().as_secs_f64());
    report.passes += 1;
    Ok(KmeansResult {
        centers,
        assign,
        objective,
        iterations: one.iterations,
        converged: one.converged,
    })
}

/// PCA outputs from one streaming pass.
pub struct PcaReport {
    /// Unbiased sample-mean estimate (Thm 4), original-domain.
    pub mean: Vec<f64>,
    /// Unbiased covariance estimate `Ĉ_n` (Thm 6) in the *preconditioned*
    /// domain (PC directions are unmixed below).
    pub covariance: Mat,
    /// Top-k principal components, unmixed to the original domain.
    pub pca: Pca,
}

/// One-pass streaming PCA: accumulate the Thm 4/6 estimators chunk by
/// chunk, eigendecompose, and unmix the components (PCs of `HDX` map to
/// PCs of `X` through `(HD)ᵀ`).
pub fn run_pca_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    topk: usize,
    stream: StreamConfig,
) -> Result<(PcaReport, PipelineReport)> {
    let sp = Sparsifier::new(source.p(), scfg)?;
    let mut timer = Timer::new();
    let mut mean_est = SparseMeanEstimator::new(sp.p(), sp.m());
    // the covariance scatter is the PCA hot path; give it the same pool
    // width as the compress stage (bitwise invariant to the worker count)
    let mut cov_est = CovarianceEstimator::new(sp.p(), sp.m()).with_workers(stream.workers);
    // Racing workers deliver chunks out of stream order; f64 accumulation
    // is order-sensitive, so reorder through a pending map (bounded by
    // the pipeline's in-flight cap) and fold in global column order —
    // this is what makes the estimates bitwise invariant to the worker
    // count, the same discipline as the store writer.
    let mut pending: BTreeMap<usize, SparseChunk> = BTreeMap::new();
    let mut next_col = 0usize;
    let mut fold = |c: SparseChunk| -> Result<()> {
        pending.insert(c.start_col(), c);
        loop {
            let first = match pending.keys().next() {
                Some(&k) if k == next_col => k,
                _ => break,
            };
            let chunk = pending.remove(&first).expect("key just observed");
            next_col += chunk.n();
            mean_est.accumulate(&chunk);
            cov_est.accumulate(&chunk);
        }
        Ok(())
    };
    let n = compress_stream(source, &sp, stream, true, &mut fold, &mut timer)?;
    if !pending.is_empty() || next_col != n {
        return invalid(format!(
            "pca stream: non-contiguous chunk stream (folded {next_col} of {n} columns)"
        ));
    }
    let covariance = cov_est.estimate();
    let pca_pre = timer.time("eig", || Pca::from_covariance(&covariance, topk, scfg.seed));
    // unmix components and mean to the original domain
    let components = sp.unmix(&pca_pre.components);
    let mean_pre = Mat::from_vec(sp.p(), 1, mean_est.estimate())?;
    let mean = sp.unmix(&mean_pre).col(0).to_vec();
    let report = PipelineReport { timer, n, passes: 1, iterations: 0, engine: "native" };
    Ok((
        PcaReport {
            mean,
            covariance,
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        },
        report,
    ))
}

/// Compress a raw stream **once** into an on-disk sparse store at `dir`
/// (the "compress once" half of compress-once/analyze-many). The store's
/// bytes depend only on the global column order, so they are identical
/// for every `stream.workers` setting. Counts as one pass over the raw
/// data.
pub fn run_compress_to_store(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    dir: &Path,
    shard_cols: usize,
    stream: StreamConfig,
    precondition: bool,
) -> Result<(StoreManifest, PipelineReport)> {
    let sp = Sparsifier::new(source.p(), scfg)?;
    let mut timer = Timer::new();
    let mut writer = SparseStoreWriter::create(dir, &sp, scfg, precondition, shard_cols)?;
    let mut sink = |c: SparseChunk| writer.append(c);
    let n = compress_stream(source, &sp, stream, precondition, &mut sink, &mut timer)?;
    let manifest = timer.time("store", || writer.finish())?;
    Ok((
        manifest,
        PipelineReport { timer, n, passes: 1, iterations: 0, engine: "native" },
    ))
}

/// Drain a sparse source into memory, order and coalesce the chunks for
/// an efficient fit. Returns the chunks plus the total sample count.
fn collect_sparse(
    source: &mut dyn SparseChunkSource,
    timer: &mut Timer,
) -> Result<(Vec<SparseChunk>, usize)> {
    let t0 = Instant::now();
    let mut chunks = Vec::new();
    while let Some(c) = source.next_chunk()? {
        chunks.push(c);
    }
    timer.add("load", t0.elapsed().as_secs_f64());
    let n = chunks.iter().map(|c| c.n()).sum();
    chunks.sort_by_key(|c| c.start_col());
    let chunks = coalesce_chunks(chunks, FIT_COALESCE_COLS)?;
    Ok((chunks, n))
}

/// Sparsified K-means (Algorithm 1) over already-compressed chunks — the
/// "analyze" half of compress-once/analyze-many. `sp` must be the
/// sparsifier the chunks were produced with (for center unmixing); pass
/// `unmix = false` when they skipped preconditioning. Zero passes over
/// the raw data; bit-identical to
/// [`run_sparsified_kmeans_stream`] on the same stream because every fit
/// step depends only on the global column order, not chunk boundaries.
///
/// Memory note: Lloyd iterations revisit every sample, so this driver
/// materializes the whole compressed source (~`12·m·n` bytes — the
/// paper's working-set model) regardless of any reader memory budget;
/// budgets bound chunk granularity, not the fit's working set.
pub fn run_sparsified_kmeans_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    workers: usize,
    unmix: bool,
) -> Result<(SparsifiedModel, PipelineReport)> {
    if source.p() != sp.p() || source.m() != sp.m() {
        return invalid(format!(
            "sparse fit: source is p={} m={}, sparsifier is p={} m={}",
            source.p(),
            source.m(),
            sp.p(),
            sp.m()
        ));
    }
    let mut timer = Timer::new();
    let (chunks, n) = collect_sparse(source, &mut timer)?;
    if n == 0 {
        return invalid("sparse fit: source is empty");
    }
    let scfg = SparsifyConfig { gamma: sp.gamma(), transform: sp.ros().kind(), seed: sp.seed() };
    let sk = SparsifiedKmeans::new(scfg, k, opts).with_workers(workers.max(1));
    let model =
        timer.time("kmeans", || sk.fit_chunks_raw(sp, &chunks, assigner, unmix))?;
    let iterations = model.result.iterations;
    Ok((
        model,
        PipelineReport { timer, n, passes: 0, iterations, engine: assigner.name() },
    ))
}

/// Sparsified K-means straight from a persistent store: rebuilds the
/// sparsifier from the manifest and fits without touching the raw data.
pub fn run_sparsified_kmeans_from_store(
    store: &mut SparseStoreReader,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    workers: usize,
) -> Result<(SparsifiedModel, PipelineReport)> {
    let sp = store.sparsifier()?;
    let unmix = store.manifest().preconditioned;
    run_sparsified_kmeans_sparse(store, &sp, k, opts, assigner, workers, unmix)
}

/// One-pass PCA over already-compressed chunks: fold the Thm 4/6
/// estimators in global column order, eigendecompose, unmix. Zero passes
/// over the raw data. `preconditioned = false` (ablation stores) skips
/// the adjoint and only drops padding.
pub fn run_pca_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    topk: usize,
    workers: usize,
    preconditioned: bool,
) -> Result<(PcaReport, PipelineReport)> {
    if source.p() != sp.p() || source.m() != sp.m() {
        return invalid(format!(
            "sparse pca: source is p={} m={}, sparsifier is p={} m={}",
            source.p(),
            source.m(),
            sp.p(),
            sp.m()
        ));
    }
    let mut timer = Timer::new();
    let mut mean_est = SparseMeanEstimator::new(sp.p(), sp.m());
    let mut cov_est = CovarianceEstimator::new(sp.p(), sp.m()).with_workers(workers.max(1));
    let mut n = 0usize;
    loop {
        let t0 = Instant::now();
        let next = source.next_chunk()?;
        timer.add("load", t0.elapsed().as_secs_f64());
        let Some(chunk) = next else { break };
        n += chunk.n();
        let t1 = Instant::now();
        mean_est.accumulate(&chunk);
        cov_est.accumulate(&chunk);
        timer.add("accumulate", t1.elapsed().as_secs_f64());
    }
    if n == 0 {
        return invalid("sparse pca: source is empty");
    }
    let covariance = cov_est.estimate();
    let pca_pre = timer.time("eig", || Pca::from_covariance(&covariance, topk, sp.seed()));
    let (components, mean) = if preconditioned {
        let components = sp.unmix(&pca_pre.components);
        let mean_pre = Mat::from_vec(sp.p(), 1, mean_est.estimate())?;
        (components, sp.unmix(&mean_pre).col(0).to_vec())
    } else {
        let components = sp.truncate(&pca_pre.components);
        let mean_pre = Mat::from_vec(sp.p(), 1, mean_est.estimate())?;
        (components, sp.truncate(&mean_pre).col(0).to_vec())
    };
    let report = PipelineReport { timer, n, passes: 0, iterations: 0, engine: "native" };
    Ok((
        PcaReport {
            mean,
            covariance,
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        },
        report,
    ))
}

/// Streaming PCA straight from a persistent store (manifest-driven
/// sparsifier reconstruction; zero raw-data passes).
pub fn run_pca_from_store(
    store: &mut SparseStoreReader,
    topk: usize,
    workers: usize,
) -> Result<(PcaReport, PipelineReport)> {
    let sp = store.sparsifier()?;
    let preconditioned = store.manifest().preconditioned;
    run_pca_sparse(store, &sp, topk, workers, preconditioned)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MatSource;
    use crate::data::gaussian_blobs;
    use crate::kmeans::NativeAssigner;
    use crate::metrics::clustering_accuracy;
    use crate::pca::recovered_components;
    use crate::rng::Pcg64;
    use crate::transform::TransformKind;

    #[test]
    fn one_pass_stream_matches_fit_dense() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(32, 300, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 4 };
        let opts = KmeansOpts { n_init: 2, ..Default::default() };

        let mut src = MatSource::new(&d.data, 64);
        let (model, report) = run_sparsified_kmeans_stream(
            &mut src,
            scfg,
            3,
            opts,
            &NativeAssigner,
            StreamConfig { workers: 2, ..Default::default() },
            true,
        )
        .unwrap();
        assert_eq!(report.n, 300);
        assert_eq!(report.passes, 1);

        let sk = SparsifiedKmeans::new(scfg, 3, opts);
        let direct = sk.fit_dense(&d.data).unwrap();
        assert_eq!(model.result.assign, direct.assign);
        assert!(model.result.centers.sub(&direct.centers).max_abs() < 1e-9);
    }

    #[test]
    fn two_pass_improves_or_matches() {
        let mut rng = Pcg64::seed(3);
        let d = gaussian_blobs(64, 800, 3, 0.3, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.1, transform: TransformKind::Hadamard, seed: 7 };
        let opts = KmeansOpts { n_init: 4, ..Default::default() };
        let mut src = MatSource::new(&d.data, 128);
        let (two, report) =
            run_two_pass_stream(&mut src, scfg, 3, opts, &NativeAssigner, StreamConfig::default())
                .unwrap();
        assert_eq!(report.passes, 2);
        assert!(report.timer.get("pass2") > 0.0);
        let acc2 = clustering_accuracy(&two.assign, &d.labels, 3);
        assert!(acc2 > 0.9, "two-pass accuracy {acc2}");
        // centers are exact class means of pass-1 assignment: finite & sane
        assert!(two.centers.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn streaming_pca_recovers_spiked_components() {
        let mut rng = Pcg64::seed(5);
        let d = crate::data::spiked(64, 6000, &[8.0, 5.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 2 };
        let mut src = MatSource::new(&d.data, 512);
        let (pca_report, report) =
            run_pca_stream(&mut src, scfg, 3, StreamConfig::default()).unwrap();
        assert_eq!(report.n, 6000);
        let rec = recovered_components(&pca_report.pca.components, &d.centers, 0.9);
        assert!(rec >= 2, "recovered {rec}/3 spiked PCs");
    }

    #[test]
    fn streaming_pca_is_bitwise_worker_invariant() {
        // the fold reorders out-of-order worker output before
        // accumulating, so every worker count produces identical bits
        let mut rng = Pcg64::seed(41);
        let d = crate::data::spiked(32, 700, &[5.0, 2.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 6 };
        let mut base_src = MatSource::new(&d.data, 64);
        let base_stream = StreamConfig { workers: 1, chunk_cols: 64, ..Default::default() };
        let (base, _) = run_pca_stream(&mut base_src, scfg, 2, base_stream).unwrap();
        for workers in [2usize, 4] {
            let mut src = MatSource::new(&d.data, 64);
            let stream = StreamConfig { workers, chunk_cols: 64, ..Default::default() };
            let (par, _) = run_pca_stream(&mut src, scfg, 2, stream).unwrap();
            for (a, b) in par.covariance.as_slice().iter().zip(base.covariance.as_slice()) {
                assert_eq!(a.to_bits(), b.to_bits(), "covariance, workers={workers}");
            }
            for (a, b) in par.mean.iter().zip(&base.mean) {
                assert_eq!(a.to_bits(), b.to_bits(), "mean, workers={workers}");
            }
        }
    }

    fn tmpdir(name: &str) -> std::path::PathBuf {
        let p = std::env::temp_dir()
            .join(format!("pds_driver_test_{name}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&p);
        p
    }

    #[test]
    fn kmeans_from_store_is_bit_identical_to_streaming() {
        let mut rng = Pcg64::seed(17);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 5 };
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let stream = StreamConfig { workers: 2, chunk_cols: 64, ..Default::default() };

        // reference: the in-memory streaming path
        let mut src = MatSource::new(&d.data, 64);
        let (direct, dreport) = run_sparsified_kmeans_stream(
            &mut src,
            scfg,
            3,
            opts,
            &crate::kmeans::NativeAssigner,
            stream,
            true,
        )
        .unwrap();
        assert_eq!(dreport.passes, 1);

        // compress once to a store (different shard size than chunk size,
        // on purpose), then fit from it
        let dir = tmpdir("kmeans_roundtrip");
        let mut src2 = MatSource::new(&d.data, 64);
        let (manifest, creport) =
            run_compress_to_store(&mut src2, scfg, &dir, 50, stream, true).unwrap();
        assert_eq!(manifest.n, 400);
        assert_eq!(creport.passes, 1);
        let mut store = crate::store::SparseStoreReader::open(&dir).unwrap();
        for workers in [1usize, 2] {
            store.rewind();
            let (from_store, sreport) = run_sparsified_kmeans_from_store(
                &mut store,
                3,
                opts,
                &crate::kmeans::NativeAssigner,
                workers,
            )
            .unwrap();
            assert_eq!(sreport.passes, 0, "fit from store reads no raw data");
            assert_eq!(from_store.result.assign, direct.result.assign, "workers={workers}");
            assert_eq!(
                from_store.result.objective.to_bits(),
                direct.result.objective.to_bits()
            );
            for (a, b) in from_store
                .result
                .centers
                .as_slice()
                .iter()
                .zip(direct.result.centers.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "centers, workers={workers}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pca_from_store_is_bit_identical_to_streaming() {
        let mut rng = Pcg64::seed(23);
        let d = crate::data::spiked(32, 900, &[6.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 11 };
        // workers = 2: the streaming fold reorders racing chunks, so the
        // accumulation order is the global column order either way
        let stream = StreamConfig { workers: 2, chunk_cols: 128, ..Default::default() };

        let mut src = MatSource::new(&d.data, 128);
        let (direct, _) = run_pca_stream(&mut src, scfg, 2, stream).unwrap();

        let dir = tmpdir("pca_roundtrip");
        let mut src2 = MatSource::new(&d.data, 128);
        run_compress_to_store(&mut src2, scfg, &dir, 77, stream, true).unwrap();
        let mut store = crate::store::SparseStoreReader::open(&dir).unwrap();
        let (from_store, report) = run_pca_from_store(&mut store, 2, 1).unwrap();
        assert_eq!(report.passes, 0);
        assert_eq!(report.n, 900);
        for (a, b) in from_store
            .covariance
            .as_slice()
            .iter()
            .zip(direct.covariance.as_slice())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "covariance");
        }
        for (a, b) in from_store.mean.iter().zip(&direct.mean) {
            assert_eq!(a.to_bits(), b.to_bits(), "mean");
        }
        for (a, b) in from_store
            .pca
            .components
            .as_slice()
            .iter()
            .zip(direct.pca.components.as_slice())
        {
            assert_eq!(a.to_bits(), b.to_bits(), "components");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn one_store_serves_many_analyses() {
        // the whole point: one compression pass, multiple consumers
        let mut rng = Pcg64::seed(31);
        let d = gaussian_blobs(16, 300, 2, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 3 };
        let dir = tmpdir("many_analyses");
        let mut src = MatSource::new(&d.data, 100);
        run_compress_to_store(&mut src, scfg, &dir, 64, StreamConfig::default(), true).unwrap();

        let mut store = crate::store::SparseStoreReader::open(&dir).unwrap();
        let opts = KmeansOpts { n_init: 2, ..Default::default() };
        let (model, _) = run_sparsified_kmeans_from_store(
            &mut store,
            2,
            opts,
            &crate::kmeans::NativeAssigner,
            1,
        )
        .unwrap();
        assert_eq!(model.result.assign.len(), 300);

        store.rewind();
        let (pca, _) = run_pca_from_store(&mut store, 2, 1).unwrap();
        assert_eq!(pca.mean.len(), 16);
        assert_eq!(pca.pca.components.cols(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
