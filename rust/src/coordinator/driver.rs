//! High-level drivers: end-to-end runs combining the compress pipeline
//! with the estimators / K-means, with pass accounting and the timing
//! breakdowns of Tables III–V.

use crate::error::Result;
use crate::estimators::{CovarianceEstimator, SparseMeanEstimator};
use crate::kmeans::{
    assign_dense, KmeansOpts, KmeansResult, SparseAssigner, SparsifiedKmeans, SparsifiedModel,
};
use crate::linalg::Mat;
use crate::metrics::Timer;
use crate::pca::Pca;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::sparse::SparseChunk;

use super::{compress_stream, ChunkSource, StreamConfig};

/// Accounting for one driver run — the raw material of Tables III/IV.
#[derive(Debug)]
pub struct PipelineReport {
    /// Phase timings: `load`, `compress`, `kmeans` / `eig`, `pass2`.
    pub timer: Timer,
    /// Samples processed.
    pub n: usize,
    /// Passes over the raw data.
    pub passes: usize,
    /// Lloyd iterations (K-means drivers).
    pub iterations: usize,
    /// Assignment engine used.
    pub engine: &'static str,
}

/// Target column count when coalescing stream chunks for a fit.
const FIT_COALESCE_COLS: usize = 8192;

/// Merge sorted, contiguous stream chunks into pieces of at least
/// `target_cols` columns (the tail piece may be smaller).
fn coalesce_chunks(chunks: Vec<SparseChunk>, target_cols: usize) -> Result<Vec<SparseChunk>> {
    let mut out = Vec::new();
    let mut group: Vec<SparseChunk> = Vec::new();
    let mut group_cols = 0usize;
    for c in chunks {
        group_cols += c.n();
        group.push(c);
        if group_cols >= target_cols {
            out.push(merge_group(&mut group)?);
            group_cols = 0;
        }
    }
    if !group.is_empty() {
        out.push(merge_group(&mut group)?);
    }
    Ok(out)
}

fn merge_group(group: &mut Vec<SparseChunk>) -> Result<SparseChunk> {
    let merged = if group.len() == 1 {
        group.pop().expect("non-empty group")
    } else {
        SparseChunk::concat(group)?
    };
    group.clear();
    Ok(merged)
}

/// One-pass sparsified K-means over a stream (Algorithm 1 at scale):
/// compress with backpressure (the compressed data — `γ·p·n` values — is
/// what's held in memory, never the raw stream), then iterate.
pub fn run_sparsified_kmeans_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    stream: StreamConfig,
    precondition: bool,
) -> Result<(SparsifiedModel, PipelineReport)> {
    let sp = Sparsifier::new(source.p(), scfg)?;
    let mut timer = Timer::new();
    let mut chunks: Vec<SparseChunk> = Vec::new();
    let mut collect = |c: SparseChunk| -> Result<()> {
        chunks.push(c);
        Ok(())
    };
    let n = compress_stream(source, &sp, stream, precondition, &mut collect, &mut timer)?;
    chunks.sort_by_key(|c| c.start_col());
    // coalesce the (often chunk_cols-sized) stream pieces so the parallel
    // assigner fans out over large column ranges instead of paying a
    // fork/join per tiny chunk; bitwise identical — the fit depends only
    // on the global column order
    let chunks = coalesce_chunks(chunks, FIT_COALESCE_COLS)?;
    // reuse the compress pool width for the fit: assignment and center
    // accumulation are bitwise worker-count-invariant, so this only
    // changes speed
    let sk = SparsifiedKmeans::new(scfg, k, opts).with_workers(stream.workers);
    let model = timer.time("kmeans", || sk.fit_chunks(&sp, &chunks, assigner))?;
    let iterations = model.result.iterations;
    Ok((
        model,
        PipelineReport { timer, n, passes: 1, iterations, engine: assigner.name() },
    ))
}

/// Two-pass sparsified K-means over a stream (Algorithm 2 at scale): run
/// the one-pass algorithm, then revisit the raw stream once to (a)
/// recompute centers as exact class means and (b) reassign against the
/// pass-1 center estimates in the original domain.
pub fn run_two_pass_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    stream: StreamConfig,
) -> Result<(KmeansResult, PipelineReport)> {
    let (model, mut report) = run_sparsified_kmeans_stream(
        source, scfg, k, opts, assigner, stream, true,
    )?;
    let result = two_pass_refine_stream(source, &model, k, &mut report)?;
    Ok((result, report))
}

/// The second pass of Algorithm 2, applied to an existing pass-1 model:
/// revisit the raw stream once to recompute exact class means and to
/// reassign against the pass-1 centers in the original domain.
pub fn two_pass_refine_stream(
    source: &mut dyn ChunkSource,
    model: &SparsifiedModel,
    k: usize,
    report: &mut PipelineReport,
) -> Result<KmeansResult> {
    let one = &model.result;
    let p = source.p();
    source.reset()?;
    let t0 = std::time::Instant::now();
    let mut sums = Mat::zeros(p, k);
    let mut counts = vec![0usize; k];
    let mut assign = vec![0u32; one.assign.len()];
    let mut objective = 0.0;
    while let Some(chunk) = source.next_chunk()? {
        // (a) exact class means under the pass-1 assignment
        for j in 0..chunk.data.cols() {
            let c = one.assign[chunk.start_col + j] as usize;
            counts[c] += 1;
            let col = chunk.data.col(j);
            let s = sums.col_mut(c);
            for i in 0..p {
                s[i] += col[i];
            }
        }
        // (b) reassignment against pass-1 centers, original domain
        let (a, obj) = assign_dense(&chunk.data, &one.centers);
        objective += obj;
        assign[chunk.start_col..chunk.start_col + a.len()].copy_from_slice(&a);
    }
    let mut centers = one.centers.clone();
    for c in 0..k {
        if counts[c] > 0 {
            let inv = 1.0 / counts[c] as f64;
            for v in centers.col_mut(c).iter_mut() {
                *v *= 0.0;
            }
            let (s, dst) = (sums.col(c), centers.col_mut(c));
            for i in 0..p {
                dst[i] = s[i] * inv;
            }
        }
    }
    report.timer.add("pass2", t0.elapsed().as_secs_f64());
    report.passes += 1;
    Ok(KmeansResult {
        centers,
        assign,
        objective,
        iterations: one.iterations,
        converged: one.converged,
    })
}

/// PCA outputs from one streaming pass.
pub struct PcaReport {
    /// Unbiased sample-mean estimate (Thm 4), original-domain.
    pub mean: Vec<f64>,
    /// Unbiased covariance estimate `Ĉ_n` (Thm 6) in the *preconditioned*
    /// domain (PC directions are unmixed below).
    pub covariance: Mat,
    /// Top-k principal components, unmixed to the original domain.
    pub pca: Pca,
}

/// One-pass streaming PCA: accumulate the Thm 4/6 estimators chunk by
/// chunk, eigendecompose, and unmix the components (PCs of `HDX` map to
/// PCs of `X` through `(HD)ᵀ`).
pub fn run_pca_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    topk: usize,
    stream: StreamConfig,
) -> Result<(PcaReport, PipelineReport)> {
    let sp = Sparsifier::new(source.p(), scfg)?;
    let mut timer = Timer::new();
    let mut mean_est = SparseMeanEstimator::new(sp.p(), sp.m());
    // the covariance scatter is the PCA hot path; give it the same pool
    // width as the compress stage (bitwise invariant to the worker count)
    let mut cov_est = CovarianceEstimator::new(sp.p(), sp.m()).with_workers(stream.workers);
    let mut fold = |c: SparseChunk| -> Result<()> {
        mean_est.accumulate(&c);
        cov_est.accumulate(&c);
        Ok(())
    };
    let n = compress_stream(source, &sp, stream, true, &mut fold, &mut timer)?;
    let covariance = cov_est.estimate();
    let pca_pre = timer.time("eig", || Pca::from_covariance(&covariance, topk, scfg.seed));
    // unmix components and mean to the original domain
    let components = sp.unmix(&pca_pre.components);
    let mean_pre = Mat::from_vec(sp.p(), 1, mean_est.estimate())?;
    let mean = sp.unmix(&mean_pre).col(0).to_vec();
    let report = PipelineReport { timer, n, passes: 1, iterations: 0, engine: "native" };
    Ok((
        PcaReport {
            mean,
            covariance,
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        },
        report,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MatSource;
    use crate::data::gaussian_blobs;
    use crate::kmeans::NativeAssigner;
    use crate::metrics::clustering_accuracy;
    use crate::pca::recovered_components;
    use crate::rng::Pcg64;
    use crate::transform::TransformKind;

    #[test]
    fn one_pass_stream_matches_fit_dense() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(32, 300, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 4 };
        let opts = KmeansOpts { n_init: 2, ..Default::default() };

        let mut src = MatSource::new(&d.data, 64);
        let (model, report) = run_sparsified_kmeans_stream(
            &mut src,
            scfg,
            3,
            opts,
            &NativeAssigner,
            StreamConfig { workers: 2, ..Default::default() },
            true,
        )
        .unwrap();
        assert_eq!(report.n, 300);
        assert_eq!(report.passes, 1);

        let sk = SparsifiedKmeans::new(scfg, 3, opts);
        let direct = sk.fit_dense(&d.data).unwrap();
        assert_eq!(model.result.assign, direct.assign);
        assert!(model.result.centers.sub(&direct.centers).max_abs() < 1e-9);
    }

    #[test]
    fn two_pass_improves_or_matches() {
        let mut rng = Pcg64::seed(3);
        let d = gaussian_blobs(64, 800, 3, 0.3, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.1, transform: TransformKind::Hadamard, seed: 7 };
        let opts = KmeansOpts { n_init: 4, ..Default::default() };
        let mut src = MatSource::new(&d.data, 128);
        let (two, report) =
            run_two_pass_stream(&mut src, scfg, 3, opts, &NativeAssigner, StreamConfig::default())
                .unwrap();
        assert_eq!(report.passes, 2);
        assert!(report.timer.get("pass2") > 0.0);
        let acc2 = clustering_accuracy(&two.assign, &d.labels, 3);
        assert!(acc2 > 0.9, "two-pass accuracy {acc2}");
        // centers are exact class means of pass-1 assignment: finite & sane
        assert!(two.centers.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn streaming_pca_recovers_spiked_components() {
        let mut rng = Pcg64::seed(5);
        let d = crate::data::spiked(64, 6000, &[8.0, 5.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 2 };
        let mut src = MatSource::new(&d.data, 512);
        let (pca_report, report) =
            run_pca_stream(&mut src, scfg, 3, StreamConfig::default()).unwrap();
        assert_eq!(report.n, 6000);
        let rec = recovered_components(&pca_report.pca.components, &d.centers, 0.9);
        assert!(rec >= 2, "recovered {rec}/3 spiked PCs");
    }
}
