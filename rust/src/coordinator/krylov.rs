//! Covariance-free PCA drivers: the block-Krylov solver wired to the
//! streaming pipeline and to the persistent sparse store.
//!
//! [`run_pca_stream`](super::run_pca_stream) materializes the p×p
//! Theorem 6 estimate before eigendecomposing — O(p²) memory and the
//! dominant cost at large p. The drivers here keep only the sparsified
//! chunks and evaluate the estimate's *action* per block product
//! ([`estimators::SparseCovOp`](crate::estimators::SparseCovOp), or
//! [`SourceCovOp`] streaming a [`SparseChunkSource`] once per product),
//! so the whole fit runs in O(p·(k+4)) working memory on top of the
//! compressed data:
//!
//! * [`run_pca_krylov_stream`] — compress the raw stream once (1 raw
//!   pass), hold the compressed chunks, solve in memory.
//! * [`run_pca_krylov_from_store`] / [`run_pca_krylov_sparse`] — fit
//!   straight from a sparse store (or any sparse source) with **zero**
//!   raw passes; each Krylov iteration is one memory-budgeted pass over
//!   the store, so even the compressed data never has to fit in RAM.
//!
//! Every path inherits the PR 1 bitwise contract: results are identical
//! for every worker count and every reader memory budget, and the
//! mean estimate is bit-identical to the covariance path's.

use std::time::Instant;

use crate::error::{invalid, Result};
use crate::estimators::{
    finish_apply, scatter_chunk, unbias_scales, ScatterDiag, SparseCovOp, SparseMeanEstimator,
};
use crate::linalg::{Mat, SymOp};
use crate::metrics::Timer;
use crate::pca::Pca;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::sparse::SparseChunk;
use crate::store::SparseStoreReader;

use super::driver::{coalesce_chunks, FIT_COALESCE_COLS};
use super::{compress_stream, ChunkSource, PipelineReport, SparseChunkSource, StreamConfig};

/// Krylov iterations used by the drivers — the same constant as
/// [`Pca::from_covariance`]'s subspace-iteration count
/// ([`pca::DEFAULT_PCA_ITERS`](crate::pca::DEFAULT_PCA_ITERS)), so the
/// two solvers always run matched budgets. Each iteration costs one pass
/// over the compressed data.
pub const DEFAULT_KRYLOV_ITERS: usize = crate::pca::DEFAULT_PCA_ITERS;

/// PCA outputs of the covariance-free path. Unlike
/// [`PcaReport`](super::PcaReport) there is no `covariance` field — not
/// materializing it is the point.
pub struct KrylovPcaReport {
    /// Unbiased sample-mean estimate (Thm 4), original-domain.
    pub mean: Vec<f64>,
    /// Top-k principal components + eigenvalues of the implicit Thm 6
    /// estimate, unmixed to the original domain.
    pub pca: Pca,
}

/// The Theorem 6 covariance estimate over a rewindable
/// [`SparseChunkSource`], as a [`SymOp`]: every
/// [`apply`](SymOp::apply) resets the source and streams it once,
/// folding each chunk through the same partition-invariant scatter as
/// [`SparseCovOp`](crate::estimators::SparseCovOp) — bits never depend
/// on the worker count or the source's chunk granularity (a store
/// reader's memory budget included).
pub struct SourceCovOp<'a> {
    source: &'a mut dyn SparseChunkSource,
    p: usize,
    c1: f64,
    c2: f64,
    diag: Vec<f64>,
    workers: usize,
    passes: usize,
}

impl<'a> SourceCovOp<'a> {
    /// Build the operator: one stats pass over the source (from the
    /// start) accumulates `diag(W Wᵀ)` and the sample count.
    pub fn new(source: &'a mut dyn SparseChunkSource, workers: usize) -> Result<Self> {
        let mut stats = ScatterDiag::new(source.p());
        source.reset()?;
        while let Some(chunk) = source.next_chunk()? {
            stats.accumulate(&chunk);
        }
        Self::from_stats(source, &stats, workers)
    }

    /// Build from an already-accumulated stats pass (the drivers fold
    /// the diagonal into their mean pass to avoid a second sweep).
    pub(crate) fn from_stats(
        source: &'a mut dyn SparseChunkSource,
        stats: &ScatterDiag,
        workers: usize,
    ) -> Result<Self> {
        let (p, m) = (source.p(), source.m());
        if m < 2 {
            return invalid("SourceCovOp needs m >= 2 (Eq. 19 rescale)");
        }
        if stats.diag().len() != p {
            return invalid(format!(
                "SourceCovOp: stats dimension {} != source p {p}",
                stats.diag().len()
            ));
        }
        if stats.n() == 0 {
            return invalid("SourceCovOp: source is empty");
        }
        let (c1, c2) = unbias_scales(p, m, stats.n());
        Ok(SourceCovOp {
            source,
            p,
            c1,
            c2,
            diag: stats.diag().to_vec(),
            workers: workers.max(1),
            passes: 0,
        })
    }

    /// Passes over the sparse source made by [`apply`](SymOp::apply) so
    /// far (a top-k solve costs `iters + 2`).
    pub fn passes(&self) -> usize {
        self.passes
    }
}

impl SymOp for SourceCovOp<'_> {
    fn dim(&self) -> usize {
        self.p
    }

    fn apply(&mut self, block: &Mat) -> Result<Mat> {
        assert_eq!(block.rows(), self.p, "SourceCovOp: block rows != p");
        let bt = block.transpose();
        let mut gt = Mat::zeros(block.cols(), self.p);
        self.source.reset()?;
        while let Some(chunk) = self.source.next_chunk()? {
            scatter_chunk(&chunk, &bt, &mut gt, self.workers);
        }
        self.passes += 1;
        Ok(finish_apply(block, &gt, self.c1, self.c2, &self.diag))
    }
}

/// One-pass covariance-free streaming PCA: compress the raw stream
/// (the only raw pass), hold the compressed chunks, and solve the top-k
/// eigenproblem by block-Krylov iteration over them. Memory is the
/// compressed size (~`12·m·n` bytes) plus O(p·(k+4)) solver state —
/// never a p×p matrix. The mean estimate is bit-identical to
/// [`run_pca_stream`](super::run_pca_stream)'s.
pub fn run_pca_krylov_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    topk: usize,
    stream: StreamConfig,
) -> Result<(KrylovPcaReport, PipelineReport)> {
    let sp = Sparsifier::new(source.p(), scfg)?;
    let mut timer = Timer::new();
    let mut chunks: Vec<SparseChunk> = Vec::new();
    let mut collect = |c: SparseChunk| -> Result<()> {
        chunks.push(c);
        Ok(())
    };
    let n = compress_stream(source, &sp, stream, true, &mut collect, &mut timer)?;
    if n == 0 {
        return invalid("krylov pca stream: source is empty");
    }
    // racing workers deliver chunks out of order; sort + coalesce so
    // every downstream fold runs in global column order
    chunks.sort_by_key(|c| c.start_col());
    let chunks = coalesce_chunks(chunks, FIT_COALESCE_COLS)?;
    let mut mean_est = SparseMeanEstimator::new(sp.p(), sp.m());
    for c in &chunks {
        mean_est.accumulate(c);
    }
    let mut op = SparseCovOp::new(&chunks, stream.workers)?;
    let pca_pre = timer.time("eig", || {
        Pca::from_sparse_operator(&mut op, topk, DEFAULT_KRYLOV_ITERS, scfg.seed)
    })?;
    let components = sp.unmix(&pca_pre.components);
    let mean_pre = Mat::from_vec(sp.p(), 1, mean_est.estimate())?;
    let mean = sp.unmix(&mean_pre).col(0).to_vec();
    let report = PipelineReport { timer, n, passes: 1, iterations: 0, engine: "native" };
    Ok((
        KrylovPcaReport { mean, pca: Pca { components, eigenvalues: pca_pre.eigenvalues } },
        report,
    ))
}

/// Covariance-free PCA over any rewindable sparse source: one stats
/// pass (mean + scatter diagonal), then `DEFAULT_KRYLOV_ITERS + 2`
/// streamed block products. Zero passes over the raw data. The source
/// is consumed from the start (the driver rewinds it).
/// `preconditioned = false` skips the adjoint and only drops padding
/// (ablation stores).
pub fn run_pca_krylov_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    topk: usize,
    workers: usize,
    preconditioned: bool,
) -> Result<(KrylovPcaReport, PipelineReport)> {
    if source.p() != sp.p() || source.m() != sp.m() {
        return invalid(format!(
            "krylov pca: source is p={} m={}, sparsifier is p={} m={}",
            source.p(),
            source.m(),
            sp.p(),
            sp.m()
        ));
    }
    let mut timer = Timer::new();
    let t0 = Instant::now();
    let mut mean_est = SparseMeanEstimator::new(sp.p(), sp.m());
    let mut stats = ScatterDiag::new(sp.p());
    source.reset()?;
    while let Some(chunk) = source.next_chunk()? {
        mean_est.accumulate(&chunk);
        stats.accumulate(&chunk);
    }
    timer.add("stats", t0.elapsed().as_secs_f64());
    let n = stats.n();
    if n == 0 {
        return invalid("krylov pca: source is empty");
    }
    let mut op = SourceCovOp::from_stats(source, &stats, workers)?;
    let pca_pre = timer.time("eig", || {
        Pca::from_sparse_operator(&mut op, topk, DEFAULT_KRYLOV_ITERS, sp.seed())
    })?;
    let mean_pre = Mat::from_vec(sp.p(), 1, mean_est.estimate())?;
    let (components, mean) = if preconditioned {
        (sp.unmix(&pca_pre.components), sp.unmix(&mean_pre).col(0).to_vec())
    } else {
        (sp.truncate(&pca_pre.components), sp.truncate(&mean_pre).col(0).to_vec())
    };
    let report = PipelineReport { timer, n, passes: 0, iterations: 0, engine: "native" };
    Ok((
        KrylovPcaReport { mean, pca: Pca { components, eigenvalues: pca_pre.eigenvalues } },
        report,
    ))
}

/// Covariance-free PCA straight from a persistent sparse store
/// (manifest-driven sparsifier reconstruction; zero raw-data passes).
/// Each Krylov iteration streams the store once under the reader's
/// memory budget, so neither p×p *nor* the full compressed data needs
/// to fit in RAM — the budget bounds the fit's working set.
pub fn run_pca_krylov_from_store(
    store: &mut SparseStoreReader,
    topk: usize,
    workers: usize,
) -> Result<(KrylovPcaReport, PipelineReport)> {
    let sp = store.sparsifier()?;
    let preconditioned = store.manifest().preconditioned;
    run_pca_krylov_sparse(store, &sp, topk, workers, preconditioned)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_pca_stream, MatSource};
    use crate::pca::recovered_components;
    use crate::rng::Pcg64;
    use crate::transform::TransformKind;

    #[test]
    fn krylov_stream_matches_covariance_solver() {
        let mut rng = Pcg64::seed(19);
        let d = crate::data::spiked(32, 900, &[8.0, 4.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 3 };
        let stream = StreamConfig { workers: 2, chunk_cols: 128, ..Default::default() };

        let mut src = MatSource::new(&d.data, 128);
        let (cov, cov_report) = run_pca_stream(&mut src, scfg, 2, stream).unwrap();
        let mut src2 = MatSource::new(&d.data, 128);
        let (kry, kry_report) = run_pca_krylov_stream(&mut src2, scfg, 2, stream).unwrap();

        assert_eq!(cov_report.passes, 1);
        assert_eq!(kry_report.passes, 1);
        assert_eq!(kry_report.n, 900);
        // same implicit matrix, same iteration budget: same components
        assert_eq!(
            recovered_components(&kry.pca.components, &cov.pca.components, 0.95),
            2
        );
        // the mean estimator path is shared — bit-identical
        for (a, b) in kry.mean.iter().zip(&cov.mean) {
            assert_eq!(a.to_bits(), b.to_bits(), "mean");
        }
        // both recover the planted spikes
        assert!(recovered_components(&kry.pca.components, &d.centers, 0.9) >= 2);
    }

    #[test]
    fn krylov_stream_is_bitwise_worker_invariant() {
        let mut rng = Pcg64::seed(47);
        let d = crate::data::spiked(32, 500, &[5.0, 2.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 8 };
        let mut base_src = MatSource::new(&d.data, 64);
        let base_stream = StreamConfig { workers: 1, chunk_cols: 64, ..Default::default() };
        let (base, _) = run_pca_krylov_stream(&mut base_src, scfg, 2, base_stream).unwrap();
        for workers in [2usize, 4] {
            let mut src = MatSource::new(&d.data, 64);
            let stream = StreamConfig { workers, chunk_cols: 64, ..Default::default() };
            let (par, _) = run_pca_krylov_stream(&mut src, scfg, 2, stream).unwrap();
            for (a, b) in par
                .pca
                .components
                .as_slice()
                .iter()
                .zip(base.pca.components.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "components, workers={workers}");
            }
            for (a, b) in par.pca.eigenvalues.iter().zip(&base.pca.eigenvalues) {
                assert_eq!(a.to_bits(), b.to_bits(), "eigenvalues, workers={workers}");
            }
            for (a, b) in par.mean.iter().zip(&base.mean) {
                assert_eq!(a.to_bits(), b.to_bits(), "mean, workers={workers}");
            }
        }
    }

    #[test]
    fn source_op_counts_its_passes() {
        let mut rng = Pcg64::seed(5);
        let d = crate::data::spiked(16, 200, &[4.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 2 };
        let sp = Sparsifier::new(16, scfg).unwrap();
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();
        let mut source = crate::coordinator::SparseVecSource::new(vec![chunk]).unwrap();
        let mut op = SourceCovOp::new(&mut source, 1).unwrap();
        assert_eq!(op.dim(), 16);
        assert_eq!(op.passes(), 0);
        let (_, _) = crate::linalg::block_krylov_topk(&mut op, 2, 5, 1).unwrap();
        assert_eq!(op.passes(), 5 + 2);
    }
}
