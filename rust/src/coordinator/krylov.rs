//! Covariance-free PCA: the [`SourceCovOp`] streaming operator plus the
//! legacy `run_pca_krylov_*` drivers (now **deprecated** shims over
//! [`FitPlan::pca().solver(Solver::Krylov)`](super::FitPlan)).
//!
//! [`Solver::Covariance`](super::Solver::Covariance) materializes the p×p
//! Theorem 6 estimate before eigendecomposing — O(p²) memory and the
//! dominant cost at large p. The Krylov path keeps only the sparsified
//! chunks and evaluates the estimate's *action* per block product
//! ([`estimators::SparseCovOp`](crate::estimators::SparseCovOp) in
//! memory, or [`SourceCovOp`] streaming a
//! [`SparseChunkSource`](crate::sparse::SparseChunkSource) once per
//! product), so the whole fit runs in O(p·(k+4)) working memory on top
//! of the compressed data. Every path inherits the PR 1 bitwise
//! contract: results are identical for every worker count and every
//! reader memory budget, and the mean estimate is bit-identical to the
//! covariance path's.

use crate::error::{invalid, Result};
use crate::estimators::{finish_apply, scatter_chunk, unbias_scales, weighted_scales, ScatterDiag};
use crate::linalg::{Mat, SymOp};
use crate::pca::Pca;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::sparse::SparseChunkSource;
use crate::store::SparseStoreReader;

use super::plan::{FitOutcome, FitPlan, FitReport, Solver};
use super::{ChunkSource, PipelineReport, StreamConfig};

/// Krylov iterations used by the drivers — the same constant as
/// [`Pca::from_covariance`]'s subspace-iteration count
/// ([`pca::DEFAULT_PCA_ITERS`](crate::pca::DEFAULT_PCA_ITERS)), so the
/// two solvers always run matched budgets. Each iteration costs one pass
/// over the compressed data.
pub const DEFAULT_KRYLOV_ITERS: usize = crate::pca::DEFAULT_PCA_ITERS;

/// PCA outputs of the covariance-free path. Unlike
/// [`PcaReport`](super::PcaReport) there is no `covariance` field — not
/// materializing it is the point.
pub struct KrylovPcaReport {
    /// Unbiased sample-mean estimate (Thm 4), original-domain.
    pub mean: Vec<f64>,
    /// Top-k principal components + eigenvalues of the implicit Thm 6
    /// estimate, unmixed to the original domain.
    pub pca: Pca,
}

/// The Theorem 6 covariance estimate over a rewindable
/// [`SparseChunkSource`], as a [`SymOp`]: every
/// [`apply`](SymOp::apply) resets the source and streams it once,
/// folding each chunk through the same partition-invariant scatter as
/// [`SparseCovOp`](crate::estimators::SparseCovOp) — bits never depend
/// on the worker count or the source's chunk granularity (a store
/// reader's memory budget included).
pub struct SourceCovOp<'a> {
    source: &'a mut dyn SparseChunkSource,
    p: usize,
    c1: f64,
    c2: f64,
    diag: Vec<f64>,
    workers: usize,
    passes: usize,
}

impl<'a> SourceCovOp<'a> {
    /// Build the operator over a **uniform-scheme** source: one stats
    /// pass over the source (from the start) accumulates `diag(W Wᵀ)`
    /// and the sample count.
    pub fn new(source: &'a mut dyn SparseChunkSource, workers: usize) -> Result<Self> {
        Self::new_with_calib(source, workers, false)
    }

    /// As [`new`](Self::new) but selecting the estimator calibration
    /// explicitly: `weighted = true` for sources of weighted
    /// with-replacement chunks (`sampling::Scheme::Hybrid`), where the
    /// accumulated per-slot diagonal is the exact cross-slot correction.
    pub fn new_with_calib(
        source: &'a mut dyn SparseChunkSource,
        workers: usize,
        weighted: bool,
    ) -> Result<Self> {
        let mut stats = ScatterDiag::new(source.p());
        source.reset()?;
        while let Some(chunk) = source.next_chunk()? {
            stats.accumulate(&chunk);
        }
        Self::from_stats(source, &stats, workers, weighted)
    }

    /// Build from an already-accumulated stats pass (the drivers fold
    /// the diagonal into their mean pass to avoid a second sweep).
    pub(crate) fn from_stats(
        source: &'a mut dyn SparseChunkSource,
        stats: &ScatterDiag,
        workers: usize,
        weighted: bool,
    ) -> Result<Self> {
        let (p, m) = (source.p(), source.m());
        if m < 2 {
            return invalid("SourceCovOp needs m >= 2 (Eq. 19 rescale)");
        }
        if stats.diag().len() != p {
            return invalid(format!(
                "SourceCovOp: stats dimension {} != source p {p}",
                stats.diag().len()
            ));
        }
        if stats.n() == 0 {
            return invalid("SourceCovOp: source is empty");
        }
        let (c1, c2) = if weighted {
            weighted_scales(m, stats.n())
        } else {
            unbias_scales(p, m, stats.n())
        };
        Ok(SourceCovOp {
            source,
            p,
            c1,
            c2,
            diag: stats.diag().to_vec(),
            workers: workers.max(1),
            passes: 0,
        })
    }

    /// Passes over the sparse source made by [`apply`](SymOp::apply) so
    /// far (a top-k solve costs `iters + 2`).
    pub fn passes(&self) -> usize {
        self.passes
    }
}

impl SymOp for SourceCovOp<'_> {
    fn dim(&self) -> usize {
        self.p
    }

    fn apply(&mut self, block: &Mat) -> Result<Mat> {
        assert_eq!(block.rows(), self.p, "SourceCovOp: block rows != p");
        let bt = block.transpose();
        let mut gt = Mat::zeros(block.cols(), self.p);
        self.source.reset()?;
        while let Some(chunk) = self.source.next_chunk()? {
            scatter_chunk(&chunk, &bt, &mut gt, self.workers);
        }
        self.passes += 1;
        Ok(finish_apply(block, &gt, self.c1, self.c2, &self.diag))
    }
}

fn legacy_krylov(report: FitReport) -> (KrylovPcaReport, PipelineReport) {
    let FitReport { timer, n, raw_passes, iterations, engine, outcome, .. } = report;
    let rep = PipelineReport { timer, n, passes: raw_passes, iterations, engine };
    match outcome {
        FitOutcome::Pca(fit) => (KrylovPcaReport { mean: fit.mean, pca: fit.pca }, rep),
        _ => unreachable!("pca plan returns a pca outcome"),
    }
}

/// One-pass covariance-free streaming PCA.
#[deprecated(
    note = "use FitPlan::pca().stream(source, scfg).topk(k).solver(Solver::Krylov).run()"
)]
pub fn run_pca_krylov_stream(
    source: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    topk: usize,
    stream: StreamConfig,
) -> Result<(KrylovPcaReport, PipelineReport)> {
    let report = FitPlan::pca()
        .stream(source, scfg)
        .topk(topk)
        .solver(Solver::Krylov)
        .stream_config(stream)
        .run()?;
    Ok(legacy_krylov(report))
}

/// Covariance-free PCA over any rewindable sparse source.
#[deprecated(
    note = "use FitPlan::pca().source(source, sp, preconditioned).topk(k)\
            .solver(Solver::Krylov).workers(w).run()"
)]
pub fn run_pca_krylov_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    topk: usize,
    workers: usize,
    preconditioned: bool,
) -> Result<(KrylovPcaReport, PipelineReport)> {
    let report = FitPlan::pca()
        .source(source, sp, preconditioned)
        .topk(topk)
        .solver(Solver::Krylov)
        .workers(workers)
        .run()?;
    Ok(legacy_krylov(report))
}

/// Covariance-free PCA straight from a persistent sparse store.
#[deprecated(
    note = "use FitPlan::pca().store(store).topk(k).solver(Solver::Krylov).workers(w).run()"
)]
pub fn run_pca_krylov_from_store(
    store: &mut SparseStoreReader,
    topk: usize,
    workers: usize,
) -> Result<(KrylovPcaReport, PipelineReport)> {
    let report = FitPlan::pca()
        .store(store)
        .topk(topk)
        .solver(Solver::Krylov)
        .workers(workers)
        .run()?;
    Ok(legacy_krylov(report))
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::super::MatSource;
    use super::*;
    use crate::pca::recovered_components;
    use crate::rng::Pcg64;
    use crate::transform::TransformKind;

    #[test]
    fn krylov_shim_matches_fitplan_bitwise() {
        let mut rng = Pcg64::seed(19);
        let d = crate::data::spiked(32, 900, &[8.0, 4.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 3 };
        let stream = StreamConfig { workers: 2, chunk_cols: 128, ..Default::default() };

        let mut src = MatSource::new(&d.data, 128);
        let (kry, report) = run_pca_krylov_stream(&mut src, scfg, 2, stream).unwrap();
        assert_eq!(report.passes, 1);
        assert_eq!(report.n, 900);

        let mut src2 = MatSource::new(&d.data, 128);
        let plan = FitPlan::pca()
            .stream(&mut src2, scfg)
            .topk(2)
            .solver(Solver::Krylov)
            .stream_config(stream)
            .run()
            .unwrap();
        let fit = plan.pca_fit().unwrap();
        for (a, b) in kry.mean.iter().zip(&fit.mean) {
            assert_eq!(a.to_bits(), b.to_bits(), "mean");
        }
        for (a, b) in kry.pca.components.as_slice().iter().zip(fit.pca.components.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "components");
        }
        // both recover the planted spikes
        assert!(recovered_components(&kry.pca.components, &d.centers, 0.9) >= 2);
    }

    #[test]
    fn krylov_stream_is_bitwise_worker_invariant() {
        let mut rng = Pcg64::seed(47);
        let d = crate::data::spiked(32, 500, &[5.0, 2.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 8 };
        let mut base_src = MatSource::new(&d.data, 64);
        let base_stream = StreamConfig { workers: 1, chunk_cols: 64, ..Default::default() };
        let (base, _) = run_pca_krylov_stream(&mut base_src, scfg, 2, base_stream).unwrap();
        for workers in [2usize, 4] {
            let mut src = MatSource::new(&d.data, 64);
            let stream = StreamConfig { workers, chunk_cols: 64, ..Default::default() };
            let (par, _) = run_pca_krylov_stream(&mut src, scfg, 2, stream).unwrap();
            for (a, b) in par
                .pca
                .components
                .as_slice()
                .iter()
                .zip(base.pca.components.as_slice())
            {
                assert_eq!(a.to_bits(), b.to_bits(), "components, workers={workers}");
            }
            for (a, b) in par.pca.eigenvalues.iter().zip(&base.pca.eigenvalues) {
                assert_eq!(a.to_bits(), b.to_bits(), "eigenvalues, workers={workers}");
            }
            for (a, b) in par.mean.iter().zip(&base.mean) {
                assert_eq!(a.to_bits(), b.to_bits(), "mean, workers={workers}");
            }
        }
    }

    #[test]
    fn source_op_counts_its_passes() {
        let mut rng = Pcg64::seed(5);
        let d = crate::data::spiked(16, 200, &[4.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 2 };
        let sp = Sparsifier::new(16, scfg).unwrap();
        let chunk = sp.compress_chunk(&d.data, 0).unwrap();
        let mut source = crate::sparse::SparseVecSource::new(vec![chunk]).unwrap();
        let mut op = SourceCovOp::new(&mut source, 1).unwrap();
        assert_eq!(op.dim(), 16);
        assert_eq!(op.passes(), 0);
        let (_, _) = crate::linalg::block_krylov_topk(&mut op, 2, 5, 1).unwrap();
        assert_eq!(op.passes(), 5 + 2);
    }
}
