//! `FitPlan` — the one composable entry point to the driver stack.
//!
//! The coordinator used to expose a combinatorial
//! `run_{pca,pca_krylov,sparsified_kmeans,two_pass,compress}_{stream,sparse,from_store}`
//! matrix (12+ near-duplicate free functions) that every new solver
//! multiplied. `FitPlan` collapses it into a builder over three
//! orthogonal axes:
//!
//! * **task** — [`FitPlan::pca`], [`FitPlan::kmeans`],
//!   [`FitPlan::compress`];
//! * **source** — a raw dense stream ([`stream`](FitPlan::stream)), an
//!   already-sparsified source ([`source`](FitPlan::source)), or a
//!   persistent sparse store ([`store`](FitPlan::store));
//! * **solver** — [`Solver::Covariance`] / [`Solver::Krylov`] for PCA,
//!   [`Solver::InMemory`] / [`Solver::Stream`] / [`Solver::Coreset`] for
//!   K-means.
//!
//! Store-backed plans additionally support **distributed fits**:
//! [`partition`](FitPlan::partition) runs the fit as N mergeable
//! shard-range partials (bit-identical for every N and merge order),
//! [`partials`](FitPlan::partials) emits the workers' serialized
//! [`PartialFit`](crate::distributed::PartialFit) artifacts instead of
//! fitting, and [`merge_partials`](FitPlan::merge_partials) folds such
//! artifacts back into the same [`FitReport`] a single-process fit
//! produces.
//!
//! Every combination returns the same [`FitReport`]: phase timings, raw
//! *and* sparse pass accounting, and — for K-means — the paper's
//! per-iteration center-error bound evaluated from
//! [`estimators::center_error_bound`](crate::estimators::center_error_bound).
//! The legacy `run_*` functions survive as thin deprecated shims over
//! this module.
//!
//! Invariants inherited from the kernels underneath: for a fixed seed,
//! results are bitwise identical for every worker count, every reader
//! memory budget, and every chunk granularity, and a store-backed fit is
//! bit-for-bit the streaming fit of the same data.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::distributed::{
    kind, peek_kind, weighted_kmeans, CoresetPartial, PartialFit, PcaPartial,
};
use crate::error::{invalid, Result};
use crate::estimators::{CovarianceEstimator, ScatterDiag, SparseCovOp, SparseMeanEstimator};
use crate::kmeans::{
    assign_dense, KmeansOpts, KmeansResult, NativeAssigner, SparseAssigner, SparsifiedKmeans,
    SparsifiedModel,
};
use crate::linalg::Mat;
use crate::metrics::Timer;
use crate::parallel;
use crate::pca::Pca;
use crate::sampling::{Scheme, Sparsifier, SparsifyConfig};
use crate::sparse::{Precision, SparseChunk, SparseChunkSource};
use crate::store::{ShardEntry, SparseStoreReader, SparseStoreWriter, StoreManifest};

use super::krylov::{SourceCovOp, DEFAULT_KRYLOV_ITERS};
use super::{compress_stream, ChunkSource, StreamConfig};

/// Default number of principal components when a PCA plan does not set
/// [`topk`](FitPlan::topk).
pub const DEFAULT_TOPK: usize = 5;

/// What a [`FitPlan`] computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Streaming PCA (Thm 4 mean + Thm 6 covariance estimates).
    Pca,
    /// Sparsified K-means (Algorithm 1, optional Algorithm 2 refinement).
    Kmeans,
    /// Compress a raw stream into a persistent sparse store.
    Compress,
}

/// Solver selection, spanning both tasks (validated per task at
/// [`run`](FitPlan::run) time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// PCA: materialize the p×p Thm 6 estimate, then eigendecompose.
    Covariance,
    /// PCA: covariance-free block-Krylov on the implicit estimate —
    /// O(p·(k+4)) solver memory, one sparse pass per block product.
    Krylov,
    /// K-means: hold the (coalesced) sparse chunks in memory and iterate
    /// over them — the fastest path when the compressed data fits in RAM.
    InMemory,
    /// K-means: source-driven Lloyd via the `CenterStep` kernel — one
    /// sparse pass per iteration, nothing materialized; with a
    /// memory-budgeted store reader the whole fit is out-of-core.
    Stream,
    /// K-means: one-pass streaming via the merge-and-reduce coreset tree
    /// (arXiv:1511.08990) — each store shard becomes a leaf, sibling
    /// nodes reduce by importance sampling down to
    /// [`coreset_size`](FitPlan::coreset_size) weighted points, and the
    /// final weighted K-means runs on the surviving O(log n) nodes.
    /// Approximate (see `EXPERIMENTS.md` for the tolerance contract vs
    /// Lloyd) but single-pass, mergeable across workers, and
    /// store-backed only.
    Coreset,
}

impl Solver {
    /// CLI-facing name (`pds fit --solver <name>`).
    pub fn name(self) -> &'static str {
        match self {
            Solver::Covariance => "covariance",
            Solver::Krylov => "krylov",
            Solver::InMemory => "inmemory",
            Solver::Stream => "stream",
            Solver::Coreset => "coreset",
        }
    }

    /// Parse a CLI-facing solver name.
    pub fn parse(name: &str) -> Result<Solver> {
        Ok(match name {
            "covariance" => Solver::Covariance,
            "krylov" => Solver::Krylov,
            "inmemory" => Solver::InMemory,
            "stream" => Solver::Stream,
            "coreset" => Solver::Coreset,
            other => {
                return invalid(format!(
                    "unknown solver {other:?} (want covariance|krylov|inmemory|stream|coreset)"
                ))
            }
        })
    }
}

/// PCA outputs of a [`FitPlan`] run.
pub struct PcaFit {
    /// Unbiased sample-mean estimate (Thm 4), original-domain.
    pub mean: Vec<f64>,
    /// The materialized Thm 6 covariance estimate in the *preconditioned*
    /// domain — `Some` only for [`Solver::Covariance`] (not materializing
    /// it is the point of [`Solver::Krylov`]).
    pub covariance: Option<Mat>,
    /// Top-k principal components + eigenvalues, unmixed to the original
    /// domain.
    pub pca: Pca,
}

/// Task-specific result carried by a [`FitReport`].
pub enum FitOutcome {
    /// PCA components / eigenvalues / mean.
    Pca(PcaFit),
    /// The fitted K-means model, plus the Algorithm 2 refinement when the
    /// plan asked for [`two_pass`](FitPlan::two_pass).
    Kmeans {
        /// The pass-1 sparsified model (original-domain centers).
        model: SparsifiedModel,
        /// Exact-mean / original-domain reassignment (Algorithm 2), if
        /// a refinement pass ran.
        refined: Option<KmeansResult>,
    },
    /// Manifest of the store written by a [`FitPlan::compress`] run.
    Compressed(StoreManifest),
}

/// The single report every plan returns: accounting + outcome.
pub struct FitReport {
    /// Phase timings (`load`, `compress`, `kmeans`, `eig`, `stats`,
    /// `pass2`, `store` — whichever phases the plan exercised).
    pub timer: Timer,
    /// Samples processed.
    pub n: usize,
    /// Passes over the **raw** dense data (paper Table II discipline):
    /// 1 for a fresh compress, 0 for sparse/store-backed fits, +1 for an
    /// Algorithm 2 refinement.
    pub raw_passes: usize,
    /// Passes started over the **sparsified** data: 1 for an in-memory
    /// materialization; for [`Solver::Stream`] every source walk counts —
    /// one per Lloyd iteration plus the k-means++ seeding's sub-passes
    /// (≈2 per seed, some stopped early) per restart; `iters + 2` block
    /// products (+1 stats pass) for [`Solver::Krylov`].
    pub sparse_passes: usize,
    /// Lloyd iterations of the winning restart (K-means tasks).
    pub iterations: usize,
    /// Assignment engine used (K-means tasks; `"native"` otherwise).
    pub engine: &'static str,
    /// Per-iteration worst-cluster center-error bound (Eq. 43 at
    /// δ = [`CENTER_BOUND_DELTA`](crate::kmeans::CENTER_BOUND_DELTA)),
    /// copied from [`SparsifiedModel::center_bound`]; empty for PCA /
    /// compress plans. The bound applies to the uniform sampling schemes
    /// only — weighted (hybrid) fits and [`Solver::Coreset`] fits (whose
    /// centers come from the coreset, not the Eq. 39 estimator) record
    /// `NaN` per iteration, never a number the theory does not back.
    pub center_bound: Vec<f64>,
    /// The task-specific result.
    pub outcome: FitOutcome,
}

impl FitReport {
    /// The fitted K-means model, if this was a K-means plan.
    pub fn kmeans_model(&self) -> Option<&SparsifiedModel> {
        match &self.outcome {
            FitOutcome::Kmeans { model, .. } => Some(model),
            _ => None,
        }
    }

    /// The Algorithm 2 refinement, if the plan ran one.
    pub fn refined(&self) -> Option<&KmeansResult> {
        match &self.outcome {
            FitOutcome::Kmeans { refined, .. } => refined.as_ref(),
            _ => None,
        }
    }

    /// The PCA outputs, if this was a PCA plan.
    pub fn pca_fit(&self) -> Option<&PcaFit> {
        match &self.outcome {
            FitOutcome::Pca(fit) => Some(fit),
            _ => None,
        }
    }

    /// The written store's manifest, if this was a compress plan.
    pub fn store_manifest(&self) -> Option<&StoreManifest> {
        match &self.outcome {
            FitOutcome::Compressed(m) => Some(m),
            _ => None,
        }
    }
}

/// The plan's data input, normalized at `run` time.
enum SourceKind<'a> {
    /// Raw dense stream + the compression config to apply.
    Raw(&'a mut dyn ChunkSource),
    /// Already-sparsified source with its (cloned) sparsifier.
    Sparse {
        src: &'a mut dyn SparseChunkSource,
        sp: Sparsifier,
        preconditioned: bool,
    },
    /// Persistent sparse store (sparsifier rebuilt from the manifest).
    Store(&'a mut SparseStoreReader),
}

/// Builder for one end-to-end fit over three orthogonal axes — task
/// ([`pca`](Self::pca) / [`kmeans`](Self::kmeans) /
/// [`compress`](Self::compress)), source ([`stream`](Self::stream) /
/// [`source`](Self::source) / [`store`](Self::store)), and
/// [`solver`](Self::solver) — validated at [`run`](Self::run) time. All
/// setters are chainable and `run` consumes the plan.
///
/// # Example — PCA
///
/// ```
/// use pds::coordinator::{FitPlan, MatSource, Solver};
/// use pds::linalg::Mat;
/// use pds::rng::Pcg64;
/// use pds::sampling::SparsifyConfig;
/// use pds::transform::TransformKind;
///
/// let mut rng = Pcg64::seed(1);
/// let x = Mat::from_fn(16, 300, |_, _| rng.normal());
/// let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 2 };
/// let mut src = MatSource::new(&x, 64);
/// let report = FitPlan::pca()
///     .stream(&mut src, scfg)
///     .topk(2)
///     .solver(Solver::Krylov)
///     .workers(2)
///     .run()?;
/// let fit = report.pca_fit().expect("pca plan");
/// assert_eq!(fit.pca.components.cols(), 2);
/// assert_eq!(fit.mean.len(), 16);
/// assert_eq!(report.raw_passes, 1);
/// # Ok::<(), pds::Error>(())
/// ```
pub struct FitPlan<'a> {
    task: Task,
    source: Option<SourceKind<'a>>,
    scfg: Option<SparsifyConfig>,
    stream: StreamConfig,
    precondition: bool,
    /// `Some` only when the caller set a scheme explicitly — sparse- and
    /// store-backed plans validate it against the source's recorded
    /// scheme instead of silently ignoring it.
    scheme: Option<Scheme>,
    /// `Some` only when the caller set a precision explicitly — sparse-
    /// and store-backed plans validate it against the source's recorded
    /// precision, mirroring the `scheme` contract.
    precision: Option<Precision>,
    topk: usize,
    solver: Option<Solver>,
    k: Option<usize>,
    opts: KmeansOpts,
    assigner: Option<&'a dyn SparseAssigner>,
    two_pass: bool,
    refine: Option<&'a mut dyn ChunkSource>,
    store_dir: Option<PathBuf>,
    shard_cols: usize,
    /// `Some(n)` runs a store-backed fit as `n` mergeable shard-range
    /// partials (the distributed path); `None` is the classic
    /// single-accumulator fit.
    partition: Option<usize>,
    /// Node capacity of the [`Solver::Coreset`] merge-and-reduce tree.
    coreset_size: usize,
}

/// Default [`Solver::Coreset`] node capacity
/// ([`FitPlan::coreset_size`]): 256 weighted points per surviving tree
/// node.
pub const DEFAULT_CORESET_SIZE: usize = 256;

/// Shared default assigner instance (`&'static` so the builder can fall
/// back to it without an allocation).
static NATIVE_ASSIGNER: NativeAssigner = NativeAssigner::new();

impl<'a> FitPlan<'a> {
    fn new(task: Task) -> Self {
        FitPlan {
            task,
            source: None,
            scfg: None,
            stream: StreamConfig::default(),
            precondition: true,
            scheme: None,
            precision: None,
            topk: DEFAULT_TOPK,
            solver: None,
            k: None,
            opts: KmeansOpts::default(),
            assigner: None,
            two_pass: false,
            refine: None,
            store_dir: None,
            shard_cols: 8192,
            partition: None,
            coreset_size: DEFAULT_CORESET_SIZE,
        }
    }

    /// Plan a streaming PCA fit.
    pub fn pca() -> Self {
        FitPlan::new(Task::Pca)
    }

    /// Plan a sparsified K-means fit (Algorithm 1).
    ///
    /// ```
    /// use pds::coordinator::FitPlan;
    /// use pds::data::gaussian_blobs;
    /// use pds::coordinator::MatSource;
    /// use pds::rng::Pcg64;
    /// use pds::sampling::SparsifyConfig;
    /// use pds::transform::TransformKind;
    ///
    /// let mut rng = Pcg64::seed(3);
    /// let d = gaussian_blobs(32, 300, 3, 0.1, &mut rng);
    /// let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 4 };
    /// let mut src = MatSource::new(&d.data, 64);
    /// let report = FitPlan::kmeans()
    ///     .stream(&mut src, scfg)
    ///     .k(3)
    ///     .restarts(2)
    ///     .run()?;
    /// let model = report.kmeans_model().expect("kmeans plan");
    /// assert_eq!(model.result.assign.len(), 300);
    /// // one Thm-level center-error bound per Lloyd iteration
    /// assert_eq!(report.center_bound.len(), report.iterations);
    /// assert_eq!(report.raw_passes, 1);
    /// # Ok::<(), pds::Error>(())
    /// ```
    pub fn kmeans() -> Self {
        FitPlan::new(Task::Kmeans)
    }

    /// Plan a compress-once pass into a persistent sparse store.
    pub fn compress() -> Self {
        FitPlan::new(Task::Compress)
    }

    /// Feed the plan from a raw dense stream, compressed on the fly with
    /// `scfg` (this is the plan's one raw pass).
    pub fn stream(mut self, src: &'a mut dyn ChunkSource, scfg: SparsifyConfig) -> Self {
        self.source = Some(SourceKind::Raw(src));
        self.scfg = Some(scfg);
        self
    }

    /// Feed the plan from an already-sparsified source. `sp` must be the
    /// sparsifier the chunks were produced with; `preconditioned = false`
    /// marks ablation data compressed without the ROS (centers /
    /// components then only drop padding instead of unmixing).
    pub fn source(
        mut self,
        src: &'a mut dyn SparseChunkSource,
        sp: &Sparsifier,
        preconditioned: bool,
    ) -> Self {
        self.source = Some(SourceKind::Sparse { src, sp: sp.clone(), preconditioned });
        self
    }

    /// Feed the plan from a persistent sparse store (zero raw passes; the
    /// sparsifier is rebuilt from the manifest).
    pub fn store(mut self, reader: &'a mut SparseStoreReader) -> Self {
        self.source = Some(SourceKind::Store(reader));
        self
    }

    /// Fork/join width for every stage (compress workers, assignment,
    /// center/covariance accumulation, restart fan-out). Any value yields
    /// bitwise identical results.
    pub fn workers(mut self, workers: usize) -> Self {
        self.stream.workers = workers.max(1);
        self
    }

    /// Full streaming configuration (queue depth, chunk columns, workers)
    /// for raw-stream sources.
    pub fn stream_config(mut self, cfg: StreamConfig) -> Self {
        self.stream = cfg;
        self
    }

    /// Toggle the ROS preconditioning on a raw-stream compress (default
    /// `true`; `false` is the paper's ablation arm — equivalent to
    /// [`scheme(Scheme::Uniform)`](Self::scheme)).
    pub fn precondition(mut self, on: bool) -> Self {
        self.precondition = on;
        self
    }

    /// Element-sampling scheme (default [`Scheme::Precond`], the paper's
    /// operator — byte-identical to not calling this).
    /// [`Scheme::Hybrid`] selects the weighted hybrid-(ℓ1,ℓ2) comparison
    /// scheme; the plan then wires the weighted estimator calibration
    /// automatically. Sparse-source and store-backed plans take their
    /// scheme from the sparsifier / manifest; setting one explicitly
    /// there asserts it — a mismatch fails the plan instead of silently
    /// fitting the wrong comparison arm.
    pub fn scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = Some(scheme);
        self
    }

    /// Storage precision for the sparsified values (default
    /// [`Precision::F64`] — byte-identical to not calling this).
    /// [`Precision::F32`] quantizes each kept value once at compress
    /// time and halves the chunk / store value bytes; all accumulation
    /// stays in `f64`, so the only error is the per-value quantization
    /// (≤ 0.5 ulp of `f32`). Sparse-source and store-backed plans take
    /// their precision from the source / manifest; setting one
    /// explicitly there asserts it — a mismatch fails the plan.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = Some(precision);
        self
    }

    /// Sparse-/store-backed plans: an explicitly requested precision
    /// must match the source's recorded one.
    fn check_requested_precision(
        requested: Option<Precision>,
        actual: Precision,
    ) -> Result<()> {
        if let Some(req) = requested {
            if req != actual {
                return invalid(format!(
                    "FitPlan: .precision({}) does not match this source's recorded \
                     precision ({})",
                    req.name(),
                    actual.name()
                ));
            }
        }
        Ok(())
    }

    /// The effective selection law of a raw-stream plan: the configured
    /// scheme, downgraded from `Precond` to `Uniform` when the legacy
    /// [`precondition(false)`](Self::precondition) ablation toggle is
    /// set.
    fn effective_scheme(&self) -> Scheme {
        let scheme = self.scheme.unwrap_or(Scheme::Precond);
        if !self.precondition && scheme == Scheme::Precond {
            Scheme::Uniform
        } else {
            scheme
        }
    }

    /// Sparse-/store-backed plans: an explicitly requested scheme must
    /// match the source's recorded one.
    fn check_requested_scheme(requested: Option<Scheme>, actual: Scheme) -> Result<()> {
        if let Some(req) = requested {
            if req != actual {
                return invalid(format!(
                    "FitPlan: .scheme({}) does not match this source's recorded scheme ({})",
                    req.name(),
                    actual.name()
                ));
            }
        }
        Ok(())
    }

    /// Number of principal components (PCA plans; default
    /// [`DEFAULT_TOPK`]).
    pub fn topk(mut self, topk: usize) -> Self {
        self.topk = topk;
        self
    }

    /// Solver override. PCA accepts [`Solver::Covariance`] (default) or
    /// [`Solver::Krylov`]; K-means accepts [`Solver::InMemory`]
    /// (default), [`Solver::Stream`] or [`Solver::Coreset`]
    /// (store-backed only).
    pub fn solver(mut self, solver: Solver) -> Self {
        self.solver = Some(solver);
        self
    }

    /// Number of clusters (required for K-means plans).
    pub fn k(mut self, k: usize) -> Self {
        self.k = Some(k);
        self
    }

    /// Lloyd / restart options (K-means plans).
    pub fn kmeans_opts(mut self, opts: KmeansOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Number of k-means++ restarts (`opts.n_init`): restarts run over
    /// seeded sub-RNG streams — in parallel on the in-memory solver when
    /// [`workers`](Self::workers) allows — and the best inertia wins,
    /// deterministically for a fixed seed regardless of worker count.
    pub fn restarts(mut self, restarts: usize) -> Self {
        self.opts.n_init = restarts.max(1);
        self
    }

    /// Assignment engine (default: the native masked-distance assigner).
    pub fn assigner(mut self, assigner: &'a dyn SparseAssigner) -> Self {
        self.assigner = Some(assigner);
        self
    }

    /// Run the Algorithm 2 refinement after the fit: one extra pass over
    /// the raw stream recomputing exact class means and reassigning in
    /// the original domain. Raw-stream plans reuse their own source;
    /// sparse/store plans must provide one via
    /// [`refine_stream`](Self::refine_stream).
    pub fn two_pass(mut self, on: bool) -> Self {
        self.two_pass = on;
        self
    }

    /// Raw stream for the Algorithm 2 refinement of a sparse/store-backed
    /// plan (implies [`two_pass`](Self::two_pass)).
    pub fn refine_stream(mut self, raw: &'a mut dyn ChunkSource) -> Self {
        self.refine = Some(raw);
        self.two_pass = true;
        self
    }

    /// Output directory for a [`compress`](Self::compress) plan.
    pub fn store_dir(mut self, dir: &Path) -> Self {
        self.store_dir = Some(dir.to_path_buf());
        self
    }

    /// Columns per shard for a [`compress`](Self::compress) plan
    /// (default 8192).
    pub fn shard_cols(mut self, cols: usize) -> Self {
        self.shard_cols = cols.max(1);
        self
    }

    /// Run a store-backed fit as `n` mergeable shard-range partials —
    /// the in-process form of the distributed fit, where each "worker"
    /// folds its contiguous range of the store's shards into a
    /// [`PartialFit`](crate::distributed::PartialFit) and the partials
    /// are merged before finalizing. Because every partial keeps
    /// per-shard subtotals (merged by disjoint union, finalized in
    /// shard-index order), the fitted model is **bitwise identical for
    /// every `n` and merge order**. Applies to store sources only;
    /// supported by the covariance PCA solver and every K-means solver.
    pub fn partition(mut self, n: usize) -> Self {
        self.partition = Some(n.max(1));
        self
    }

    /// Node capacity of the [`Solver::Coreset`] merge-and-reduce tree
    /// (default [`DEFAULT_CORESET_SIZE`]). Larger values track the exact
    /// Lloyd objective more closely at more memory per tree node.
    pub fn coreset_size(mut self, size: usize) -> Self {
        self.coreset_size = size.max(2);
        self
    }

    /// Execute the plan.
    pub fn run(self) -> Result<FitReport> {
        match self.task {
            Task::Pca => self.run_pca(),
            Task::Kmeans => self.run_kmeans(),
            Task::Compress => self.run_compress(),
        }
    }

    /// Validate + resolve the solver for the task.
    fn resolve_solver(&self) -> Result<Solver> {
        let solver = self.solver.unwrap_or(match self.task {
            Task::Pca => Solver::Covariance,
            _ => Solver::InMemory,
        });
        let ok = match self.task {
            Task::Pca => matches!(solver, Solver::Covariance | Solver::Krylov),
            Task::Kmeans => {
                matches!(solver, Solver::InMemory | Solver::Stream | Solver::Coreset)
            }
            Task::Compress => true,
        };
        if !ok {
            return invalid(format!(
                "FitPlan: solver {:?} does not apply to task {:?} (pca: covariance|krylov, \
                 kmeans: inmemory|stream|coreset)",
                self.solver, self.task
            ));
        }
        Ok(solver)
    }

    /// Distributed features (partitioned fits, the coreset solver,
    /// partial artifacts) are keyed to the store's shard table — they
    /// need a store source.
    fn check_distributed_source(&self, what: &str) -> Result<()> {
        if !matches!(self.source, Some(SourceKind::Store(_))) {
            return invalid(format!(
                "FitPlan: {what} needs a store source (.store(reader)) — the store's \
                 shards define the mergeable work units"
            ));
        }
        Ok(())
    }

    fn take_source(source: &mut Option<SourceKind<'a>>) -> Result<SourceKind<'a>> {
        source.take().ok_or_else(|| {
            crate::error::Error::Invalid(
                "FitPlan: no source — call .stream(), .source() or .store()".into(),
            )
        })
    }

    // ---------------------------------------------------------------- pca

    fn run_pca(mut self) -> Result<FitReport> {
        let solver = self.resolve_solver()?;
        let topk = self.topk;
        let workers = self.stream.workers;
        let scheme = self.effective_scheme();
        let precision = self.precision.unwrap_or_default();
        if let Some(parts) = self.partition {
            self.check_distributed_source(".partition()")?;
            if solver == Solver::Krylov {
                return invalid(
                    "FitPlan: .partition() applies to the covariance PCA solver — krylov \
                     iterates over the whole store and has no one-shot partial",
                );
            }
            let SourceKind::Store(reader) = Self::take_source(&mut self.source)? else {
                unreachable!("checked above");
            };
            let sp = reader.sparsifier()?;
            Self::check_requested_scheme(self.scheme, sp.scheme())?;
            Self::check_requested_precision(self.precision, reader.manifest().precision)?;
            let preconditioned = reader.manifest().preconditioned;
            return pca_cov_partitioned(reader, &sp, topk, preconditioned, parts);
        }
        match Self::take_source(&mut self.source)? {
            SourceKind::Raw(src) => {
                let Some(scfg) = self.scfg else {
                    return invalid("FitPlan: raw stream needs a SparsifyConfig");
                };
                match solver {
                    Solver::Covariance => {
                        pca_cov_stream(src, scfg, scheme, precision, topk, self.stream)
                    }
                    _ => pca_krylov_stream(src, scfg, scheme, precision, topk, self.stream),
                }
            }
            SourceKind::Sparse { src, sp, preconditioned } => {
                Self::check_requested_scheme(self.scheme, sp.scheme())?;
                Self::check_requested_precision(self.precision, src.precision())?;
                match solver {
                    Solver::Covariance => pca_cov_sparse(src, &sp, topk, workers, preconditioned),
                    _ => pca_krylov_sparse(src, &sp, topk, workers, preconditioned),
                }
            }
            SourceKind::Store(reader) => {
                let sp = reader.sparsifier()?;
                Self::check_requested_scheme(self.scheme, sp.scheme())?;
                Self::check_requested_precision(self.precision, reader.manifest().precision)?;
                let preconditioned = reader.manifest().preconditioned;
                match solver {
                    Solver::Covariance => {
                        pca_cov_sparse(reader, &sp, topk, workers, preconditioned)
                    }
                    _ => pca_krylov_sparse(reader, &sp, topk, workers, preconditioned),
                }
            }
        }
    }

    // ------------------------------------------------------------- kmeans

    fn run_kmeans(mut self) -> Result<FitReport> {
        let solver = self.resolve_solver()?;
        let Some(k) = self.k else {
            return invalid("FitPlan::kmeans() needs .k(clusters)");
        };
        // a StreamConfig fan-out override builds a configured local
        // assigner; otherwise the shared static default is used as-is
        let local_assigner;
        let assigner: &dyn SparseAssigner = match self.assigner {
            Some(a) => a,
            None => match self.stream.assign_cols_per_worker {
                Some(cols) => {
                    local_assigner = NativeAssigner::new().with_cols_per_worker(cols);
                    &local_assigner
                }
                None => &NATIVE_ASSIGNER,
            },
        };
        let workers = self.stream.workers;
        let opts = self.opts;
        let scheme = self.effective_scheme();
        let precision = self.precision.unwrap_or_default();
        if solver == Solver::Coreset {
            self.check_distributed_source("the coreset solver")?;
        }
        if self.partition.is_some() {
            self.check_distributed_source(".partition()")?;
        }
        let refine = self.refine.take();
        let report = match Self::take_source(&mut self.source)? {
            SourceKind::Raw(src) => {
                let Some(scfg) = self.scfg else {
                    return invalid("FitPlan: raw stream needs a SparsifyConfig");
                };
                if solver == Solver::Stream {
                    return invalid(
                        "FitPlan: the stream K-means solver re-reads the sparse data every \
                         iteration; compress to a store first (FitPlan::compress), then \
                         .store(reader).solver(Solver::Stream)",
                    );
                }
                // reborrow: the plan's own source is revisited below when
                // a two-pass refinement was requested
                let mut report = kmeans_inmemory_stream(
                    &mut *src,
                    scfg,
                    scheme,
                    precision,
                    k,
                    opts,
                    assigner,
                    self.stream,
                )?;
                if self.two_pass {
                    if !scheme.preconditions() {
                        return invalid(
                            "FitPlan: the Algorithm 2 refinement needs preconditioned \
                             pass-1 centers (precondition(true) with the precond scheme)",
                        );
                    }
                    // Algorithm 2 revisits the raw data: an explicit
                    // .refine_stream() source wins, else the plan's own
                    // source is rewound and reused
                    match refine {
                        Some(raw) => refine_into_report(raw, k, &mut report)?,
                        None => refine_into_report(src, k, &mut report)?,
                    }
                }
                report
            }
            SourceKind::Sparse { src, sp, preconditioned } => {
                Self::check_requested_scheme(self.scheme, sp.scheme())?;
                Self::check_requested_precision(self.precision, src.precision())?;
                let mut report = kmeans_from_sparse(
                    src,
                    &sp,
                    k,
                    opts,
                    assigner,
                    workers,
                    preconditioned,
                    solver,
                )?;
                if self.two_pass {
                    if !preconditioned {
                        return invalid(
                            "FitPlan: the Algorithm 2 refinement needs preconditioned \
                             pass-1 centers (this source was compressed without the ROS)",
                        );
                    }
                    let Some(raw) = refine else {
                        return invalid(
                            "FitPlan: a sparse-source two-pass refinement needs \
                             .refine_stream(raw source)",
                        );
                    };
                    refine_into_report(raw, k, &mut report)?;
                }
                return Ok(report);
            }
            SourceKind::Store(reader) => {
                let sp = reader.sparsifier()?;
                Self::check_requested_scheme(self.scheme, sp.scheme())?;
                Self::check_requested_precision(self.precision, reader.manifest().precision)?;
                let preconditioned = reader.manifest().preconditioned;
                let mut report = match (solver, self.partition) {
                    (Solver::Coreset, parts) => kmeans_coreset_store(
                        reader,
                        &sp,
                        k,
                        opts,
                        assigner,
                        preconditioned,
                        parts.unwrap_or(1),
                        self.coreset_size,
                    )?,
                    (_, Some(parts)) => kmeans_partitioned_store(
                        reader,
                        &sp,
                        k,
                        opts,
                        assigner,
                        workers,
                        preconditioned,
                        parts,
                    )?,
                    (_, None) => kmeans_from_sparse(
                        reader,
                        &sp,
                        k,
                        opts,
                        assigner,
                        workers,
                        preconditioned,
                        solver,
                    )?,
                };
                if self.two_pass {
                    if !preconditioned {
                        return invalid(
                            "FitPlan: the Algorithm 2 refinement needs preconditioned \
                             pass-1 centers (this store was compressed without the ROS)",
                        );
                    }
                    let Some(raw) = refine else {
                        return invalid(
                            "FitPlan: a store-backed two-pass refinement needs \
                             .refine_stream(raw source)",
                        );
                    };
                    refine_into_report(raw, k, &mut report)?;
                }
                return Ok(report);
            }
        };
        // only raw-source plans fall through here (the sparse/store arms
        // return early so `refine` can be moved per arm)
        Ok(report)
    }

    // ----------------------------------------------------------- compress

    fn run_compress(mut self) -> Result<FitReport> {
        let Some(dir) = self.store_dir.clone() else {
            return invalid("FitPlan::compress() needs .store_dir(path)");
        };
        let SourceKind::Raw(src) = Self::take_source(&mut self.source)? else {
            return invalid("FitPlan::compress() consumes a raw stream (.stream(...))");
        };
        let Some(scfg) = self.scfg else {
            return invalid("FitPlan: raw stream needs a SparsifyConfig");
        };
        let scheme = self.effective_scheme();
        let precondition = scheme.preconditions();
        let sp = Sparsifier::with_scheme(src.p(), scfg, scheme)?;
        let mut timer = Timer::new();
        let mut writer =
            SparseStoreWriter::create(&dir, &sp, scfg, precondition, self.shard_cols)?
                .with_precision(self.precision.unwrap_or_default());
        let mut sink = |c: SparseChunk| writer.append(c);
        let n = compress_stream(src, &sp, self.stream, precondition, &mut sink, &mut timer)?;
        let manifest = timer.time("store", || writer.finish())?;
        Ok(FitReport {
            timer,
            n,
            raw_passes: 1,
            sparse_passes: 0,
            iterations: 0,
            engine: "native",
            center_bound: Vec::new(),
            outcome: FitOutcome::Compressed(manifest),
        })
    }

    // -------------------------------------------------- distributed fit

    /// Run the plan's worker side only: fold each of the
    /// [`partition`](Self::partition) shard ranges (default 1) into a
    /// serialized [`PartialFit`](crate::distributed::PartialFit)
    /// artifact, one per worker, **without** finalizing a model. The
    /// artifacts round-trip through the versioned `PDSP` envelope and are
    /// merged — in any order, by any process holding (a piece of) the
    /// same store — with [`merge_partials`](Self::merge_partials).
    ///
    /// Supported plans: PCA with the covariance solver (one
    /// [`PcaPartial`](crate::distributed::PcaPartial) per worker) and
    /// K-means with [`Solver::Coreset`] (one
    /// [`CoresetPartial`](crate::distributed::CoresetPartial) per
    /// worker). The Lloyd K-means solvers are iterative — their partials
    /// are per-iteration, so a one-shot worker artifact cannot exist;
    /// use [`run`](Self::run) with [`partition`](Self::partition)
    /// instead.
    pub fn partials(mut self) -> Result<Vec<Vec<u8>>> {
        let solver = self.resolve_solver()?;
        let parts = self.partition.unwrap_or(1);
        self.check_distributed_source(".partials()")?;
        let SourceKind::Store(reader) = Self::take_source(&mut self.source)? else {
            unreachable!("checked above");
        };
        let sp = reader.sparsifier()?;
        Self::check_requested_scheme(self.scheme, sp.scheme())?;
        Self::check_requested_precision(self.precision, reader.manifest().precision)?;
        check_source_shape(reader, &sp)?;
        let shards = reader.manifest().shards.clone();
        if shards.is_empty() {
            return invalid("FitPlan: source is empty");
        }
        match (self.task, solver) {
            (Task::Pca, Solver::Covariance) => {
                let mut out = Vec::new();
                for range in parallel::split_ranges(shards.len(), parts) {
                    let partial = pca_partial_for_shards(reader, &sp, &shards[range])?;
                    out.push(partial.to_bytes());
                }
                Ok(out)
            }
            (Task::Kmeans, Solver::Coreset) => {
                let mut out = Vec::new();
                for range in parallel::split_ranges(shards.len(), parts) {
                    let partial = coreset_partial_for_shards(
                        reader,
                        &sp,
                        &shards[range],
                        self.coreset_size,
                        self.opts.seed,
                    )?;
                    out.push(partial.to_bytes());
                }
                Ok(out)
            }
            (task, solver) => invalid(format!(
                "FitPlan: no one-shot partial for task {:?} with solver {:?} (pca: \
                 covariance, kmeans: coreset; the Lloyd solvers merge per-iteration — \
                 use .run() with .partition(n))",
                task, solver
            )),
        }
    }

    /// Coordinator side of the distributed fit: decode + merge worker
    /// artifacts from [`partials`](Self::partials) (any order, any
    /// grouping) and finalize them into the same [`FitReport`] the
    /// equivalent single-process [`run`](Self::run) produces — bitwise
    /// identical for PCA. The plan must hold the same store (`.store()`)
    /// the workers fit, and the merged artifacts must cover its shard
    /// set exactly; gaps, overlaps, kind mixtures and truncated or
    /// tampered artifacts all fail with typed errors.
    pub fn merge_partials(mut self, artifacts: &[Vec<u8>]) -> Result<FitReport> {
        // the same default-assigner fallback as run_kmeans
        let local_assigner;
        let assigner: &dyn SparseAssigner = match self.assigner {
            Some(a) => a,
            None => match self.stream.assign_cols_per_worker {
                Some(cols) => {
                    local_assigner = NativeAssigner::new().with_cols_per_worker(cols);
                    &local_assigner
                }
                None => &NATIVE_ASSIGNER,
            },
        };
        self.check_distributed_source(".merge_partials()")?;
        let SourceKind::Store(reader) = Self::take_source(&mut self.source)? else {
            unreachable!("checked above");
        };
        let sp = reader.sparsifier()?;
        Self::check_requested_scheme(self.scheme, sp.scheme())?;
        Self::check_requested_precision(self.precision, reader.manifest().precision)?;
        check_source_shape(reader, &sp)?;
        let preconditioned = reader.manifest().preconditioned;
        let Some(first) = artifacts.first() else {
            return invalid("FitPlan: merge_partials() got no partial artifacts");
        };
        match peek_kind(first)? {
            kind::PCA => {
                if self.task != Task::Pca {
                    return invalid(format!(
                        "FitPlan: pca partial artifacts under a {:?} plan",
                        self.task
                    ));
                }
                let mut merged = PcaPartial::from_bytes(first)?;
                for bytes in &artifacts[1..] {
                    merged.merge_from(&PcaPartial::from_bytes(bytes)?)?;
                }
                let want: Vec<u32> =
                    reader.manifest().shards.iter().map(|s| s.index as u32).collect();
                if merged.shards() != want {
                    return invalid(format!(
                        "FitPlan: merged pca partials cover shards {:?}, the store holds \
                         {:?}",
                        merged.shards(),
                        want
                    ));
                }
                pca_report_from_partial(&merged, &sp, self.topk, preconditioned, Timer::new(), 0)
            }
            kind::CORESET => {
                if self.task != Task::Kmeans {
                    return invalid(format!(
                        "FitPlan: coreset partial artifacts under a {:?} plan",
                        self.task
                    ));
                }
                let Some(k) = self.k else {
                    return invalid("FitPlan::kmeans() needs .k(clusters)");
                };
                let mut merged = CoresetPartial::from_bytes(first)?;
                for bytes in &artifacts[1..] {
                    merged.merge_from(&CoresetPartial::from_bytes(bytes)?)?;
                }
                coreset_report(
                    &merged,
                    reader,
                    &sp,
                    k,
                    self.opts,
                    assigner,
                    preconditioned,
                    Timer::new(),
                    0,
                )
            }
            other => invalid(format!(
                "FitPlan: cannot merge partial kind {other} (want pca or coreset worker \
                 artifacts)"
            )),
        }
    }
}

// ====================================================================
// shared machinery (the former run_* driver bodies)
// ====================================================================

/// Target column count when coalescing stream chunks for a fit.
pub(crate) const FIT_COALESCE_COLS: usize = 8192;

/// Merge sorted, contiguous stream chunks into pieces of at least
/// `target_cols` columns (the tail piece may be smaller).
pub(crate) fn coalesce_chunks(
    chunks: Vec<SparseChunk>,
    target_cols: usize,
) -> Result<Vec<SparseChunk>> {
    let mut out = Vec::new();
    let mut group: Vec<SparseChunk> = Vec::new();
    let mut group_cols = 0usize;
    for c in chunks {
        group_cols += c.n();
        group.push(c);
        if group_cols >= target_cols {
            out.push(merge_group(&mut group)?);
            group_cols = 0;
        }
    }
    if !group.is_empty() {
        out.push(merge_group(&mut group)?);
    }
    Ok(out)
}

fn merge_group(group: &mut Vec<SparseChunk>) -> Result<SparseChunk> {
    let merged = if group.len() == 1 {
        group.pop().expect("non-empty group")
    } else {
        SparseChunk::concat(group)?
    };
    group.clear();
    Ok(merged)
}

/// Compress a raw stream, collecting the chunks sorted + coalesced for an
/// efficient in-memory fit. Returns (chunks, n). Chunks are quantized to
/// `precision` as they arrive (a no-op at `F64`), so the fit sees exactly
/// what an equivalent store round trip would yield.
fn compress_collect(
    src: &mut dyn ChunkSource,
    sp: &Sparsifier,
    stream: StreamConfig,
    precondition: bool,
    precision: Precision,
    timer: &mut Timer,
) -> Result<(Vec<SparseChunk>, usize)> {
    let mut chunks: Vec<SparseChunk> = Vec::new();
    let mut collect = |c: SparseChunk| -> Result<()> {
        chunks.push(c.with_precision(precision));
        Ok(())
    };
    let n = compress_stream(src, sp, stream, precondition, &mut collect, timer)?;
    chunks.sort_by_key(|c| c.start_col());
    // coalesce the (often chunk_cols-sized) stream pieces so the parallel
    // kernels fan out over large column ranges instead of paying a
    // fork/join per tiny chunk; bitwise identical — every fit depends
    // only on the global column order
    let chunks = coalesce_chunks(chunks, FIT_COALESCE_COLS)?;
    Ok((chunks, n))
}

/// Drain a sparse source into memory, order and coalesce the chunks for
/// an efficient fit. Returns the chunks plus the total sample count.
fn collect_sparse(
    source: &mut dyn SparseChunkSource,
    timer: &mut Timer,
) -> Result<(Vec<SparseChunk>, usize)> {
    let t0 = Instant::now();
    let mut chunks = Vec::new();
    while let Some(c) = source.next_chunk()? {
        chunks.push(c);
    }
    timer.add("load", t0.elapsed().as_secs_f64());
    let n = chunks.iter().map(|c| c.n()).sum();
    chunks.sort_by_key(|c| c.start_col());
    let chunks = coalesce_chunks(chunks, FIT_COALESCE_COLS)?;
    Ok((chunks, n))
}

fn check_source_shape(source: &dyn SparseChunkSource, sp: &Sparsifier) -> Result<()> {
    if source.p() != sp.p() || source.m() != sp.m() {
        return invalid(format!(
            "FitPlan: source is p={} m={}, sparsifier is p={} m={}",
            source.p(),
            source.m(),
            sp.p(),
            sp.m()
        ));
    }
    Ok(())
}

/// One-pass sparsified K-means over a raw stream (Algorithm 1 at scale):
/// compress with backpressure, hold the compressed chunks, iterate.
#[allow(clippy::too_many_arguments)]
fn kmeans_inmemory_stream(
    src: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    scheme: Scheme,
    precision: Precision,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    stream: StreamConfig,
) -> Result<FitReport> {
    let precondition = scheme.preconditions();
    let sp = Sparsifier::with_scheme(src.p(), scfg, scheme)?;
    let mut timer = Timer::new();
    let (chunks, n) = compress_collect(src, &sp, stream, precondition, precision, &mut timer)?;
    if n == 0 {
        return invalid("FitPlan: stream is empty");
    }
    // reuse the compress pool width for the fit (assignment, center
    // accumulation and the restart fan-out are all bitwise
    // worker-count-invariant, so this only changes speed)
    let sk = SparsifiedKmeans::new(scfg, k, opts)
        .with_workers(stream.workers)
        .with_restart_workers(stream.workers);
    let model = timer.time("kmeans", || sk.fit_chunks_raw(&sp, &chunks, assigner, precondition))?;
    let iterations = model.result.iterations;
    let center_bound = model.center_bound.clone();
    Ok(FitReport {
        timer,
        n,
        raw_passes: 1,
        sparse_passes: 1,
        iterations,
        engine: assigner.name(),
        center_bound,
        outcome: FitOutcome::Kmeans { model, refined: None },
    })
}

/// Sparsified K-means over an already-compressed source — in-memory
/// (materialize + iterate) or streaming (one source pass per Lloyd
/// iteration through the `CenterStep` kernel). Zero raw passes either
/// way, and bit-identical outputs to the raw-stream path on the same
/// data.
#[allow(clippy::too_many_arguments)]
fn kmeans_from_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    workers: usize,
    preconditioned: bool,
    solver: Solver,
) -> Result<FitReport> {
    check_source_shape(source, sp)?;
    let scfg = SparsifyConfig { gamma: sp.gamma(), transform: sp.ros().kind(), seed: sp.seed() };
    let mut timer = Timer::new();
    let (model, n, sparse_passes) = if solver == Solver::Stream {
        let sk = SparsifiedKmeans::new(scfg, k, opts).with_workers(workers.max(1));
        let (model, passes) =
            timer.time("kmeans", || sk.fit_source(sp, source, assigner, preconditioned))?;
        let n = model.result.assign.len();
        (model, n, passes)
    } else {
        let (chunks, n) = collect_sparse(source, &mut timer)?;
        if n == 0 {
            return invalid("FitPlan: source is empty");
        }
        let sk = SparsifiedKmeans::new(scfg, k, opts)
            .with_workers(workers.max(1))
            .with_restart_workers(workers.max(1));
        let model =
            timer.time("kmeans", || sk.fit_chunks_raw(sp, &chunks, assigner, preconditioned))?;
        (model, n, 1)
    };
    let iterations = model.result.iterations;
    let center_bound = model.center_bound.clone();
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes,
        iterations,
        engine: assigner.name(),
        center_bound,
        outcome: FitOutcome::Kmeans { model, refined: None },
    })
}

/// The second pass of Algorithm 2, applied to an existing pass-1 model:
/// revisit the raw stream once to recompute exact class means and to
/// reassign against the pass-1 centers in the original domain. Returns
/// the refined result and the pass's wall-clock seconds.
pub fn two_pass_refine_stream(
    source: &mut dyn ChunkSource,
    model: &SparsifiedModel,
    k: usize,
) -> Result<(KmeansResult, f64)> {
    let one = &model.result;
    let p = source.p();
    source.reset()?;
    let t0 = Instant::now();
    let mut sums = Mat::zeros(p, k);
    let mut counts = vec![0usize; k];
    let mut assign = vec![0u32; one.assign.len()];
    let mut objective = 0.0;
    while let Some(chunk) = source.next_chunk()? {
        // (a) exact class means under the pass-1 assignment
        for j in 0..chunk.data.cols() {
            let c = one.assign[chunk.start_col + j] as usize;
            counts[c] += 1;
            let col = chunk.data.col(j);
            let s = sums.col_mut(c);
            for i in 0..p {
                s[i] += col[i];
            }
        }
        // (b) reassignment against pass-1 centers, original domain
        let (a, obj) = assign_dense(&chunk.data, &one.centers);
        objective += obj;
        assign[chunk.start_col..chunk.start_col + a.len()].copy_from_slice(&a);
    }
    let mut centers = one.centers.clone();
    for c in 0..k {
        if counts[c] > 0 {
            let inv = 1.0 / counts[c] as f64;
            let (s, dst) = (sums.col(c), centers.col_mut(c));
            for i in 0..p {
                dst[i] = s[i] * inv;
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    Ok((
        KmeansResult {
            centers,
            assign,
            objective,
            iterations: one.iterations,
            converged: one.converged,
        },
        secs,
    ))
}

/// Run the Algorithm 2 refinement and fold it into a K-means report.
fn refine_into_report(
    source: &mut dyn ChunkSource,
    k: usize,
    report: &mut FitReport,
) -> Result<()> {
    let FitOutcome::Kmeans { model, refined } = &mut report.outcome else {
        return invalid("FitPlan: refinement applies to K-means plans only");
    };
    let (result, secs) = two_pass_refine_stream(source, model, k)?;
    *refined = Some(result);
    report.timer.add("pass2", secs);
    report.raw_passes += 1;
    Ok(())
}

/// Mean estimator matched to the sparsifier's scheme calibration
/// (weighted schemes store unbiased sketches — scale 1, not p/m).
fn mean_estimator(sp: &Sparsifier) -> SparseMeanEstimator {
    let est = SparseMeanEstimator::new(sp.p(), sp.m());
    if sp.weighted() {
        est.with_scale(1.0)
    } else {
        est
    }
}

/// Covariance estimator matched to the sparsifier's scheme calibration.
fn cov_estimator(sp: &Sparsifier, workers: usize) -> CovarianceEstimator {
    let est = if sp.weighted() {
        CovarianceEstimator::new_weighted(sp.p(), sp.m())
    } else {
        CovarianceEstimator::new(sp.p(), sp.m())
    };
    est.with_workers(workers)
}

/// One-pass streaming PCA, covariance solver: fold the Thm 4/6 estimators
/// in global column order during the compress, eigendecompose, unmix.
fn pca_cov_stream(
    src: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    scheme: Scheme,
    precision: Precision,
    topk: usize,
    stream: StreamConfig,
) -> Result<FitReport> {
    let precondition = scheme.preconditions();
    let sp = Sparsifier::with_scheme(src.p(), scfg, scheme)?;
    let mut timer = Timer::new();
    let mut mean_est = mean_estimator(&sp);
    // the covariance scatter is the PCA hot path; give it the same pool
    // width as the compress stage (bitwise invariant to the worker count)
    let mut cov_est = cov_estimator(&sp, stream.workers);
    // Racing workers deliver chunks out of stream order; f64 accumulation
    // is order-sensitive, so reorder through a pending map (bounded by
    // the pipeline's in-flight cap) and fold in global column order —
    // this is what makes the estimates bitwise invariant to the worker
    // count, the same discipline as the store writer.
    let mut pending: BTreeMap<usize, SparseChunk> = BTreeMap::new();
    let mut next_col = 0usize;
    let mut fold = |c: SparseChunk| -> Result<()> {
        // quantize (no-op at F64) before the in-order fold, so the
        // estimates match a store round trip at the same precision
        let c = c.with_precision(precision);
        pending.insert(c.start_col(), c);
        loop {
            let first = match pending.keys().next() {
                Some(&k) if k == next_col => k,
                _ => break,
            };
            let chunk = pending.remove(&first).expect("key just observed");
            next_col += chunk.n();
            mean_est.accumulate(&chunk);
            cov_est.accumulate(&chunk);
        }
        Ok(())
    };
    let n = compress_stream(src, &sp, stream, precondition, &mut fold, &mut timer)?;
    if !pending.is_empty() || next_col != n {
        return invalid(format!(
            "pca stream: non-contiguous chunk stream (folded {next_col} of {n} columns)"
        ));
    }
    if n == 0 {
        return invalid("FitPlan: stream is empty");
    }
    let covariance = cov_est.estimate();
    let pca_pre = timer.time("eig", || Pca::from_covariance(&covariance, topk, scfg.seed));
    let (components, mean) = unmix_outputs(&sp, &pca_pre.components, &mean_est, precondition)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 1,
        sparse_passes: 1,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: Some(covariance),
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

/// One-pass covariance-free streaming PCA: compress (the only raw pass),
/// hold the compressed chunks, solve top-k by block-Krylov over them.
fn pca_krylov_stream(
    src: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    scheme: Scheme,
    precision: Precision,
    topk: usize,
    stream: StreamConfig,
) -> Result<FitReport> {
    let precondition = scheme.preconditions();
    let sp = Sparsifier::with_scheme(src.p(), scfg, scheme)?;
    let mut timer = Timer::new();
    let (chunks, n) = compress_collect(src, &sp, stream, precondition, precision, &mut timer)?;
    if n == 0 {
        return invalid("FitPlan: stream is empty");
    }
    let mut mean_est = mean_estimator(&sp);
    for c in &chunks {
        mean_est.accumulate(c);
    }
    let mut op = if sp.weighted() {
        SparseCovOp::new_weighted(&chunks, stream.workers)?
    } else {
        SparseCovOp::new(&chunks, stream.workers)?
    };
    let pca_pre = timer.time("eig", || {
        Pca::from_sparse_operator(&mut op, topk, DEFAULT_KRYLOV_ITERS, scfg.seed)
    })?;
    let (components, mean) = unmix_outputs(&sp, &pca_pre.components, &mean_est, precondition)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 1,
        // one mean sweep + (iters + 2) block products over the chunks
        sparse_passes: 1 + DEFAULT_KRYLOV_ITERS + 2,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: None,
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

/// One-pass PCA over an already-compressed source, covariance solver.
fn pca_cov_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    topk: usize,
    workers: usize,
    preconditioned: bool,
) -> Result<FitReport> {
    check_source_shape(source, sp)?;
    let mut timer = Timer::new();
    let mut mean_est = mean_estimator(sp);
    let mut cov_est = cov_estimator(sp, workers.max(1));
    let mut n = 0usize;
    loop {
        let t0 = Instant::now();
        let next = source.next_chunk()?;
        timer.add("load", t0.elapsed().as_secs_f64());
        let Some(chunk) = next else { break };
        n += chunk.n();
        let t1 = Instant::now();
        mean_est.accumulate(&chunk);
        cov_est.accumulate(&chunk);
        timer.add("accumulate", t1.elapsed().as_secs_f64());
    }
    if n == 0 {
        return invalid("FitPlan: source is empty");
    }
    let covariance = cov_est.estimate();
    let pca_pre = timer.time("eig", || Pca::from_covariance(&covariance, topk, sp.seed()));
    let (components, mean) = unmix_outputs(sp, &pca_pre.components, &mean_est, preconditioned)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes: 1,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: Some(covariance),
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

/// Covariance-free PCA over any rewindable sparse source: one stats pass
/// (mean + scatter diagonal), then `DEFAULT_KRYLOV_ITERS + 2` streamed
/// block products. With a memory-budgeted store reader the whole fit is
/// out-of-core.
fn pca_krylov_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    topk: usize,
    workers: usize,
    preconditioned: bool,
) -> Result<FitReport> {
    check_source_shape(source, sp)?;
    let mut timer = Timer::new();
    let t0 = Instant::now();
    let mut mean_est = mean_estimator(sp);
    let mut stats = ScatterDiag::new(sp.p());
    source.reset()?;
    while let Some(chunk) = source.next_chunk()? {
        mean_est.accumulate(&chunk);
        stats.accumulate(&chunk);
    }
    timer.add("stats", t0.elapsed().as_secs_f64());
    let n = stats.n();
    if n == 0 {
        return invalid("FitPlan: source is empty");
    }
    let mut op = SourceCovOp::from_stats(source, &stats, workers, sp.weighted())?;
    let pca_pre = timer.time("eig", || {
        Pca::from_sparse_operator(&mut op, topk, DEFAULT_KRYLOV_ITERS, sp.seed())
    })?;
    let op_passes = op.passes();
    let (components, mean) = unmix_outputs(sp, &pca_pre.components, &mean_est, preconditioned)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes: 1 + op_passes,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: None,
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

// ====================================================================
// distributed drivers (FitPlan::partition / partials / merge_partials)
// ====================================================================

/// Fold `shards` (a contiguous range of a store's shard table) into one
/// worker's [`PcaPartial`]: per-shard mean + covariance subtotals,
/// keyed by global shard index.
pub(crate) fn pca_partial_for_shards(
    reader: &mut SparseStoreReader,
    sp: &Sparsifier,
    shards: &[ShardEntry],
) -> Result<PcaPartial> {
    let mut partial = PcaPartial::new(sp.p(), sp.m(), sp.weighted());
    for entry in shards {
        reader.seek_to_col(entry.start_col)?;
        let mut covered = 0usize;
        while covered < entry.n_cols {
            let Some(chunk) = reader.next_chunk()? else { break };
            covered += chunk.n();
            partial.fold_chunk(entry.index as u32, &chunk)?;
        }
        if covered != entry.n_cols {
            return invalid(format!(
                "FitPlan: shard {} pass covered {covered} of {} columns",
                entry.index, entry.n_cols
            ));
        }
    }
    Ok(partial)
}

/// Finalize a merged [`PcaPartial`] into the covariance-solver PCA
/// report — the same estimate → eigendecompose → unmix tail as
/// [`pca_cov_sparse`], so a merged distributed fit and a partitioned
/// in-process fit return identical reports.
pub(crate) fn pca_report_from_partial(
    partial: &PcaPartial,
    sp: &Sparsifier,
    topk: usize,
    preconditioned: bool,
    mut timer: Timer,
    sparse_passes: usize,
) -> Result<FitReport> {
    let n = partial.n();
    if n == 0 {
        return invalid("FitPlan: source is empty");
    }
    let (mean_est, cov_est) = partial.finalize()?;
    let covariance = cov_est.estimate();
    let pca_pre = timer.time("eig", || Pca::from_covariance(&covariance, topk, sp.seed()));
    let (components, mean) = unmix_outputs(sp, &pca_pre.components, &mean_est, preconditioned)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: Some(covariance),
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

/// Partitioned covariance-solver PCA over a store: one [`PcaPartial`]
/// per shard-range "worker", merged by disjoint union, finalized in
/// shard-index order — bitwise identical for every partition count and
/// merge order (`parts = 1` is the reference).
fn pca_cov_partitioned(
    reader: &mut SparseStoreReader,
    sp: &Sparsifier,
    topk: usize,
    preconditioned: bool,
    parts: usize,
) -> Result<FitReport> {
    check_source_shape(reader, sp)?;
    let shards = reader.manifest().shards.clone();
    if shards.is_empty() {
        return invalid("FitPlan: source is empty");
    }
    let mut timer = Timer::new();
    let t0 = Instant::now();
    let mut merged: Option<PcaPartial> = None;
    for range in parallel::split_ranges(shards.len(), parts) {
        let partial = pca_partial_for_shards(reader, sp, &shards[range])?;
        match &mut merged {
            Some(m) => m.merge_from(&partial)?,
            None => merged = Some(partial),
        }
    }
    timer.add("accumulate", t0.elapsed().as_secs_f64());
    let merged = merged.expect("split_ranges yields at least one range");
    pca_report_from_partial(&merged, sp, topk, preconditioned, timer, 1)
}

/// Partitioned Lloyd K-means over a store (the in-process distributed
/// fit): per-shard `CenterStep` subtotals captured in one
/// [`CenterPartial`](crate::distributed::CenterPartial) per partition
/// and merged every iteration. Bitwise identical for every partition
/// count.
#[allow(clippy::too_many_arguments)]
fn kmeans_partitioned_store(
    reader: &mut SparseStoreReader,
    sp: &Sparsifier,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    workers: usize,
    preconditioned: bool,
    parts: usize,
) -> Result<FitReport> {
    let scfg = SparsifyConfig { gamma: sp.gamma(), transform: sp.ros().kind(), seed: sp.seed() };
    let mut timer = Timer::new();
    let sk = SparsifiedKmeans::new(scfg, k, opts).with_workers(workers.max(1));
    let (model, sparse_passes) = timer.time("kmeans", || {
        sk.fit_store_partitioned(sp, reader, assigner, preconditioned, parts)
    })?;
    let n = model.result.assign.len();
    let iterations = model.result.iterations;
    let center_bound = model.center_bound.clone();
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes,
        iterations,
        engine: assigner.name(),
        center_bound,
        outcome: FitOutcome::Kmeans { model, refined: None },
    })
}

/// Fold `shards` into one worker's [`CoresetPartial`]: each shard's
/// columns are densified (at the scheme's unbiased scale — `p/m` for
/// the uniform schemes, 1 for weighted sketches) and ingested as one
/// unit-weight leaf of the merge-and-reduce tree.
pub(crate) fn coreset_partial_for_shards(
    reader: &mut SparseStoreReader,
    sp: &Sparsifier,
    shards: &[ShardEntry],
    capacity: usize,
    seed: u64,
) -> Result<CoresetPartial> {
    let p = sp.p();
    let scale = if sp.weighted() { 1.0 } else { p as f64 / sp.m() as f64 };
    let mut partial = CoresetPartial::new(p, capacity, seed)?;
    for entry in shards {
        reader.seek_to_col(entry.start_col)?;
        let mut points = Mat::zeros(p, entry.n_cols);
        let mut covered = 0usize;
        while covered < entry.n_cols {
            let Some(chunk) = reader.next_chunk()? else { break };
            let dense = chunk.to_dense();
            for j in 0..chunk.n() {
                let (src, dst) = (dense.col(j), points.col_mut(covered + j));
                for i in 0..p {
                    dst[i] = src[i] * scale;
                }
            }
            covered += chunk.n();
        }
        if covered != entry.n_cols {
            return invalid(format!(
                "FitPlan: shard {} pass covered {covered} of {} columns",
                entry.index, entry.n_cols
            ));
        }
        partial.add_leaf(entry.index as u64, points, vec![1.0; entry.n_cols])?;
    }
    Ok(partial)
}

/// Finalize a merged [`CoresetPartial`] into a K-means report: weighted
/// K-means on the surviving tree nodes, then one full-store assignment
/// pass so `assign` / `objective` are measured on the real data with
/// the same masked metric as the Lloyd solvers (which is what the
/// documented inertia tolerance is stated against). The Eq. 43
/// center-error bound does not cover the coreset estimator, so
/// `center_bound` records `NaN` per iteration — the same "never present
/// an unbacked number" rule as the weighted schemes.
#[allow(clippy::too_many_arguments)]
fn coreset_report(
    partial: &CoresetPartial,
    reader: &mut SparseStoreReader,
    sp: &Sparsifier,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    preconditioned: bool,
    mut timer: Timer,
    sparse_passes: usize,
) -> Result<FitReport> {
    let shard_count = reader.manifest().shards.len() as u64;
    if !partial.covers_exactly(shard_count) {
        return invalid(format!(
            "FitPlan: merged coreset partials cover shard ranges {:?}, the store holds \
             shards 0..{shard_count}",
            partial.coverage()
        ));
    }
    let (points, weights) = partial.points();
    let (centers_pre, iterations, converged) =
        timer.time("kmeans", || weighted_kmeans(&points, &weights, k, &opts))?;
    // one real pass: assignments + the Eq. 34 objective on the store
    let n = reader.manifest().n;
    let col0 = reader.manifest().start_col();
    let mut assign = vec![0u32; n];
    let mut objective = 0.0;
    let t0 = Instant::now();
    reader.reset()?;
    let mut covered = 0usize;
    while let Some(chunk) = reader.next_chunk()? {
        let (a, obj) = assigner.assign(&chunk, &centers_pre)?;
        let off = chunk.start_col() - col0;
        assign[off..off + a.len()].copy_from_slice(&a);
        objective += obj;
        covered += chunk.n();
    }
    timer.add("assign", t0.elapsed().as_secs_f64());
    if covered != n {
        return invalid(format!("FitPlan: assignment pass covered {covered} of {n} samples"));
    }
    let centers =
        if preconditioned { sp.unmix(&centers_pre) } else { sp.truncate(&centers_pre) };
    let center_bound = vec![f64::NAN; iterations];
    let model = SparsifiedModel {
        result: KmeansResult { centers, assign, objective, iterations, converged },
        centers_precond: centers_pre,
        center_bound: center_bound.clone(),
    };
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes: sparse_passes + 1,
        iterations,
        engine: assigner.name(),
        center_bound,
        outcome: FitOutcome::Kmeans { model, refined: None },
    })
}

/// Store-backed [`Solver::Coreset`] K-means: build the merge-and-reduce
/// tree in one pass (one worker partial per shard range, merged), then
/// finalize through [`coreset_report`]. Approximate but single-pass and
/// mergeable; bitwise identical for every partition count because leaf
/// and reduction RNG streams are keyed by tree position, never by
/// worker.
#[allow(clippy::too_many_arguments)]
fn kmeans_coreset_store(
    reader: &mut SparseStoreReader,
    sp: &Sparsifier,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    preconditioned: bool,
    parts: usize,
    capacity: usize,
) -> Result<FitReport> {
    check_source_shape(reader, sp)?;
    let shards = reader.manifest().shards.clone();
    if shards.is_empty() {
        return invalid("FitPlan: source is empty");
    }
    let mut timer = Timer::new();
    let t0 = Instant::now();
    let mut merged: Option<CoresetPartial> = None;
    for range in parallel::split_ranges(shards.len(), parts) {
        let partial =
            coreset_partial_for_shards(reader, sp, &shards[range], capacity, opts.seed)?;
        match &mut merged {
            Some(m) => m.merge_from(&partial)?,
            None => merged = Some(partial),
        }
    }
    timer.add("coreset", t0.elapsed().as_secs_f64());
    let merged = merged.expect("split_ranges yields at least one range");
    coreset_report(&merged, reader, sp, k, opts, assigner, preconditioned, timer, 1)
}

/// Map preconditioned-domain components + mean back to the original
/// domain: the ROS adjoint when the data was preconditioned, a plain
/// padding drop otherwise.
fn unmix_outputs(
    sp: &Sparsifier,
    components_pre: &Mat,
    mean_est: &SparseMeanEstimator,
    preconditioned: bool,
) -> Result<(Mat, Vec<f64>)> {
    let mean_pre = Mat::from_vec(sp.p(), 1, mean_est.estimate())?;
    Ok(if preconditioned {
        (sp.unmix(components_pre), sp.unmix(&mean_pre).col(0).to_vec())
    } else {
        (sp.truncate(components_pre), sp.truncate(&mean_pre).col(0).to_vec())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MatSource;
    use crate::data::gaussian_blobs;
    use crate::rng::Pcg64;
    use crate::transform::TransformKind;

    #[test]
    fn plan_validates_task_solver_combinations() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(16, 50, 2, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 1 };

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::pca().stream(&mut src, scfg).solver(Solver::Stream).run();
        assert!(err.is_err(), "pca + stream solver must be rejected");

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::kmeans().stream(&mut src, scfg).k(2).solver(Solver::Krylov).run();
        assert!(err.is_err(), "kmeans + krylov solver must be rejected");

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::kmeans().stream(&mut src, scfg).k(2).solver(Solver::Stream).run();
        assert!(err.is_err(), "kmeans stream solver needs a sparse source");

        let err = FitPlan::kmeans().k(2).run();
        assert!(err.is_err(), "missing source must be rejected");

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::kmeans().stream(&mut src, scfg).run();
        assert!(err.is_err(), "missing k must be rejected");

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::compress().stream(&mut src, scfg).run();
        assert!(err.is_err(), "compress without store_dir must be rejected");
    }

    #[test]
    fn kmeans_report_carries_bounds_and_passes() {
        let mut rng = Pcg64::seed(5);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 7 };
        let mut src = MatSource::new(&d.data, 128);
        let report = FitPlan::kmeans()
            .stream(&mut src, scfg)
            .k(3)
            .restarts(2)
            .workers(2)
            .run()
            .unwrap();
        assert_eq!(report.n, 400);
        assert_eq!(report.raw_passes, 1);
        assert_eq!(report.sparse_passes, 1);
        assert!(report.iterations > 0);
        assert_eq!(report.center_bound.len(), report.iterations);
        assert!(report.center_bound.iter().all(|b| b.is_finite() && *b > 0.0));
        let model = report.kmeans_model().unwrap();
        assert_eq!(model.result.assign.len(), 400);
        assert!(report.refined().is_none());
        assert!(report.pca_fit().is_none());
    }

    #[test]
    fn two_pass_plan_refines_and_counts_the_extra_raw_pass() {
        let mut rng = Pcg64::seed(9);
        let d = gaussian_blobs(32, 500, 3, 0.2, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 3 };
        let mut src = MatSource::new(&d.data, 128);
        let report = FitPlan::kmeans()
            .stream(&mut src, scfg)
            .k(3)
            .restarts(2)
            .two_pass(true)
            .run()
            .unwrap();
        assert_eq!(report.raw_passes, 2);
        assert!(report.timer.get("pass2") > 0.0);
        let refined = report.refined().expect("refinement ran");
        assert_eq!(refined.assign.len(), 500);
        assert!(refined.centers.as_slice().iter().all(|v| v.is_finite()));

        // an explicit .refine_stream() on a raw plan is honored (not
        // silently replaced by the plan's own source): same data through
        // a differently-chunked refine source gives the same refinement
        let mut src_a = MatSource::new(&d.data, 128);
        let mut src_b = MatSource::new(&d.data, 256);
        let report2 = FitPlan::kmeans()
            .stream(&mut src_a, scfg)
            .k(3)
            .restarts(2)
            .refine_stream(&mut src_b)
            .run()
            .unwrap();
        assert_eq!(report2.refined().expect("refinement ran").assign, refined.assign);
    }

    #[test]
    fn explicit_precond_scheme_is_byte_identical_to_the_default_plan() {
        // `--scheme precond` must reproduce current behavior bit for bit
        let mut rng = Pcg64::seed(15);
        let d = crate::data::spiked(32, 400, &[6.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 2 };
        let mut src_a = MatSource::new(&d.data, 128);
        let base = FitPlan::pca().stream(&mut src_a, scfg).topk(2).run().unwrap();
        let mut src_b = MatSource::new(&d.data, 128);
        let explicit = FitPlan::pca()
            .stream(&mut src_b, scfg)
            .scheme(Scheme::Precond)
            .topk(2)
            .run()
            .unwrap();
        let (a, b) = (base.pca_fit().unwrap(), explicit.pca_fit().unwrap());
        for (x, y) in a.mean.iter().zip(&b.mean) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.pca.components.as_slice().iter().zip(b.pca.components.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // and the legacy precondition(false) toggle equals the uniform
        // scheme, also bitwise
        let mut src_c = MatSource::new(&d.data, 128);
        let ablation =
            FitPlan::pca().stream(&mut src_c, scfg).precondition(false).topk(2).run().unwrap();
        let mut src_d = MatSource::new(&d.data, 128);
        let uniform = FitPlan::pca()
            .stream(&mut src_d, scfg)
            .scheme(Scheme::Uniform)
            .topk(2)
            .run()
            .unwrap();
        let (c, u) = (ablation.pca_fit().unwrap(), uniform.pca_fit().unwrap());
        for (x, y) in c.mean.iter().zip(&u.mean) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in c.pca.components.as_slice().iter().zip(u.pca.components.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn hybrid_scheme_plans_run_both_tasks_and_solvers() {
        // the hybrid comparison arm must flow end to end: weighted mean
        // calibration (scale 1), weighted covariance calibration on both
        // PCA solvers, and a K-means fit on the weighted sketch
        let mut rng = Pcg64::seed(27);
        let d = crate::data::spiked(32, 600, &[9.0, 5.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 8 };
        let mut src = MatSource::new(&d.data, 128);
        let cov = FitPlan::pca()
            .stream(&mut src, scfg)
            .scheme(Scheme::Hybrid)
            .topk(2)
            .run()
            .unwrap();
        let covf = cov.pca_fit().unwrap();
        assert!(covf.mean.iter().all(|v| v.is_finite()));
        // hybrid samples the raw domain, so the mean estimate must be
        // close to the true sample mean (scale-1 calibration; p/m here
        // is 2.5x, so a mis-calibration would be far outside tolerance)
        let truth = d.data.col_mean();
        let scale = truth.iter().fold(0.0f64, |a, &b| a.max(b.abs())).max(1.0);
        for (est, tru) in covf.mean.iter().zip(&truth) {
            assert!((est - tru).abs() < 0.5 * scale, "mean {est} vs {tru}");
        }
        let mut src2 = MatSource::new(&d.data, 128);
        let kry = FitPlan::pca()
            .stream(&mut src2, scfg)
            .scheme(Scheme::Hybrid)
            .topk(2)
            .solver(Solver::Krylov)
            .run()
            .unwrap();
        let kryf = kry.pca_fit().unwrap();
        // both solvers apply the same weighted estimate; with a strong
        // planted spike they agree on the leading subspace
        assert_eq!(
            crate::pca::recovered_components(&kryf.pca.components, &covf.pca.components, 0.9),
            2
        );
        // K-means on the weighted sketch runs and labels every sample
        let bl = gaussian_blobs(32, 300, 3, 0.05, &mut Pcg64::seed(5));
        let mut src3 = MatSource::new(&bl.data, 128);
        let km = FitPlan::kmeans()
            .stream(&mut src3, scfg)
            .scheme(Scheme::Hybrid)
            .k(3)
            .restarts(2)
            .run()
            .unwrap();
        let model = km.kmeans_model().unwrap();
        assert_eq!(model.result.assign.len(), 300);
        assert!(model.result.centers.as_slice().iter().all(|v| v.is_finite()));
        // the Eq. 43 bound is uniform-scheme theory: hybrid fits must
        // record NaN (one per iteration), not a fake guarantee
        assert_eq!(km.center_bound.len(), km.iterations);
        assert!(km.center_bound.iter().all(|b| b.is_nan()));
        // hybrid + two-pass refinement is rejected (needs preconditioned
        // pass-1 centers)
        let mut src4 = MatSource::new(&bl.data, 128);
        let err = FitPlan::kmeans()
            .stream(&mut src4, scfg)
            .scheme(Scheme::Hybrid)
            .k(3)
            .two_pass(true)
            .run();
        assert!(err.is_err());
    }

    #[test]
    fn pca_solvers_agree_through_the_plan() {
        let mut rng = Pcg64::seed(11);
        let d = crate::data::spiked(32, 800, &[7.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 5 };
        let mut src = MatSource::new(&d.data, 128);
        let cov = FitPlan::pca().stream(&mut src, scfg).topk(2).run().unwrap();
        let mut src2 = MatSource::new(&d.data, 128);
        let kry = FitPlan::pca()
            .stream(&mut src2, scfg)
            .topk(2)
            .solver(Solver::Krylov)
            .run()
            .unwrap();
        let covf = cov.pca_fit().unwrap();
        let kryf = kry.pca_fit().unwrap();
        assert!(covf.covariance.is_some());
        assert!(kryf.covariance.is_none());
        assert!(kry.sparse_passes > cov.sparse_passes, "krylov makes iters+2 sparse passes");
        // shared mean-estimator path is bit-identical
        for (a, b) in kryf.mean.iter().zip(&covf.mean) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(
            crate::pca::recovered_components(&kryf.pca.components, &covf.pca.components, 0.95),
            2
        );
    }

    #[test]
    fn explicit_f64_precision_is_byte_identical_to_the_default_plan() {
        // `--precision f64` must reproduce current behavior bit for bit
        let mut rng = Pcg64::seed(31);
        let d = crate::data::spiked(32, 400, &[6.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 2 };
        let mut src_a = MatSource::new(&d.data, 128);
        let base = FitPlan::pca().stream(&mut src_a, scfg).topk(2).run().unwrap();
        let mut src_b = MatSource::new(&d.data, 128);
        let explicit = FitPlan::pca()
            .stream(&mut src_b, scfg)
            .precision(Precision::F64)
            .topk(2)
            .run()
            .unwrap();
        let (a, b) = (base.pca_fit().unwrap(), explicit.pca_fit().unwrap());
        for (x, y) in a.mean.iter().zip(&b.mean) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.pca.components.as_slice().iter().zip(b.pca.components.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn f32_precision_tracks_f64_pca_within_tolerance() {
        // f32 storage + f64 accumulation: the only error source is the
        // one-time value quantization at the sparsifier boundary, so the
        // recovered spectrum must agree to well under the documented 1e-3
        // relative explained-variance tolerance
        let mut rng = Pcg64::seed(33);
        let d = crate::data::spiked(32, 800, &[7.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 5 };
        let mut src = MatSource::new(&d.data, 128);
        let full = FitPlan::pca().stream(&mut src, scfg).topk(2).run().unwrap();
        let mut src2 = MatSource::new(&d.data, 128);
        let quant = FitPlan::pca()
            .stream(&mut src2, scfg)
            .precision(Precision::F32)
            .topk(2)
            .run()
            .unwrap();
        let a = full.pca_fit().unwrap();
        let b = quant.pca_fit().unwrap();
        let ev64: f64 = a.pca.eigenvalues.iter().sum();
        let ev32: f64 = b.pca.eigenvalues.iter().sum();
        let rel = ((ev64 - ev32) / ev64).abs();
        assert!(rel < 1e-3, "explained-variance drift {rel:e} exceeds 1e-3");
        assert_eq!(
            crate::pca::recovered_components(&b.pca.components, &a.pca.components, 0.95),
            2
        );
    }

    #[test]
    fn f32_store_roundtrip_fits_and_precision_mismatch_is_rejected() {
        let mut rng = Pcg64::seed(35);
        let d = gaussian_blobs(32, 300, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 7 };
        let base = std::env::temp_dir()
            .join(format!("pds_plan_precision_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let dir32 = base.join("f32");
        let dir64 = base.join("f64");

        let mut src = MatSource::new(&d.data, 64);
        let report = FitPlan::compress()
            .stream(&mut src, scfg)
            .precision(Precision::F32)
            .store_dir(&dir32)
            .run()
            .unwrap();
        assert_eq!(report.store_manifest().unwrap().precision, Precision::F32);
        let mut src = MatSource::new(&d.data, 64);
        FitPlan::compress().stream(&mut src, scfg).store_dir(&dir64).run().unwrap();

        // the f32 store fits end to end, and an explicit matching
        // .precision() passes the compatibility check
        let mut reader = SparseStoreReader::open(&dir32).unwrap();
        let fit = FitPlan::kmeans()
            .store(&mut reader)
            .k(3)
            .precision(Precision::F32)
            .run()
            .unwrap();
        let model = fit.kmeans_model().unwrap();
        assert_eq!(model.result.assign.len(), 300);
        assert!(model.result.objective.is_finite());

        // mismatches are rejected in both directions
        let mut reader = SparseStoreReader::open(&dir32).unwrap();
        let err = FitPlan::pca().store(&mut reader).precision(Precision::F64).run();
        assert!(err.is_err(), "f64 request on an f32 store must be rejected");
        let mut reader = SparseStoreReader::open(&dir64).unwrap();
        let err =
            FitPlan::kmeans().store(&mut reader).k(3).precision(Precision::F32).run();
        assert!(err.is_err(), "f32 request on an f64 store must be rejected");

        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn assign_cols_per_worker_override_is_bitwise_invariant() {
        // the StreamConfig fan-out override only moves the serial/parallel
        // crossover; the fit itself must not change
        let mut rng = Pcg64::seed(37);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 9 };
        let mut src = MatSource::new(&d.data, 128);
        let serial = FitPlan::kmeans().stream(&mut src, scfg).k(3).run().unwrap();
        let mut src = MatSource::new(&d.data, 128);
        let fanned = FitPlan::kmeans()
            .stream(&mut src, scfg)
            .k(3)
            .stream_config(StreamConfig {
                workers: 4,
                assign_cols_per_worker: Some(16),
                ..Default::default()
            })
            .run()
            .unwrap();
        let a = serial.kmeans_model().unwrap();
        let b = fanned.kmeans_model().unwrap();
        assert_eq!(a.result.assign, b.result.assign);
        for (x, y) in a.result.centers.as_slice().iter().zip(b.result.centers.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn distributed_plans_validate_sources_and_solvers() {
        assert_eq!(Solver::parse("coreset").unwrap(), Solver::Coreset);
        assert_eq!(Solver::Coreset.name(), "coreset");

        let mut rng = Pcg64::seed(39);
        let d = gaussian_blobs(16, 60, 2, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 5 };

        // the coreset solver and .partition() are keyed to a store's
        // shard table — raw-stream plans must be rejected
        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::kmeans().stream(&mut src, scfg).k(2).solver(Solver::Coreset).run();
        assert!(err.is_err(), "coreset solver without a store must be rejected");
        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::kmeans().stream(&mut src, scfg).k(2).partition(2).run();
        assert!(err.is_err(), "partitioned kmeans without a store must be rejected");
        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::pca().stream(&mut src, scfg).partition(2).run();
        assert!(err.is_err(), "partitioned pca without a store must be rejected");
        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::pca().stream(&mut src, scfg).partials();
        assert!(err.is_err(), "partials() without a store must be rejected");

        // pca + coreset is not a valid task/solver pairing
        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::pca().stream(&mut src, scfg).solver(Solver::Coreset).run();
        assert!(err.is_err(), "pca + coreset solver must be rejected");

        // store-backed, but still invalid: krylov has no one-shot partial,
        // Lloyd solvers have no one-shot partial, and merging nothing or
        // garbage fails typed
        let base = std::env::temp_dir()
            .join(format!("pds_plan_distributed_invalid_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut src = MatSource::new(&d.data, 16);
        FitPlan::compress().stream(&mut src, scfg).store_dir(&base).shard_cols(16).run().unwrap();

        let mut reader = SparseStoreReader::open(&base).unwrap();
        let err = FitPlan::pca().store(&mut reader).solver(Solver::Krylov).partition(2).run();
        assert!(err.is_err(), "krylov + partition must be rejected");
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let err = FitPlan::kmeans().store(&mut reader).k(2).partials();
        assert!(err.is_err(), "Lloyd kmeans has no one-shot partial");
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let err = FitPlan::pca().store(&mut reader).merge_partials(&[]);
        assert!(err.is_err(), "merging zero artifacts must be rejected");
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let err = FitPlan::pca().store(&mut reader).merge_partials(&[vec![0u8; 4]]);
        assert!(matches!(err, Err(crate::error::Error::Corrupt(_))), "garbage artifact");

        // artifact kind must match the plan's task
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let pca_artifacts = FitPlan::pca().store(&mut reader).partials().unwrap();
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let err = FitPlan::kmeans().store(&mut reader).k(2).merge_partials(&pca_artifacts);
        assert!(err.is_err(), "pca artifacts under a kmeans plan must be rejected");

        // incomplete shard coverage is rejected at merge time
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let partials =
            FitPlan::pca().store(&mut reader).partition(2).partials().unwrap();
        assert_eq!(partials.len(), 2);
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let err = FitPlan::pca().store(&mut reader).merge_partials(&partials[..1]);
        assert!(err.is_err(), "a missing worker artifact must be rejected");

        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn distributed_store_fits_are_partition_invariant_and_mergeable() {
        let mut rng = Pcg64::seed(41);
        let d = gaussian_blobs(16, 120, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 11 };
        let base = std::env::temp_dir()
            .join(format!("pds_plan_distributed_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let mut src = MatSource::new(&d.data, 32);
        FitPlan::compress().stream(&mut src, scfg).store_dir(&base).shard_cols(16).run().unwrap();

        let pca_bits = |report: &FitReport| {
            let fit = report.pca_fit().unwrap();
            (
                fit.pca.components.as_slice().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fit.pca.eigenvalues.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                fit.mean.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            )
        };
        // every partition count produces the same bits as partition(1)
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let reference = FitPlan::pca().store(&mut reader).topk(3).partition(1).run().unwrap();
        for parts in [2usize, 4, 8] {
            let mut reader = SparseStoreReader::open(&base).unwrap();
            let got =
                FitPlan::pca().store(&mut reader).topk(3).partition(parts).run().unwrap();
            assert_eq!(pca_bits(&got), pca_bits(&reference), "pca partition({parts})");
        }
        // worker artifacts merge — in any order — to the same report
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let mut artifacts =
            FitPlan::pca().store(&mut reader).topk(3).partition(4).partials().unwrap();
        assert_eq!(artifacts.len(), 4);
        artifacts.reverse();
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let merged =
            FitPlan::pca().store(&mut reader).topk(3).merge_partials(&artifacts).unwrap();
        assert_eq!(pca_bits(&merged), pca_bits(&reference), "merged pca artifacts");
        assert_eq!(merged.raw_passes, 0);

        let km_bits = |report: &FitReport| {
            let m = report.kmeans_model().unwrap();
            (
                m.result.assign.clone(),
                m.result.centers.as_slice().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                m.result.objective.to_bits(),
            )
        };
        // distributed Lloyd: partition-invariant
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let km1 =
            FitPlan::kmeans().store(&mut reader).k(3).restarts(2).partition(1).run().unwrap();
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let km3 =
            FitPlan::kmeans().store(&mut reader).k(3).restarts(2).partition(3).run().unwrap();
        assert_eq!(km_bits(&km1), km_bits(&km3), "kmeans partition(3)");
        assert_eq!(km1.n, 120);
        assert!(km1.iterations >= 1);
        assert_eq!(km1.center_bound.len(), km1.iterations);

        // coreset: partition-invariant, merge-order-invariant, and within
        // the documented inertia tolerance of the exact Lloyd fit
        fn coreset_plan(reader: &mut SparseStoreReader) -> FitPlan<'_> {
            FitPlan::kmeans()
                .store(reader)
                .k(3)
                .restarts(4)
                .solver(Solver::Coreset)
                .coreset_size(48)
        }
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let cs1 = coreset_plan(&mut reader).partition(1).run().unwrap();
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let cs4 = coreset_plan(&mut reader).partition(4).run().unwrap();
        assert_eq!(km_bits(&cs1), km_bits(&cs4), "coreset partition(4)");
        assert!(cs1.center_bound.iter().all(|b| b.is_nan()), "no Eq. 43 claim for coresets");
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let mut artifacts = coreset_plan(&mut reader).partition(4).partials().unwrap();
        assert_eq!(artifacts.len(), 4);
        artifacts.rotate_left(1);
        let mut reader = SparseStoreReader::open(&base).unwrap();
        let cs_merged = coreset_plan(&mut reader).merge_partials(&artifacts).unwrap();
        assert_eq!(km_bits(&cs_merged), km_bits(&cs1), "merged coreset artifacts");

        let mut reader = SparseStoreReader::open(&base).unwrap();
        let lloyd =
            FitPlan::kmeans().store(&mut reader).k(3).restarts(4).run().unwrap();
        let exact = lloyd.kmeans_model().unwrap().result.objective;
        let approx = cs1.kmeans_model().unwrap().result.objective;
        assert!(
            approx <= exact * 1.5 + 1e-9,
            "coreset inertia {approx} exceeds 1.5x the Lloyd inertia {exact}"
        );

        std::fs::remove_dir_all(&base).ok();
    }
}
