//! `FitPlan` — the one composable entry point to the driver stack.
//!
//! The coordinator used to expose a combinatorial
//! `run_{pca,pca_krylov,sparsified_kmeans,two_pass,compress}_{stream,sparse,from_store}`
//! matrix (12+ near-duplicate free functions) that every new solver
//! multiplied. `FitPlan` collapses it into a builder over three
//! orthogonal axes:
//!
//! * **task** — [`FitPlan::pca`], [`FitPlan::kmeans`],
//!   [`FitPlan::compress`];
//! * **source** — a raw dense stream ([`stream`](FitPlan::stream)), an
//!   already-sparsified source ([`source`](FitPlan::source)), or a
//!   persistent sparse store ([`store`](FitPlan::store));
//! * **solver** — [`Solver::Covariance`] / [`Solver::Krylov`] for PCA,
//!   [`Solver::InMemory`] / [`Solver::Stream`] for K-means.
//!
//! Every combination returns the same [`FitReport`]: phase timings, raw
//! *and* sparse pass accounting, and — for K-means — the paper's
//! per-iteration center-error bound evaluated from
//! [`estimators::center_error_bound`](crate::estimators::center_error_bound).
//! The legacy `run_*` functions survive as thin deprecated shims over
//! this module.
//!
//! Invariants inherited from the kernels underneath: for a fixed seed,
//! results are bitwise identical for every worker count, every reader
//! memory budget, and every chunk granularity, and a store-backed fit is
//! bit-for-bit the streaming fit of the same data.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::error::{invalid, Result};
use crate::estimators::{CovarianceEstimator, ScatterDiag, SparseCovOp, SparseMeanEstimator};
use crate::kmeans::{
    assign_dense, KmeansOpts, KmeansResult, NativeAssigner, SparseAssigner, SparsifiedKmeans,
    SparsifiedModel,
};
use crate::linalg::Mat;
use crate::metrics::Timer;
use crate::pca::Pca;
use crate::sampling::{Scheme, Sparsifier, SparsifyConfig};
use crate::sparse::{Precision, SparseChunk, SparseChunkSource};
use crate::store::{SparseStoreReader, SparseStoreWriter, StoreManifest};

use super::krylov::{SourceCovOp, DEFAULT_KRYLOV_ITERS};
use super::{compress_stream, ChunkSource, StreamConfig};

/// Default number of principal components when a PCA plan does not set
/// [`topk`](FitPlan::topk).
pub const DEFAULT_TOPK: usize = 5;

/// What a [`FitPlan`] computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Streaming PCA (Thm 4 mean + Thm 6 covariance estimates).
    Pca,
    /// Sparsified K-means (Algorithm 1, optional Algorithm 2 refinement).
    Kmeans,
    /// Compress a raw stream into a persistent sparse store.
    Compress,
}

/// Solver selection, spanning both tasks (validated per task at
/// [`run`](FitPlan::run) time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// PCA: materialize the p×p Thm 6 estimate, then eigendecompose.
    Covariance,
    /// PCA: covariance-free block-Krylov on the implicit estimate —
    /// O(p·(k+4)) solver memory, one sparse pass per block product.
    Krylov,
    /// K-means: hold the (coalesced) sparse chunks in memory and iterate
    /// over them — the fastest path when the compressed data fits in RAM.
    InMemory,
    /// K-means: source-driven Lloyd via the `CenterStep` kernel — one
    /// sparse pass per iteration, nothing materialized; with a
    /// memory-budgeted store reader the whole fit is out-of-core.
    Stream,
}

impl Solver {
    /// CLI-facing name (`pds fit --solver <name>`).
    pub fn name(self) -> &'static str {
        match self {
            Solver::Covariance => "covariance",
            Solver::Krylov => "krylov",
            Solver::InMemory => "inmemory",
            Solver::Stream => "stream",
        }
    }

    /// Parse a CLI-facing solver name.
    pub fn parse(name: &str) -> Result<Solver> {
        Ok(match name {
            "covariance" => Solver::Covariance,
            "krylov" => Solver::Krylov,
            "inmemory" => Solver::InMemory,
            "stream" => Solver::Stream,
            other => {
                return invalid(format!(
                    "unknown solver {other:?} (want covariance|krylov|inmemory|stream)"
                ))
            }
        })
    }
}

/// PCA outputs of a [`FitPlan`] run.
pub struct PcaFit {
    /// Unbiased sample-mean estimate (Thm 4), original-domain.
    pub mean: Vec<f64>,
    /// The materialized Thm 6 covariance estimate in the *preconditioned*
    /// domain — `Some` only for [`Solver::Covariance`] (not materializing
    /// it is the point of [`Solver::Krylov`]).
    pub covariance: Option<Mat>,
    /// Top-k principal components + eigenvalues, unmixed to the original
    /// domain.
    pub pca: Pca,
}

/// Task-specific result carried by a [`FitReport`].
pub enum FitOutcome {
    /// PCA components / eigenvalues / mean.
    Pca(PcaFit),
    /// The fitted K-means model, plus the Algorithm 2 refinement when the
    /// plan asked for [`two_pass`](FitPlan::two_pass).
    Kmeans {
        /// The pass-1 sparsified model (original-domain centers).
        model: SparsifiedModel,
        /// Exact-mean / original-domain reassignment (Algorithm 2), if
        /// a refinement pass ran.
        refined: Option<KmeansResult>,
    },
    /// Manifest of the store written by a [`FitPlan::compress`] run.
    Compressed(StoreManifest),
}

/// The single report every plan returns: accounting + outcome.
pub struct FitReport {
    /// Phase timings (`load`, `compress`, `kmeans`, `eig`, `stats`,
    /// `pass2`, `store` — whichever phases the plan exercised).
    pub timer: Timer,
    /// Samples processed.
    pub n: usize,
    /// Passes over the **raw** dense data (paper Table II discipline):
    /// 1 for a fresh compress, 0 for sparse/store-backed fits, +1 for an
    /// Algorithm 2 refinement.
    pub raw_passes: usize,
    /// Passes started over the **sparsified** data: 1 for an in-memory
    /// materialization; for [`Solver::Stream`] every source walk counts —
    /// one per Lloyd iteration plus the k-means++ seeding's sub-passes
    /// (≈2 per seed, some stopped early) per restart; `iters + 2` block
    /// products (+1 stats pass) for [`Solver::Krylov`].
    pub sparse_passes: usize,
    /// Lloyd iterations of the winning restart (K-means tasks).
    pub iterations: usize,
    /// Assignment engine used (K-means tasks; `"native"` otherwise).
    pub engine: &'static str,
    /// Per-iteration worst-cluster center-error bound (Eq. 43 at
    /// δ = [`CENTER_BOUND_DELTA`](crate::kmeans::CENTER_BOUND_DELTA)),
    /// copied from [`SparsifiedModel::center_bound`]; empty for PCA /
    /// compress plans. The bound applies to the uniform sampling schemes
    /// only — weighted (hybrid) fits record `NaN` per iteration, never a
    /// number the theory does not back.
    pub center_bound: Vec<f64>,
    /// The task-specific result.
    pub outcome: FitOutcome,
}

impl FitReport {
    /// The fitted K-means model, if this was a K-means plan.
    pub fn kmeans_model(&self) -> Option<&SparsifiedModel> {
        match &self.outcome {
            FitOutcome::Kmeans { model, .. } => Some(model),
            _ => None,
        }
    }

    /// The Algorithm 2 refinement, if the plan ran one.
    pub fn refined(&self) -> Option<&KmeansResult> {
        match &self.outcome {
            FitOutcome::Kmeans { refined, .. } => refined.as_ref(),
            _ => None,
        }
    }

    /// The PCA outputs, if this was a PCA plan.
    pub fn pca_fit(&self) -> Option<&PcaFit> {
        match &self.outcome {
            FitOutcome::Pca(fit) => Some(fit),
            _ => None,
        }
    }

    /// The written store's manifest, if this was a compress plan.
    pub fn store_manifest(&self) -> Option<&StoreManifest> {
        match &self.outcome {
            FitOutcome::Compressed(m) => Some(m),
            _ => None,
        }
    }
}

/// The plan's data input, normalized at `run` time.
enum SourceKind<'a> {
    /// Raw dense stream + the compression config to apply.
    Raw(&'a mut dyn ChunkSource),
    /// Already-sparsified source with its (cloned) sparsifier.
    Sparse {
        src: &'a mut dyn SparseChunkSource,
        sp: Sparsifier,
        preconditioned: bool,
    },
    /// Persistent sparse store (sparsifier rebuilt from the manifest).
    Store(&'a mut SparseStoreReader),
}

/// Builder for one end-to-end fit over three orthogonal axes — task
/// ([`pca`](Self::pca) / [`kmeans`](Self::kmeans) /
/// [`compress`](Self::compress)), source ([`stream`](Self::stream) /
/// [`source`](Self::source) / [`store`](Self::store)), and
/// [`solver`](Self::solver) — validated at [`run`](Self::run) time. All
/// setters are chainable and `run` consumes the plan.
///
/// # Example — PCA
///
/// ```
/// use pds::coordinator::{FitPlan, MatSource, Solver};
/// use pds::linalg::Mat;
/// use pds::rng::Pcg64;
/// use pds::sampling::SparsifyConfig;
/// use pds::transform::TransformKind;
///
/// let mut rng = Pcg64::seed(1);
/// let x = Mat::from_fn(16, 300, |_, _| rng.normal());
/// let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 2 };
/// let mut src = MatSource::new(&x, 64);
/// let report = FitPlan::pca()
///     .stream(&mut src, scfg)
///     .topk(2)
///     .solver(Solver::Krylov)
///     .workers(2)
///     .run()?;
/// let fit = report.pca_fit().expect("pca plan");
/// assert_eq!(fit.pca.components.cols(), 2);
/// assert_eq!(fit.mean.len(), 16);
/// assert_eq!(report.raw_passes, 1);
/// # Ok::<(), pds::Error>(())
/// ```
pub struct FitPlan<'a> {
    task: Task,
    source: Option<SourceKind<'a>>,
    scfg: Option<SparsifyConfig>,
    stream: StreamConfig,
    precondition: bool,
    /// `Some` only when the caller set a scheme explicitly — sparse- and
    /// store-backed plans validate it against the source's recorded
    /// scheme instead of silently ignoring it.
    scheme: Option<Scheme>,
    /// `Some` only when the caller set a precision explicitly — sparse-
    /// and store-backed plans validate it against the source's recorded
    /// precision, mirroring the `scheme` contract.
    precision: Option<Precision>,
    topk: usize,
    solver: Option<Solver>,
    k: Option<usize>,
    opts: KmeansOpts,
    assigner: Option<&'a dyn SparseAssigner>,
    two_pass: bool,
    refine: Option<&'a mut dyn ChunkSource>,
    store_dir: Option<PathBuf>,
    shard_cols: usize,
}

/// Shared default assigner instance (`&'static` so the builder can fall
/// back to it without an allocation).
static NATIVE_ASSIGNER: NativeAssigner = NativeAssigner::new();

impl<'a> FitPlan<'a> {
    fn new(task: Task) -> Self {
        FitPlan {
            task,
            source: None,
            scfg: None,
            stream: StreamConfig::default(),
            precondition: true,
            scheme: None,
            precision: None,
            topk: DEFAULT_TOPK,
            solver: None,
            k: None,
            opts: KmeansOpts::default(),
            assigner: None,
            two_pass: false,
            refine: None,
            store_dir: None,
            shard_cols: 8192,
        }
    }

    /// Plan a streaming PCA fit.
    pub fn pca() -> Self {
        FitPlan::new(Task::Pca)
    }

    /// Plan a sparsified K-means fit (Algorithm 1).
    ///
    /// ```
    /// use pds::coordinator::FitPlan;
    /// use pds::data::gaussian_blobs;
    /// use pds::coordinator::MatSource;
    /// use pds::rng::Pcg64;
    /// use pds::sampling::SparsifyConfig;
    /// use pds::transform::TransformKind;
    ///
    /// let mut rng = Pcg64::seed(3);
    /// let d = gaussian_blobs(32, 300, 3, 0.1, &mut rng);
    /// let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 4 };
    /// let mut src = MatSource::new(&d.data, 64);
    /// let report = FitPlan::kmeans()
    ///     .stream(&mut src, scfg)
    ///     .k(3)
    ///     .restarts(2)
    ///     .run()?;
    /// let model = report.kmeans_model().expect("kmeans plan");
    /// assert_eq!(model.result.assign.len(), 300);
    /// // one Thm-level center-error bound per Lloyd iteration
    /// assert_eq!(report.center_bound.len(), report.iterations);
    /// assert_eq!(report.raw_passes, 1);
    /// # Ok::<(), pds::Error>(())
    /// ```
    pub fn kmeans() -> Self {
        FitPlan::new(Task::Kmeans)
    }

    /// Plan a compress-once pass into a persistent sparse store.
    pub fn compress() -> Self {
        FitPlan::new(Task::Compress)
    }

    /// Feed the plan from a raw dense stream, compressed on the fly with
    /// `scfg` (this is the plan's one raw pass).
    pub fn stream(mut self, src: &'a mut dyn ChunkSource, scfg: SparsifyConfig) -> Self {
        self.source = Some(SourceKind::Raw(src));
        self.scfg = Some(scfg);
        self
    }

    /// Feed the plan from an already-sparsified source. `sp` must be the
    /// sparsifier the chunks were produced with; `preconditioned = false`
    /// marks ablation data compressed without the ROS (centers /
    /// components then only drop padding instead of unmixing).
    pub fn source(
        mut self,
        src: &'a mut dyn SparseChunkSource,
        sp: &Sparsifier,
        preconditioned: bool,
    ) -> Self {
        self.source = Some(SourceKind::Sparse { src, sp: sp.clone(), preconditioned });
        self
    }

    /// Feed the plan from a persistent sparse store (zero raw passes; the
    /// sparsifier is rebuilt from the manifest).
    pub fn store(mut self, reader: &'a mut SparseStoreReader) -> Self {
        self.source = Some(SourceKind::Store(reader));
        self
    }

    /// Fork/join width for every stage (compress workers, assignment,
    /// center/covariance accumulation, restart fan-out). Any value yields
    /// bitwise identical results.
    pub fn workers(mut self, workers: usize) -> Self {
        self.stream.workers = workers.max(1);
        self
    }

    /// Full streaming configuration (queue depth, chunk columns, workers)
    /// for raw-stream sources.
    pub fn stream_config(mut self, cfg: StreamConfig) -> Self {
        self.stream = cfg;
        self
    }

    /// Toggle the ROS preconditioning on a raw-stream compress (default
    /// `true`; `false` is the paper's ablation arm — equivalent to
    /// [`scheme(Scheme::Uniform)`](Self::scheme)).
    pub fn precondition(mut self, on: bool) -> Self {
        self.precondition = on;
        self
    }

    /// Element-sampling scheme (default [`Scheme::Precond`], the paper's
    /// operator — byte-identical to not calling this).
    /// [`Scheme::Hybrid`] selects the weighted hybrid-(ℓ1,ℓ2) comparison
    /// scheme; the plan then wires the weighted estimator calibration
    /// automatically. Sparse-source and store-backed plans take their
    /// scheme from the sparsifier / manifest; setting one explicitly
    /// there asserts it — a mismatch fails the plan instead of silently
    /// fitting the wrong comparison arm.
    pub fn scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = Some(scheme);
        self
    }

    /// Storage precision for the sparsified values (default
    /// [`Precision::F64`] — byte-identical to not calling this).
    /// [`Precision::F32`] quantizes each kept value once at compress
    /// time and halves the chunk / store value bytes; all accumulation
    /// stays in `f64`, so the only error is the per-value quantization
    /// (≤ 0.5 ulp of `f32`). Sparse-source and store-backed plans take
    /// their precision from the source / manifest; setting one
    /// explicitly there asserts it — a mismatch fails the plan.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = Some(precision);
        self
    }

    /// Sparse-/store-backed plans: an explicitly requested precision
    /// must match the source's recorded one.
    fn check_requested_precision(
        requested: Option<Precision>,
        actual: Precision,
    ) -> Result<()> {
        if let Some(req) = requested {
            if req != actual {
                return invalid(format!(
                    "FitPlan: .precision({}) does not match this source's recorded \
                     precision ({})",
                    req.name(),
                    actual.name()
                ));
            }
        }
        Ok(())
    }

    /// The effective selection law of a raw-stream plan: the configured
    /// scheme, downgraded from `Precond` to `Uniform` when the legacy
    /// [`precondition(false)`](Self::precondition) ablation toggle is
    /// set.
    fn effective_scheme(&self) -> Scheme {
        let scheme = self.scheme.unwrap_or(Scheme::Precond);
        if !self.precondition && scheme == Scheme::Precond {
            Scheme::Uniform
        } else {
            scheme
        }
    }

    /// Sparse-/store-backed plans: an explicitly requested scheme must
    /// match the source's recorded one.
    fn check_requested_scheme(requested: Option<Scheme>, actual: Scheme) -> Result<()> {
        if let Some(req) = requested {
            if req != actual {
                return invalid(format!(
                    "FitPlan: .scheme({}) does not match this source's recorded scheme ({})",
                    req.name(),
                    actual.name()
                ));
            }
        }
        Ok(())
    }

    /// Number of principal components (PCA plans; default
    /// [`DEFAULT_TOPK`]).
    pub fn topk(mut self, topk: usize) -> Self {
        self.topk = topk;
        self
    }

    /// Solver override. PCA accepts [`Solver::Covariance`] (default) or
    /// [`Solver::Krylov`]; K-means accepts [`Solver::InMemory`] (default)
    /// or [`Solver::Stream`].
    pub fn solver(mut self, solver: Solver) -> Self {
        self.solver = Some(solver);
        self
    }

    /// Number of clusters (required for K-means plans).
    pub fn k(mut self, k: usize) -> Self {
        self.k = Some(k);
        self
    }

    /// Lloyd / restart options (K-means plans).
    pub fn kmeans_opts(mut self, opts: KmeansOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Number of k-means++ restarts (`opts.n_init`): restarts run over
    /// seeded sub-RNG streams — in parallel on the in-memory solver when
    /// [`workers`](Self::workers) allows — and the best inertia wins,
    /// deterministically for a fixed seed regardless of worker count.
    pub fn restarts(mut self, restarts: usize) -> Self {
        self.opts.n_init = restarts.max(1);
        self
    }

    /// Assignment engine (default: the native masked-distance assigner).
    pub fn assigner(mut self, assigner: &'a dyn SparseAssigner) -> Self {
        self.assigner = Some(assigner);
        self
    }

    /// Run the Algorithm 2 refinement after the fit: one extra pass over
    /// the raw stream recomputing exact class means and reassigning in
    /// the original domain. Raw-stream plans reuse their own source;
    /// sparse/store plans must provide one via
    /// [`refine_stream`](Self::refine_stream).
    pub fn two_pass(mut self, on: bool) -> Self {
        self.two_pass = on;
        self
    }

    /// Raw stream for the Algorithm 2 refinement of a sparse/store-backed
    /// plan (implies [`two_pass`](Self::two_pass)).
    pub fn refine_stream(mut self, raw: &'a mut dyn ChunkSource) -> Self {
        self.refine = Some(raw);
        self.two_pass = true;
        self
    }

    /// Output directory for a [`compress`](Self::compress) plan.
    pub fn store_dir(mut self, dir: &Path) -> Self {
        self.store_dir = Some(dir.to_path_buf());
        self
    }

    /// Columns per shard for a [`compress`](Self::compress) plan
    /// (default 8192).
    pub fn shard_cols(mut self, cols: usize) -> Self {
        self.shard_cols = cols.max(1);
        self
    }

    /// Execute the plan.
    pub fn run(self) -> Result<FitReport> {
        match self.task {
            Task::Pca => self.run_pca(),
            Task::Kmeans => self.run_kmeans(),
            Task::Compress => self.run_compress(),
        }
    }

    /// Validate + resolve the solver for the task.
    fn resolve_solver(&self) -> Result<Solver> {
        let solver = self.solver.unwrap_or(match self.task {
            Task::Pca => Solver::Covariance,
            _ => Solver::InMemory,
        });
        let ok = match self.task {
            Task::Pca => matches!(solver, Solver::Covariance | Solver::Krylov),
            Task::Kmeans => matches!(solver, Solver::InMemory | Solver::Stream),
            Task::Compress => true,
        };
        if !ok {
            return invalid(format!(
                "FitPlan: solver {:?} does not apply to task {:?} (pca: covariance|krylov, \
                 kmeans: inmemory|stream)",
                self.solver, self.task
            ));
        }
        Ok(solver)
    }

    fn take_source(source: &mut Option<SourceKind<'a>>) -> Result<SourceKind<'a>> {
        source.take().ok_or_else(|| {
            crate::error::Error::Invalid(
                "FitPlan: no source — call .stream(), .source() or .store()".into(),
            )
        })
    }

    // ---------------------------------------------------------------- pca

    fn run_pca(mut self) -> Result<FitReport> {
        let solver = self.resolve_solver()?;
        let topk = self.topk;
        let workers = self.stream.workers;
        let scheme = self.effective_scheme();
        let precision = self.precision.unwrap_or_default();
        match Self::take_source(&mut self.source)? {
            SourceKind::Raw(src) => {
                let Some(scfg) = self.scfg else {
                    return invalid("FitPlan: raw stream needs a SparsifyConfig");
                };
                match solver {
                    Solver::Covariance => {
                        pca_cov_stream(src, scfg, scheme, precision, topk, self.stream)
                    }
                    _ => pca_krylov_stream(src, scfg, scheme, precision, topk, self.stream),
                }
            }
            SourceKind::Sparse { src, sp, preconditioned } => {
                Self::check_requested_scheme(self.scheme, sp.scheme())?;
                Self::check_requested_precision(self.precision, src.precision())?;
                match solver {
                    Solver::Covariance => pca_cov_sparse(src, &sp, topk, workers, preconditioned),
                    _ => pca_krylov_sparse(src, &sp, topk, workers, preconditioned),
                }
            }
            SourceKind::Store(reader) => {
                let sp = reader.sparsifier()?;
                Self::check_requested_scheme(self.scheme, sp.scheme())?;
                Self::check_requested_precision(self.precision, reader.manifest().precision)?;
                let preconditioned = reader.manifest().preconditioned;
                match solver {
                    Solver::Covariance => {
                        pca_cov_sparse(reader, &sp, topk, workers, preconditioned)
                    }
                    _ => pca_krylov_sparse(reader, &sp, topk, workers, preconditioned),
                }
            }
        }
    }

    // ------------------------------------------------------------- kmeans

    fn run_kmeans(mut self) -> Result<FitReport> {
        let solver = self.resolve_solver()?;
        let Some(k) = self.k else {
            return invalid("FitPlan::kmeans() needs .k(clusters)");
        };
        // a StreamConfig fan-out override builds a configured local
        // assigner; otherwise the shared static default is used as-is
        let local_assigner;
        let assigner: &dyn SparseAssigner = match self.assigner {
            Some(a) => a,
            None => match self.stream.assign_cols_per_worker {
                Some(cols) => {
                    local_assigner = NativeAssigner::new().with_cols_per_worker(cols);
                    &local_assigner
                }
                None => &NATIVE_ASSIGNER,
            },
        };
        let workers = self.stream.workers;
        let opts = self.opts;
        let scheme = self.effective_scheme();
        let precision = self.precision.unwrap_or_default();
        let refine = self.refine.take();
        let report = match Self::take_source(&mut self.source)? {
            SourceKind::Raw(src) => {
                let Some(scfg) = self.scfg else {
                    return invalid("FitPlan: raw stream needs a SparsifyConfig");
                };
                if solver == Solver::Stream {
                    return invalid(
                        "FitPlan: the stream K-means solver re-reads the sparse data every \
                         iteration; compress to a store first (FitPlan::compress), then \
                         .store(reader).solver(Solver::Stream)",
                    );
                }
                // reborrow: the plan's own source is revisited below when
                // a two-pass refinement was requested
                let mut report = kmeans_inmemory_stream(
                    &mut *src,
                    scfg,
                    scheme,
                    precision,
                    k,
                    opts,
                    assigner,
                    self.stream,
                )?;
                if self.two_pass {
                    if !scheme.preconditions() {
                        return invalid(
                            "FitPlan: the Algorithm 2 refinement needs preconditioned \
                             pass-1 centers (precondition(true) with the precond scheme)",
                        );
                    }
                    // Algorithm 2 revisits the raw data: an explicit
                    // .refine_stream() source wins, else the plan's own
                    // source is rewound and reused
                    match refine {
                        Some(raw) => refine_into_report(raw, k, &mut report)?,
                        None => refine_into_report(src, k, &mut report)?,
                    }
                }
                report
            }
            SourceKind::Sparse { src, sp, preconditioned } => {
                Self::check_requested_scheme(self.scheme, sp.scheme())?;
                Self::check_requested_precision(self.precision, src.precision())?;
                let mut report = kmeans_from_sparse(
                    src,
                    &sp,
                    k,
                    opts,
                    assigner,
                    workers,
                    preconditioned,
                    solver,
                )?;
                if self.two_pass {
                    if !preconditioned {
                        return invalid(
                            "FitPlan: the Algorithm 2 refinement needs preconditioned \
                             pass-1 centers (this source was compressed without the ROS)",
                        );
                    }
                    let Some(raw) = refine else {
                        return invalid(
                            "FitPlan: a sparse-source two-pass refinement needs \
                             .refine_stream(raw source)",
                        );
                    };
                    refine_into_report(raw, k, &mut report)?;
                }
                return Ok(report);
            }
            SourceKind::Store(reader) => {
                let sp = reader.sparsifier()?;
                Self::check_requested_scheme(self.scheme, sp.scheme())?;
                Self::check_requested_precision(self.precision, reader.manifest().precision)?;
                let preconditioned = reader.manifest().preconditioned;
                let mut report = kmeans_from_sparse(
                    reader,
                    &sp,
                    k,
                    opts,
                    assigner,
                    workers,
                    preconditioned,
                    solver,
                )?;
                if self.two_pass {
                    if !preconditioned {
                        return invalid(
                            "FitPlan: the Algorithm 2 refinement needs preconditioned \
                             pass-1 centers (this store was compressed without the ROS)",
                        );
                    }
                    let Some(raw) = refine else {
                        return invalid(
                            "FitPlan: a store-backed two-pass refinement needs \
                             .refine_stream(raw source)",
                        );
                    };
                    refine_into_report(raw, k, &mut report)?;
                }
                return Ok(report);
            }
        };
        // only raw-source plans fall through here (the sparse/store arms
        // return early so `refine` can be moved per arm)
        Ok(report)
    }

    // ----------------------------------------------------------- compress

    fn run_compress(mut self) -> Result<FitReport> {
        let Some(dir) = self.store_dir.clone() else {
            return invalid("FitPlan::compress() needs .store_dir(path)");
        };
        let SourceKind::Raw(src) = Self::take_source(&mut self.source)? else {
            return invalid("FitPlan::compress() consumes a raw stream (.stream(...))");
        };
        let Some(scfg) = self.scfg else {
            return invalid("FitPlan: raw stream needs a SparsifyConfig");
        };
        let scheme = self.effective_scheme();
        let precondition = scheme.preconditions();
        let sp = Sparsifier::with_scheme(src.p(), scfg, scheme)?;
        let mut timer = Timer::new();
        let mut writer =
            SparseStoreWriter::create(&dir, &sp, scfg, precondition, self.shard_cols)?
                .with_precision(self.precision.unwrap_or_default());
        let mut sink = |c: SparseChunk| writer.append(c);
        let n = compress_stream(src, &sp, self.stream, precondition, &mut sink, &mut timer)?;
        let manifest = timer.time("store", || writer.finish())?;
        Ok(FitReport {
            timer,
            n,
            raw_passes: 1,
            sparse_passes: 0,
            iterations: 0,
            engine: "native",
            center_bound: Vec::new(),
            outcome: FitOutcome::Compressed(manifest),
        })
    }
}

// ====================================================================
// shared machinery (the former run_* driver bodies)
// ====================================================================

/// Target column count when coalescing stream chunks for a fit.
pub(crate) const FIT_COALESCE_COLS: usize = 8192;

/// Merge sorted, contiguous stream chunks into pieces of at least
/// `target_cols` columns (the tail piece may be smaller).
pub(crate) fn coalesce_chunks(
    chunks: Vec<SparseChunk>,
    target_cols: usize,
) -> Result<Vec<SparseChunk>> {
    let mut out = Vec::new();
    let mut group: Vec<SparseChunk> = Vec::new();
    let mut group_cols = 0usize;
    for c in chunks {
        group_cols += c.n();
        group.push(c);
        if group_cols >= target_cols {
            out.push(merge_group(&mut group)?);
            group_cols = 0;
        }
    }
    if !group.is_empty() {
        out.push(merge_group(&mut group)?);
    }
    Ok(out)
}

fn merge_group(group: &mut Vec<SparseChunk>) -> Result<SparseChunk> {
    let merged = if group.len() == 1 {
        group.pop().expect("non-empty group")
    } else {
        SparseChunk::concat(group)?
    };
    group.clear();
    Ok(merged)
}

/// Compress a raw stream, collecting the chunks sorted + coalesced for an
/// efficient in-memory fit. Returns (chunks, n). Chunks are quantized to
/// `precision` as they arrive (a no-op at `F64`), so the fit sees exactly
/// what an equivalent store round trip would yield.
fn compress_collect(
    src: &mut dyn ChunkSource,
    sp: &Sparsifier,
    stream: StreamConfig,
    precondition: bool,
    precision: Precision,
    timer: &mut Timer,
) -> Result<(Vec<SparseChunk>, usize)> {
    let mut chunks: Vec<SparseChunk> = Vec::new();
    let mut collect = |c: SparseChunk| -> Result<()> {
        chunks.push(c.with_precision(precision));
        Ok(())
    };
    let n = compress_stream(src, sp, stream, precondition, &mut collect, timer)?;
    chunks.sort_by_key(|c| c.start_col());
    // coalesce the (often chunk_cols-sized) stream pieces so the parallel
    // kernels fan out over large column ranges instead of paying a
    // fork/join per tiny chunk; bitwise identical — every fit depends
    // only on the global column order
    let chunks = coalesce_chunks(chunks, FIT_COALESCE_COLS)?;
    Ok((chunks, n))
}

/// Drain a sparse source into memory, order and coalesce the chunks for
/// an efficient fit. Returns the chunks plus the total sample count.
fn collect_sparse(
    source: &mut dyn SparseChunkSource,
    timer: &mut Timer,
) -> Result<(Vec<SparseChunk>, usize)> {
    let t0 = Instant::now();
    let mut chunks = Vec::new();
    while let Some(c) = source.next_chunk()? {
        chunks.push(c);
    }
    timer.add("load", t0.elapsed().as_secs_f64());
    let n = chunks.iter().map(|c| c.n()).sum();
    chunks.sort_by_key(|c| c.start_col());
    let chunks = coalesce_chunks(chunks, FIT_COALESCE_COLS)?;
    Ok((chunks, n))
}

fn check_source_shape(source: &dyn SparseChunkSource, sp: &Sparsifier) -> Result<()> {
    if source.p() != sp.p() || source.m() != sp.m() {
        return invalid(format!(
            "FitPlan: source is p={} m={}, sparsifier is p={} m={}",
            source.p(),
            source.m(),
            sp.p(),
            sp.m()
        ));
    }
    Ok(())
}

/// One-pass sparsified K-means over a raw stream (Algorithm 1 at scale):
/// compress with backpressure, hold the compressed chunks, iterate.
#[allow(clippy::too_many_arguments)]
fn kmeans_inmemory_stream(
    src: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    scheme: Scheme,
    precision: Precision,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    stream: StreamConfig,
) -> Result<FitReport> {
    let precondition = scheme.preconditions();
    let sp = Sparsifier::with_scheme(src.p(), scfg, scheme)?;
    let mut timer = Timer::new();
    let (chunks, n) = compress_collect(src, &sp, stream, precondition, precision, &mut timer)?;
    if n == 0 {
        return invalid("FitPlan: stream is empty");
    }
    // reuse the compress pool width for the fit (assignment, center
    // accumulation and the restart fan-out are all bitwise
    // worker-count-invariant, so this only changes speed)
    let sk = SparsifiedKmeans::new(scfg, k, opts)
        .with_workers(stream.workers)
        .with_restart_workers(stream.workers);
    let model = timer.time("kmeans", || sk.fit_chunks_raw(&sp, &chunks, assigner, precondition))?;
    let iterations = model.result.iterations;
    let center_bound = model.center_bound.clone();
    Ok(FitReport {
        timer,
        n,
        raw_passes: 1,
        sparse_passes: 1,
        iterations,
        engine: assigner.name(),
        center_bound,
        outcome: FitOutcome::Kmeans { model, refined: None },
    })
}

/// Sparsified K-means over an already-compressed source — in-memory
/// (materialize + iterate) or streaming (one source pass per Lloyd
/// iteration through the `CenterStep` kernel). Zero raw passes either
/// way, and bit-identical outputs to the raw-stream path on the same
/// data.
#[allow(clippy::too_many_arguments)]
fn kmeans_from_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    k: usize,
    opts: KmeansOpts,
    assigner: &dyn SparseAssigner,
    workers: usize,
    preconditioned: bool,
    solver: Solver,
) -> Result<FitReport> {
    check_source_shape(source, sp)?;
    let scfg = SparsifyConfig { gamma: sp.gamma(), transform: sp.ros().kind(), seed: sp.seed() };
    let mut timer = Timer::new();
    let (model, n, sparse_passes) = if solver == Solver::Stream {
        let sk = SparsifiedKmeans::new(scfg, k, opts).with_workers(workers.max(1));
        let (model, passes) =
            timer.time("kmeans", || sk.fit_source(sp, source, assigner, preconditioned))?;
        let n = model.result.assign.len();
        (model, n, passes)
    } else {
        let (chunks, n) = collect_sparse(source, &mut timer)?;
        if n == 0 {
            return invalid("FitPlan: source is empty");
        }
        let sk = SparsifiedKmeans::new(scfg, k, opts)
            .with_workers(workers.max(1))
            .with_restart_workers(workers.max(1));
        let model =
            timer.time("kmeans", || sk.fit_chunks_raw(sp, &chunks, assigner, preconditioned))?;
        (model, n, 1)
    };
    let iterations = model.result.iterations;
    let center_bound = model.center_bound.clone();
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes,
        iterations,
        engine: assigner.name(),
        center_bound,
        outcome: FitOutcome::Kmeans { model, refined: None },
    })
}

/// The second pass of Algorithm 2, applied to an existing pass-1 model:
/// revisit the raw stream once to recompute exact class means and to
/// reassign against the pass-1 centers in the original domain. Returns
/// the refined result and the pass's wall-clock seconds.
pub fn two_pass_refine_stream(
    source: &mut dyn ChunkSource,
    model: &SparsifiedModel,
    k: usize,
) -> Result<(KmeansResult, f64)> {
    let one = &model.result;
    let p = source.p();
    source.reset()?;
    let t0 = Instant::now();
    let mut sums = Mat::zeros(p, k);
    let mut counts = vec![0usize; k];
    let mut assign = vec![0u32; one.assign.len()];
    let mut objective = 0.0;
    while let Some(chunk) = source.next_chunk()? {
        // (a) exact class means under the pass-1 assignment
        for j in 0..chunk.data.cols() {
            let c = one.assign[chunk.start_col + j] as usize;
            counts[c] += 1;
            let col = chunk.data.col(j);
            let s = sums.col_mut(c);
            for i in 0..p {
                s[i] += col[i];
            }
        }
        // (b) reassignment against pass-1 centers, original domain
        let (a, obj) = assign_dense(&chunk.data, &one.centers);
        objective += obj;
        assign[chunk.start_col..chunk.start_col + a.len()].copy_from_slice(&a);
    }
    let mut centers = one.centers.clone();
    for c in 0..k {
        if counts[c] > 0 {
            let inv = 1.0 / counts[c] as f64;
            let (s, dst) = (sums.col(c), centers.col_mut(c));
            for i in 0..p {
                dst[i] = s[i] * inv;
            }
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    Ok((
        KmeansResult {
            centers,
            assign,
            objective,
            iterations: one.iterations,
            converged: one.converged,
        },
        secs,
    ))
}

/// Run the Algorithm 2 refinement and fold it into a K-means report.
fn refine_into_report(
    source: &mut dyn ChunkSource,
    k: usize,
    report: &mut FitReport,
) -> Result<()> {
    let FitOutcome::Kmeans { model, refined } = &mut report.outcome else {
        return invalid("FitPlan: refinement applies to K-means plans only");
    };
    let (result, secs) = two_pass_refine_stream(source, model, k)?;
    *refined = Some(result);
    report.timer.add("pass2", secs);
    report.raw_passes += 1;
    Ok(())
}

/// Mean estimator matched to the sparsifier's scheme calibration
/// (weighted schemes store unbiased sketches — scale 1, not p/m).
fn mean_estimator(sp: &Sparsifier) -> SparseMeanEstimator {
    let est = SparseMeanEstimator::new(sp.p(), sp.m());
    if sp.weighted() {
        est.with_scale(1.0)
    } else {
        est
    }
}

/// Covariance estimator matched to the sparsifier's scheme calibration.
fn cov_estimator(sp: &Sparsifier, workers: usize) -> CovarianceEstimator {
    let est = if sp.weighted() {
        CovarianceEstimator::new_weighted(sp.p(), sp.m())
    } else {
        CovarianceEstimator::new(sp.p(), sp.m())
    };
    est.with_workers(workers)
}

/// One-pass streaming PCA, covariance solver: fold the Thm 4/6 estimators
/// in global column order during the compress, eigendecompose, unmix.
fn pca_cov_stream(
    src: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    scheme: Scheme,
    precision: Precision,
    topk: usize,
    stream: StreamConfig,
) -> Result<FitReport> {
    let precondition = scheme.preconditions();
    let sp = Sparsifier::with_scheme(src.p(), scfg, scheme)?;
    let mut timer = Timer::new();
    let mut mean_est = mean_estimator(&sp);
    // the covariance scatter is the PCA hot path; give it the same pool
    // width as the compress stage (bitwise invariant to the worker count)
    let mut cov_est = cov_estimator(&sp, stream.workers);
    // Racing workers deliver chunks out of stream order; f64 accumulation
    // is order-sensitive, so reorder through a pending map (bounded by
    // the pipeline's in-flight cap) and fold in global column order —
    // this is what makes the estimates bitwise invariant to the worker
    // count, the same discipline as the store writer.
    let mut pending: BTreeMap<usize, SparseChunk> = BTreeMap::new();
    let mut next_col = 0usize;
    let mut fold = |c: SparseChunk| -> Result<()> {
        // quantize (no-op at F64) before the in-order fold, so the
        // estimates match a store round trip at the same precision
        let c = c.with_precision(precision);
        pending.insert(c.start_col(), c);
        loop {
            let first = match pending.keys().next() {
                Some(&k) if k == next_col => k,
                _ => break,
            };
            let chunk = pending.remove(&first).expect("key just observed");
            next_col += chunk.n();
            mean_est.accumulate(&chunk);
            cov_est.accumulate(&chunk);
        }
        Ok(())
    };
    let n = compress_stream(src, &sp, stream, precondition, &mut fold, &mut timer)?;
    if !pending.is_empty() || next_col != n {
        return invalid(format!(
            "pca stream: non-contiguous chunk stream (folded {next_col} of {n} columns)"
        ));
    }
    if n == 0 {
        return invalid("FitPlan: stream is empty");
    }
    let covariance = cov_est.estimate();
    let pca_pre = timer.time("eig", || Pca::from_covariance(&covariance, topk, scfg.seed));
    let (components, mean) = unmix_outputs(&sp, &pca_pre.components, &mean_est, precondition)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 1,
        sparse_passes: 1,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: Some(covariance),
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

/// One-pass covariance-free streaming PCA: compress (the only raw pass),
/// hold the compressed chunks, solve top-k by block-Krylov over them.
fn pca_krylov_stream(
    src: &mut dyn ChunkSource,
    scfg: SparsifyConfig,
    scheme: Scheme,
    precision: Precision,
    topk: usize,
    stream: StreamConfig,
) -> Result<FitReport> {
    let precondition = scheme.preconditions();
    let sp = Sparsifier::with_scheme(src.p(), scfg, scheme)?;
    let mut timer = Timer::new();
    let (chunks, n) = compress_collect(src, &sp, stream, precondition, precision, &mut timer)?;
    if n == 0 {
        return invalid("FitPlan: stream is empty");
    }
    let mut mean_est = mean_estimator(&sp);
    for c in &chunks {
        mean_est.accumulate(c);
    }
    let mut op = if sp.weighted() {
        SparseCovOp::new_weighted(&chunks, stream.workers)?
    } else {
        SparseCovOp::new(&chunks, stream.workers)?
    };
    let pca_pre = timer.time("eig", || {
        Pca::from_sparse_operator(&mut op, topk, DEFAULT_KRYLOV_ITERS, scfg.seed)
    })?;
    let (components, mean) = unmix_outputs(&sp, &pca_pre.components, &mean_est, precondition)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 1,
        // one mean sweep + (iters + 2) block products over the chunks
        sparse_passes: 1 + DEFAULT_KRYLOV_ITERS + 2,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: None,
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

/// One-pass PCA over an already-compressed source, covariance solver.
fn pca_cov_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    topk: usize,
    workers: usize,
    preconditioned: bool,
) -> Result<FitReport> {
    check_source_shape(source, sp)?;
    let mut timer = Timer::new();
    let mut mean_est = mean_estimator(sp);
    let mut cov_est = cov_estimator(sp, workers.max(1));
    let mut n = 0usize;
    loop {
        let t0 = Instant::now();
        let next = source.next_chunk()?;
        timer.add("load", t0.elapsed().as_secs_f64());
        let Some(chunk) = next else { break };
        n += chunk.n();
        let t1 = Instant::now();
        mean_est.accumulate(&chunk);
        cov_est.accumulate(&chunk);
        timer.add("accumulate", t1.elapsed().as_secs_f64());
    }
    if n == 0 {
        return invalid("FitPlan: source is empty");
    }
    let covariance = cov_est.estimate();
    let pca_pre = timer.time("eig", || Pca::from_covariance(&covariance, topk, sp.seed()));
    let (components, mean) = unmix_outputs(sp, &pca_pre.components, &mean_est, preconditioned)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes: 1,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: Some(covariance),
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

/// Covariance-free PCA over any rewindable sparse source: one stats pass
/// (mean + scatter diagonal), then `DEFAULT_KRYLOV_ITERS + 2` streamed
/// block products. With a memory-budgeted store reader the whole fit is
/// out-of-core.
fn pca_krylov_sparse(
    source: &mut dyn SparseChunkSource,
    sp: &Sparsifier,
    topk: usize,
    workers: usize,
    preconditioned: bool,
) -> Result<FitReport> {
    check_source_shape(source, sp)?;
    let mut timer = Timer::new();
    let t0 = Instant::now();
    let mut mean_est = mean_estimator(sp);
    let mut stats = ScatterDiag::new(sp.p());
    source.reset()?;
    while let Some(chunk) = source.next_chunk()? {
        mean_est.accumulate(&chunk);
        stats.accumulate(&chunk);
    }
    timer.add("stats", t0.elapsed().as_secs_f64());
    let n = stats.n();
    if n == 0 {
        return invalid("FitPlan: source is empty");
    }
    let mut op = SourceCovOp::from_stats(source, &stats, workers, sp.weighted())?;
    let pca_pre = timer.time("eig", || {
        Pca::from_sparse_operator(&mut op, topk, DEFAULT_KRYLOV_ITERS, sp.seed())
    })?;
    let op_passes = op.passes();
    let (components, mean) = unmix_outputs(sp, &pca_pre.components, &mean_est, preconditioned)?;
    Ok(FitReport {
        timer,
        n,
        raw_passes: 0,
        sparse_passes: 1 + op_passes,
        iterations: 0,
        engine: "native",
        center_bound: Vec::new(),
        outcome: FitOutcome::Pca(PcaFit {
            mean,
            covariance: None,
            pca: Pca { components, eigenvalues: pca_pre.eigenvalues },
        }),
    })
}

/// Map preconditioned-domain components + mean back to the original
/// domain: the ROS adjoint when the data was preconditioned, a plain
/// padding drop otherwise.
fn unmix_outputs(
    sp: &Sparsifier,
    components_pre: &Mat,
    mean_est: &SparseMeanEstimator,
    preconditioned: bool,
) -> Result<(Mat, Vec<f64>)> {
    let mean_pre = Mat::from_vec(sp.p(), 1, mean_est.estimate())?;
    Ok(if preconditioned {
        (sp.unmix(components_pre), sp.unmix(&mean_pre).col(0).to_vec())
    } else {
        (sp.truncate(components_pre), sp.truncate(&mean_pre).col(0).to_vec())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::MatSource;
    use crate::data::gaussian_blobs;
    use crate::rng::Pcg64;
    use crate::transform::TransformKind;

    #[test]
    fn plan_validates_task_solver_combinations() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(16, 50, 2, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 1 };

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::pca().stream(&mut src, scfg).solver(Solver::Stream).run();
        assert!(err.is_err(), "pca + stream solver must be rejected");

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::kmeans().stream(&mut src, scfg).k(2).solver(Solver::Krylov).run();
        assert!(err.is_err(), "kmeans + krylov solver must be rejected");

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::kmeans().stream(&mut src, scfg).k(2).solver(Solver::Stream).run();
        assert!(err.is_err(), "kmeans stream solver needs a sparse source");

        let err = FitPlan::kmeans().k(2).run();
        assert!(err.is_err(), "missing source must be rejected");

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::kmeans().stream(&mut src, scfg).run();
        assert!(err.is_err(), "missing k must be rejected");

        let mut src = MatSource::new(&d.data, 16);
        let err = FitPlan::compress().stream(&mut src, scfg).run();
        assert!(err.is_err(), "compress without store_dir must be rejected");
    }

    #[test]
    fn kmeans_report_carries_bounds_and_passes() {
        let mut rng = Pcg64::seed(5);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 7 };
        let mut src = MatSource::new(&d.data, 128);
        let report = FitPlan::kmeans()
            .stream(&mut src, scfg)
            .k(3)
            .restarts(2)
            .workers(2)
            .run()
            .unwrap();
        assert_eq!(report.n, 400);
        assert_eq!(report.raw_passes, 1);
        assert_eq!(report.sparse_passes, 1);
        assert!(report.iterations > 0);
        assert_eq!(report.center_bound.len(), report.iterations);
        assert!(report.center_bound.iter().all(|b| b.is_finite() && *b > 0.0));
        let model = report.kmeans_model().unwrap();
        assert_eq!(model.result.assign.len(), 400);
        assert!(report.refined().is_none());
        assert!(report.pca_fit().is_none());
    }

    #[test]
    fn two_pass_plan_refines_and_counts_the_extra_raw_pass() {
        let mut rng = Pcg64::seed(9);
        let d = gaussian_blobs(32, 500, 3, 0.2, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.2, transform: TransformKind::Hadamard, seed: 3 };
        let mut src = MatSource::new(&d.data, 128);
        let report = FitPlan::kmeans()
            .stream(&mut src, scfg)
            .k(3)
            .restarts(2)
            .two_pass(true)
            .run()
            .unwrap();
        assert_eq!(report.raw_passes, 2);
        assert!(report.timer.get("pass2") > 0.0);
        let refined = report.refined().expect("refinement ran");
        assert_eq!(refined.assign.len(), 500);
        assert!(refined.centers.as_slice().iter().all(|v| v.is_finite()));

        // an explicit .refine_stream() on a raw plan is honored (not
        // silently replaced by the plan's own source): same data through
        // a differently-chunked refine source gives the same refinement
        let mut src_a = MatSource::new(&d.data, 128);
        let mut src_b = MatSource::new(&d.data, 256);
        let report2 = FitPlan::kmeans()
            .stream(&mut src_a, scfg)
            .k(3)
            .restarts(2)
            .refine_stream(&mut src_b)
            .run()
            .unwrap();
        assert_eq!(report2.refined().expect("refinement ran").assign, refined.assign);
    }

    #[test]
    fn explicit_precond_scheme_is_byte_identical_to_the_default_plan() {
        // `--scheme precond` must reproduce current behavior bit for bit
        let mut rng = Pcg64::seed(15);
        let d = crate::data::spiked(32, 400, &[6.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 2 };
        let mut src_a = MatSource::new(&d.data, 128);
        let base = FitPlan::pca().stream(&mut src_a, scfg).topk(2).run().unwrap();
        let mut src_b = MatSource::new(&d.data, 128);
        let explicit = FitPlan::pca()
            .stream(&mut src_b, scfg)
            .scheme(Scheme::Precond)
            .topk(2)
            .run()
            .unwrap();
        let (a, b) = (base.pca_fit().unwrap(), explicit.pca_fit().unwrap());
        for (x, y) in a.mean.iter().zip(&b.mean) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.pca.components.as_slice().iter().zip(b.pca.components.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // and the legacy precondition(false) toggle equals the uniform
        // scheme, also bitwise
        let mut src_c = MatSource::new(&d.data, 128);
        let ablation =
            FitPlan::pca().stream(&mut src_c, scfg).precondition(false).topk(2).run().unwrap();
        let mut src_d = MatSource::new(&d.data, 128);
        let uniform = FitPlan::pca()
            .stream(&mut src_d, scfg)
            .scheme(Scheme::Uniform)
            .topk(2)
            .run()
            .unwrap();
        let (c, u) = (ablation.pca_fit().unwrap(), uniform.pca_fit().unwrap());
        for (x, y) in c.mean.iter().zip(&u.mean) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in c.pca.components.as_slice().iter().zip(u.pca.components.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn hybrid_scheme_plans_run_both_tasks_and_solvers() {
        // the hybrid comparison arm must flow end to end: weighted mean
        // calibration (scale 1), weighted covariance calibration on both
        // PCA solvers, and a K-means fit on the weighted sketch
        let mut rng = Pcg64::seed(27);
        let d = crate::data::spiked(32, 600, &[9.0, 5.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 8 };
        let mut src = MatSource::new(&d.data, 128);
        let cov = FitPlan::pca()
            .stream(&mut src, scfg)
            .scheme(Scheme::Hybrid)
            .topk(2)
            .run()
            .unwrap();
        let covf = cov.pca_fit().unwrap();
        assert!(covf.mean.iter().all(|v| v.is_finite()));
        // hybrid samples the raw domain, so the mean estimate must be
        // close to the true sample mean (scale-1 calibration; p/m here
        // is 2.5x, so a mis-calibration would be far outside tolerance)
        let truth = d.data.col_mean();
        let scale = truth.iter().fold(0.0f64, |a, &b| a.max(b.abs())).max(1.0);
        for (est, tru) in covf.mean.iter().zip(&truth) {
            assert!((est - tru).abs() < 0.5 * scale, "mean {est} vs {tru}");
        }
        let mut src2 = MatSource::new(&d.data, 128);
        let kry = FitPlan::pca()
            .stream(&mut src2, scfg)
            .scheme(Scheme::Hybrid)
            .topk(2)
            .solver(Solver::Krylov)
            .run()
            .unwrap();
        let kryf = kry.pca_fit().unwrap();
        // both solvers apply the same weighted estimate; with a strong
        // planted spike they agree on the leading subspace
        assert_eq!(
            crate::pca::recovered_components(&kryf.pca.components, &covf.pca.components, 0.9),
            2
        );
        // K-means on the weighted sketch runs and labels every sample
        let bl = gaussian_blobs(32, 300, 3, 0.05, &mut Pcg64::seed(5));
        let mut src3 = MatSource::new(&bl.data, 128);
        let km = FitPlan::kmeans()
            .stream(&mut src3, scfg)
            .scheme(Scheme::Hybrid)
            .k(3)
            .restarts(2)
            .run()
            .unwrap();
        let model = km.kmeans_model().unwrap();
        assert_eq!(model.result.assign.len(), 300);
        assert!(model.result.centers.as_slice().iter().all(|v| v.is_finite()));
        // the Eq. 43 bound is uniform-scheme theory: hybrid fits must
        // record NaN (one per iteration), not a fake guarantee
        assert_eq!(km.center_bound.len(), km.iterations);
        assert!(km.center_bound.iter().all(|b| b.is_nan()));
        // hybrid + two-pass refinement is rejected (needs preconditioned
        // pass-1 centers)
        let mut src4 = MatSource::new(&bl.data, 128);
        let err = FitPlan::kmeans()
            .stream(&mut src4, scfg)
            .scheme(Scheme::Hybrid)
            .k(3)
            .two_pass(true)
            .run();
        assert!(err.is_err());
    }

    #[test]
    fn pca_solvers_agree_through_the_plan() {
        let mut rng = Pcg64::seed(11);
        let d = crate::data::spiked(32, 800, &[7.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 5 };
        let mut src = MatSource::new(&d.data, 128);
        let cov = FitPlan::pca().stream(&mut src, scfg).topk(2).run().unwrap();
        let mut src2 = MatSource::new(&d.data, 128);
        let kry = FitPlan::pca()
            .stream(&mut src2, scfg)
            .topk(2)
            .solver(Solver::Krylov)
            .run()
            .unwrap();
        let covf = cov.pca_fit().unwrap();
        let kryf = kry.pca_fit().unwrap();
        assert!(covf.covariance.is_some());
        assert!(kryf.covariance.is_none());
        assert!(kry.sparse_passes > cov.sparse_passes, "krylov makes iters+2 sparse passes");
        // shared mean-estimator path is bit-identical
        for (a, b) in kryf.mean.iter().zip(&covf.mean) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(
            crate::pca::recovered_components(&kryf.pca.components, &covf.pca.components, 0.95),
            2
        );
    }

    #[test]
    fn explicit_f64_precision_is_byte_identical_to_the_default_plan() {
        // `--precision f64` must reproduce current behavior bit for bit
        let mut rng = Pcg64::seed(31);
        let d = crate::data::spiked(32, 400, &[6.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 2 };
        let mut src_a = MatSource::new(&d.data, 128);
        let base = FitPlan::pca().stream(&mut src_a, scfg).topk(2).run().unwrap();
        let mut src_b = MatSource::new(&d.data, 128);
        let explicit = FitPlan::pca()
            .stream(&mut src_b, scfg)
            .precision(Precision::F64)
            .topk(2)
            .run()
            .unwrap();
        let (a, b) = (base.pca_fit().unwrap(), explicit.pca_fit().unwrap());
        for (x, y) in a.mean.iter().zip(&b.mean) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in a.pca.components.as_slice().iter().zip(b.pca.components.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn f32_precision_tracks_f64_pca_within_tolerance() {
        // f32 storage + f64 accumulation: the only error source is the
        // one-time value quantization at the sparsifier boundary, so the
        // recovered spectrum must agree to well under the documented 1e-3
        // relative explained-variance tolerance
        let mut rng = Pcg64::seed(33);
        let d = crate::data::spiked(32, 800, &[7.0, 3.0], false, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 5 };
        let mut src = MatSource::new(&d.data, 128);
        let full = FitPlan::pca().stream(&mut src, scfg).topk(2).run().unwrap();
        let mut src2 = MatSource::new(&d.data, 128);
        let quant = FitPlan::pca()
            .stream(&mut src2, scfg)
            .precision(Precision::F32)
            .topk(2)
            .run()
            .unwrap();
        let a = full.pca_fit().unwrap();
        let b = quant.pca_fit().unwrap();
        let ev64: f64 = a.pca.eigenvalues.iter().sum();
        let ev32: f64 = b.pca.eigenvalues.iter().sum();
        let rel = ((ev64 - ev32) / ev64).abs();
        assert!(rel < 1e-3, "explained-variance drift {rel:e} exceeds 1e-3");
        assert_eq!(
            crate::pca::recovered_components(&b.pca.components, &a.pca.components, 0.95),
            2
        );
    }

    #[test]
    fn f32_store_roundtrip_fits_and_precision_mismatch_is_rejected() {
        let mut rng = Pcg64::seed(35);
        let d = gaussian_blobs(32, 300, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.4, transform: TransformKind::Hadamard, seed: 7 };
        let base = std::env::temp_dir()
            .join(format!("pds_plan_precision_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let dir32 = base.join("f32");
        let dir64 = base.join("f64");

        let mut src = MatSource::new(&d.data, 64);
        let report = FitPlan::compress()
            .stream(&mut src, scfg)
            .precision(Precision::F32)
            .store_dir(&dir32)
            .run()
            .unwrap();
        assert_eq!(report.store_manifest().unwrap().precision, Precision::F32);
        let mut src = MatSource::new(&d.data, 64);
        FitPlan::compress().stream(&mut src, scfg).store_dir(&dir64).run().unwrap();

        // the f32 store fits end to end, and an explicit matching
        // .precision() passes the compatibility check
        let mut reader = SparseStoreReader::open(&dir32).unwrap();
        let fit = FitPlan::kmeans()
            .store(&mut reader)
            .k(3)
            .precision(Precision::F32)
            .run()
            .unwrap();
        let model = fit.kmeans_model().unwrap();
        assert_eq!(model.result.assign.len(), 300);
        assert!(model.result.objective.is_finite());

        // mismatches are rejected in both directions
        let mut reader = SparseStoreReader::open(&dir32).unwrap();
        let err = FitPlan::pca().store(&mut reader).precision(Precision::F64).run();
        assert!(err.is_err(), "f64 request on an f32 store must be rejected");
        let mut reader = SparseStoreReader::open(&dir64).unwrap();
        let err =
            FitPlan::kmeans().store(&mut reader).k(3).precision(Precision::F32).run();
        assert!(err.is_err(), "f32 request on an f64 store must be rejected");

        std::fs::remove_dir_all(&base).ok();
    }

    #[test]
    fn assign_cols_per_worker_override_is_bitwise_invariant() {
        // the StreamConfig fan-out override only moves the serial/parallel
        // crossover; the fit itself must not change
        let mut rng = Pcg64::seed(37);
        let d = gaussian_blobs(32, 400, 3, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.3, transform: TransformKind::Hadamard, seed: 9 };
        let mut src = MatSource::new(&d.data, 128);
        let serial = FitPlan::kmeans().stream(&mut src, scfg).k(3).run().unwrap();
        let mut src = MatSource::new(&d.data, 128);
        let fanned = FitPlan::kmeans()
            .stream(&mut src, scfg)
            .k(3)
            .stream_config(StreamConfig {
                workers: 4,
                assign_cols_per_worker: Some(16),
                ..Default::default()
            })
            .run()
            .unwrap();
        let a = serial.kmeans_model().unwrap();
        let b = fanned.kmeans_model().unwrap();
        assert_eq!(a.result.assign, b.result.assign);
        for (x, y) in a.result.centers.as_slice().iter().zip(b.result.centers.as_slice()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
