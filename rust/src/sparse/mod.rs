//! Fixed-stride sparse chunk storage.
//!
//! The paper's sparsifier keeps *exactly* `m` of `p` entries per sample,
//! so the natural storage is not general CSC but a fixed-stride layout:
//! column `i` owns `indices[i*m .. (i+1)*m]` / `values[i*m .. (i+1)*m]`.
//! This gives branch-free iteration, trivially computable offsets, and
//! `8·m·n + 4·m·n` bytes — the compression ratio the paper reports.

mod source;

pub use source::{SparseChunkSource, SparseVecSource};

use crate::error::{shape_err, Result};
use crate::linalg::Mat;

/// Value-storage precision of a chunk (and of the on-disk store that
/// serializes it — `docs/FORMAT.md` §Value encoding).
///
/// This is a *storage* axis, not a compute axis: kernels always
/// accumulate in `f64`. In [`F32`](Precision::F32) mode every kept value
/// is quantized through `f32` the moment it enters a chunk (≤ 0.5 ulp of
/// `f32` relative error per value, the mode's documented ULP bound) and
/// is widened back exactly for arithmetic, so downstream results differ
/// from `f64` mode only by that initial quantization while shard value
/// blocks shrink from 8 to 4 bytes per entry.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// 4-byte stored values, `f64` accumulation.
    F32,
    /// Full 8-byte values end to end (the default; byte-identical to
    /// the pre-precision-axis format).
    #[default]
    F64,
}

impl Precision {
    /// Stable lowercase name (CLI `--precision`, store manifests).
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F64 => "f64",
        }
    }

    /// Parse a [`name`](Self::name).
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "f64" => Some(Precision::F64),
            _ => None,
        }
    }

    /// Bytes per stored value in a shard's value block.
    pub fn val_bytes(self) -> usize {
        match self {
            Precision::F32 => 4,
            Precision::F64 => 8,
        }
    }
}

/// A sparsified chunk of `n` samples in dimension `p`, exactly `m` kept
/// entries per sample. Indices within each column are stored sorted.
///
/// # Example
///
/// ```
/// use pds::sparse::SparseChunk;
///
/// // p = 5, m = 2 kept entries per sample, n = 2 samples starting at
/// // global column 0: column 0 keeps coordinates {0, 3}, column 1 {1, 4}.
/// let chunk = SparseChunk::from_raw(
///     5,
///     2,
///     2,
///     vec![0, 3, 1, 4],
///     vec![0.5, -1.0, 2.0, 0.25],
///     0,
/// )
/// .unwrap();
/// chunk.validate().unwrap();
/// assert_eq!(chunk.col_indices(1), &[1, 4]);
/// assert_eq!(chunk.col_values(0), &[0.5, -1.0]);
/// assert_eq!(chunk.gamma(), 0.4); // m / p
/// let dense = chunk.to_dense(); // zeros at unsampled coordinates
/// assert_eq!(dense.get(3, 0), -1.0);
/// assert_eq!(dense.get(2, 0), 0.0);
/// ```
#[derive(Clone, Debug)]
pub struct SparseChunk {
    p: usize,
    m: usize,
    n: usize,
    /// Column `i`'s kept coordinates: `indices[i*m..(i+1)*m]`, sorted.
    indices: Vec<u32>,
    /// Matching kept values (preconditioned-domain).
    values: Vec<f64>,
    /// Global index of the first sample in this chunk (streaming offset).
    start_col: usize,
    /// Storage precision marker. In-RAM values are always `f64`; under
    /// [`Precision::F32`] they are guaranteed exactly
    /// `f32`-representable (quantized on entry), so `f64` arithmetic on
    /// them equals `f32` storage with `f64` accumulators bit for bit.
    precision: Precision,
}

impl SparseChunk {
    /// Allocate an empty chunk (filled via [`col_mut`](Self::col_mut)).
    pub fn with_capacity(p: usize, m: usize, n: usize, start_col: usize) -> Self {
        SparseChunk {
            p,
            m,
            n,
            indices: vec![0; m * n],
            values: vec![0.0; m * n],
            start_col,
            precision: Precision::F64,
        }
    }

    /// Construct from raw fixed-stride buffers.
    pub fn from_raw(
        p: usize,
        m: usize,
        n: usize,
        indices: Vec<u32>,
        values: Vec<f64>,
        start_col: usize,
    ) -> Result<Self> {
        if indices.len() != m * n || values.len() != m * n {
            return shape_err(format!(
                "SparseChunk::from_raw: buffers {}/{} != m*n={}",
                indices.len(),
                values.len(),
                m * n
            ));
        }
        Ok(SparseChunk { p, m, n, indices, values, start_col, precision: Precision::F64 })
    }

    /// Convert this chunk to the given storage precision. `F32`
    /// quantizes every value through `f32` (idempotent; ≤ 0.5 ulp of
    /// `f32` per value); `F64` only sets the marker — it cannot restore
    /// bits a previous quantization dropped.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        if precision == Precision::F32 {
            for v in self.values.iter_mut() {
                *v = *v as f32 as f64;
            }
        }
        self.precision = precision;
        self
    }

    /// Storage precision marker of this chunk.
    #[inline]
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Ambient (possibly padded) dimension.
    #[inline]
    pub fn p(&self) -> usize {
        self.p
    }

    /// Kept entries per sample.
    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Samples in this chunk.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Global column offset of this chunk in the stream.
    #[inline]
    pub fn start_col(&self) -> usize {
        self.start_col
    }

    /// Compression factor γ = m/p.
    pub fn gamma(&self) -> f64 {
        self.m as f64 / self.p as f64
    }

    /// Sorted kept coordinates of column `i` (length `m`).
    #[inline]
    pub fn col_indices(&self, i: usize) -> &[u32] {
        &self.indices[i * self.m..(i + 1) * self.m]
    }

    /// Kept values of column `i` (length `m`, preconditioned-domain).
    #[inline]
    pub fn col_values(&self, i: usize) -> &[f64] {
        &self.values[i * self.m..(i + 1) * self.m]
    }

    /// The whole fixed-stride index buffer (`m·n` entries, column `i` at
    /// `[i*m, (i+1)*m)`) — the exact layout the on-disk sparse store
    /// serializes (see `docs/FORMAT.md`).
    #[inline]
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// The whole fixed-stride value buffer (`m·n` entries, matching
    /// [`indices`](Self::indices)).
    #[inline]
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Mutable access to one column's (indices, values).
    pub fn col_mut(&mut self, i: usize) -> (&mut [u32], &mut [f64]) {
        (
            &mut self.indices[i * self.m..(i + 1) * self.m],
            &mut self.values[i * self.m..(i + 1) * self.m],
        )
    }

    /// Heap bytes held by this chunk.
    pub fn memory_bytes(&self) -> usize {
        self.indices.len() * 4 + self.values.len() * 8
    }

    /// Concatenate stream-contiguous chunks (same `p`/`m`, each chunk
    /// starting where the previous one ends) into one chunk. The fixed
    /// stride makes this a pair of buffer copies. Used by the drivers to
    /// coalesce small streaming chunks before a fit, so the parallel
    /// assignment fans out over usefully large column ranges instead of
    /// paying a fork/join per tiny chunk.
    pub fn concat(chunks: &[SparseChunk]) -> Result<SparseChunk> {
        let first = match chunks.first() {
            Some(c) => c,
            None => return shape_err("SparseChunk::concat: no chunks"),
        };
        let (p, m, start_col) = (first.p, first.m, first.start_col);
        let precision = first.precision;
        let mut expected = start_col;
        let mut n = 0usize;
        for c in chunks {
            if c.p != p || c.m != m {
                return shape_err(format!(
                    "SparseChunk::concat: mixed shapes ({}x{} vs {p}x{m})",
                    c.p, c.m
                ));
            }
            if c.precision != precision {
                return shape_err(format!(
                    "SparseChunk::concat: mixed precisions ({} vs {})",
                    c.precision.name(),
                    precision.name()
                ));
            }
            if c.start_col != expected {
                return shape_err(format!(
                    "SparseChunk::concat: chunk at {} not contiguous (expected {expected})",
                    c.start_col
                ));
            }
            expected += c.n;
            n += c.n;
        }
        let mut indices = Vec::with_capacity(m * n);
        let mut values = Vec::with_capacity(m * n);
        for c in chunks {
            indices.extend_from_slice(&c.indices);
            values.extend_from_slice(&c.values);
        }
        Ok(SparseChunk { p, m, n, indices, values, start_col, precision })
    }

    /// Densify into a `p×n` matrix (zeros at unsampled coordinates):
    /// the `w_i = R_i R_iᵀ y_i` representation.
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.p, self.n);
        for i in 0..self.n {
            let col = out.col_mut(i);
            for (idx, val) in self.col_indices(i).iter().zip(self.col_values(i)) {
                col[*idx as usize] = *val;
            }
        }
        out
    }

    /// Densify values + 0/1 mask as f32 column-major buffers — the exact
    /// operand layout of the AOT `assign`/`kmeans_step` executables.
    /// Values are scatter-*added* so a weighted chunk's duplicate slots
    /// densify to the sketch `v = Σ u·e` rather than silently dropping
    /// slots (identical to plain assignment for distinct-index chunks).
    pub fn to_dense_f32_masked(&self) -> (Vec<f32>, Vec<f32>) {
        let mut w = vec![0.0f32; self.p * self.n];
        let mut mask = vec![0.0f32; self.p * self.n];
        for i in 0..self.n {
            let base = i * self.p;
            for (idx, val) in self.col_indices(i).iter().zip(self.col_values(i)) {
                w[base + *idx as usize] += *val as f32;
                mask[base + *idx as usize] = 1.0;
            }
        }
        (w, mask)
    }

    /// Squared l2 norm of column `i`.
    pub fn col_norm2(&self, i: usize) -> f64 {
        self.col_values(i).iter().map(|v| v * v).sum()
    }

    /// Structural invariants (used by property tests and debug assertions):
    /// **strictly** sorted, distinct, in-range indices in every column —
    /// the contract of the uniform (without-replacement) sampling
    /// schemes. Chunks from weighted with-replacement schemes (e.g.
    /// `sampling::Scheme::Hybrid`) legally repeat indices; validate those
    /// with [`validate_weighted`](Self::validate_weighted) instead.
    pub fn validate(&self) -> Result<()> {
        for i in 0..self.n {
            let idx = self.col_indices(i);
            for w in idx.windows(2) {
                if w[0] >= w[1] {
                    return shape_err(format!("col {i}: indices not strictly sorted"));
                }
            }
            if let Some(&last) = idx.last() {
                if last as usize >= self.p {
                    return shape_err(format!("col {i}: index {last} >= p={}", self.p));
                }
            }
        }
        Ok(())
    }

    /// [`validate`](Self::validate) for weighted with-replacement chunks:
    /// indices must be non-decreasing and in range, but duplicates — one
    /// slot per draw — are allowed.
    pub fn validate_weighted(&self) -> Result<()> {
        for i in 0..self.n {
            let idx = self.col_indices(i);
            for w in idx.windows(2) {
                if w[0] > w[1] {
                    return shape_err(format!("col {i}: indices not sorted"));
                }
            }
            if let Some(&last) = idx.last() {
                if last as usize >= self.p {
                    return shape_err(format!("col {i}: index {last} >= p={}", self.p));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_chunk() -> SparseChunk {
        // p=5, m=2, n=3
        SparseChunk::from_raw(
            5,
            2,
            3,
            vec![0, 3, 1, 4, 2, 3],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            7,
        )
        .unwrap()
    }

    #[test]
    fn accessors() {
        let c = sample_chunk();
        assert_eq!(c.p(), 5);
        assert_eq!(c.m(), 2);
        assert_eq!(c.n(), 3);
        assert_eq!(c.start_col(), 7);
        assert_eq!(c.col_indices(1), &[1, 4]);
        assert_eq!(c.col_values(2), &[5.0, 6.0]);
        assert!((c.gamma() - 0.4).abs() < 1e-15);
        assert_eq!(c.memory_bytes(), 6 * 4 + 6 * 8);
    }

    #[test]
    fn densify() {
        let c = sample_chunk();
        let d = c.to_dense();
        assert_eq!(d.get(0, 0), 1.0);
        assert_eq!(d.get(3, 0), 2.0);
        assert_eq!(d.get(1, 0), 0.0);
        assert_eq!(d.get(4, 1), 4.0);
        let (w, mask) = c.to_dense_f32_masked();
        assert_eq!(w[0], 1.0);
        assert_eq!(mask[0], 1.0);
        assert_eq!(mask[1], 0.0);
        assert_eq!(w.len(), 15);
    }

    #[test]
    fn col_norms() {
        let c = sample_chunk();
        assert!((c.col_norm2(0) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn validate_catches_bad_indices() {
        let bad = SparseChunk::from_raw(5, 2, 1, vec![3, 3], vec![0.0, 0.0], 0).unwrap();
        assert!(bad.validate().is_err());
        let oob = SparseChunk::from_raw(5, 2, 1, vec![3, 9], vec![0.0, 0.0], 0).unwrap();
        assert!(oob.validate().is_err());
        assert!(sample_chunk().validate().is_ok());
    }

    #[test]
    fn validate_weighted_allows_duplicates_but_not_disorder() {
        // duplicates (one slot per with-replacement draw) pass the
        // weighted check while still failing the strict one
        let dup = SparseChunk::from_raw(5, 3, 1, vec![1, 1, 4], vec![0.5, 0.5, 1.0], 0).unwrap();
        assert!(dup.validate().is_err());
        assert!(dup.validate_weighted().is_ok());
        let unsorted = SparseChunk::from_raw(5, 3, 1, vec![4, 1, 1], vec![0.0; 3], 0).unwrap();
        assert!(unsorted.validate_weighted().is_err());
        let oob = SparseChunk::from_raw(5, 2, 1, vec![3, 9], vec![0.0, 0.0], 0).unwrap();
        assert!(oob.validate_weighted().is_err());
        assert!(sample_chunk().validate_weighted().is_ok());
    }

    #[test]
    fn precision_marker_and_quantization() {
        let c = sample_chunk();
        assert_eq!(c.precision(), Precision::F64);
        let exact = 1.0 + 2f64.powi(-40); // not f32-representable
        let mut q = SparseChunk::from_raw(5, 1, 1, vec![2], vec![exact], 0).unwrap();
        q = q.with_precision(Precision::F32);
        assert_eq!(q.precision(), Precision::F32);
        assert_eq!(q.col_values(0)[0], 1.0); // quantized
        assert_eq!(q.col_values(0)[0] as f32 as f64, q.col_values(0)[0]); // idempotent
        // concat refuses mixed precisions and propagates matching ones
        let a = SparseChunk::from_raw(5, 1, 1, vec![0], vec![0.5], 0)
            .unwrap()
            .with_precision(Precision::F32);
        let b64 = SparseChunk::from_raw(5, 1, 1, vec![1], vec![0.25], 1).unwrap();
        assert!(SparseChunk::concat(&[a.clone(), b64]).is_err());
        let b32 = SparseChunk::from_raw(5, 1, 1, vec![1], vec![0.25], 1)
            .unwrap()
            .with_precision(Precision::F32);
        let joined = SparseChunk::concat(&[a, b32]).unwrap();
        assert_eq!(joined.precision(), Precision::F32);
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(Precision::F32.val_bytes(), 4);
        assert_eq!(Precision::F64.val_bytes(), 8);
    }

    #[test]
    fn from_raw_shape_check() {
        assert!(SparseChunk::from_raw(5, 2, 3, vec![0; 5], vec![0.0; 6], 0).is_err());
    }

    #[test]
    fn concat_joins_contiguous_chunks() {
        let a = SparseChunk::from_raw(5, 2, 2, vec![0, 3, 1, 4], vec![1.0, 2.0, 3.0, 4.0], 7)
            .unwrap();
        let b = SparseChunk::from_raw(5, 2, 1, vec![2, 3], vec![5.0, 6.0], 9).unwrap();
        let joined = SparseChunk::concat(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(joined.n(), 3);
        assert_eq!(joined.start_col(), 7);
        assert_eq!(joined.col_indices(0), a.col_indices(0));
        assert_eq!(joined.col_values(1), a.col_values(1));
        assert_eq!(joined.col_indices(2), b.col_indices(0));
        assert_eq!(joined.col_values(2), b.col_values(0));
        joined.validate().unwrap();
        // gaps and shape mismatches are rejected
        let gap = SparseChunk::from_raw(5, 2, 1, vec![0, 1], vec![0.0, 0.0], 11).unwrap();
        assert!(SparseChunk::concat(&[a.clone(), gap]).is_err());
        let other_m = SparseChunk::from_raw(5, 3, 1, vec![0, 1, 2], vec![0.0; 3], 9).unwrap();
        assert!(SparseChunk::concat(&[a, other_m]).is_err());
        assert!(SparseChunk::concat(&[]).is_err());
    }
}
