//! Sources of already-sparsified chunks.
//!
//! [`SparseChunkSource`] is the data-layer mirror of
//! [`ChunkSource`](crate::coordinator::ChunkSource): a rewindable stream
//! of [`SparseChunk`]s that skipped (or already paid for) the compression
//! pass. It lives here — not in the coordinator — so that every consumer
//! layer (estimators, K-means, the PCA operators) can stream sparsified
//! data without depending on the pipeline orchestration. The canonical
//! on-disk implementation is
//! [`SparseStoreReader`](crate::store::SparseStoreReader); the in-memory
//! one is [`SparseVecSource`].
//!
//! The contract every implementation upholds:
//!
//! * chunks are yielded in **global column order** and are contiguous
//!   within a pass,
//! * every chunk has the source's `(p, m)` shape,
//! * [`reset`](SparseChunkSource::reset) restarts an identical pass —
//!   byte-for-byte the same chunks in the same order (chunk *boundaries*
//!   may legally differ between implementations, e.g. under different
//!   reader memory budgets; all downstream folds are
//!   granularity-invariant by design).

use crate::error::Result;
use crate::sparse::{Precision, SparseChunk};

/// Abstract source of **already-sparsified** chunks — the mirror of
/// [`ChunkSource`](crate::coordinator::ChunkSource) for data that skipped
/// (or already paid for) the compression pass. Consumers fold the yielded
/// chunks into the estimators / K-means exactly as the streaming drivers
/// do — the estimators never know whether data came from a fresh
/// compress pass or from disk.
pub trait SparseChunkSource: Send {
    /// Working (possibly padded) ambient dimension of every chunk.
    fn p(&self) -> usize;
    /// Kept entries per sample.
    fn m(&self) -> usize;
    /// Total samples if known.
    fn n_hint(&self) -> Option<usize>;
    /// Pull the next chunk (in global column order); `None` ends the pass.
    fn next_chunk(&mut self) -> Result<Option<SparseChunk>>;
    /// Restart for another pass.
    fn reset(&mut self) -> Result<()>;
    /// Storage precision of the yielded chunks. Defaults to
    /// [`Precision::F64`] (every pre-precision-axis source); the store
    /// reader overrides it from the manifest.
    fn precision(&self) -> Precision {
        Precision::F64
    }
}

/// In-memory [`SparseChunkSource`]: replays a vector of chunks (sorted by
/// `start_col` on construction).
pub struct SparseVecSource {
    chunks: Vec<SparseChunk>,
    p: usize,
    m: usize,
    precision: Precision,
    pos: usize,
}

impl SparseVecSource {
    /// Wrap chunks (must be non-empty, uniform `p`/`m`, and — after the
    /// sort — contiguous in the global column order: each chunk starts
    /// exactly where the previous one ends).
    ///
    /// Contiguity is a hard error, not a warning: an overlapping or
    /// duplicated `start_col` range would silently double-count those
    /// samples in every estimator/K-means fold, and a gap would
    /// mis-align every consumer that indexes per-sample state by
    /// `start_col` (assignments, the two-pass refinement).
    pub fn new(mut chunks: Vec<SparseChunk>) -> Result<Self> {
        let Some(first) = chunks.first() else {
            return crate::error::invalid("SparseVecSource: no chunks");
        };
        let (p, m) = (first.p(), first.m());
        let precision = first.precision();
        if chunks.iter().any(|c| c.p() != p || c.m() != m) {
            return crate::error::shape_err("SparseVecSource: mixed chunk shapes");
        }
        if chunks.iter().any(|c| c.precision() != precision) {
            return crate::error::shape_err("SparseVecSource: mixed chunk precisions");
        }
        chunks.sort_by_key(|c| c.start_col());
        let mut expected = chunks[0].start_col();
        for c in &chunks {
            let start = c.start_col();
            if start < expected {
                return crate::error::shape_err(format!(
                    "SparseVecSource: chunk at column {start} overlaps the previous chunk \
                     (which ends at {expected})"
                ));
            }
            if start > expected {
                return crate::error::shape_err(format!(
                    "SparseVecSource: gap in the stream — columns {expected}..{start} are \
                     missing"
                ));
            }
            expected = start + c.n();
        }
        Ok(SparseVecSource { chunks, p, m, precision, pos: 0 })
    }
}

impl SparseChunkSource for SparseVecSource {
    fn p(&self) -> usize {
        self.p
    }

    fn m(&self) -> usize {
        self.m
    }

    fn n_hint(&self) -> Option<usize> {
        Some(self.chunks.iter().map(|c| c.n()).sum())
    }

    fn next_chunk(&mut self) -> Result<Option<SparseChunk>> {
        if self.pos >= self.chunks.len() {
            return Ok(None);
        }
        let chunk = self.chunks[self.pos].clone();
        self.pos += 1;
        Ok(Some(chunk))
    }

    fn reset(&mut self) -> Result<()> {
        self.pos = 0;
        Ok(())
    }

    fn precision(&self) -> Precision {
        self.precision
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(start: usize, n: usize) -> SparseChunk {
        let indices: Vec<u32> = (0..n).flat_map(|_| [0u32, 2]).collect();
        let values: Vec<f64> = (0..2 * n).map(|v| v as f64).collect();
        SparseChunk::from_raw(4, 2, n, indices, values, start).unwrap()
    }

    #[test]
    fn vec_source_replays_in_order() {
        // construct out of order; the source must sort by start_col
        let mut src = SparseVecSource::new(vec![chunk(3, 2), chunk(0, 3)]).unwrap();
        assert_eq!(src.p(), 4);
        assert_eq!(src.m(), 2);
        assert_eq!(src.n_hint(), Some(5));
        let mut starts = Vec::new();
        while let Some(c) = src.next_chunk().unwrap() {
            starts.push(c.start_col());
        }
        assert_eq!(starts, vec![0, 3]);
        src.reset().unwrap();
        assert_eq!(src.next_chunk().unwrap().unwrap().start_col(), 0);
    }

    #[test]
    fn vec_source_rejects_bad_shapes() {
        assert!(SparseVecSource::new(vec![]).is_err());
        let odd =
            SparseChunk::from_raw(4, 1, 1, vec![1], vec![9.0], 3).unwrap();
        assert!(SparseVecSource::new(vec![chunk(0, 3), odd]).is_err());
    }

    #[test]
    fn vec_source_rejects_overlap_gap_and_duplicate_start() {
        use crate::error::Error;
        // overlap: [0,3) and [2,4) double-count columns 2
        match SparseVecSource::new(vec![chunk(0, 3), chunk(2, 2)]) {
            Err(Error::Shape(msg)) => assert!(msg.contains("overlap"), "{msg}"),
            other => panic!("expected Shape overlap error, got ok={}", other.is_ok()),
        }
        // gap: [0,3) then [5,7) leaves columns 3..5 missing
        match SparseVecSource::new(vec![chunk(0, 3), chunk(5, 2)]) {
            Err(Error::Shape(msg)) => assert!(msg.contains("gap"), "{msg}"),
            other => panic!("expected Shape gap error, got ok={}", other.is_ok()),
        }
        // duplicate start: two chunks both claiming column 0
        match SparseVecSource::new(vec![chunk(0, 2), chunk(0, 2)]) {
            Err(Error::Shape(msg)) => assert!(msg.contains("overlap"), "{msg}"),
            other => panic!("expected Shape overlap error, got ok={}", other.is_ok()),
        }
        // contiguous (possibly offset) streams still pass
        assert!(SparseVecSource::new(vec![chunk(7, 2), chunk(9, 3)]).is_ok());
    }
}
