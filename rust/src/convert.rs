//! Checked integer conversions with typed errors.
//!
//! Grown out of the store manifest's `lookup_u32`/`lookup_usize`
//! helpers: every place the codecs and the daemon move a length or an
//! index across integer widths goes through one of these instead of a
//! bare `as` cast, so overflow is a typed [`Error::Corrupt`] /
//! [`Error::Invalid`] instead of silent truncation. `pds-lint`'s
//! `lossy-cast` rule holds the line — the `as` casts live here, once,
//! behind `try_into` checks, and new bare casts elsewhere fail the
//! lint unless baselined.
//!
//! Two error flavors, chosen by what the value *is*:
//!
//! * [`Corrupt`](Error::Corrupt) — the value came from bytes we read
//!   back (a manifest field, an artifact length): an overflow means
//!   the input is damaged or hostile.
//! * [`Invalid`](Error::Invalid) — the value came from configuration
//!   or in-memory state (a column count about to be serialized): an
//!   overflow means the caller asked for something this format cannot
//!   represent.

use crate::error::{Error, Result};

/// `usize -> u32` for a value about to be serialized into a `u32`
/// field; overflow is `Invalid` (the in-memory state does not fit the
/// format).
pub fn usize_to_u32(v: usize, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| Error::Invalid(format!("{what} {v} does not fit in u32")))
}

/// `u64 -> u32` for a value read back from serialized bytes; overflow
/// is `Corrupt`.
pub fn u64_to_u32(v: u64, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| Error::Corrupt(format!("{what} {v} does not fit in u32")))
}

/// `u64 -> usize` for a length/index read back from serialized bytes;
/// overflow is `Corrupt` (cannot be addressed on this target).
pub fn u64_to_usize(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v)
        .map_err(|_| Error::Corrupt(format!("{what} {v} does not fit in usize")))
}

/// `u32 -> usize`, infallible on every target pds supports (32- and
/// 64-bit); `From<u32> for usize` is not provided by the standard
/// library, so the audited cast lives here, once.
#[inline]
pub fn u32_to_usize(v: u32) -> usize {
    // lint:allow(lossy-cast) — u32 -> usize cannot truncate on any
    // supported pds target (32- and 64-bit); centralized here so call
    // sites stay cast-free.
    v as usize
}

/// `usize -> u64`, infallible on every supported target (usize is at
/// most 64 bits); centralized so call sites stay cast-free.
#[inline]
pub fn usize_to_u64(v: usize) -> u64 {
    // lint:allow(lossy-cast) — usize -> u64 cannot truncate on any
    // supported pds target.
    v as u64
}

/// Deliberate `f64 -> f32` narrowing — the mixed-precision store's
/// quantization step. Centralized so the one intentionally lossy float
/// cast in the codebase is auditable in a single place.
#[inline]
pub fn f64_to_f32(v: f64) -> f32 {
    // lint:allow(lossy-cast) — quantization is the point: the store's
    // F32 precision mode rounds each value to the nearest f32 exactly
    // once (Lazy SPCA recipe), and this is that rounding.
    v as f32
}

/// Quantize through `f32` and widen back exactly: the value the F32
/// store will reproduce on read-back.
#[inline]
pub fn quantize_f32(v: f64) -> f64 {
    f64::from(f64_to_f32(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn narrowing_overflow_is_typed() {
        assert_eq!(usize_to_u32(7, "cols").unwrap(), 7);
        assert!(matches!(
            usize_to_u32(usize::try_from(u64::from(u32::MAX) + 1).unwrap(), "cols"),
            Err(Error::Invalid(_))
        ));
        assert_eq!(u64_to_u32(7, "field").unwrap(), 7);
        assert!(matches!(
            u64_to_u32(u64::from(u32::MAX) + 1, "field"),
            Err(Error::Corrupt(_))
        ));
        assert_eq!(u64_to_usize(9, "len").unwrap(), 9);
    }

    #[test]
    fn widening_is_lossless() {
        assert_eq!(u32_to_usize(u32::MAX), 4_294_967_295);
        assert_eq!(usize_to_u64(123), 123);
    }
}
