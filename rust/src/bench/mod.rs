//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Used by every target in `rust/benches/`: warmup + timed iterations,
//! reporting median and MAD. Keep output grep-friendly: one line per
//! benchmark, `bench <name> ... median <t> mad <t>`.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark name.
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// Median absolute deviation of the timings.
    pub mad_s: f64,
    /// Fastest iteration.
    pub min_s: f64,
}

impl BenchResult {
    /// One grep-friendly report line.
    pub fn report(&self) -> String {
        format!(
            "bench {:<44} iters {:>3}  median {:>12}  mad {:>10}  min {:>12}",
            self.name,
            self.iters,
            fmt_time(self.median_s),
            fmt_time(self.mad_s),
            fmt_time(self.min_s),
        )
    }
}

/// Human time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` with `warmup` unmeasured runs and `iters` measured runs;
/// prints and returns the result. A `black_box`-style sink is the
/// caller's responsibility (return a value from `f` and accumulate it).
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        iters: iters.max(1),
        median_s: median,
        mad_s: devs[devs.len() / 2],
        min_s: times[0],
    };
    println!("{}", result.report());
    result
}

/// Print a section header so bench output reads like the paper's tables.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(r.iters, 5);
        assert!(r.median_s >= 0.0 && r.min_s <= r.median_s);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }
}
