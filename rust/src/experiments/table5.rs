//! Table V — per-iteration speedup: time to find assignments and to
//! update all centers, full K-means vs sparsified, γ = 0.05.
//!
//! Paper: n = 9.6M → 100×/26.4×/40.1×. The absolute factors scale with
//! the machine; the claim is assignments ≈ 1/γ speedup, updates a large
//! constant, combined ≥ 1/(2γ).

use std::time::Instant;

use crate::cli::Args;
use crate::data::{digits, DigitConfig};
use crate::error::Result;
use crate::experiments::common::{print_table, scaled};
use crate::kmeans::{
    accumulate_center_update, assign_dense, kmeans_pp_dense, solve_centers, NativeAssigner,
    SparseAssigner,
};
use crate::linalg::Mat;
use crate::rng::Pcg64;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::transform::TransformKind;

const K: usize = 3;

/// Run this experiment (`pds xp table5`).
pub fn run(args: &Args) -> Result<()> {
    let n = scaled(args, args.get_parse("n", 50_000)?, 600_000);
    let gamma: f64 = args.get_parse("gamma", 0.05)?;
    println!("Table V: digits n={n} gamma={gamma} (single Lloyd iteration)");
    let d = digits(n, DigitConfig::default());
    let p = d.data.rows();
    let mut rng = Pcg64::seed(5);

    // --- full K-means iteration ---
    let centers = kmeans_pp_dense(&d.data, K, &mut rng);
    let t0 = Instant::now();
    let (assign_full, _) = assign_dense(&d.data, &centers);
    let t_assign_full = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    {
        let mut sums = Mat::zeros(p, K);
        let mut counts = vec![0usize; K];
        for (j, &c) in assign_full.iter().enumerate() {
            counts[c as usize] += 1;
            let col = d.data.col(j);
            let s = sums.col_mut(c as usize);
            for i in 0..p {
                s[i] += col[i];
            }
        }
        std::hint::black_box(&sums);
    }
    let t_update_full = t0.elapsed().as_secs_f64();

    // --- sparsified iteration ---
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 6 };
    let sp = Sparsifier::new(p, scfg)?;
    let chunk = sp.compress_chunk(&d.data, 0)?;
    let centers_pre = sp.precondition_dense(&centers);
    let t0 = Instant::now();
    let (assign_sp, _) = NativeAssigner::new().assign(&chunk, &centers_pre)?;
    let t_assign_sp = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    {
        let mut sums = Mat::zeros(sp.p(), K);
        let mut counts = Mat::zeros(sp.p(), K);
        accumulate_center_update(&chunk, &assign_sp, &mut sums, &mut counts);
        std::hint::black_box(&solve_centers(&sums, &counts, &centers_pre));
    }
    let t_update_sp = t0.elapsed().as_secs_f64();

    let comb_full = t_assign_full + t_update_full;
    let comb_sp = t_assign_sp + t_update_sp;
    print_table(
        "Table V: estimated per-iteration speedup",
        &["algorithm", "assign s", "speedup", "update s", "speedup", "combined s", "speedup"],
        &[
            vec![
                "K-means".into(),
                format!("{t_assign_full:.3}"),
                "1x".into(),
                format!("{t_update_full:.3}"),
                "1x".into(),
                format!("{comb_full:.3}"),
                "1x".into(),
            ],
            vec![
                "Sparsified K-means".into(),
                format!("{t_assign_sp:.3}"),
                format!("{:.1}x", t_assign_full / t_assign_sp.max(1e-9)),
                format!("{t_update_sp:.3}"),
                format!("{:.1}x", t_update_full / t_update_sp.max(1e-9)),
                format!("{comb_sp:.3}"),
                format!("{:.1}x", comb_full / comb_sp.max(1e-9)),
            ],
        ],
    );
    println!("paper: 100x / 26.4x / 40.1x at n=9.6M, gamma=0.05 (16 cores, in-cache sparse data)");
    Ok(())
}
