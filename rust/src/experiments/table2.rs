//! Table II — passes over the data per algorithm (analytic; printed from
//! the algorithms' structure, verified by the drivers' pass counters).

use crate::cli::Args;
use crate::error::Result;
use crate::experiments::common::print_table;

/// Run this experiment (`pds xp table2`).
pub fn run(_args: &Args) -> Result<()> {
    print_table(
        "Table II: low-pass algorithms for K-means clustering",
        &["algorithm", "passes to find centers", "passes to find assignments"],
        &[
            vec!["Sparsified K-means (1-pass)".into(), "1".into(), "1".into()],
            vec!["Sparsified K-means (2-pass)".into(), "2".into(), "2".into()],
            vec!["Feature extraction".into(), "2".into(), "1".into()],
            vec!["Feature selection".into(), "4".into(), "3".into()],
        ],
    );
    println!(
        "(the session API exposes the actual counts in FitReport::raw_passes / \
         sparse_passes; the integration tests assert 1 and 2 for the sparsified variants)"
    );
    Ok(())
}
