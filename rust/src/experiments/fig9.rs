//! Fig. 9 — quality of the 1-pass center estimates at γ = 0.03.
//!
//! The paper shows center images; we report the numeric equivalent:
//! per-pixel RMSE of each algorithm's centers against the true class
//! templates. The claim under test: sparsified K-means returns usable
//! centers in ONE pass (consistent estimator, §VII.B); feature
//! extraction's `Ω⁺`-lifted centers do not improve with n, and feature
//! selection has no 1-pass centers at all.

use crate::cli::Args;
use crate::data::{digits, DigitConfig};
use crate::error::Result;
use crate::experiments::common::{center_rmse, print_table, run_algo, scaled, Algo};
use crate::kmeans::KmeansOpts;

/// Run this experiment (`pds xp fig9`).
pub fn run(args: &Args) -> Result<()> {
    let n = scaled(args, args.get_parse("n", 4000)?, 21_002);
    let gamma: f64 = args.get_parse("gamma", 0.03)?;
    let n_init = scaled(args, 5, 20);
    println!("Fig 9: digits n={n} gamma={gamma} (center RMSE vs true templates)");
    let d = digits(n, DigitConfig::default());
    let opts = KmeansOpts { n_init, max_iters: 100, tol_frac: 0.0, seed: 0 };

    let mut rows = Vec::new();
    for (algo, passes) in [
        (Algo::Sparsified, 1),
        (Algo::SparsifiedNoPrecond, 1),
        (Algo::SparsifiedTwoPass, 2),
        (Algo::FeatureExtraction, 1),
        (Algo::FeatureSelection, 3),
    ] {
        let run = run_algo(algo, &d, 3, gamma, opts, 99)?;
        rows.push(vec![
            algo.name().to_string(),
            format!("{passes}"),
            format!("{:.4}", center_rmse(&run.result.centers, &d.centers)),
            format!("{:.4}", run.accuracy),
        ]);
    }
    print_table(
        "Fig 9: center estimate quality",
        &["algorithm", "passes", "center RMSE", "accuracy"],
        &rows,
    );
    println!(
        "paper shape: sparsified 1-pass centers close to truth; feature extraction \
         1-pass centers visibly degraded (pinv lift), fixed only by an extra pass"
    );
    Ok(())
}
