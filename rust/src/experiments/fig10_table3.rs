//! Fig. 10 + Table III — the in-core "big data" digit run through the
//! streaming coordinator (paper: n = 6·10⁵; scaled default n = 5·10⁴).
//!
//! Fig. 10: accuracy vs γ for sparsified (±precond, ±2-pass) and feature
//! extraction. Table III: the timing breakdown at γ = 0.05 (total / time
//! to sample+precondition / K-means iterations).

use std::time::Instant;

use crate::baselines::FeatureExtraction;
use crate::cli::Args;
use crate::coordinator::{FitPlan, GeneratorSource, StreamConfig};
use crate::data::{DigitConfig, DigitStream, DIGIT_P};
use crate::error::Result;
use crate::experiments::common::{pm, print_table, scaled};
use crate::kmeans::KmeansOpts;
use crate::metrics::{clustering_accuracy, mean_std};
use crate::rng::Pcg64;
use crate::sampling::SparsifyConfig;
use crate::transform::TransformKind;

const K: usize = 3;

fn source(n: usize, seed: u64) -> (DigitStream, GeneratorSource<impl FnMut(usize, usize) -> crate::linalg::Mat + Send>) {
    let stream = DigitStream::new(DigitConfig { seed, ..Default::default() });
    let gen_stream = DigitStream::new(DigitConfig { seed, ..Default::default() });
    let src = GeneratorSource::new(DIGIT_P, n, 2048, move |start, cols| {
        gen_stream.chunk(start, cols)
    });
    (stream, src)
}

struct BigRun {
    accuracy: f64,
    iterations: usize,
    total_s: f64,
    compress_s: f64,
    load_s: f64,
    kmeans_s: f64,
}

fn run_one(
    n: usize,
    gamma: f64,
    precond: bool,
    two_pass: bool,
    opts: KmeansOpts,
    seed: u64,
) -> Result<BigRun> {
    let (stream, mut src) = source(n, seed);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: seed ^ 0x10 };
    let stream_cfg = StreamConfig { workers: 1, queue_depth: 4, chunk_cols: 2048, ..Default::default() };
    let t0 = Instant::now();
    let report = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .k(K)
        .kmeans_opts(opts)
        .stream_config(stream_cfg)
        .precondition(precond)
        .two_pass(two_pass)
        .run()?;
    let assign = match report.refined() {
        Some(refined) => refined.assign.clone(),
        None => report.kmeans_model().expect("kmeans plan").result.assign.clone(),
    };
    let total_s = t0.elapsed().as_secs_f64();
    let labels = stream.labels(0, n);
    Ok(BigRun {
        accuracy: clustering_accuracy(&assign, &labels, K),
        iterations: report.iterations,
        total_s,
        compress_s: report.timer.get("compress"),
        load_s: report.timer.get("load"),
        kmeans_s: report.timer.get("kmeans"),
    })
}

fn run_fe(n: usize, gamma: f64, opts: KmeansOpts, seed: u64) -> Result<BigRun> {
    let (stream, mut src) = source(n, seed);
    let m = ((gamma * DIGIT_P as f64).round() as usize).clamp(2, DIGIT_P);
    let mut rng = Pcg64::seed(seed ^ 0xFE);
    let fe = FeatureExtraction::new(DIGIT_P, m, &mut rng);
    let t0 = Instant::now();
    // streaming compress: Z chunks accumulate into an m×n matrix
    let mut z = crate::linalg::Mat::zeros(m, n);
    let mut load_s = 0.0;
    let mut compress_s = 0.0;
    use crate::coordinator::ChunkSource;
    loop {
        let t_load = Instant::now();
        let Some(chunk) = src.next_chunk()? else { break };
        load_s += t_load.elapsed().as_secs_f64();
        let t_c = Instant::now();
        let zc = fe.compress(&chunk.data);
        for j in 0..zc.cols() {
            z.col_mut(chunk.start_col + j).copy_from_slice(zc.col(j));
        }
        compress_s += t_c.elapsed().as_secs_f64();
    }
    let t_k = Instant::now();
    let res = crate::kmeans::kmeans_dense(&z, K, opts);
    let kmeans_s = t_k.elapsed().as_secs_f64();
    let labels = stream.labels(0, n);
    Ok(BigRun {
        accuracy: clustering_accuracy(&res.assign, &labels, K),
        iterations: res.iterations,
        total_s: t0.elapsed().as_secs_f64(),
        compress_s,
        load_s,
        kmeans_s,
    })
}

/// Run the Fig. 10 experiment (`pds xp fig10`).
pub fn run_fig10(args: &Args) -> Result<()> {
    let n = scaled(args, args.get_parse("n", 50_000)?, 600_000);
    let trials = scaled(args, args.get_parse("trials", 2)?, 10);
    let n_init = scaled(args, 3, 10);
    let gammas = args.get_list_f64("gammas", &[0.01, 0.02, 0.05, 0.1])?;
    println!("Fig 10: streaming digits n={n} trials={trials} starts={n_init}");
    let opts = KmeansOpts { n_init, max_iters: 100, tol_frac: 0.0, seed: 0 };

    let arms: [(&str, bool, bool); 3] = [
        ("sparsified", true, false),
        ("sparsified no-precond", false, false),
        ("sparsified 2-pass", true, true),
    ];
    let mut rows = Vec::new();
    for &gamma in &gammas {
        let mut row = vec![format!("{gamma:.3}")];
        for &(_, precond, two_pass) in &arms {
            let accs: Vec<f64> = (0..trials)
                .map(|t| {
                    run_one(n, gamma, precond, two_pass, opts, 9 + t as u64).map(|r| r.accuracy)
                })
                .collect::<Result<_>>()?;
            let (m, s) = mean_std(&accs);
            row.push(pm(m, s));
        }
        let fe_accs: Vec<f64> = (0..trials)
            .map(|t| run_fe(n, gamma, opts, 9 + t as u64).map(|r| r.accuracy))
            .collect::<Result<_>>()?;
        let (m, s) = mean_std(&fe_accs);
        row.push(pm(m, s));
        rows.push(row);
    }
    print_table(
        "Fig 10: big-data accuracy vs gamma",
        &["gamma", "sparsified", "no precond", "2-pass", "feature extraction"],
        &rows,
    );
    println!(
        "paper shape: precond >> no-precond; 2-pass ~ optimal from gamma >= 1%; \
         sparsified beats FE with lower variance"
    );
    Ok(())
}

/// Run the Table III experiment (`pds xp table3`).
pub fn run_table3(args: &Args) -> Result<()> {
    let n = scaled(args, args.get_parse("n", 50_000)?, 600_000);
    let gamma: f64 = args.get_parse("gamma", 0.05)?;
    let n_init = scaled(args, 3, 10);
    println!("Table III: streaming digits n={n} gamma={gamma}");
    let opts = KmeansOpts { n_init, max_iters: 100, tol_frac: 0.0, seed: 0 };

    let mut rows = Vec::new();
    let one = run_one(n, gamma, true, false, opts, 3)?;
    let two = run_one(n, gamma, true, true, opts, 3)?;
    let nop = run_one(n, gamma, false, false, opts, 3)?;
    let fe = run_fe(n, gamma, opts, 3)?;
    for (name, r) in [
        ("Sparsified K-means", &one),
        ("Sparsified K-means, 2 pass", &two),
        ("Sparsified, no precond", &nop),
        ("Feature extraction", &fe),
    ] {
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", r.total_s),
            format!("{:.1}", r.compress_s),
            format!("{:.1}", r.load_s),
            format!("{:.1}", r.kmeans_s),
            format!("{}", r.iterations),
            format!("{:.4}", r.accuracy),
        ]);
    }
    print_table(
        "Table III: timing breakdown",
        &["algorithm", "total s", "compress s", "gen/load s", "kmeans s", "iters", "accuracy"],
        &rows,
    );
    println!(
        "paper shape: majority of time in the K-means iterations; no-precond \
         fails to converge (100 iters) and is the slowest sparsified arm"
    );
    Ok(())
}
