//! Shared experiment plumbing: table printing, center-error metrics, and
//! the digit-workload runners used by Figs. 7–10 / Tables III–V.

use std::time::Instant;

use crate::baselines::{FeatureExtraction, FeatureSelection};
use crate::cli::Args;
use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::{two_pass_refine, KmeansOpts, KmeansResult, SparsifiedKmeans};
use crate::linalg::Mat;
use crate::metrics::clustering_accuracy;
use crate::rng::Pcg64;
use crate::sampling::SparsifyConfig;
use crate::transform::TransformKind;

/// Print a header row followed by aligned columns.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n--- {title} ---");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>width$}", c, width = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Format `mean ± std`.
pub fn pm(mean: f64, std: f64) -> String {
    format!("{mean:.4} ± {std:.4}")
}

/// Sum over estimated centers of the distance to the best-matching true
/// center (greedy one-to-one), normalized by `sqrt(p)` — the Fig. 9
/// center-quality metric.
pub fn center_rmse(est: &Mat, truth: &Mat) -> f64 {
    let k = est.cols();
    let p = est.rows() as f64;
    let mut used = vec![false; truth.cols()];
    let mut total = 0.0;
    for c in 0..k {
        let mut best = (f64::INFINITY, 0usize);
        for t in 0..truth.cols() {
            if used[t] {
                continue;
            }
            let d: f64 = est
                .col(c)
                .iter()
                .zip(truth.col(t))
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            if d < best.0 {
                best = (d, t);
            }
        }
        used[best.1] = true;
        total += (best.0 / p).sqrt();
    }
    total / k as f64
}

/// Which clustering algorithm a digit-workload run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    /// Sparsified K-means, Algorithm 1 (ROS + uniform element sampling).
    Sparsified,
    /// Ablation arm: element sampling without the ROS preconditioner.
    SparsifiedNoPrecond,
    /// Algorithm 2: Algorithm 1 plus one refinement pass over raw data.
    SparsifiedTwoPass,
    /// Boutsidis et al. random-projection feature extraction baseline.
    FeatureExtraction,
    /// Boutsidis et al. leverage-score feature selection baseline.
    FeatureSelection,
}

impl Algo {
    /// Human-readable name used in experiment tables.
    pub fn name(self) -> &'static str {
        match self {
            Algo::Sparsified => "sparsified",
            Algo::SparsifiedNoPrecond => "sparsified (no precond)",
            Algo::SparsifiedTwoPass => "sparsified (2-pass)",
            Algo::FeatureExtraction => "feature extraction",
            Algo::FeatureSelection => "feature selection",
        }
    }

    /// Every algorithm, in the paper's table order.
    pub const ALL: [Algo; 5] = [
        Algo::Sparsified,
        Algo::SparsifiedNoPrecond,
        Algo::SparsifiedTwoPass,
        Algo::FeatureExtraction,
        Algo::FeatureSelection,
    ];
}

/// One digit-workload measurement.
pub struct AlgoRun {
    /// Clustering accuracy against ground-truth labels.
    pub accuracy: f64,
    /// Wall-clock seconds for the whole run.
    pub seconds: f64,
    /// The fitted clustering.
    pub result: KmeansResult,
}

/// Run one algorithm at compression factor `gamma` on an in-memory
/// labeled dataset. `m` for the feature baselines is `round(γ·p)` so
/// every method keeps the same per-sample budget.
pub fn run_algo(
    algo: Algo,
    d: &Dataset,
    k: usize,
    gamma: f64,
    opts: KmeansOpts,
    seed: u64,
) -> Result<AlgoRun> {
    let p = d.data.rows();
    let t0 = Instant::now();
    let result = match algo {
        Algo::Sparsified | Algo::SparsifiedNoPrecond | Algo::SparsifiedTwoPass => {
            let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
            if algo == Algo::SparsifiedNoPrecond {
                // No preconditioning: sample raw coordinates. Use the DCT
                // config so p is not padded (the transform is never
                // applied on this path) — sampling stays over the true p
                // coordinates, as in the paper's ablation.
                let scfg_np = SparsifyConfig { transform: TransformKind::Dct, ..scfg };
                let sp = crate::sampling::Sparsifier::new(p, scfg_np)?;
                let chunk = sp.compress_chunk_no_precondition(&d.data, 0)?;
                let sk = SparsifiedKmeans::new(scfg_np, k, opts);
                let model =
                    sk.fit_chunks_raw(&sp, &[chunk], &crate::kmeans::NativeAssigner::new(), false)?;
                model.result
            } else {
                let sk = SparsifiedKmeans::new(scfg, k, opts);
                let one = sk.fit_dense(&d.data)?;
                if algo == Algo::SparsifiedTwoPass {
                    two_pass_refine(&d.data, &one)
                } else {
                    one
                }
            }
        }
        Algo::FeatureExtraction => {
            let m = ((gamma * p as f64).round() as usize).clamp(2, p);
            let mut rng = Pcg64::seed(seed);
            let fe = FeatureExtraction::new(p, m, &mut rng);
            fe.fit(&d.data, k, opts)?
        }
        Algo::FeatureSelection => {
            let m = ((gamma * p as f64).round() as usize).clamp(2, p);
            let mut rng = Pcg64::seed(seed);
            let fs = FeatureSelection::new(&d.data, m, k, &mut rng);
            fs.fit(&d.data, k, opts)?
        }
    };
    let seconds = t0.elapsed().as_secs_f64();
    let accuracy = clustering_accuracy(&result.assign, &d.labels, k);
    Ok(AlgoRun { accuracy, seconds, result })
}

/// Standard scaled-vs-full sizing helper.
pub fn scaled(args: &Args, small: usize, full: usize) -> usize {
    if args.flag("full") {
        full
    } else {
        small
    }
}
