//! Table IV — the out-of-core run: raw data lives on disk in the PDS1
//! dense chunk store (paper: 4.9 GB, n = 9.6M, 58 chunks), is compressed
//! **once** into the sharded sparse store (`docs/FORMAT.md`), and every
//! clustering run then streams the compressed shards — the
//! compress-once/analyze-many workflow the paper's §VII.C argues for.
//! Disk-load and compress time are reported separately from the fits.
//!
//! Per γ: one compression pass over the raw store, then the 1-pass and
//! 2-pass K-means arms both fit from the same sparse store (the 2-pass
//! arm adds its one refinement pass over the raw data, Algorithm 2).
//!
//! Scaled default n = 10⁵ (~300 MB f32 on disk); `--full` uses n = 9.6M
//! if the filesystem has room. γ ∈ {0.01, 0.05} as in the paper.

use std::time::Instant;

use crate::cli::Args;
use crate::coordinator::{FitPlan, StoreSource, StreamConfig};
use crate::data::{ChunkStore, ChunkStoreReader, DigitConfig, DigitStream, DIGIT_P};
use crate::error::Result;
use crate::experiments::common::{print_table, scaled};
use crate::kmeans::KmeansOpts;
use crate::metrics::clustering_accuracy;
use crate::sampling::{Scheme, SparsifyConfig};
use crate::store::SparseStoreReader;
use crate::transform::TransformKind;

const K: usize = 3;

/// Run the Table IV experiment (`pds xp table4`).
pub fn run(args: &Args) -> Result<()> {
    let n = scaled(args, args.get_parse("n", 100_000)?, 9_631_605);
    let chunk_cols = args.get_parse("chunk-cols", 16_384)?;
    let n_init = scaled(args, 3, 10);
    let gammas = args.get_list_f64("gammas", &[0.01, 0.05])?;
    let raw_path = std::env::temp_dir().join(format!("pds_table4_{}", std::process::id()));
    let opts = KmeansOpts { n_init, max_iters: 100, tol_frac: 0.0, seed: 0 };

    // stage the raw dataset once (this is the dataset "download", not
    // timed as part of the algorithms)
    println!(
        "Table IV: writing {} samples (p={DIGIT_P}) to {} ({} MB f32)...",
        n,
        raw_path.display(),
        n * DIGIT_P * 4 / (1024 * 1024)
    );
    let stream = DigitStream::new(DigitConfig { seed: 44, ..Default::default() });
    {
        let mut store = ChunkStore::create(&raw_path, DIGIT_P, chunk_cols)?;
        let mut start = 0usize;
        while start < n {
            let cols = (n - start).min(chunk_cols);
            store.append(&stream.chunk(start, cols))?;
            start += cols;
        }
        store.finish()?;
    }
    let labels = stream.labels(0, n);

    let mut rows = Vec::new();
    for (gi, &gamma) in gammas.iter().enumerate() {
        let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 7 };
        let stream_cfg = StreamConfig { workers: 1, queue_depth: 4, chunk_cols, ..Default::default() };

        // compress ONCE per gamma: raw store -> sparse store (1 raw pass)
        let sparse_dir = std::env::temp_dir()
            .join(format!("pds_table4_sparse_{}_{gi}", std::process::id()));
        let _ = std::fs::remove_dir_all(&sparse_dir);
        let mut raw = StoreSource::new(ChunkStoreReader::open(&raw_path)?);
        let t0 = Instant::now();
        let creport = FitPlan::compress()
            .stream(&mut raw, scfg)
            .store_dir(&sparse_dir)
            .shard_cols(chunk_cols)
            .stream_config(stream_cfg)
            .run()?;
        let compress_total = t0.elapsed().as_secs_f64();
        let manifest = creport.store_manifest().expect("compress plan");
        let sparse_mb = manifest.payload_bytes() as f64 / (1024.0 * 1024.0);

        for two_pass in [false, true] {
            // every fit consumes the SAME sparse store — no re-compression
            let mut store = SparseStoreReader::open(&sparse_dir)?;
            let mut raw2;
            let t1 = Instant::now();
            let mut plan = FitPlan::kmeans().store(&mut store).k(K).kmeans_opts(opts);
            if two_pass {
                raw2 = StoreSource::new(ChunkStoreReader::open(&raw_path)?);
                plan = plan.refine_stream(&mut raw2);
            }
            let freport = plan.run()?;
            let assign = match freport.refined() {
                Some(refined) => refined.assign.clone(),
                None => freport.kmeans_model().expect("kmeans plan").result.assign.clone(),
            };
            let fit_total = t1.elapsed().as_secs_f64();
            let acc = clustering_accuracy(&assign, &labels, K);
            rows.push(vec![
                format!("{gamma:.2}"),
                if two_pass { "Sparsified K-means, 2 pass" } else { "Sparsified K-means" }
                    .to_string(),
                format!("{acc:.4}"),
                format!("{}", freport.iterations),
                format!("{:.1}", compress_total + fit_total),
                format!("{:.1}", creport.timer.get("compress")),
                format!(
                    "{:.1}",
                    creport.timer.get("load")
                        + freport.timer.get("load")
                        + freport.timer.get("pass2")
                ),
                format!("{sparse_mb:.0}"),
                // raw passes: 1 compress (+1 refinement for Algorithm 2)
                format!("{}", creport.raw_passes + freport.raw_passes),
            ]);
        }
        std::fs::remove_dir_all(&sparse_dir).ok();

        // scheme-comparison arm (the paper's "related sampling
        // approaches" contrast): compress the same raw data once with the
        // hybrid-(l1,l2) scheme, fit the 1-pass K-means from that store
        let hybrid_dir = std::env::temp_dir()
            .join(format!("pds_table4_hybrid_{}_{gi}", std::process::id()));
        let _ = std::fs::remove_dir_all(&hybrid_dir);
        let mut raw_h = StoreSource::new(ChunkStoreReader::open(&raw_path)?);
        let t2 = Instant::now();
        let hreport = FitPlan::compress()
            .stream(&mut raw_h, scfg)
            .scheme(Scheme::Hybrid)
            .store_dir(&hybrid_dir)
            .shard_cols(chunk_cols)
            .stream_config(stream_cfg)
            .run()?;
        let hybrid_compress = t2.elapsed().as_secs_f64();
        let hmanifest = hreport.store_manifest().expect("compress plan");
        let hybrid_mb = hmanifest.payload_bytes() as f64 / (1024.0 * 1024.0);
        let mut hstore = SparseStoreReader::open(&hybrid_dir)?;
        let t3 = Instant::now();
        let hfit = FitPlan::kmeans().store(&mut hstore).k(K).kmeans_opts(opts).run()?;
        let hfit_total = t3.elapsed().as_secs_f64();
        let hassign = hfit.kmeans_model().expect("kmeans plan").result.assign.clone();
        rows.push(vec![
            format!("{gamma:.2}"),
            "Sparsified K-means, hybrid-(l1,l2)".to_string(),
            format!("{:.4}", clustering_accuracy(&hassign, &labels, K)),
            format!("{}", hfit.iterations),
            format!("{:.1}", hybrid_compress + hfit_total),
            format!("{:.1}", hreport.timer.get("compress")),
            format!("{:.1}", hreport.timer.get("load") + hfit.timer.get("load")),
            format!("{hybrid_mb:.0}"),
            format!("{}", hreport.raw_passes + hfit.raw_passes),
        ]);
        std::fs::remove_dir_all(&hybrid_dir).ok();
    }
    std::fs::remove_file(&raw_path).ok();
    print_table(
        "Table IV: out-of-core runs (compress once, fit from the sparse store)",
        &[
            "gamma",
            "algorithm",
            "accuracy",
            "iters",
            "total s",
            "compress s",
            "disk s",
            "store MB",
            "raw passes",
        ],
        &rows,
    );
    println!(
        "paper shape: disk load significant but not dominant; 1-pass preferred when \
         loads are expensive; 2-pass accuracy ~0.93 already at gamma=0.01. Both arms \
         reuse one compressed store per gamma — the compression pass is paid once. The \
         hybrid-(l1,l2) row is the scheme-comparison arm: same budget, importance-weighted \
         element sampling (Kundu et al.) instead of the preconditioned-uniform operator."
    );
    Ok(())
}
