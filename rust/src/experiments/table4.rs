//! Table IV — the out-of-core run: data lives on disk in the PDS1 chunk
//! store (paper: 4.9 GB, n = 9.6M, 58 chunks), is loaded chunk-by-chunk,
//! compressed, and clustered; disk-load time is reported separately.
//!
//! Scaled default n = 10⁵ (~300 MB f32 on disk); `--full` uses n = 9.6M
//! if the filesystem has room. γ ∈ {0.01, 0.05} as in the paper.

use std::time::Instant;

use crate::cli::Args;
use crate::coordinator::{
    run_sparsified_kmeans_stream, run_two_pass_stream, StoreSource, StreamConfig,
};
use crate::data::{ChunkStore, ChunkStoreReader, DigitConfig, DigitStream, DIGIT_P};
use crate::error::Result;
use crate::experiments::common::{print_table, scaled};
use crate::kmeans::{KmeansOpts, NativeAssigner};
use crate::metrics::clustering_accuracy;
use crate::sampling::SparsifyConfig;
use crate::transform::TransformKind;

const K: usize = 3;

pub fn run(args: &Args) -> Result<()> {
    let n = scaled(args, args.get_parse("n", 100_000)?, 9_631_605);
    let chunk_cols = args.get_parse("chunk-cols", 16_384)?;
    let n_init = scaled(args, 3, 10);
    let gammas = args.get_list_f64("gammas", &[0.01, 0.05])?;
    let path = std::env::temp_dir().join(format!("pds_table4_{}", std::process::id()));
    let opts = KmeansOpts { n_init, max_iters: 100, tol_frac: 0.0, seed: 0 };

    // write the store once (this is the dataset "download", not timed as
    // part of the algorithms)
    println!(
        "Table IV: writing {} samples (p={DIGIT_P}) to {} ({} MB f32)...",
        n,
        path.display(),
        n * DIGIT_P * 4 / (1024 * 1024)
    );
    let stream = DigitStream::new(DigitConfig { seed: 44, ..Default::default() });
    {
        let mut store = ChunkStore::create(&path, DIGIT_P, chunk_cols)?;
        let mut start = 0usize;
        while start < n {
            let cols = (n - start).min(chunk_cols);
            store.append(&stream.chunk(start, cols))?;
            start += cols;
        }
        store.finish()?;
    }
    let labels = stream.labels(0, n);

    let mut rows = Vec::new();
    for &gamma in &gammas {
        let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 7 };
        let stream_cfg = StreamConfig { workers: 1, queue_depth: 4, chunk_cols };
        for two_pass in [false, true] {
            let mut src = StoreSource::new(ChunkStoreReader::open(&path)?);
            let t0 = Instant::now();
            let (assign, report) = if two_pass {
                let (res, rep) =
                    run_two_pass_stream(&mut src, scfg, K, opts, &NativeAssigner, stream_cfg)?;
                (res.assign, rep)
            } else {
                let (model, rep) = run_sparsified_kmeans_stream(
                    &mut src, scfg, K, opts, &NativeAssigner, stream_cfg, true,
                )?;
                (model.result.assign, rep)
            };
            let total = t0.elapsed().as_secs_f64();
            let acc = clustering_accuracy(&assign, &labels, K);
            rows.push(vec![
                format!("{gamma:.2}"),
                if two_pass { "Sparsified K-means, 2 pass" } else { "Sparsified K-means" }
                    .to_string(),
                format!("{acc:.4}"),
                format!("{}", report.iterations),
                format!("{total:.1}"),
                format!("{:.1}", report.timer.get("compress")),
                format!("{:.1}", report.timer.get("load") + report.timer.get("pass2")),
                format!("{}", report.passes),
            ]);
        }
    }
    std::fs::remove_file(&path).ok();
    print_table(
        "Table IV: out-of-core runs",
        &["gamma", "algorithm", "accuracy", "iters", "total s", "compress s", "disk s", "passes"],
        &rows,
    );
    println!(
        "paper shape: disk load significant but not dominant; 1-pass preferred when \
         loads are expensive; 2-pass accuracy ~0.93 already at gamma=0.01"
    );
    Ok(())
}
