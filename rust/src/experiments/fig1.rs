//! Fig. 1 — accuracy of estimated PCs via one-pass methods: uniform
//! column sampling vs precondition+sparsify, on heavy-tailed data.
//!
//! Paper setup: p=512, n=1024, multivariate t (df=1) with Toeplitz
//! covariance `C_ij = 2·0.5^|i−j|`, k=10 PCs, 1000 runs per γ. The
//! headline is not the means (comparable) but the *standard deviations*:
//! column sampling is catastrophically variable, sparsification is not.

use crate::baselines::uniform_column_sampling;
use crate::cli::Args;
use crate::data::multivariate_t;
use crate::error::Result;
use crate::estimators::{CovarianceEstimator, SparseCovOp};
use crate::experiments::common::{pm, print_table, scaled};
use crate::linalg::{sym_eig_topk, Mat};
use crate::metrics::mean_std;
use crate::pca::{explained_variance, Pca, DEFAULT_PCA_ITERS};
use crate::rng::Pcg64;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::transform::TransformKind;

/// Run this experiment (`pds xp fig1`).
pub fn run(args: &Args) -> Result<()> {
    let p: usize = args.get_parse("p", 512)?;
    let n: usize = args.get_parse("n", 1024)?;
    let k: usize = args.get_parse("k", 10)?;
    let runs = scaled(args, args.get_parse("runs", 10)?, 1000);
    let gammas = args.get_list_f64("gammas", &[0.1, 0.2, 0.3, 0.4, 0.5])?;
    println!("Fig 1: p={p} n={n} k={k} runs={runs} (multivariate t, df=1)");

    let mut rows = Vec::new();
    for &gamma in &gammas {
        let mut ev_sparse = Vec::new();
        let mut ev_krylov = Vec::new();
        let mut ev_cols = Vec::new();
        for run in 0..runs {
            let mut rng = Pcg64::seed_stream(777, run as u64);
            let d = multivariate_t(p, n, 1.0, &mut rng);
            // reference covariance of the raw data (the metric's C)
            let c_full = d.data.syrk().scaled(1.0 / n as f64);

            // arm 1: precondition+sparsify -> covariance estimator -> PCs,
            // unmixed back to the original domain
            let scfg =
                SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 1000 + run as u64 };
            let sp = Sparsifier::new(p, scfg)?;
            let chunk = sp.compress_chunk(&d.data, 0)?;
            let mut est = CovarianceEstimator::new(sp.p(), sp.m());
            est.accumulate(&chunk);
            let pca = Pca::from_covariance(&est.estimate(), k, run as u64);
            let components = sp.unmix(&pca.components);
            ev_sparse.push(explained_variance(&components, &c_full));

            // arm 1k: same chunk, covariance-free block-Krylov solver —
            // the same Thm 6 estimate applied implicitly, no p×p matrix,
            // matched iteration budget so the comparison isolates the
            // solver
            let chunks = [chunk];
            let mut op = SparseCovOp::new(&chunks, 1)?;
            let pca_k = Pca::from_sparse_operator(&mut op, k, DEFAULT_PCA_ITERS, run as u64)?;
            let components_k = sp.unmix(&pca_k.components);
            ev_krylov.push(explained_variance(&components_k, &c_full));

            // arm 2: uniform column sampling with matched storage:
            // sparse keeps m·n values; 2γ·n columns keep the same count
            // when n = 2p (paper's setup).
            let cols = ((2.0 * gamma * n as f64).round() as usize).clamp(k + 1, n);
            let sub = uniform_column_sampling(&d.data, cols, &mut rng);
            let c_sub = sub.syrk().scaled(1.0 / cols as f64);
            let (_, u_sub) = sym_eig_topk(&c_sub, k, 30, run as u64);
            let u_sub = Mat::from_vec(p, k, u_sub.as_slice().to_vec())?;
            ev_cols.push(explained_variance(&u_sub, &c_full));
        }
        let (ms, ss) = mean_std(&ev_sparse);
        let (mk, sk) = mean_std(&ev_krylov);
        let (mc, sc) = mean_std(&ev_cols);
        rows.push(vec![
            format!("{gamma:.2}"),
            pm(ms, ss),
            pm(mk, sk),
            pm(mc, sc),
            format!("{:.1}x", sc / ss.max(1e-12)),
        ]);
    }
    print_table(
        "Fig 1: explained variance (mean ± std over runs)",
        &["gamma", "sparsify (cov)", "sparsify (krylov)", "column sampling", "std ratio"],
        &rows,
    );
    println!(
        "paper shape: comparable means, column-sampling std O(10x) larger \
         (0.20-0.31 vs <0.04 at gamma=0.1-0.3); the two sparsify solvers \
         (materialized covariance vs covariance-free krylov) should agree \
         to ~3 decimals"
    );
    Ok(())
}
