//! Fig. 3 — accuracy of the Theorem 6 covariance bound on spiked data:
//! (a) error vs n at fixed γ, (b) error vs γ at fixed n; empirical
//! average/max vs the theoretical t at δ₂ = 0.01 (paper scales its plot
//! by 10; we report the raw ratio instead).
//!
//! Paper setup: p=1000 (scaled default 256), k=5 spikes λ=(10,8,6,4,2),
//! 100 runs.

use crate::cli::Args;
use crate::data::spiked;
use crate::error::Result;
use crate::estimators::{rho_preconditioned, CovBoundInputs, CovarianceEstimator, DataStats};
use crate::experiments::common::{print_table, scaled};
use crate::linalg::spectral_norm_sym;
use crate::metrics::mean_std;
use crate::rng::Pcg64;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::transform::TransformKind;

const LAMBDAS: [f64; 5] = [10.0, 8.0, 6.0, 4.0, 2.0];

struct Obs {
    err: f64,
    bound: f64,
}

fn one_run(p: usize, n: usize, gamma: f64, seed: u64, delta2: f64) -> Result<Obs> {
    let mut rng = Pcg64::seed(seed);
    let d = spiked(p, n, &LAMBDAS, false, &mut rng);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: seed ^ 0xF00 };
    let sp = Sparsifier::new(p, scfg)?;
    let y = sp.precondition_dense(&d.data);
    let cemp = y.syrk().scaled(1.0 / n as f64);
    let chunk = sp.compress_chunk(&d.data, 0)?;
    let mut est = CovarianceEstimator::new(sp.p(), sp.m());
    est.accumulate(&chunk);
    let err = spectral_norm_sym(&est.estimate().sub(&cemp), 1e-8, 1000);
    let mut stats = DataStats::new(sp.p());
    stats.accumulate(&y);
    let inputs = CovBoundInputs {
        p: sp.p(),
        m: sp.m(),
        n,
        rho: rho_preconditioned(sp.m(), sp.p(), n, 1.0, 0.01),
        max_col_norm2: stats.max_col_norm().powi(2),
        max_abs2: stats.max_abs().powi(2),
        frob2: stats.frob2(),
        cov_norm: spectral_norm_sym(&cemp, 1e-8, 1000),
        cov_diag_norm: cemp.diagonal().iter().fold(0.0f64, |a, &b| a.max(b.abs())),
        max_row_pow4: stats.max_row_pow4(),
    };
    Ok(Obs { err, bound: inputs.t_for_delta(delta2) })
}

fn summarize(obs: &[Obs]) -> (f64, f64, f64) {
    let errs: Vec<f64> = obs.iter().map(|o| o.err).collect();
    let (mean, _) = mean_std(&errs);
    let max = errs.iter().cloned().fold(0.0f64, f64::max);
    let bound = obs.iter().map(|o| o.bound).sum::<f64>() / obs.len() as f64;
    (mean, max, bound)
}

/// Run this experiment (`pds xp fig3`).
pub fn run(args: &Args) -> Result<()> {
    let p: usize = scaled(args, args.get_parse("p", 256)?, 1000);
    let runs = scaled(args, args.get_parse("runs", 10)?, 100);
    let delta2 = 0.01;
    println!("Fig 3: p={p} runs={runs} spikes lambda={LAMBDAS:?} delta2={delta2}");

    // (a) vary n at gamma = 0.3
    let mut rows = Vec::new();
    for mult in [2usize, 5, 10, 20] {
        let n = mult * p;
        let obs: Vec<Obs> = (0..runs)
            .map(|r| one_run(p, n, 0.3, 31 * n as u64 + r as u64, delta2))
            .collect::<Result<_>>()?;
        let (mean, max, bound) = summarize(&obs);
        rows.push(vec![
            format!("{n}"),
            format!("{mean:.4}"),
            format!("{max:.4}"),
            format!("{bound:.3}"),
            format!("{:.1}", bound / max.max(1e-12)),
        ]);
    }
    print_table(
        "Fig 3a: cov error vs n (gamma=0.3)",
        &["n", "avg err", "max err", "bound t", "bound/max"],
        &rows,
    );

    // (b) vary gamma at n = 10p
    let n = 10 * p;
    let mut rows = Vec::new();
    for gamma in [0.1, 0.2, 0.3, 0.4, 0.5] {
        let obs: Vec<Obs> = (0..runs)
            .map(|r| one_run(p, n, gamma, 77 * r as u64 + (gamma * 100.0) as u64, delta2))
            .collect::<Result<_>>()?;
        let (mean, max, bound) = summarize(&obs);
        rows.push(vec![
            format!("{gamma:.1}"),
            format!("{mean:.4}"),
            format!("{max:.4}"),
            format!("{bound:.3}"),
            format!("{:.1}", bound / max.max(1e-12)),
        ]);
    }
    print_table(
        "Fig 3b: cov error vs gamma (n=10p)",
        &["gamma", "avg err", "max err", "bound t", "bound/max"],
        &rows,
    );
    println!(
        "paper shape: bound within an order of magnitude (paper plots bound/10), \
         error decreasing in n and in gamma"
    );
    Ok(())
}
