//! Figs. 7 & 8 — the digit benchmark: clustering accuracy (Fig. 7) and
//! wall time (Fig. 8) vs γ for the five algorithms.
//!
//! Paper setup: MNIST digits {0,3,9}, p=784, n=21002, 50 trials, best of
//! 20 starts. Here: synthetic digits (DESIGN.md §2), scaled defaults
//! (n=3000, 3 trials, best of 5 starts), `--full` for paper sizes.

use crate::cli::Args;
use crate::data::{digits, DigitConfig};
use crate::error::Result;
use crate::experiments::common::{pm, print_table, run_algo, scaled, Algo};
use crate::kmeans::{kmeans_dense, KmeansOpts};
use crate::metrics::{clustering_accuracy, mean_std};

struct Grid {
    gammas: Vec<f64>,
    /// acc[gamma][algo] -> (mean, std); time likewise.
    acc: Vec<Vec<(f64, f64)>>,
    time: Vec<Vec<(f64, f64)>>,
    full_acc: f64,
    full_time: f64,
}

fn run_grid(args: &Args) -> Result<Grid> {
    let n = scaled(args, args.get_parse("n", 3000)?, 21_002);
    let trials = scaled(args, args.get_parse("trials", 3)?, 50);
    let n_init = scaled(args, args.get_parse("starts", 5)?, 20);
    let gammas = args.get_list_f64("gammas", &[0.01, 0.02, 0.05, 0.1, 0.2, 0.3])?;
    let k = 3;
    println!("Figs 7/8: digits n={n} trials={trials} starts={n_init} K={k}");
    let d = digits(n, DigitConfig::default());
    let opts = KmeansOpts { n_init, max_iters: 100, tol_frac: 0.0, seed: 0 };

    // full-data reference (standard K-means)
    let t0 = std::time::Instant::now();
    let full = kmeans_dense(&d.data, k, KmeansOpts { n_init: n_init.min(5), ..opts });
    let full_time = t0.elapsed().as_secs_f64();
    let full_acc = clustering_accuracy(&full.assign, &d.labels, k);

    let mut acc = Vec::new();
    let mut time = Vec::new();
    for &gamma in &gammas {
        let mut acc_row = Vec::new();
        let mut time_row = Vec::new();
        for algo in Algo::ALL {
            let mut accs = Vec::new();
            let mut times = Vec::new();
            for trial in 0..trials {
                let run = run_algo(
                    algo,
                    &d,
                    k,
                    gamma,
                    KmeansOpts { seed: trial as u64, ..opts },
                    4242 + trial as u64,
                )?;
                accs.push(run.accuracy);
                times.push(run.seconds);
            }
            acc_row.push(mean_std(&accs));
            time_row.push(mean_std(&times));
        }
        acc.push(acc_row);
        time.push(time_row);
    }
    Ok(Grid { gammas, acc, time, full_acc, full_time })
}

/// Run the Fig. 7 experiment (`pds xp fig7`).
pub fn run_fig7(args: &Args) -> Result<()> {
    let g = run_grid(args)?;
    let mut rows = Vec::new();
    for (gi, &gamma) in g.gammas.iter().enumerate() {
        let mut row = vec![format!("{gamma:.3}")];
        for (ai, _) in Algo::ALL.iter().enumerate() {
            let (m, s) = g.acc[gi][ai];
            row.push(pm(m, s));
        }
        rows.push(row);
    }
    let header: Vec<&str> = std::iter::once("gamma")
        .chain(Algo::ALL.iter().map(|a| a.name()))
        .collect();
    print_table("Fig 7: clustering accuracy vs gamma (digits)", &header, &rows);
    println!("standard K-means reference accuracy: {:.4}", g.full_acc);
    println!(
        "paper shape: sparsified >= feature extraction > feature selection ~ no-precond; \
         2-pass reaches the full-data accuracy; feature-based stds much larger"
    );
    Ok(())
}

/// Run the Fig. 8 experiment (`pds xp fig8`).
pub fn run_fig8(args: &Args) -> Result<()> {
    let g = run_grid(args)?;
    let mut rows = Vec::new();
    for (gi, &gamma) in g.gammas.iter().enumerate() {
        let mut row = vec![format!("{gamma:.3}")];
        for (ai, _) in Algo::ALL.iter().enumerate() {
            row.push(format!("{:.2}", g.time[gi][ai].0));
        }
        row.push(format!("{:.2}", g.full_time)); // full-data reference
        rows.push(row);
    }
    let header: Vec<&str> = std::iter::once("gamma")
        .chain(Algo::ALL.iter().map(|a| a.name()))
        .chain(std::iter::once("full kmeans"))
        .collect();
    print_table("Fig 8: clustering time (s) vs gamma (digits)", &header, &rows);
    println!("paper shape: times ~ proportional to gamma until fixed costs dominate (~5%)");
    Ok(())
}
