//! Fig. 5 — tightness of the Theorem 7 bound on `‖H_k − I‖₂` vs n.
//!
//! Paper setup: p=100, γ=0.3, 1000 runs per n, δ₃ = 0.001.

use crate::cli::Args;
use crate::error::Result;
use crate::estimators::HkAccumulator;
use crate::experiments::common::{print_table, scaled};
use crate::metrics::mean_std;
use crate::rng::Pcg64;
use crate::sampling::sample_indices;

/// Run this experiment (`pds xp fig5`).
pub fn run(args: &Args) -> Result<()> {
    let p: usize = args.get_parse("p", 100)?;
    let gamma: f64 = args.get_parse("gamma", 0.3)?;
    let runs = scaled(args, args.get_parse("runs", 200)?, 1000);
    let m = ((gamma * p as f64).round() as usize).max(2);
    let delta3 = 1e-3;
    println!("Fig 5: p={p} m={m} runs={runs} delta3={delta3}");

    let mut rows = Vec::new();
    for n in [100usize, 300, 1000, 3000, 10_000] {
        let mut devs = Vec::new();
        for run in 0..runs {
            let mut rng = Pcg64::seed_stream(4040, (n * 31 + run) as u64);
            // direct mask simulation — H_k depends only on the masks
            let mut counts = vec![0u64; p];
            let mut idx = vec![0u32; m];
            let mut perm = vec![0u32; p];
            for _ in 0..n {
                sample_indices(&mut rng, p, &mut idx, &mut perm);
                for &j in &idx {
                    counts[j as usize] += 1;
                }
            }
            let scale = p as f64 / (m as f64 * n as f64);
            let dev = counts
                .iter()
                .map(|&c| (c as f64 * scale - 1.0).abs())
                .fold(0.0f64, f64::max);
            devs.push(dev);
        }
        let (mean, _) = mean_std(&devs);
        let max = devs.iter().cloned().fold(0.0f64, f64::max);
        let bound = HkAccumulator::t_for_delta(p, m, n, delta3);
        rows.push(vec![
            format!("{n}"),
            format!("{mean:.4}"),
            format!("{max:.4}"),
            format!("{bound:.4}"),
            format!("{:.2}", bound / max.max(1e-12)),
        ]);
    }
    print_table(
        "Fig 5: ||H_k - I||_2 vs Theorem 7 bound",
        &["n", "avg dev", "max dev", "bound t", "bound/max"],
        &rows,
    );
    println!("paper shape: bound tight (close to max of runs), ~1/sqrt(n) decay");
    Ok(())
}
