//! Fig. 6 — standard vs sparsified K-means on well-separated synthetic
//! blobs: same clustering quality, ~γ⁻¹ speedup.
//!
//! Paper setup: p=512, n=1e5, K=5, Hadamard + 5% sampling (67× observed
//! on their 16-core box; single-core ratios are smaller but the ~1/γ
//! scaling shape is the claim).

use std::time::Instant;

use crate::cli::Args;
use crate::data::gaussian_blobs;
use crate::error::Result;
use crate::experiments::common::{print_table, scaled};
use crate::kmeans::{kmeans_dense, KmeansOpts, SparsifiedKmeans};
use crate::metrics::clustering_accuracy;
use crate::rng::Pcg64;
use crate::sampling::SparsifyConfig;
use crate::transform::TransformKind;

/// Run this experiment (`pds xp fig6`).
pub fn run(args: &Args) -> Result<()> {
    let p: usize = args.get_parse("p", 512)?;
    let n = scaled(args, args.get_parse("n", 20_000)?, 100_000);
    let k: usize = args.get_parse("k", 5)?;
    let gamma: f64 = args.get_parse("gamma", 0.05)?;
    println!("Fig 6: p={p} n={n} K={k} gamma={gamma}");
    let mut rng = Pcg64::seed(606);
    let d = gaussian_blobs(p, n, k, 0.05, &mut rng);
    let opts = KmeansOpts { n_init: 3, max_iters: 100, tol_frac: 0.0, seed: 1 };

    let t0 = Instant::now();
    let full = kmeans_dense(&d.data, k, opts);
    let t_full = t0.elapsed().as_secs_f64();
    let acc_full = clustering_accuracy(&full.assign, &d.labels, k);

    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed: 2 };
    let t0 = Instant::now();
    let sk = SparsifiedKmeans::new(scfg, k, opts);
    let sparse = sk.fit_dense(&d.data)?;
    let t_sparse = t0.elapsed().as_secs_f64();
    let acc_sparse = clustering_accuracy(&sparse.assign, &d.labels, k);

    print_table(
        "Fig 6: standard vs sparsified K-means",
        &["algorithm", "accuracy", "time (s)", "iterations", "speedup"],
        &[
            vec![
                "standard K-means".into(),
                format!("{acc_full:.4}"),
                format!("{t_full:.2}"),
                format!("{}", full.iterations),
                "1.0x".into(),
            ],
            vec![
                format!("sparsified (gamma={gamma})"),
                format!("{acc_sparse:.4}"),
                format!("{t_sparse:.2}"),
                format!("{}", sparse.iterations),
                format!("{:.1}x", t_full / t_sparse.max(1e-9)),
            ],
        ],
    );
    println!("paper shape: no quality loss, speedup ~1/gamma (67x at their scale/cores)");
    Ok(())
}
