//! Fig. 2 — sharpness of the Theorem 4 mean-estimator bound: average and
//! max ℓ∞ error over runs vs the theoretical t at δ₁ = 0.001.
//!
//! Paper setup: p=100, γ=0.3, x_i = x̄ + N(0, I), 1000 runs per n.

use crate::cli::Args;
use crate::error::Result;
use crate::estimators::{MeanBoundInputs, SparseMeanEstimator};
use crate::experiments::common::{print_table, scaled};
use crate::linalg::Mat;
use crate::metrics::mean_std;
use crate::rng::Pcg64;
use crate::sampling::{Sparsifier, SparsifyConfig};
use crate::transform::TransformKind;

/// Run this experiment (`pds xp fig2`).
pub fn run(args: &Args) -> Result<()> {
    let p: usize = args.get_parse("p", 100)?;
    let gamma: f64 = args.get_parse("gamma", 0.3)?;
    let runs = scaled(args, args.get_parse("runs", 100)?, 1000);
    let ns: Vec<usize> = args
        .get_list_f64("ns", &[500.0, 1000.0, 2000.0, 5000.0, 10000.0])?
        .into_iter()
        .map(|v| v as usize)
        .collect();
    let delta1 = 1e-3;
    println!("Fig 2: p={p} gamma={gamma} runs={runs} delta1={delta1}");

    // fixed mean, fresh noise per run (paper's generative model)
    let mut base_rng = Pcg64::seed(42);
    let xbar: Vec<f64> = (0..p).map(|_| base_rng.normal()).collect();

    let mut rows = Vec::new();
    for &n in &ns {
        let mut errs = Vec::new();
        let mut bound = 0.0f64;
        for run in 0..runs {
            let mut rng = Pcg64::seed_stream(9000, (n * 131 + run) as u64);
            let x = Mat::from_fn(p, n, |i, _| xbar[i] + rng.normal());
            let scfg = SparsifyConfig {
                gamma,
                transform: TransformKind::Hadamard,
                seed: (n * 7 + run) as u64,
            };
            let sp = Sparsifier::new(p, scfg)?;
            let y = sp.precondition_dense(&x);
            let chunk = sp.compress_chunk(&x, 0)?;
            let mut est = SparseMeanEstimator::new(sp.p(), sp.m());
            est.accumulate(&chunk);
            let got = est.estimate();
            let truth = y.col_mean();
            let err = got
                .iter()
                .zip(&truth)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            errs.push(err);
            if run == 0 {
                // bound from the actual preconditioned-data norms
                let inputs = MeanBoundInputs {
                    max_abs: y.max_abs(),
                    max_row_norm: y.max_row_norm(),
                    n,
                    p: sp.p(),
                    m: sp.m(),
                };
                bound = inputs.t_for_delta(delta1);
            }
        }
        let (mean, _) = mean_std(&errs);
        let max = errs.iter().cloned().fold(0.0f64, f64::max);
        rows.push(vec![
            format!("{n}"),
            format!("{mean:.5}"),
            format!("{max:.5}"),
            format!("{bound:.5}"),
            format!("{:.2}", bound / max.max(1e-12)),
        ]);
    }
    print_table(
        "Fig 2: l-inf mean estimation error vs Theorem 4 bound",
        &["n", "avg err", "max err", "bound t", "bound/max"],
        &rows,
    );
    println!("paper shape: bound tight (close to max of runs), decays ~1/sqrt(n)");
    Ok(())
}
