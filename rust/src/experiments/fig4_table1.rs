//! Fig. 4 + Table I — the preconditioning ablation on adversarial data
//! (canonical-basis principal components, λ = 10..1):
//! Fig. 4 compares covariance estimation error with vs without the ROS;
//! Table I counts recovered PCs (|⟨û, u⟩| ≥ 0.95) for both arms.
//!
//! Paper setup: p=512, n=1024, k=10, 100 runs. `--dct` switches the ROS
//! to DCT-II (η = 1/2) — the η-ablation called out in DESIGN.md.

use crate::cli::Args;
use crate::coordinator::{FitPlan, Solver};
use crate::data::spiked;
use crate::error::Result;
use crate::estimators::{rho_preconditioned, CovBoundInputs, CovarianceEstimator, DataStats};
use crate::experiments::common::{pm, print_table, scaled};
use crate::linalg::{spectral_norm_sym, Mat};
use crate::metrics::mean_std;
use crate::pca::{recovered_components, Pca};
use crate::rng::Pcg64;
use crate::sampling::{Scheme, Sparsifier, SparsifyConfig};
use crate::sparse::SparseVecSource;
use crate::transform::TransformKind;

const K: usize = 10;

fn lambdas() -> Vec<f64> {
    (1..=10).rev().map(|v| v as f64).collect()
}

struct ArmResult {
    err: f64,
    bound: f64,
    recovered: usize,
    /// Same metric via the covariance-free block-Krylov solver (no p×p
    /// materialization) — Table I is produced by both solvers. `0` when
    /// the krylov arm was not requested (Fig. 4 only needs the errors).
    recovered_krylov: usize,
}

/// One run of one arm. The [`Scheme`] selects the sampling law:
/// `Precond` is the paper's operator, `Uniform` the no-ROS ablation, and
/// `Hybrid` the Kundu et al. comparison scheme (weighted estimator
/// calibration; the Thm 6 bound does not apply, so `bound` is NaN).
/// `with_krylov` additionally solves via the covariance-free path
/// (Table I's second solver — skipped for Fig. 4, which discards it).
fn one_arm(
    p: usize,
    n: usize,
    gamma: f64,
    seed: u64,
    scheme: Scheme,
    kind: TransformKind,
    with_krylov: bool,
) -> Result<ArmResult> {
    let mut rng = Pcg64::seed(seed);
    let d = spiked(p, n, &lambdas(), true, &mut rng);
    let precondition = scheme.preconditions();
    // For the raw-domain arms the reference C_emp is of the data itself;
    // for the precond arm it is of Y = HDX (paper Section V).
    let scfg = SparsifyConfig { gamma, transform: kind, seed: seed ^ 0xAB };
    let sp = Sparsifier::with_scheme(p, scfg, scheme)?;
    let chunk = sp.compress_chunk(&d.data, 0)?;
    let reference = if precondition { sp.precondition_dense(&d.data) } else { d.data.clone() };
    let cemp = reference.syrk().scaled(1.0 / n as f64);
    let mut est = if sp.weighted() {
        CovarianceEstimator::new_weighted(sp.p(), sp.m())
    } else {
        CovarianceEstimator::new(sp.p(), sp.m())
    };
    est.accumulate(&chunk);
    let chat = est.estimate();
    let err = spectral_norm_sym(&chat.sub(&cemp), 1e-8, 1000);

    // the Thm 6 concentration bound is derived for the uniform schemes
    // only; the hybrid arm reports NaN (printed as "n/a")
    let bound = if sp.weighted() {
        f64::NAN
    } else {
        let mut stats = DataStats::new(sp.p());
        stats.accumulate(&reference);
        let rho = if precondition {
            rho_preconditioned(sp.m(), sp.p(), n, kind.eta(), 0.01)
        } else {
            1.0
        };
        let inputs = CovBoundInputs {
            p: sp.p(),
            m: sp.m(),
            n,
            rho,
            max_col_norm2: stats.max_col_norm().powi(2),
            max_abs2: stats.max_abs().powi(2),
            frob2: stats.frob2(),
            cov_norm: spectral_norm_sym(&cemp, 1e-8, 1000),
            cov_diag_norm: cemp.diagonal().iter().fold(0.0f64, |a, &b| a.max(b.abs())),
            max_row_pow4: stats.max_row_pow4(),
        };
        inputs.t_for_delta(0.01)
    };

    // recovered PCs: eig of the estimate, unmixed when preconditioned
    let pca = Pca::from_covariance(&chat, K, seed);
    let comps: Mat = if precondition { sp.unmix(&pca.components) } else { pca.components };
    let recovered = recovered_components(&comps, &d.centers, 0.95);

    // krylov arm: the same estimate applied implicitly via the session
    // API (matched iteration budget — DEFAULT_KRYLOV_ITERS ==
    // DEFAULT_PCA_ITERS); unmix/truncate + weighted calibration handled
    // by the plan (the sparsifier carries the scheme)
    let recovered_krylov = if with_krylov {
        let mut src = SparseVecSource::new(vec![chunk])?;
        let report = FitPlan::pca()
            .source(&mut src, &sp, precondition)
            .topk(K)
            .solver(Solver::Krylov)
            .run()?;
        let fit = report.pca_fit().expect("pca plan");
        recovered_components(&fit.pca.components, &d.centers, 0.95)
    } else {
        0
    };

    Ok(ArmResult { err, bound, recovered, recovered_krylov })
}

fn gather(
    p: usize,
    n: usize,
    gamma: f64,
    runs: usize,
    scheme: Scheme,
    kind: TransformKind,
    with_krylov: bool,
) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, Vec<f64>)> {
    let mut errs = Vec::new();
    let mut bounds = Vec::new();
    let mut recs = Vec::new();
    let mut recs_krylov = Vec::new();
    for r in 0..runs {
        let arm = one_arm(
            p,
            n,
            gamma,
            1000 * (gamma * 100.0) as u64 + r as u64,
            scheme,
            kind,
            with_krylov,
        )?;
        errs.push(arm.err);
        bounds.push(arm.bound);
        recs.push(arm.recovered as f64);
        recs_krylov.push(arm.recovered_krylov as f64);
    }
    Ok((errs, bounds, recs, recs_krylov))
}

fn kind_of(args: &Args) -> TransformKind {
    if args.flag("dct") {
        TransformKind::Dct
    } else {
        TransformKind::Hadamard
    }
}

/// Run the Fig. 4 experiment (`pds xp fig4`).
pub fn run_fig4(args: &Args) -> Result<()> {
    let p: usize = args.get_parse("p", 512)?;
    let n: usize = args.get_parse("n", 1024)?;
    let runs = scaled(args, args.get_parse("runs", 10)?, 100);
    let kind = kind_of(args);
    println!("Fig 4: p={p} n={n} runs={runs} transform={kind:?} (canonical-basis PCs)");
    let mut rows = Vec::new();
    for gamma in [0.1, 0.2, 0.3, 0.4, 0.5] {
        let (e_no, b_no, _, _) = gather(p, n, gamma, runs, Scheme::Uniform, kind, false)?;
        let (e_pc, b_pc, _, _) = gather(p, n, gamma, runs, Scheme::Precond, kind, false)?;
        let (e_hy, _, _, _) = gather(p, n, gamma, runs, Scheme::Hybrid, kind, false)?;
        let (m_no, _) = mean_std(&e_no);
        let (m_pc, _) = mean_std(&e_pc);
        let (m_hy, _) = mean_std(&e_hy);
        rows.push(vec![
            format!("{gamma:.1}"),
            format!("{m_no:.4}"),
            format!("{m_pc:.4}"),
            format!("{m_hy:.4}"),
            format!("{:.2}x", m_no / m_pc.max(1e-12)),
            format!("{:.2}", b_no.iter().sum::<f64>() / runs as f64),
            format!("{:.2}", b_pc.iter().sum::<f64>() / runs as f64),
        ]);
    }
    print_table(
        "Fig 4: covariance estimation error — uniform (no HD) vs preconditioned vs \
         hybrid-(l1,l2)",
        &["gamma", "err (no HD)", "err (HD)", "err (hybrid)", "gain", "bound (no HD)", "bound (HD)"],
        &rows,
    );
    println!(
        "paper shape: preconditioning reduces error ~2x, in both empirical and bound; the \
         hybrid-(l1,l2) arm (Kundu et al.) is the \"related sampling approaches\" contrast — \
         unbiased via the weighted calibration, but without the Thm 6 bound"
    );
    Ok(())
}

/// Run the Table I experiment (`pds xp table1`).
pub fn run_table1(args: &Args) -> Result<()> {
    let p: usize = args.get_parse("p", 512)?;
    let n: usize = args.get_parse("n", 1024)?;
    let runs = scaled(args, args.get_parse("runs", 10)?, 100);
    let kind = kind_of(args);
    println!("Table I: p={p} n={n} runs={runs} k={K} threshold 0.95");
    let mut rows = Vec::new();
    for gamma in [0.1, 0.2, 0.3, 0.4, 0.5] {
        let (_, _, r_no, rk_no) = gather(p, n, gamma, runs, Scheme::Uniform, kind, true)?;
        let (_, _, r_pc, rk_pc) = gather(p, n, gamma, runs, Scheme::Precond, kind, true)?;
        let (_, _, r_hy, rk_hy) = gather(p, n, gamma, runs, Scheme::Hybrid, kind, true)?;
        let (mn, sn) = mean_std(&r_no);
        let (mp, spd) = mean_std(&r_pc);
        let (mh, sh) = mean_std(&r_hy);
        let (mkn, skn) = mean_std(&rk_no);
        let (mkp, skp) = mean_std(&rk_pc);
        let (mkh, skh) = mean_std(&rk_hy);
        rows.push(vec![
            format!("{gamma:.1}"),
            pm(mn, sn),
            pm(mp, spd),
            pm(mh, sh),
            pm(mkn, skn),
            pm(mkp, skp),
            pm(mkh, skh),
        ]);
    }
    print_table(
        "Table I: number of recovered PCs (of 10), per scheme, covariance vs krylov solver",
        &[
            "gamma",
            "uniform (cov)",
            "precond (cov)",
            "hybrid (cov)",
            "uniform (kry)",
            "precond (kry)",
            "hybrid (kry)",
        ],
        &rows,
    );
    println!(
        "paper: 0.98/3.53/6.85/8.18/9.31 (no HD) vs 5.12/7.01/8.00/8.42/9.00 (HD), \
         HD std much smaller; the krylov columns apply the same estimate without \
         materializing it and should match the cov columns closely. The hybrid columns \
         reproduce the \"related approaches\" contrast: importance weights help on spiky \
         data but lack the preconditioned scheme's distribution-free guarantees"
    );
    Ok(())
}
