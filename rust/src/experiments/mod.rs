//! The experiment harness: one module per table/figure of the paper's
//! evaluation, each regenerating the corresponding rows/series.
//!
//! Every experiment accepts `--runs`, `--full` (paper-scale sizes; the
//! defaults are scaled for a single-core CI box and preserve the paper's
//! qualitative shape), and experiment-specific knobs. Invoke via
//! `pds xp <id>` or the matching `cargo bench` target.

pub mod common;
pub mod fig1;
pub mod fig10_table3;
pub mod fig2;
pub mod fig3;
pub mod fig4_table1;
pub mod fig5;
pub mod fig6;
pub mod fig7_8;
pub mod fig9;
pub mod table2;
pub mod table4;
pub mod table5;

use crate::cli::Args;
use crate::error::{invalid, Result};

/// All experiment ids with one-line descriptions.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("fig1", "explained variance: precond+sparsify vs uniform column sampling (mv-t data)"),
    ("fig2", "sample-mean estimator error vs n + Theorem 4 bound"),
    ("fig3", "covariance estimator error vs n and vs gamma + Theorem 6 bound"),
    ("fig4", "preconditioning effect on covariance error vs gamma"),
    ("table1", "recovered principal components with/without preconditioning"),
    ("fig5", "||H_k - I||_2 vs n + Theorem 7 bound"),
    ("fig6", "standard vs sparsified K-means speedup on synthetic blobs"),
    ("fig7", "clustering accuracy vs gamma, 5 algorithms, digit data"),
    ("fig8", "clustering time vs gamma, digit data"),
    ("fig9", "one-pass center estimate quality (RMSE) per algorithm"),
    ("fig10", "big-data accuracy vs gamma (streaming digits)"),
    ("table2", "passes over the data per algorithm (analytic)"),
    ("table3", "timing breakdown at gamma=0.05 (streaming digits)"),
    ("table4", "out-of-core run: accuracy + timing incl. disk loads"),
    ("table5", "per-iteration assignment/update speedup, full vs sparsified"),
];

/// Dispatch an experiment by id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "fig1" => fig1::run(args),
        "fig2" => fig2::run(args),
        "fig3" => fig3::run(args),
        "fig4" => fig4_table1::run_fig4(args),
        "table1" => fig4_table1::run_table1(args),
        "fig5" => fig5::run(args),
        "fig6" => fig6::run(args),
        "fig7" => fig7_8::run_fig7(args),
        "fig8" => fig7_8::run_fig8(args),
        "fig9" => fig9::run(args),
        "fig10" => fig10_table3::run_fig10(args),
        "table2" => table2::run(args),
        "table3" => fig10_table3::run_table3(args),
        "table4" => table4::run(args),
        "table5" => table5::run(args),
        "all" => {
            for (id, _) in EXPERIMENTS {
                println!("\n##### pds xp {id} #####");
                run(id, args)?;
            }
            Ok(())
        }
        other => invalid(format!("unknown experiment {other:?}; see `pds xp list`")),
    }
}
