//! `forall`-style randomized property tests with deterministic replay.

use crate::rng::Pcg64;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Pcg64,
    /// Case index (0-based) — stable identifier for replaying a failure.
    pub case: usize,
}

impl Gen {
    /// Integer uniform in `[lo, hi]` (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.rng.next_u64() % span) as i64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn float(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Standard normal draw.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Bernoulli(prob).
    pub fn bool(&mut self, prob: f64) -> bool {
        self.rng.next_f64() < prob
    }

    /// A fresh RNG derived from this case (for code that needs its own).
    pub fn rng(&mut self) -> Pcg64 {
        Pcg64::seed(self.rng.next_u64())
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.rng.next_u64() % items.len() as u64) as usize]
    }
}

/// Seed for the whole property-test run; override with `PDS_PROP_SEED` to
/// replay a failing run.
fn root_seed() -> u64 {
    std::env::var("PDS_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xDEFA_17)
}

/// Case-count override for the whole property-test run: `PDS_PROP_CASES`
/// replaces every `forall` call's `cases` argument (the CI property job
/// sets it high; local runs keep the in-tree defaults). Zero or
/// non-numeric values are ignored.
fn case_override() -> Option<usize> {
    std::env::var("PDS_PROP_CASES")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .filter(|&n: &usize| n > 0)
}

/// Run `body` over `cases` generated inputs (or `PDS_PROP_CASES` inputs
/// when that env var is set — every suite is case-count tunable without
/// touching call sites). Panics propagate with a header identifying the
/// property, case index and root seed.
pub fn forall(name: &str, cases: usize, mut body: impl FnMut(&mut Gen)) {
    let root = root_seed();
    let cases = case_override().unwrap_or(cases);
    for case in 0..cases {
        let rng = Pcg64::seed_stream(root, case as u64 ^ 0xF0F0);
        let mut g = Gen { rng, case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(err) = result {
            eprintln!(
                "property `{name}` failed at case {case}/{cases} (PDS_PROP_SEED={root}): rerun \
                 with that env var to replay"
            );
            std::panic::resume_unwind(err);
        }
    }
}

/// Generic merge-law checker for mergeable partial-fit states. Given a
/// pool of `items` (each one partial's worth of accumulated state), it
/// asserts the three laws every distributed fold relies on:
///
/// 1. **Identity element**: merging `identity()` into any item (and any
///    item into a fresh identity) leaves the fold result unchanged.
/// 2. **Order invariance**: merging the items in several seeded
///    permutations produces equal results.
/// 3. **Partition invariance**: pre-merging random contiguous chunkings
///    of the item list, then merging the chunk results, equals the flat
///    merge.
///
/// `merge` folds its second argument into the first (the checked
/// `PartialFit::merge` shape — a failed merge is a panic here, since the
/// pool is constructed mergeable). `eq` decides result equality: pass a
/// bitwise comparison for exact folds (per-shard maps, integer counts)
/// and a tolerance for float-direct accumulators, where permuting ≥ 3
/// items legitimately re-associates the sums.
///
/// The laws are exercised under [`forall`], so the permutations and
/// chunkings are seeded, replayable, and case-count tunable via
/// `PDS_PROP_CASES`.
pub fn assert_mergeable<T: Clone>(
    name: &str,
    items: &[T],
    identity: impl Fn() -> T,
    merge: impl Fn(&mut T, &T),
    eq: impl Fn(&T, &T) -> bool,
) {
    assert!(!items.is_empty(), "assert_mergeable({name}): need at least one item");
    let fold = |order: &[usize]| -> T {
        let mut acc = identity();
        for &i in order {
            merge(&mut acc, &items[i]);
        }
        acc
    };
    let reference = fold(&(0..items.len()).collect::<Vec<_>>());

    // law 1: identity element on both sides
    let mut left = identity();
    merge(&mut left, &reference);
    assert!(eq(&left, &reference), "assert_mergeable({name}): identity ⊕ x != x");
    let mut right = reference.clone();
    merge(&mut right, &identity());
    assert!(eq(&right, &reference), "assert_mergeable({name}): x ⊕ identity != x");

    forall(name, 12, |g| {
        // law 2: order invariance across a seeded permutation
        let mut order: Vec<usize> = (0..items.len()).collect();
        for i in (1..order.len()).rev() {
            let j = g.int(0, i as i64) as usize;
            order.swap(i, j);
        }
        let permuted = fold(&order);
        assert!(
            eq(&permuted, &reference),
            "assert_mergeable({name}): merge order {order:?} changed the result"
        );

        // law 3: partition invariance across a random contiguous chunking
        // (pre-merge each chunk, then merge the chunk results)
        let mut bounds = vec![0usize];
        while *bounds.last().unwrap() < items.len() {
            let lo = *bounds.last().unwrap();
            bounds.push(g.int(lo as i64 + 1, items.len() as i64) as usize);
        }
        let mut acc = identity();
        for w in bounds.windows(2) {
            let mut part = identity();
            for i in w[0]..w[1] {
                merge(&mut part, &items[i]);
            }
            merge(&mut acc, &part);
        }
        assert!(
            eq(&acc, &reference),
            "assert_mergeable({name}): partition {bounds:?} changed the result"
        );
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_bounds() {
        forall("gen_bounds", 100, |g| {
            let v = g.int(-5, 5);
            assert!((-5..=5).contains(&v));
            let f = g.float(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
            let c = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&c));
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        forall("det_a", 5, |g| first.push(g.int(0, 1000)));
        let mut second = Vec::new();
        forall("det_b", 5, |g| second.push(g.int(0, 1000)));
        assert_eq!(first, second);
    }

    #[test]
    fn mergeable_accepts_a_lawful_monoid() {
        // (Vec of u64 counters, element-wise +) is exactly mergeable
        let items: Vec<Vec<u64>> =
            (0..6).map(|i| vec![i as u64, 10 + i as u64, 100 * i as u64]).collect();
        assert_mergeable(
            "counter_monoid",
            &items,
            || vec![0u64; 3],
            |a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
            },
            |a, b| a == b,
        );
    }

    #[test]
    fn mergeable_rejects_an_order_dependent_merge() {
        // "keep the last seen" is not commutative — the checker must
        // catch it on some permutation
        let items: Vec<i64> = vec![1, 2, 3, 4];
        let result = std::panic::catch_unwind(|| {
            assert_mergeable(
                "last_wins",
                &items,
                || 0i64,
                |a, b| {
                    if *b != 0 {
                        *a = *b;
                    }
                },
                |a, b| a == b,
            );
        });
        assert!(result.is_err(), "order-dependent merge must be rejected");
    }

    #[test]
    fn mergeable_rejects_a_missing_identity() {
        // a nonzero "identity" breaks law 1
        let items: Vec<i64> = vec![5, 7];
        let result = std::panic::catch_unwind(|| {
            assert_mergeable("bad_identity", &items, || 1i64, |a, b| *a += *b, |a, b| a == b);
        });
        assert!(result.is_err(), "non-neutral identity must be rejected");
    }
}
