//! `forall`-style randomized property tests with deterministic replay.

use crate::rng::Pcg64;

/// Per-case generator handed to the property closure.
pub struct Gen {
    rng: Pcg64,
    /// Case index (0-based) — stable identifier for replaying a failure.
    pub case: usize,
}

impl Gen {
    /// Integer uniform in `[lo, hi]` (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.rng.next_u64() % span) as i64
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn float(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Standard normal draw.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Bernoulli(prob).
    pub fn bool(&mut self, prob: f64) -> bool {
        self.rng.next_f64() < prob
    }

    /// A fresh RNG derived from this case (for code that needs its own).
    pub fn rng(&mut self) -> Pcg64 {
        Pcg64::seed(self.rng.next_u64())
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[(self.rng.next_u64() % items.len() as u64) as usize]
    }
}

/// Seed for the whole property-test run; override with `PDS_PROP_SEED` to
/// replay a failing run.
fn root_seed() -> u64 {
    std::env::var("PDS_PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xDEFA_17)
}

/// Run `body` over `cases` generated inputs. Panics propagate with a
/// header identifying the property, case index and root seed.
pub fn forall(name: &str, cases: usize, mut body: impl FnMut(&mut Gen)) {
    let root = root_seed();
    for case in 0..cases {
        let rng = Pcg64::seed_stream(root, case as u64 ^ 0xF0F0);
        let mut g = Gen { rng, case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(err) = result {
            eprintln!(
                "property `{name}` failed at case {case}/{cases} (PDS_PROP_SEED={root}): rerun \
                 with that env var to replay"
            );
            std::panic::resume_unwind(err);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_bounds() {
        forall("gen_bounds", 100, |g| {
            let v = g.int(-5, 5);
            assert!((-5..=5).contains(&v));
            let f = g.float(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
            let c = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&c));
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut first = Vec::new();
        forall("det_a", 5, |g| first.push(g.int(0, 1000)));
        let mut second = Vec::new();
        forall("det_b", 5, |g| second.push(g.int(0, 1000)));
        assert_eq!(first, second);
    }
}
