//! In-tree test support (proptest et al. are unavailable in this offline
//! build).
//!
//! * [`prop`] — `forall`-style randomized property tests: a closure runs
//!   over `n` generated cases from a seeded [`prop::Gen`]; on panic it
//!   reports the case number and seed so the failure replays
//!   deterministically.
//! * [`fixtures`] — the seeded matrix / chunk generators shared by the
//!   inline `mod tests` blocks (one definition instead of a copy per
//!   file).

pub mod fixtures;
pub mod prop;
