//! In-tree property-testing mini-harness (proptest is unavailable in this
//! offline build). `prop::forall` runs a closure over `n` generated cases
//! from a seeded [`prop::Gen`]; on panic it reports the case number and
//! seed so the failure replays deterministically.

pub mod prop;
