//! Seeded data generators shared by the inline `mod tests` blocks.
//!
//! Before this module every test file rolled its own `randmat` /
//! `spiked_data` / `spiked_cov` helper; the generators here are those
//! helpers, hoisted verbatim so migrated tests see **identical bytes**
//! for the same `(shape, seed)` — assertions calibrated against the old
//! local fixtures keep passing unchanged. New tests should start here
//! instead of adding another local builder.

use crate::linalg::{orthonormalize, Mat};
use crate::rng::Pcg64;
use crate::sampling::IndexSampler;
use crate::sparse::SparseChunk;

/// Dense `rows × cols` matrix of i.i.d. standard normals from `seed`.
pub fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed(seed);
    Mat::from_fn(rows, cols, |_, _| rng.normal())
}

/// Random symmetric `n × n` matrix: a [`randmat`] symmetrized as
/// `(B + Bᵀ)/2`.
pub fn sym_mat(n: usize, seed: u64) -> Mat {
    let b = randmat(n, n, seed);
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            a.set(i, j, 0.5 * (b.get(i, j) + b.get(j, i)));
        }
    }
    a
}

/// Spiked sample matrix `X` (p × n): `x_i = Σ_t κ_{it} λ_t u_t` with a
/// random orthonormal `U` (k = `lambdas.len()` columns) and i.i.d. normal
/// loadings κ — the covariance-estimator workload of the paper's
/// Section V experiments.
pub fn spiked_data(p: usize, n: usize, lambdas: &[f64], seed: u64) -> Mat {
    let k = lambdas.len();
    let mut rng = Pcg64::seed(seed);
    let g = Mat::from_fn(p, k, |_, _| rng.normal());
    let u = orthonormalize(&g);
    let mut x = Mat::zeros(p, n);
    for j in 0..n {
        let kap: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        for i in 0..p {
            let mut s = 0.0;
            for t in 0..k {
                s += kap[t] * lambdas[t] * u.get(i, t);
            }
            x.set(i, j, s);
        }
    }
    x
}

/// Spiked covariance `C = Σ_t λ_t u_t u_tᵀ + 0.01·I` with a random
/// orthonormal `U`. Returns `(C, U)` — the ground-truth pair for
/// recovered-PC and explained-variance assertions. The `0.01` isotropic
/// floor keeps the matrix positive-definite.
pub fn spiked_cov(p: usize, lambdas: &[f64], seed: u64) -> (Mat, Mat) {
    let mut rng = Pcg64::seed(seed);
    let u = orthonormalize(&Mat::from_fn(p, lambdas.len(), |_, _| rng.normal()));
    let mut c = Mat::zeros(p, p);
    for (t, &l) in lambdas.iter().enumerate() {
        for i in 0..p {
            for j in 0..p {
                c.add_at(i, j, l * u.get(i, t) * u.get(j, t));
            }
        }
    }
    for i in 0..p {
        c.add_at(i, i, 0.01);
    }
    (c, u)
}

/// Random valid [`SparseChunk`] (p, m, n, starting at `start_col`):
/// per-column masks drawn uniformly without replacement (sorted, distinct,
/// in-range — `validate()` holds by construction) with standard-normal
/// kept values.
pub fn sparse_chunk(p: usize, m: usize, n: usize, start_col: usize, seed: u64) -> SparseChunk {
    assert!(m >= 1 && m <= p, "sparse_chunk: need 1 <= m <= p");
    let mut rng = Pcg64::seed(seed);
    let mut sampler = IndexSampler::new(p);
    let mut chunk = SparseChunk::with_capacity(p, m, n, start_col);
    for i in 0..n {
        let (idx, vals) = chunk.col_mut(i);
        sampler.sample(&mut rng, idx);
        for v in vals.iter_mut() {
            *v = rng.normal();
        }
    }
    chunk
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        assert_eq!(randmat(4, 3, 9).as_slice(), randmat(4, 3, 9).as_slice());
        let (c1, u1) = spiked_cov(8, &[3.0, 1.0], 5);
        let (c2, u2) = spiked_cov(8, &[3.0, 1.0], 5);
        assert_eq!(c1.as_slice(), c2.as_slice());
        assert_eq!(u1.as_slice(), u2.as_slice());
    }

    #[test]
    fn sym_mat_is_symmetric() {
        let a = sym_mat(6, 3);
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(a.get(i, j).to_bits(), a.get(j, i).to_bits());
            }
        }
    }

    #[test]
    fn spiked_data_lives_in_the_spike_subspace() {
        // with no isotropic noise, every sample is a combination of the
        // k spike directions: rank of X is at most k
        let x = spiked_data(10, 40, &[2.0, 1.0], 7);
        let c = x.syrk();
        let (vals, _) = crate::linalg::jacobi_eigh(&c);
        assert!(vals[1] > 1e-6, "two spikes must be excited");
        assert!(vals[2].abs() < 1e-8 * vals[0], "rank must be 2: {vals:?}");
    }

    #[test]
    fn sparse_chunk_is_valid() {
        let c = sparse_chunk(32, 7, 11, 4, 13);
        c.validate().unwrap();
        assert_eq!((c.p(), c.m(), c.n(), c.start_col()), (32, 7, 11, 4));
    }
}
