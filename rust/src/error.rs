//! Crate-wide error type.
//!
//! Hand-implemented `Display`/`Error` (the `thiserror` derive is
//! unavailable in this offline build); message formats are part of the
//! public contract and must not change.

use std::fmt;

/// Unified error for the pds library.
#[derive(Debug)]
pub enum Error {
    /// Shape / dimension mismatch between operands.
    Shape(String),

    /// Invalid configuration or argument value.
    Invalid(String),

    /// A required AOT artifact is missing from the manifest.
    MissingArtifact { graph: String, p: usize, b: usize, k: usize },

    /// PJRT / XLA runtime failure.
    Xla(String),

    /// Numerical failure (non-convergence, singularity, NaN).
    Numerical(String),

    /// On-disk data failed validation: bad magic, checksum mismatch,
    /// truncated shard, or a manifest inconsistent with its shards.
    Corrupt(String),

    /// I/O (out-of-core store, manifest).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::Invalid(msg) => write!(f, "invalid argument: {msg}"),
            Error::MissingArtifact { graph, p, b, k } => write!(
                f,
                "missing artifact: graph={graph} p={p} b={b} k={k} (run `make artifacts`)"
            ),
            Error::Xla(msg) => write!(f, "xla runtime: {msg}"),
            Error::Numerical(msg) => write!(f, "numerical: {msg}"),
            Error::Corrupt(msg) => write!(f, "corrupt store: {msg}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias over [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand for building a shape error.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Shape(msg.into()))
}

/// Shorthand for building an invalid-argument error.
pub fn invalid<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Invalid(msg.into()))
}

/// Shorthand for building a corrupt-store error.
pub fn corrupt<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Corrupt(msg.into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats_are_stable() {
        assert_eq!(Error::Shape("a".into()).to_string(), "shape mismatch: a");
        assert_eq!(Error::Invalid("b".into()).to_string(), "invalid argument: b");
        assert_eq!(Error::Xla("c".into()).to_string(), "xla runtime: c");
        assert_eq!(Error::Numerical("d".into()).to_string(), "numerical: d");
        assert_eq!(Error::Corrupt("e".into()).to_string(), "corrupt store: e");
        let ma = Error::MissingArtifact { graph: "assign".into(), p: 1, b: 2, k: 3 };
        assert_eq!(
            ma.to_string(),
            "missing artifact: graph=assign p=1 b=2 k=3 (run `make artifacts`)"
        );
        let io = Error::from(std::io::Error::new(std::io::ErrorKind::Other, "nope"));
        assert!(io.to_string().starts_with("io: "));
    }

    #[test]
    fn io_source_is_preserved() {
        use std::error::Error as _;
        let e = Error::from(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(e.source().is_some());
        assert!(Error::Shape("x".into()).source().is_none());
    }
}
