//! Crate-wide error type.

use thiserror::Error;

/// Unified error for the pds library.
#[derive(Error, Debug)]
pub enum Error {
    /// Shape / dimension mismatch between operands.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Invalid configuration or argument value.
    #[error("invalid argument: {0}")]
    Invalid(String),

    /// A required AOT artifact is missing from the manifest.
    #[error("missing artifact: graph={graph} p={p} b={b} k={k} (run `make artifacts`)")]
    MissingArtifact { graph: String, p: usize, b: usize, k: usize },

    /// PJRT / XLA runtime failure.
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Numerical failure (non-convergence, singularity, NaN).
    #[error("numerical: {0}")]
    Numerical(String),

    /// I/O (out-of-core store, manifest).
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Shorthand for building a shape error.
pub fn shape_err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Shape(msg.into()))
}

/// Shorthand for building an invalid-argument error.
pub fn invalid<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error::Invalid(msg.into()))
}
