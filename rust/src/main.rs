//! `pds` — the command-line front end. Every fit routes through the
//! [`FitPlan`](pds::coordinator::FitPlan) session API.
//!
//! ```text
//! pds xp <id|all|list> [--runs N] [--full] [...]   regenerate a paper table/figure
//! pds kmeans [--n N] [--p P] [--k K] [--gamma G]   sparsified K-means demo run
//! pds pca    [--n N] [--p P] [--topk K] [--gamma G] streaming PCA demo run
//! pds compress --store DIR [--n N] [--gamma G]     compress a stream into a sparse store
//! pds fit --store DIR [--task kmeans|pca]          fit from a sparse store (no raw pass)
//! pds fit --store DIR --partition N                partitioned fit (N merged worker shards)
//! pds fit --store DIR --partials-out DIR           write worker partials, don't finalize
//! pds merge --store DIR FILE...                    merge worker partials into a fit
//! pds split --store DIR --into D1,D2,...           deal a store into shard-group pieces
//! pds join --stores D1,D2,... --out DIR            re-join shard-group pieces
//! pds store-info --store DIR                       print a store's manifest
//! pds serve --store DIR [--task pca|kmeans]        concurrent ingest + query daemon
//! pds artifacts-check                              verify AOT artifacts + PJRT
//! pds info                                         build/config summary
//! ```

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::time::Duration;

use pds::cli::Args;
use pds::coordinator::{
    FitPlan, FitReport, MatSource, PcaFit, Solver, StreamConfig, DEFAULT_CORESET_SIZE,
};
use pds::distributed::{kind, peek_kind};
use pds::data::{gaussian_blobs, DigitConfig};
use pds::error::{Error, Result};
use pds::kmeans::{KmeansOpts, SparsifiedModel};
use pds::metrics::clustering_accuracy;
use pds::rng::Pcg64;
use pds::runtime::{artifact_dir, XlaEngine};
use pds::sampling::{Scheme, SparsifyConfig};
use pds::serve::{ServeConfig, ServeTask};
use pds::sparse::Precision;
use pds::store::{join_stores, split_store, SparseStoreReader, StoreManifest};
use pds::transform::TransformKind;

fn main() -> ExitCode {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        usage();
        return ExitCode::FAILURE;
    }
    let cmd = raw[0].clone();
    let args = match Args::parse(&raw[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match cmd.as_str() {
        "xp" => cmd_xp(&args),
        "kmeans" => cmd_kmeans(&args),
        "pca" => cmd_pca(&args),
        "compress" => cmd_compress(&args),
        "fit" => cmd_fit(&args),
        "merge" => cmd_merge(&args),
        "split" => cmd_split(&args),
        "join" => cmd_join(&args),
        "store-info" => cmd_store_info(&args),
        "serve" => cmd_serve(&args),
        "artifacts-check" => cmd_artifacts_check(),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other:?}");
            usage();
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() {
    eprintln!(
        "pds — Preconditioned Data Sparsification (PCA & sparsified K-means)\n\
         \n\
         usage:\n\
         \x20 pds xp <id|all|list> [--runs N] [--full] [--gammas a,b,c] ...\n\
         \x20 pds kmeans [--data blobs|digits] [--n N] [--p P] [--k K] [--gamma G]\n\
         \x20\x20\x20\x20 [--restarts R] [--workers W] [--engine native|xla]\n\
         \x20\x20\x20\x20 [--scheme precond|uniform|hybrid] [--precision f32|f64]\n\
         \x20 pds pca [--n N] [--p P] [--topk K] [--gamma G] [--workers W]\n\
         \x20\x20\x20\x20 [--solver covariance|krylov] [--scheme precond|uniform|hybrid]\n\
         \x20\x20\x20\x20 [--precision f32|f64]\n\
         \x20 pds compress --store DIR [--data blobs|digits] [--n N] [--p P] [--gamma G]\n\
         \x20\x20\x20\x20 [--seed S] [--workers W] [--shard-cols C] [--no-precondition]\n\
         \x20\x20\x20\x20 [--scheme precond|uniform|hybrid] [--precision f32|f64]\n\
         \x20 pds fit --store DIR [--task kmeans|pca] [--k K] [--topk K] [--workers W]\n\
         \x20\x20\x20\x20 [--restarts R] [--budget-mb MB] [--scheme precond|uniform|hybrid]\n\
         \x20\x20\x20\x20 [--solver covariance|krylov (pca) | inmemory|stream|coreset (kmeans)]\n\
         \x20\x20\x20\x20 [--precision f32|f64] [--partition N] [--coreset-size C]\n\
         \x20\x20\x20\x20 [--partials-out DIR  write worker partials instead of fitting]\n\
         \x20 pds merge --store DIR FILE...  [--k K] [--topk K] [--restarts R]\n\
         \x20\x20\x20\x20 merge worker partial artifacts (from --partials-out) into a fit\n\
         \x20 pds split --store DIR --into DIR1,DIR2,...\n\
         \x20 pds join --stores DIR1,DIR2,... --out DIR\n\
         \x20 pds store-info --store DIR\n\
         \x20 pds serve --store DIR [--task kmeans|pca] [--p P] [--gamma G] [--seed S]\n\
         \x20\x20\x20\x20 [--k K] [--topk K] [--scheme precond|uniform|hybrid]\n\
         \x20\x20\x20\x20 [--precision f32|f64] [--no-precondition] [--shard-cols C]\n\
         \x20\x20\x20\x20 [--queue-batches B] [--refresh-ms MS] [--timeout-ms MS]\n\
         \x20\x20\x20\x20 [--batch-window-us US] [--batch-max N] [--conn-slots N]\n\
         \x20\x20\x20\x20 [--listen HOST:PORT  serve over TCP instead of stdin/stdout]\n\
         \x20\x20\x20\x20 [--socket PATH  listen on a unix socket instead of stdin/stdout]\n\
         \x20 pds artifacts-check\n\
         \x20 pds info"
    );
}

fn cmd_xp(args: &Args) -> Result<()> {
    let id = args.positional.first().map(String::as_str).unwrap_or("list");
    if id == "list" {
        println!("available experiments:");
        for (name, desc) in pds::experiments::EXPERIMENTS {
            println!("  {name:<8} {desc}");
        }
        return Ok(());
    }
    pds::experiments::run(id, args)
}

/// A report's K-means model, or a typed error when the plan produced
/// something else — these accessors sit on user-reachable CLI paths
/// (e.g. mixed-up `pds merge` artifacts), so a mismatch must never
/// panic the binary.
fn kmeans_model_of(report: &FitReport) -> Result<&SparsifiedModel> {
    report.kmeans_model().ok_or_else(|| {
        Error::Invalid("this fit did not produce a K-means model (wrong task or artifacts)".into())
    })
}

/// A report's PCA fit, as a typed error instead of a panic (see
/// [`kmeans_model_of`]).
fn pca_fit_of(report: &FitReport) -> Result<&PcaFit> {
    report.pca_fit().ok_or_else(|| {
        Error::Invalid("this fit did not produce a PCA model (wrong task or artifacts)".into())
    })
}

/// A compress report's store manifest, as a typed error instead of a
/// panic (see [`kmeans_model_of`]).
fn store_manifest_of(report: &FitReport) -> Result<&StoreManifest> {
    report.store_manifest().ok_or_else(|| {
        Error::Invalid("this run did not write a store (not a compress plan)".into())
    })
}

/// Print a K-means report's tail: objective, bound, pass counts, phases.
fn print_kmeans_report(report: &FitReport) -> Result<()> {
    let model = kmeans_model_of(report)?;
    println!("objective = {:.4}", model.result.objective);
    // NaN bounds mark a weighted (hybrid) fit, where the Eq. 43 theory
    // does not apply — omit the line rather than print a non-guarantee
    if let Some(bound) = report.center_bound.last().filter(|b| b.is_finite()) {
        println!(
            "per-iteration center-error bound (Eq. 43, worst cluster, final iter): {bound:.4}"
        );
    }
    println!(
        "passes: raw {} | sparse {}",
        report.raw_passes, report.sparse_passes
    );
    for (name, secs) in report.timer.phases() {
        println!("  {name:<10} {secs:.3} s");
    }
    Ok(())
}

fn kmeans_opts(args: &Args) -> Result<KmeansOpts> {
    // --restarts is the preferred spelling; --starts kept for
    // compatibility with earlier scripts
    let default_restarts: usize = args.get_parse("starts", 5)?;
    Ok(KmeansOpts {
        n_init: args.get_parse("restarts", default_restarts)?,
        max_iters: args.get_parse("max-iters", 100)?,
        tol_frac: 0.0,
        seed: args.get_parse("seed", 0)?,
    })
}

fn cmd_kmeans(args: &Args) -> Result<()> {
    let data_kind = args.get("data").unwrap_or("blobs");
    let k: usize = args.get_parse("k", 5)?;
    let gamma: f64 = args.get_parse("gamma", 0.05)?;
    let seed: u64 = args.get_parse("seed", 0)?;
    let (data, labels) = match data_kind {
        "digits" => {
            let n: usize = args.get_parse("n", 5000)?;
            let d = pds::data::digits(n, DigitConfig { seed, ..Default::default() });
            (d.data, d.labels)
        }
        _ => {
            let n: usize = args.get_parse("n", 20_000)?;
            let p: usize = args.get_parse("p", 512)?;
            let mut rng = Pcg64::seed(seed);
            let d = gaussian_blobs(p, n, k, 0.05, &mut rng);
            (d.data, d.labels)
        }
    };
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
    let opts = kmeans_opts(args)?;
    let mut src = MatSource::new(&data, args.get_parse("chunk", 2048)?);
    let stream = StreamConfig { workers: args.get_parse("workers", 1)?, ..Default::default() };

    let engine = if args.get("engine") == Some("xla") {
        Some(XlaEngine::new(None)?)
    } else {
        None
    };
    let scheme = scheme_arg(args)?;
    let mut plan = FitPlan::kmeans()
        .stream(&mut src, scfg)
        .scheme(scheme)
        .k(k)
        .kmeans_opts(opts)
        .stream_config(stream);
    if let Some(e) = &engine {
        plan = plan.assigner(e);
    }
    if let Some(pr) = precision_arg(args)? {
        plan = plan.precision(pr);
    }
    let report = plan.run()?;
    let model = kmeans_model_of(&report)?;
    println!(
        "sparsified K-means: n={} gamma={gamma} scheme={} engine={} restarts={} iterations={} \
         converged={}",
        report.n,
        scheme.name(),
        report.engine,
        opts.n_init,
        model.result.iterations,
        model.result.converged
    );
    if !labels.is_empty() {
        println!(
            "accuracy vs ground truth = {:.4}",
            clustering_accuracy(&model.result.assign, &labels, k)
        );
    }
    print_kmeans_report(&report)
}

/// The `--scheme` option (default: the paper's preconditioned-uniform
/// operator).
fn scheme_arg(args: &Args) -> Result<Scheme> {
    match args.get("scheme") {
        None => Ok(Scheme::Precond),
        Some(name) => Scheme::parse(name),
    }
}

/// The `--precision` option: `f32` stores sparse values in single
/// precision (accumulation stays f64); `f64` is the default full-width
/// pipeline. `None` means "whatever the source records" (stores) or f64
/// (raw streams).
fn precision_arg(args: &Args) -> Result<Option<Precision>> {
    match args.get("precision") {
        None => Ok(None),
        Some(name) => Precision::parse(name)
            .map(Some)
            .ok_or_else(|| Error::Invalid(format!("--precision {name:?} (want f32|f64)"))),
    }
}

/// The `--solver` option: validated against the task's solver family.
fn solver_arg(args: &Args, task: &str) -> Result<Option<Solver>> {
    let Some(name) = args.get("solver") else { return Ok(None) };
    let solver = Solver::parse(name)?;
    let ok = match task {
        "pca" => matches!(solver, Solver::Covariance | Solver::Krylov),
        _ => matches!(solver, Solver::InMemory | Solver::Stream | Solver::Coreset),
    };
    if !ok {
        return Err(Error::Invalid(format!(
            "--solver {name:?} does not apply to task {task:?}"
        )));
    }
    Ok(Some(solver))
}

fn cmd_pca(args: &Args) -> Result<()> {
    let n: usize = args.get_parse("n", 10_000)?;
    let p: usize = args.get_parse("p", 256)?;
    let topk: usize = args.get_parse("topk", 5)?;
    let gamma: f64 = args.get_parse("gamma", 0.1)?;
    let seed: u64 = args.get_parse("seed", 0)?;
    let solver = solver_arg(args, "pca")?.unwrap_or(Solver::Covariance);
    let mut rng = Pcg64::seed(seed);
    let d = pds::data::spiked(p, n, &[10.0, 8.0, 6.0, 4.0, 2.0], false, &mut rng);
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
    let mut src = MatSource::new(&d.data, 2048);
    let stream = StreamConfig { workers: args.get_parse("workers", 1)?, ..Default::default() };
    let scheme = scheme_arg(args)?;
    let mut plan = FitPlan::pca()
        .stream(&mut src, scfg)
        .scheme(scheme)
        .topk(topk)
        .solver(solver)
        .stream_config(stream);
    if let Some(pr) = precision_arg(args)? {
        plan = plan.precision(pr);
    }
    let report = plan.run()?;
    let fit = pca_fit_of(&report)?;
    println!(
        "streaming PCA ({} solver, {} scheme): n={} gamma={gamma} passes: raw {} | sparse {}",
        solver.name(),
        scheme.name(),
        report.n,
        report.raw_passes,
        report.sparse_passes
    );
    println!("top-{topk} eigenvalues: {:?}", fit.pca.eigenvalues);
    let rec = pds::pca::recovered_components(&fit.pca.components, &d.centers, 0.95);
    println!("recovered {rec}/{} true spiked components (threshold .95)", d.centers.cols());
    for (name, secs) in report.timer.phases() {
        println!("  {name:<10} {secs:.3} s");
    }
    Ok(())
}

/// The `--store DIR` option, required by the store commands.
fn store_arg<'a>(args: &'a Args) -> Result<&'a str> {
    args.get("store")
        .ok_or_else(|| Error::Invalid("--store DIR is required".into()))
}

fn cmd_compress(args: &Args) -> Result<()> {
    let store_dir = store_arg(args)?;
    let data_kind = args.get("data").unwrap_or("blobs");
    let gamma: f64 = args.get_parse("gamma", 0.05)?;
    let seed: u64 = args.get_parse("seed", 0)?;
    let k: usize = args.get_parse("k", 5)?;
    let data = match data_kind {
        "digits" => {
            let n: usize = args.get_parse("n", 5000)?;
            pds::data::digits(n, DigitConfig { seed, ..Default::default() }).data
        }
        _ => {
            let n: usize = args.get_parse("n", 20_000)?;
            let p: usize = args.get_parse("p", 512)?;
            let mut rng = Pcg64::seed(seed);
            gaussian_blobs(p, n, k, 0.05, &mut rng).data
        }
    };
    let scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
    let mut src = MatSource::new(&data, args.get_parse("chunk", 2048)?);
    let stream = StreamConfig { workers: args.get_parse("workers", 1)?, ..Default::default() };
    let mut plan = FitPlan::compress()
        .stream(&mut src, scfg)
        .scheme(scheme_arg(args)?)
        .store_dir(Path::new(store_dir))
        .shard_cols(args.get_parse("shard-cols", 8192)?)
        .stream_config(stream)
        .precondition(!args.flag("no-precondition"));
    if let Some(pr) = precision_arg(args)? {
        plan = plan.precision(pr);
    }
    let report = plan.run()?;
    let manifest = store_manifest_of(&report)?;
    println!(
        "compressed {} samples (p={} -> m={} per sample, gamma={:.4}, scheme={}, \
         precision={}) into {}",
        manifest.n,
        manifest.p,
        manifest.m,
        manifest.m as f64 / manifest.p as f64,
        manifest.scheme.name(),
        manifest.precision.name(),
        store_dir
    );
    println!(
        "  {} shards, {:.1} MB sparse payload ({:.1}% of dense f64), passes over raw data: {}",
        manifest.shards.len(),
        manifest.payload_bytes() as f64 / (1024.0 * 1024.0),
        100.0 * manifest.payload_bytes() as f64
            / (manifest.n as f64 * manifest.p_orig as f64 * 8.0),
        report.raw_passes
    );
    for (name, secs) in report.timer.phases() {
        println!("  {name:<10} {secs:.3} s");
    }
    Ok(())
}

fn cmd_fit(args: &Args) -> Result<()> {
    let store_dir = store_arg(args)?;
    let task = args.get("task").unwrap_or("kmeans");
    let workers: usize = args.get_parse("workers", 1)?;
    let budget_mb: usize = args.get_parse("budget-mb", 0)?;
    let solver = solver_arg(args, task)?;
    let mut reader = SparseStoreReader::open(Path::new(store_dir))?;
    if budget_mb > 0 {
        if task == "kmeans" && solver != Some(Solver::Stream) {
            // the in-memory K-means solver materializes the whole sparse
            // store (~12·m·n bytes); only --solver stream honors the
            // budget as a true working-set cap
            eprintln!(
                "note: --budget-mb caps streaming chunk sizes; the inmemory kmeans solver \
                 still holds the full compressed store in memory (use --solver stream for \
                 a true out-of-core fit)"
            );
        }
        reader = reader.with_memory_budget(budget_mb * 1024 * 1024);
    }
    let m = reader.manifest();
    // a store fit always uses the recorded scheme; an explicit --scheme
    // is validated against it so seeded comparisons fail loudly instead
    // of silently fitting the wrong arm
    if let Some(requested) = args.get("scheme") {
        let requested = Scheme::parse(requested)?;
        if requested != m.scheme {
            return Err(Error::Invalid(format!(
                "--scheme {} does not match this store (recorded scheme: {})",
                requested.name(),
                m.scheme.name()
            )));
        }
    }
    // same loud-failure contract for --precision: a store fit always uses
    // the recorded value encoding, so an explicit request must match it
    let precision = precision_arg(args)?;
    // distributed-fit knobs: --partition N folds the store's shards as N
    // merged worker partials (bitwise invariant to N for exact f64 folds);
    // --partials-out DIR writes the worker artifacts instead of finishing
    // the fit, for a later `pds merge`
    let partition: usize = args.get_parse("partition", 0)?;
    let coreset_size: usize = args.get_parse("coreset-size", DEFAULT_CORESET_SIZE)?;
    let partials_out = args.get("partials-out").map(PathBuf::from);
    println!(
        "store {}: n={} p={} m={} scheme={} precision={} preconditioned={} ({} shards)",
        store_dir,
        m.n,
        m.p,
        m.m,
        m.scheme.name(),
        m.precision.name(),
        m.preconditioned,
        m.shards.len()
    );
    match task {
        "pca" => {
            let topk: usize = args.get_parse("topk", 5)?;
            let solver = solver.unwrap_or(Solver::Covariance);
            let mut plan = FitPlan::pca()
                .store(&mut reader)
                .topk(topk)
                .solver(solver)
                .workers(workers);
            if partition > 0 {
                plan = plan.partition(partition);
            }
            if let Some(pr) = precision {
                plan = plan.precision(pr);
            }
            if let Some(dir) = partials_out {
                return write_partials(plan, &dir);
            }
            let report = plan.run()?;
            let fit = pca_fit_of(&report)?;
            println!(
                "PCA from store ({} solver): n={} passes: raw {} | sparse {}",
                solver.name(),
                report.n,
                report.raw_passes,
                report.sparse_passes
            );
            println!("top-{topk} eigenvalues: {:?}", fit.pca.eigenvalues);
            for (name, secs) in report.timer.phases() {
                println!("  {name:<10} {secs:.3} s");
            }
        }
        "kmeans" => {
            let k: usize = args.get_parse("k", 5)?;
            let opts = kmeans_opts(args)?;
            let solver = solver.unwrap_or(Solver::InMemory);
            let mut plan = FitPlan::kmeans()
                .store(&mut reader)
                .k(k)
                .kmeans_opts(opts)
                .solver(solver)
                .workers(workers)
                .coreset_size(coreset_size);
            if partition > 0 {
                plan = plan.partition(partition);
            }
            if let Some(pr) = precision {
                plan = plan.precision(pr);
            }
            if let Some(dir) = partials_out {
                return write_partials(plan, &dir);
            }
            let report = plan.run()?;
            let model = kmeans_model_of(&report)?;
            println!(
                "sparsified K-means from store ({} solver): n={} restarts={} iterations={} \
                 converged={}",
                solver.name(),
                report.n,
                opts.n_init,
                model.result.iterations,
                model.result.converged
            );
            print_kmeans_report(&report)?;
        }
        other => return Err(Error::Invalid(format!("--task {other:?} (want kmeans|pca)"))),
    }
    Ok(())
}

/// Run the plan's worker stage only: write each partial artifact to
/// `dir/partial-NNNNN.pdsp` for a later `pds merge`.
fn write_partials(plan: FitPlan<'_>, dir: &Path) -> Result<()> {
    let artifacts = plan.partials()?;
    std::fs::create_dir_all(dir)?;
    for (i, bytes) in artifacts.iter().enumerate() {
        let path = dir.join(format!("partial-{i:05}.pdsp"));
        std::fs::write(&path, bytes)?;
        println!("wrote {} ({} bytes)", path.display(), bytes.len());
    }
    println!(
        "{} worker partial(s); finalize with: pds merge --store <DIR> {}/partial-*.pdsp",
        artifacts.len(),
        dir.display()
    );
    Ok(())
}

fn cmd_merge(args: &Args) -> Result<()> {
    let store_dir = store_arg(args)?;
    if args.positional.is_empty() {
        return Err(Error::Invalid(
            "pds merge needs the worker partial files (from --partials-out) as arguments".into(),
        ));
    }
    let mut artifacts = Vec::with_capacity(args.positional.len());
    for path in &args.positional {
        artifacts.push(std::fs::read(path)?);
    }
    let mut reader = SparseStoreReader::open(Path::new(store_dir))?;
    // the artifact envelope names the partial kind, so the task does not
    // need to be respecified — it is whatever the workers fit
    match peek_kind(&artifacts[0])? {
        kind::PCA => {
            let topk: usize = args.get_parse("topk", 5)?;
            let report = FitPlan::pca()
                .store(&mut reader)
                .topk(topk)
                .merge_partials(&artifacts)?;
            let fit = pca_fit_of(&report)?;
            println!(
                "merged {} pca partial(s): n={} passes: raw {} | sparse {}",
                args.positional.len(),
                report.n,
                report.raw_passes,
                report.sparse_passes
            );
            println!("top-{topk} eigenvalues: {:?}", fit.pca.eigenvalues);
            for (name, secs) in report.timer.phases() {
                println!("  {name:<10} {secs:.3} s");
            }
        }
        kind::CORESET => {
            let k: usize = args.get_parse("k", 5)?;
            let opts = kmeans_opts(args)?;
            let report = FitPlan::kmeans()
                .store(&mut reader)
                .k(k)
                .kmeans_opts(opts)
                .solver(Solver::Coreset)
                .merge_partials(&artifacts)?;
            let model = kmeans_model_of(&report)?;
            println!(
                "merged {} coreset partial(s): k={k} n={} restarts={} converged={}",
                args.positional.len(),
                report.n,
                opts.n_init,
                model.result.converged
            );
            print_kmeans_report(&report)?;
        }
        other => {
            return Err(Error::Invalid(format!(
                "cannot merge partial kind {other} here (want pca or coreset worker \
                 artifacts; the Lloyd solvers merge per-iteration inside `pds fit \
                 --partition N`)"
            )))
        }
    }
    Ok(())
}

/// Comma-separated directory list option (`--into`, `--stores`).
fn dir_list_arg(args: &Args, name: &str) -> Result<Vec<PathBuf>> {
    let raw = args
        .get(name)
        .ok_or_else(|| Error::Invalid(format!("--{name} DIR1,DIR2,... is required")))?;
    let dirs: Vec<PathBuf> =
        raw.split(',').filter(|s| !s.is_empty()).map(PathBuf::from).collect();
    if dirs.is_empty() {
        return Err(Error::Invalid(format!("--{name} DIR1,DIR2,... got no directories")));
    }
    Ok(dirs)
}

fn cmd_split(args: &Args) -> Result<()> {
    let store_dir = store_arg(args)?;
    let dests = dir_list_arg(args, "into")?;
    let manifests = split_store(Path::new(store_dir), &dests)?;
    println!("split {store_dir} into {} shard-group piece(s):", manifests.len());
    for (m, dest) in manifests.iter().zip(&dests) {
        println!(
            "  piece {}/{}: {} — cols [{}, {}) ({} shards)",
            m.group.index + 1,
            m.group.count,
            dest.display(),
            m.start_col(),
            m.end_col(),
            m.shards.len()
        );
    }
    Ok(())
}

fn cmd_join(args: &Args) -> Result<()> {
    let srcs = dir_list_arg(args, "stores")?;
    let out = args
        .get("out")
        .ok_or_else(|| Error::Invalid("--out DIR is required".into()))?;
    let m = join_stores(&srcs, Path::new(out))?;
    println!(
        "joined {} piece(s) into {out}: n={} p={} m={} ({} shards)",
        srcs.len(),
        m.n,
        m.p,
        m.m,
        m.shards.len()
    );
    Ok(())
}

fn cmd_store_info(args: &Args) -> Result<()> {
    let store_dir = store_arg(args)?;
    let reader = SparseStoreReader::open(Path::new(store_dir))?;
    let m = reader.manifest();
    println!("sparse store {store_dir} (manifest v{})", m.version);
    println!("  samples n       = {}", m.n);
    println!("  dimension p     = {} (original {})", m.p, m.p_orig);
    println!("  kept per sample = {} (gamma {:.4})", m.m, m.m as f64 / m.p as f64);
    println!("  transform       = {}, seed {}", m.transform.name(), m.seed);
    println!("  scheme          = {}", m.scheme.name());
    println!("  precision       = {}", m.precision.name());
    println!("  preconditioned  = {}", m.preconditioned);
    println!(
        "  shards          = {} x {} cols, {:.1} MB payload",
        m.shards.len(),
        m.shard_cols,
        m.payload_bytes() as f64 / (1024.0 * 1024.0)
    );
    for s in m.shards.iter().take(4) {
        println!(
            "    shard {:>3}: cols [{}, {}) crc32 {:08x} {}",
            s.index,
            s.start_col,
            s.start_col + s.n_cols,
            s.crc32,
            s.file
        );
    }
    if m.shards.len() > 4 {
        println!("    ... {} more", m.shards.len() - 4);
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let store_dir = store_arg(args)?;
    let task = ServeTask::parse(args.get("task").unwrap_or("kmeans"))?;
    let p: usize = args.get_parse("p", 512)?;
    let gamma: f64 = args.get_parse("gamma", 0.2)?;
    let seed: u64 = args.get_parse("seed", 0)?;
    let mut cfg = ServeConfig::new(PathBuf::from(store_dir), task, p);
    cfg.scfg = SparsifyConfig { gamma, transform: TransformKind::Hadamard, seed };
    cfg.scheme = scheme_arg(args)?;
    if let Some(pr) = precision_arg(args)? {
        cfg.precision = pr;
    }
    cfg.precondition = !args.flag("no-precondition");
    cfg.shard_cols = args.get_parse("shard-cols", cfg.shard_cols)?;
    cfg.topk = args.get_parse("topk", cfg.topk)?;
    cfg.k = args.get_parse("k", cfg.k)?;
    cfg.kmeans_opts = kmeans_opts(args)?;
    cfg.coreset_capacity = args.get_parse("coreset-size", DEFAULT_CORESET_SIZE)?;
    cfg.queue_batches = args.get_parse("queue-batches", cfg.queue_batches)?;
    cfg.refresh_interval = Duration::from_millis(args.get_parse("refresh-ms", 5000)?);
    cfg.request_timeout = Duration::from_millis(args.get_parse("timeout-ms", 30_000)?);
    cfg.batch_window =
        Duration::from_micros(args.get_parse("batch-window-us", cfg.batch_window.as_micros() as u64)?);
    cfg.batch_max = args.get_parse("batch-max", cfg.batch_max)?;
    cfg.conn_slots = args.get_parse("conn-slots", cfg.conn_slots)?;
    match (args.get("listen"), args.get("socket")) {
        (Some(_), Some(_)) => {
            Err(Error::Invalid("--listen and --socket are mutually exclusive".into()))
        }
        (Some(addr), None) => pds::serve::run_tcp(cfg, addr),
        #[cfg(unix)]
        (None, Some(path)) => pds::serve::run_socket(cfg, Path::new(path)),
        #[cfg(not(unix))]
        (None, Some(_)) => Err(Error::Invalid("--socket needs a unix platform".into())),
        (None, None) => pds::serve::run_pipe(cfg),
    }
}

fn cmd_artifacts_check() -> Result<()> {
    let dir = artifact_dir();
    println!("artifact dir: {}", dir.display());
    let engine = XlaEngine::new(Some(dir))?;
    let manifest = engine.manifest().clone();
    println!("{} artifacts:", manifest.entries().len());
    for e in manifest.entries() {
        println!("  {:<22} p={:<5} b={:<4} k={:<2} {}", e.graph, e.p, e.b, e.k, e.path.display());
    }
    // compile + smoke-run one assign graph per signature
    for (p, b, k) in manifest.signatures() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(p, b, k, 0.1, &mut rng);
        let scfg = SparsifyConfig { gamma: 0.05, transform: TransformKind::Hadamard, seed: 1 };
        let sp = pds::sampling::Sparsifier::new(p, scfg)?;
        if sp.p() != p {
            continue; // padded signature exercised via the e2e example
        }
        let chunk = sp.compress_chunk(&d.data, 0)?;
        let centers = sp.precondition_dense(&d.centers);
        use pds::kmeans::SparseAssigner;
        let (a, obj) = engine.assign(&chunk, &centers)?;
        println!("  smoke p={p} b={b} k={k}: assigned {} cols, obj {obj:.2} — OK", a.len());
    }
    println!("artifacts OK");
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("pds {} — Preconditioned Data Sparsification", env!("CARGO_PKG_VERSION"));
    println!("paper: Pourkamali-Anaraki & Becker, IEEE TIT 2017 (doi 10.1109/TIT.2017.2672725)");
    println!("artifact dir: {}", artifact_dir().display());
    println!("engines: native (pure rust), xla (PJRT CPU via AOT HLO artifacts)");
    Ok(())
}
