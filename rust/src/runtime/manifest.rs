//! Artifact manifest: the TSV contract written by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};

/// One compiled-graph artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Graph name (`assign`, `precondition`, ...).
    pub graph: String,
    /// Ambient dimension the graph was compiled for.
    pub p: usize,
    /// Batch (chunk columns) the graph was compiled for.
    pub b: usize,
    /// Cluster count the graph was compiled for (0 when irrelevant).
    pub k: usize,
    /// Path to the `.hlo.txt`, resolved against the manifest directory.
    pub path: PathBuf,
}

/// Parsed `manifest.tsv`.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Invalid(format!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` anchors relative artifact paths.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 5 {
                return Err(Error::Invalid(format!(
                    "manifest line {}: expected 5 tab-separated fields, got {}",
                    lineno + 1,
                    cols.len()
                )));
            }
            let parse = |s: &str, what: &str| -> Result<usize> {
                s.parse()
                    .map_err(|_| Error::Invalid(format!("manifest line {}: bad {what} {s:?}", lineno + 1)))
            };
            entries.push(ManifestEntry {
                graph: cols[0].to_string(),
                p: parse(cols[1], "p")?,
                b: parse(cols[2], "b")?,
                k: parse(cols[3], "k")?,
                path: dir.join(cols[4]),
            });
        }
        Ok(Manifest { entries })
    }

    /// All artifacts, in file order.
    pub fn entries(&self) -> &[ManifestEntry] {
        &self.entries
    }

    /// Find the artifact for `graph` at exact shape (p, b, k). The k field
    /// is ignored for graphs that don't depend on it (precondition, cov).
    pub fn find(&self, graph: &str, p: usize, b: usize, k: usize) -> Result<&ManifestEntry> {
        let k_free = matches!(graph, "precondition" | "precondition_adjoint" | "cov_update");
        self.entries
            .iter()
            .find(|e| e.graph == graph && e.p == p && e.b == b && (k_free || e.k == k))
            .ok_or_else(|| Error::MissingArtifact { graph: graph.to_string(), p, b, k })
    }

    /// All distinct (p, b, k) signatures present.
    pub fn signatures(&self) -> Vec<(usize, usize, usize)> {
        let mut sigs: Vec<(usize, usize, usize)> =
            self.entries.iter().map(|e| (e.p, e.b, e.k)).collect();
        sigs.sort_unstable();
        sigs.dedup();
        sigs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# graph\tp\tb\tk\tfile\n\
        precondition\t512\t256\t5\tprecondition_p512_b256_k5.hlo.txt\n\
        assign\t512\t256\t5\tassign_p512_b256_k5.hlo.txt\n";

    #[test]
    fn parse_and_find() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(m.entries().len(), 2);
        let e = m.find("assign", 512, 256, 5).unwrap();
        assert!(e.path.ends_with("assign_p512_b256_k5.hlo.txt"));
        assert!(m.find("assign", 512, 256, 7).is_err());
        // k-free lookup for precondition
        assert!(m.find("precondition", 512, 256, 99).is_ok());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("too\tfew\tfields\n", Path::new(".")).is_err());
        assert!(Manifest::parse("g\tx\t1\t2\tf\n", Path::new(".")).is_err());
    }

    #[test]
    fn signatures_dedup() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert_eq!(m.signatures(), vec![(512, 256, 5)]);
    }
}
