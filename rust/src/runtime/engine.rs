//! Chunk-op engines: the PJRT-backed [`XlaEngine`] and the pure-Rust
//! [`NativeEngine`], both implementing [`SparseAssigner`] so the
//! coordinator can swap them freely.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::error::{Error, Result};
use crate::kmeans::{NativeAssigner, SparseAssigner};
use crate::linalg::Mat;
use crate::runtime::manifest::Manifest;
use crate::sparse::SparseChunk;

/// Engine selector used by drivers/experiments.
pub enum Engine {
    /// Pure-Rust chunk ops (default).
    Native(NativeEngine),
    /// PJRT-backed AOT executables.
    Xla(XlaEngine),
}

impl Engine {
    /// The assignment strategy this engine provides.
    pub fn assigner(&self) -> &dyn SparseAssigner {
        match self {
            Engine::Native(e) => e,
            Engine::Xla(e) => e,
        }
    }
}

/// Pure-Rust chunk ops (the default production path on CPU).
pub struct NativeEngine;

impl SparseAssigner for NativeEngine {
    fn assign(&self, chunk: &SparseChunk, centers: &Mat) -> Result<(Vec<u32>, f64)> {
        NativeAssigner::new().assign(chunk, centers)
    }

    fn assign_into(
        &self,
        chunk: &SparseChunk,
        centers: &Mat,
        workers: usize,
        out: &mut [u32],
        dist: &mut [f64],
    ) -> Result<()> {
        NativeAssigner::new().assign_into(chunk, centers, workers, out, dist)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Transpose a column-major `rows×cols` f32 buffer into row-major.
fn colmajor_to_rowmajor(src: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(src.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for j in 0..cols {
        for i in 0..rows {
            out[i * cols + j] = src[j * rows + i];
        }
    }
    out
}

/// PJRT-backed engine executing the AOT artifacts.
///
/// Executables are compiled lazily on first use and cached per
/// `(graph, p, b, k)` behind a `Mutex`, making the engine `Sync` — the
/// [`SparseAssigner`] contract — so the parallel multi-restart K-means
/// path can share one engine across restart threads (executions
/// serialize on the cache lock; PJRT devices are a serial resource here
/// anyway).
pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<(String, usize, usize, usize), xla::PjRtLoadedExecutable>>,
}

impl XlaEngine {
    /// Connect the CPU PJRT client and load the manifest from `dir`
    /// (defaults to [`super::artifact_dir`]).
    pub fn new(dir: Option<std::path::PathBuf>) -> Result<Self> {
        let dir = dir.unwrap_or_else(super::artifact_dir);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(XlaEngine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for a graph signature.
    fn executable(&self, graph: &str, p: usize, b: usize, k: usize) -> Result<()> {
        let key = (graph.to_string(), p, b, k);
        // hold the lock across the compile: racing restart threads must
        // not both pay the parse+compile for the same signature
        let mut cache = self.cache.lock().expect("engine cache poisoned");
        if cache.contains_key(&key) {
            return Ok(());
        }
        let entry = self.manifest.find(graph, p, b, k)?;
        let proto = xla::HloModuleProto::from_text_file(&entry.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        cache.insert(key, exe);
        Ok(())
    }

    fn run(
        &self,
        graph: &str,
        p: usize,
        b: usize,
        k: usize,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        self.executable(graph, p, b, k)?;
        let cache = self.cache.lock().expect("engine cache poisoned");
        let exe = cache.get(&(graph.to_string(), p, b, k)).expect("just inserted");
        let result = exe.execute::<xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Literal from a row-major f32 matrix buffer.
    fn mat_literal(row_major: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(row_major).reshape(&[rows as i64, cols as i64])?)
    }

    /// The batch size `b` of the artifact serving dimension `p` / arity
    /// `k` for `graph`.
    fn batch_for(&self, graph: &str, p: usize, k: usize) -> Result<usize> {
        self.manifest
            .entries()
            .iter()
            .find(|e| {
                e.graph == graph
                    && e.p == p
                    && (matches!(graph, "precondition" | "precondition_adjoint" | "cov_update")
                        || e.k == k)
            })
            .map(|e| e.b)
            .ok_or_else(|| Error::MissingArtifact { graph: graph.into(), p, b: 0, k })
    }

    /// Execute the `assign` graph over one sub-batch (exactly `b` columns,
    /// padded by the caller). Inputs are row-major (p, b)/(p, k).
    fn assign_batch(
        &self,
        w_rm: &[f32],
        mask_rm: &[f32],
        mu_rm: &[f32],
        p: usize,
        b: usize,
        k: usize,
    ) -> Result<(Vec<f32>, Vec<i32>)> {
        let args = [
            Self::mat_literal(w_rm, p, b)?,
            Self::mat_literal(mask_rm, p, b)?,
            Self::mat_literal(mu_rm, p, k)?,
        ];
        let out = self.run("assign", p, b, k, &args)?;
        if out.len() != 2 {
            return Err(Error::Xla(format!("assign: expected 2 outputs, got {}", out.len())));
        }
        let dist: Vec<f32> = out[0].to_vec()?;
        let assign: Vec<i32> = out[1].to_vec()?;
        Ok((dist, assign))
    }

    /// Execute the `precondition` graph on a dense f32 column-major chunk
    /// (must have exactly the artifact batch width); returns y col-major.
    pub fn precondition_chunk(&self, x_cm: &[f32], signs: &[f32], p: usize) -> Result<Vec<f32>> {
        let b = self.batch_for("precondition", p, 0)?;
        if x_cm.len() != p * b {
            return Err(Error::Shape(format!(
                "precondition_chunk: got {} values, artifact batch is {p}x{b}",
                x_cm.len()
            )));
        }
        let x_rm = colmajor_to_rowmajor(x_cm, p, b);
        let args = [Self::mat_literal(&x_rm, p, b)?, xla::Literal::vec1(signs)];
        let out = self.run("precondition", p, b, 0, &args)?;
        let y_rm: Vec<f32> = out[0].to_vec()?;
        Ok(colmajor_to_rowmajor(&y_rm, b, p)) // transpose back
    }

    /// Execute the `cov_update` graph: returns the chunk Gram `W Wᵀ`
    /// (p×p, col-major == row-major by symmetry).
    pub fn cov_chunk(&self, w_cm: &[f32], p: usize) -> Result<Vec<f32>> {
        let b = self.batch_for("cov_update", p, 0)?;
        if w_cm.len() != p * b {
            return Err(Error::Shape(format!(
                "cov_chunk: got {} values, artifact batch is {p}x{b}",
                w_cm.len()
            )));
        }
        let w_rm = colmajor_to_rowmajor(w_cm, p, b);
        let out = self.run("cov_update", p, b, 0, &[Self::mat_literal(&w_rm, p, b)?])?;
        Ok(out[0].to_vec()?)
    }
}

impl XlaEngine {
    /// Shared body of [`SparseAssigner::assign`] /
    /// [`SparseAssigner::assign_into`]: the chunk is densified to
    /// (w, mask) panels, processed in artifact-width sub-batches with
    /// zero padding (zero-mask columns are distance-0 everywhere and
    /// their outputs are discarded). Ids land in `out`, per-sample best
    /// distances in `dist_out`.
    fn assign_impl(
        &self,
        chunk: &SparseChunk,
        centers: &Mat,
        out: &mut [u32],
        dist_out: &mut [f64],
    ) -> Result<()> {
        let p = chunk.p();
        let k = centers.cols();
        // The masked-panel distance counts every coordinate once; the
        // native assigner's slot-wise loop counts a duplicated index
        // once per slot. Weighted (with-replacement) chunks would
        // therefore silently break the native/XLA equivalence contract —
        // reject them instead.
        for i in 0..chunk.n() {
            if chunk.col_indices(i).windows(2).any(|w| w[0] == w[1]) {
                return Err(Error::Invalid(
                    "xla engine: weighted (duplicate-slot) chunks are not supported; \
                     use the native assigner for hybrid-scheme fits"
                        .into(),
                ));
            }
        }
        let b = self.batch_for("assign", p, k)?;
        let (w_cm, mask_cm) = chunk.to_dense_f32_masked();
        // centers to row-major f32
        let mut mu_rm = vec![0.0f32; p * k];
        for c in 0..k {
            for i in 0..p {
                mu_rm[i * k + c] = centers.get(i, c) as f32;
            }
        }
        let n = chunk.n();
        debug_assert_eq!(out.len(), n);
        debug_assert_eq!(dist_out.len(), n);
        let mut w_batch = vec![0.0f32; p * b];
        let mut mask_batch = vec![0.0f32; p * b];
        let mut start = 0usize;
        while start < n {
            let cols = (n - start).min(b);
            w_batch.fill(0.0);
            mask_batch.fill(0.0);
            // copy col-major then transpose in one go
            for j in 0..cols {
                let src = (start + j) * p;
                for i in 0..p {
                    w_batch[i * b + j] = w_cm[src + i];
                    mask_batch[i * b + j] = mask_cm[src + i];
                }
            }
            let (dist, a) = self.assign_batch(&w_batch, &mask_batch, &mu_rm, p, b, k)?;
            for j in 0..cols {
                let c = a[j];
                out[start + j] = c as u32;
                dist_out[start + j] = dist[j * k + c as usize] as f64;
            }
            start += cols;
        }
        Ok(())
    }
}

impl SparseAssigner for XlaEngine {
    /// Assignment via the AOT Pallas `assign` graph.
    fn assign(&self, chunk: &SparseChunk, centers: &Mat) -> Result<(Vec<u32>, f64)> {
        let mut out = vec![0u32; chunk.n()];
        let mut dist = vec![0.0f64; chunk.n()];
        self.assign_impl(chunk, centers, &mut out, &mut dist)?;
        let obj = dist.iter().sum();
        Ok((out, obj))
    }

    /// The PJRT executable is already data-parallel internally; the
    /// `workers` hint is ignored.
    fn assign_into(
        &self,
        chunk: &SparseChunk,
        centers: &Mat,
        _workers: usize,
        out: &mut [u32],
        dist: &mut [f64],
    ) -> Result<()> {
        self.assign_impl(chunk, centers, out, dist)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_roundtrip() {
        let cm: Vec<f32> = (0..12).map(|v| v as f32).collect(); // 3x4 col-major
        let rm = colmajor_to_rowmajor(&cm, 3, 4);
        assert_eq!(rm[0 * 4 + 1], cm[1 * 3 + 0]); // (0,1)
        let back = colmajor_to_rowmajor(&rm, 4, 3); // treat rm as col-major 4x3
        assert_eq!(back, cm);
    }
}
