//! PJRT execution of the AOT-compiled JAX/Pallas graphs.
//!
//! `make artifacts` lowers the L2 graphs (`python/compile/model.py`,
//! calling the L1 Pallas kernels) to HLO **text** plus a TSV manifest.
//! [`XlaEngine`] loads those artifacts through the `xla` crate
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`), caching one compiled executable per (graph, p, b, k)
//! signature. Python never runs at execution time.
//!
//! [`NativeEngine`] implements the identical chunk ops in pure Rust; the
//! two are cross-checked in `rust/tests/xla_parity.rs` and raced in the
//! `ablation_engine` bench.

mod engine;
mod manifest;

pub use engine::{Engine, NativeEngine, XlaEngine};
pub use manifest::{Manifest, ManifestEntry};

/// Default artifact directory, relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$PDS_ARTIFACTS`, else `artifacts/`
/// relative to the current dir, else relative to the crate root.
pub fn artifact_dir() -> std::path::PathBuf {
    if let Ok(dir) = std::env::var("PDS_ARTIFACTS") {
        return dir.into();
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACT_DIR);
    if cwd.join("manifest.tsv").exists() {
        return cwd;
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACT_DIR)
}
