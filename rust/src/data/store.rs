//! Out-of-core chunk store: the on-disk format for the big-data tests
//! (Table IV). Data is written once as fixed-size f32 column chunks and
//! streamed back chunk-by-chunk so the full matrix never resides in RAM —
//! the same batched-load discipline as the paper's 58×1GB MNIST store.
//!
//! Layout (little-endian):
//! ```text
//! magic  "PDS1"          4 bytes
//! p      u32             ambient dimension
//! n      u64             total samples
//! chunk  u32             columns per chunk (last chunk may be short)
//! data   f32 × p × n     column-major, chunk after chunk
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::linalg::Mat;

const MAGIC: &[u8; 4] = b"PDS1";
const HEADER_LEN: u64 = 4 + 4 + 8 + 4;

/// Writer: create a store and append column chunks.
pub struct ChunkStore {
    file: BufWriter<File>,
    p: usize,
    n: u64,
    chunk_cols: usize,
}

impl ChunkStore {
    /// Create (truncate) a store at `path`.
    pub fn create(path: &Path, p: usize, chunk_cols: usize) -> Result<Self> {
        let mut file = BufWriter::new(File::create(path)?);
        file.write_all(MAGIC)?;
        file.write_all(&(p as u32).to_le_bytes())?;
        file.write_all(&0u64.to_le_bytes())?; // n, patched in finish()
        file.write_all(&(chunk_cols as u32).to_le_bytes())?;
        Ok(ChunkStore { file, p, n: 0, chunk_cols })
    }

    /// Append a dense chunk (must have ≤ `chunk_cols` columns; only the
    /// final chunk may be short).
    pub fn append(&mut self, x: &Mat) -> Result<()> {
        if x.rows() != self.p {
            return Err(Error::Shape(format!("append: rows {} != p {}", x.rows(), self.p)));
        }
        if x.cols() > self.chunk_cols {
            return Err(Error::Shape(format!(
                "append: {} cols exceeds chunk size {}",
                x.cols(),
                self.chunk_cols
            )));
        }
        let mut buf = Vec::with_capacity(x.rows() * x.cols() * 4);
        for &v in x.as_slice() {
            buf.extend_from_slice(&(v as f32).to_le_bytes());
        }
        self.file.write_all(&buf)?;
        self.n += x.cols() as u64;
        Ok(())
    }

    /// Flush and patch the sample count into the header.
    pub fn finish(mut self) -> Result<()> {
        self.file.flush()?;
        let mut f = self.file.into_inner().map_err(|e| Error::Io(e.into_error()))?;
        f.seek(SeekFrom::Start(8))?;
        f.write_all(&self.n.to_le_bytes())?;
        f.sync_all()?;
        Ok(())
    }
}

/// Reader: stream chunks back.
pub struct ChunkStoreReader {
    file: BufReader<File>,
    p: usize,
    n: u64,
    chunk_cols: usize,
    cursor: u64, // columns consumed
}

impl ChunkStoreReader {
    /// Open an existing store and parse its header.
    pub fn open(path: &Path) -> Result<Self> {
        let mut file = BufReader::new(File::open(path)?);
        let mut magic = [0u8; 4];
        file.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::Invalid(format!("{}: not a PDS1 store", path.display())));
        }
        let mut b4 = [0u8; 4];
        let mut b8 = [0u8; 8];
        file.read_exact(&mut b4)?;
        let p = u32::from_le_bytes(b4) as usize;
        file.read_exact(&mut b8)?;
        let n = u64::from_le_bytes(b8);
        file.read_exact(&mut b4)?;
        let chunk_cols = u32::from_le_bytes(b4) as usize;
        Ok(ChunkStoreReader { file, p, n, chunk_cols, cursor: 0 })
    }

    /// Ambient dimension.
    pub fn p(&self) -> usize {
        self.p
    }

    /// Total samples in the store.
    pub fn n(&self) -> usize {
        self.n as usize
    }

    /// Columns per chunk (the last chunk may be short).
    pub fn chunk_cols(&self) -> usize {
        self.chunk_cols
    }

    /// Number of chunks in the store.
    pub fn num_chunks(&self) -> usize {
        ((self.n as usize) + self.chunk_cols - 1) / self.chunk_cols.max(1)
    }

    /// Read the next chunk; `None` at end of stream. Returns the chunk and
    /// the global index of its first column.
    pub fn next_chunk(&mut self) -> Result<Option<(Mat, usize)>> {
        if self.cursor >= self.n {
            return Ok(None);
        }
        let cols = (self.n - self.cursor).min(self.chunk_cols as u64) as usize;
        let mut raw = vec![0u8; self.p * cols * 4];
        self.file.read_exact(&mut raw)?;
        let mut data = Vec::with_capacity(self.p * cols);
        for q in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([q[0], q[1], q[2], q[3]]) as f64);
        }
        let start = self.cursor as usize;
        self.cursor += cols as u64;
        Ok(Some((Mat::from_vec(self.p, cols, data)?, start)))
    }

    /// Restart from the first chunk (a new "pass" over the data).
    pub fn rewind(&mut self) -> Result<()> {
        self.file.seek(SeekFrom::Start(HEADER_LEN))?;
        self.cursor = 0;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("pds_store_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn write_read_roundtrip() {
        let path = tmpfile("roundtrip");
        let mut rng = Pcg64::seed(1);
        let x = Mat::from_fn(6, 25, |_, _| rng.normal());
        {
            let mut store = ChunkStore::create(&path, 6, 10).unwrap();
            store.append(&x.col_range(0, 10)).unwrap();
            store.append(&x.col_range(10, 20)).unwrap();
            store.append(&x.col_range(20, 25)).unwrap();
            store.finish().unwrap();
        }
        let mut reader = ChunkStoreReader::open(&path).unwrap();
        assert_eq!(reader.p(), 6);
        assert_eq!(reader.n(), 25);
        assert_eq!(reader.num_chunks(), 3);
        let mut got_cols = 0usize;
        let mut starts = Vec::new();
        while let Some((chunk, start)) = reader.next_chunk().unwrap() {
            starts.push(start);
            for j in 0..chunk.cols() {
                for i in 0..6 {
                    let want = x.get(i, start + j);
                    assert!((chunk.get(i, j) - want).abs() < 1e-6, "f32 roundtrip");
                }
            }
            got_cols += chunk.cols();
        }
        assert_eq!(got_cols, 25);
        assert_eq!(starts, vec![0, 10, 20]);
        // second pass after rewind
        reader.rewind().unwrap();
        let (first, s0) = reader.next_chunk().unwrap().unwrap();
        assert_eq!(s0, 0);
        assert_eq!(first.cols(), 10);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let path = tmpfile("badmagic");
        std::fs::write(&path, b"NOPE====").unwrap();
        assert!(ChunkStoreReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_wrong_shape_append() {
        let path = tmpfile("badshape");
        let mut store = ChunkStore::create(&path, 4, 8).unwrap();
        assert!(store.append(&Mat::zeros(5, 2)).is_err());
        assert!(store.append(&Mat::zeros(4, 9)).is_err());
        std::fs::remove_file(&path).ok();
    }
}
