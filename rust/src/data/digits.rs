//! Synthetic 28×28 "digit" generator — the MNIST / Infinite-MNIST
//! substitution (DESIGN.md §2).
//!
//! Each class is a fixed stroke template (piecewise-linear strokes drawn
//! into the 28×28 grid with a Gaussian pen profile, mimicking the classes
//! "0", "3", "9"). Samples apply the Infinite-MNIST style augmentations:
//! integer translation (±2 px), small intensity scaling, and pixel noise.
//! The result has the properties the paper's digit experiments exercise:
//! highly non-uniform per-pixel energy (so unpreconditioned sampling is
//! bad), smooth spatial correlation, and well-separated class means.

use crate::linalg::Mat;
use crate::rng::Pcg64;

use super::Dataset;

const SIDE: usize = 28;
/// Ambient dimension of digit data (28×28).
pub const DIGIT_P: usize = SIDE * SIDE;

/// Configuration for the digit generator.
#[derive(Clone, Copy, Debug)]
pub struct DigitConfig {
    /// Number of classes (≤ 3 uses the paper's {0, 3, 9} templates; more
    /// classes add procedurally generated stroke templates).
    pub classes: usize,
    /// Max translation in pixels (paper's deformations are small shifts).
    pub max_shift: i32,
    /// Pixel noise std.
    pub noise: f64,
    /// Root seed for templates and per-sample deformations.
    pub seed: u64,
}

impl Default for DigitConfig {
    fn default() -> Self {
        DigitConfig { classes: 3, max_shift: 2, noise: 0.1, seed: 0 }
    }
}

fn put_stroke(img: &mut [f64], x0: f64, y0: f64, x1: f64, y1: f64) {
    // draw a stroke with a soft pen (Gaussian falloff, sigma ~ 1.1px)
    let steps = 60;
    for s in 0..=steps {
        let t = s as f64 / steps as f64;
        let cx = x0 + t * (x1 - x0);
        let cy = y0 + t * (y1 - y0);
        let lo_x = (cx - 3.0).max(0.0) as usize;
        let hi_x = (cx + 3.0).min(SIDE as f64 - 1.0) as usize;
        let lo_y = (cy - 3.0).max(0.0) as usize;
        let hi_y = (cy + 3.0).min(SIDE as f64 - 1.0) as usize;
        for yy in lo_y..=hi_y {
            for xx in lo_x..=hi_x {
                let d2 = (xx as f64 - cx).powi(2) + (yy as f64 - cy).powi(2);
                let v = (-d2 / (2.0 * 1.1 * 1.1)).exp();
                let px = &mut img[yy * SIDE + xx];
                *px = (*px + v).min(1.0);
            }
        }
    }
}

fn circle(img: &mut [f64], cx: f64, cy: f64, rx: f64, ry: f64, a0: f64, a1: f64) {
    let steps = 48;
    let mut prev: Option<(f64, f64)> = None;
    for s in 0..=steps {
        let a = a0 + (a1 - a0) * s as f64 / steps as f64;
        let x = cx + rx * a.cos();
        let y = cy + ry * a.sin();
        if let Some((px, py)) = prev {
            put_stroke(img, px, py, x, y);
        }
        prev = Some((x, y));
    }
}

/// Class templates. 0: ellipse; 1 ("3"): two stacked right-open bows;
/// 2 ("9"): loop + descender; ≥3: procedural zig-zag strokes.
fn template(class: usize, rng: &mut Pcg64) -> Vec<f64> {
    let mut img = vec![0.0; DIGIT_P];
    use std::f64::consts::PI;
    match class {
        0 => circle(&mut img, 14.0, 14.0, 6.5, 9.0, 0.0, 2.0 * PI),
        1 => {
            circle(&mut img, 13.0, 9.5, 5.5, 4.5, -0.6 * PI, 0.55 * PI);
            circle(&mut img, 13.0, 18.5, 5.5, 4.5, -0.55 * PI, 0.6 * PI);
        }
        2 => {
            circle(&mut img, 13.0, 10.0, 5.5, 5.0, 0.0, 2.0 * PI);
            put_stroke(&mut img, 18.5, 10.0, 17.0, 23.0);
        }
        _ => {
            // procedural class: random but fixed zig-zag
            let mut x = 6.0 + 16.0 * rng.next_f64();
            let mut y = 5.0;
            for _ in 0..4 {
                let nx = 5.0 + 18.0 * rng.next_f64();
                let ny = y + 4.5;
                put_stroke(&mut img, x, y, nx, ny);
                x = nx;
                y = ny;
            }
        }
    }
    img
}

fn shift_image(src: &[f64], dx: i32, dy: i32, out: &mut [f64]) {
    out.fill(0.0);
    for y in 0..SIDE as i32 {
        let sy = y - dy;
        if !(0..SIDE as i32).contains(&sy) {
            continue;
        }
        for x in 0..SIDE as i32 {
            let sx = x - dx;
            if (0..SIDE as i32).contains(&sx) {
                out[(y as usize) * SIDE + x as usize] = src[(sy as usize) * SIDE + sx as usize];
            }
        }
    }
}

/// Streaming digit generator: sample `idx` is a pure function of
/// `(cfg.seed, idx)`, so chunks can be produced in any order and replayed
/// across passes — the property the out-of-core experiments (Table IV)
/// and the [`GeneratorSource`](crate::coordinator::GeneratorSource) need.
pub struct DigitStream {
    cfg: DigitConfig,
    templates: Vec<Vec<f64>>,
    root: Pcg64,
}

impl DigitStream {
    /// Build the class templates and the replayable sample stream.
    pub fn new(cfg: DigitConfig) -> Self {
        let mut rng = Pcg64::seed(cfg.seed);
        let templates = (0..cfg.classes).map(|c| template(c, &mut rng)).collect();
        DigitStream { cfg, templates, root: Pcg64::seed(cfg.seed ^ 0xD161_7515) }
    }

    /// The clean class templates (p × classes).
    pub fn centers(&self) -> Mat {
        let mut centers = Mat::zeros(DIGIT_P, self.cfg.classes);
        for (c, t) in self.templates.iter().enumerate() {
            centers.col_mut(c).copy_from_slice(t);
        }
        centers
    }

    /// Ground-truth label of sample `idx`.
    pub fn label(&self, idx: usize) -> u32 {
        let mut rng = self.root.fork(idx as u64);
        rng.next_range(self.cfg.classes as u32)
    }

    /// Write sample `idx` into `out` (length p = 784).
    pub fn sample_into(&self, idx: usize, out: &mut [f64], shifted: &mut [f64]) {
        let mut rng = self.root.fork(idx as u64);
        let class = rng.next_range(self.cfg.classes as u32) as usize;
        let dx = rng.next_range((2 * self.cfg.max_shift + 1) as u32) as i32 - self.cfg.max_shift;
        let dy = rng.next_range((2 * self.cfg.max_shift + 1) as u32) as i32 - self.cfg.max_shift;
        shift_image(&self.templates[class], dx, dy, shifted);
        // modest intensity jitter: enough within-class spread to be
        // realistic, small enough that K-means does not prefer splitting
        // a high-ink class over separating two classes (calibrated so
        // full-data K-means lands near the paper's ~92% MNIST accuracy)
        let gain = 0.95 + 0.1 * rng.next_f64();
        for i in 0..DIGIT_P {
            out[i] = (gain * shifted[i] + self.cfg.noise * rng.normal()).max(0.0);
        }
    }

    /// Materialize columns `[start, start+cols)` as a dense chunk.
    pub fn chunk(&self, start: usize, cols: usize) -> Mat {
        let mut out = Mat::zeros(DIGIT_P, cols);
        let mut shifted = vec![0.0; DIGIT_P];
        for j in 0..cols {
            let mut buf = vec![0.0; DIGIT_P];
            self.sample_into(start + j, &mut buf, &mut shifted);
            out.col_mut(j).copy_from_slice(&buf);
        }
        out
    }

    /// Labels for a contiguous range.
    pub fn labels(&self, start: usize, n: usize) -> Vec<u32> {
        (start..start + n).map(|i| self.label(i)).collect()
    }
}

/// Generate `n` digit samples (p = 784, samples as columns, values in
/// [0, ~1.3]); in-memory convenience over [`DigitStream`].
pub fn digits(n: usize, cfg: DigitConfig) -> Dataset {
    let stream = DigitStream::new(cfg);
    Dataset { data: stream.chunk(0, n), labels: stream.labels(0, n), centers: stream.centers() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::clustering_accuracy;

    #[test]
    fn shapes_and_labels() {
        let d = digits(60, DigitConfig::default());
        assert_eq!(d.data.rows(), 784);
        assert_eq!(d.data.cols(), 60);
        assert_eq!(d.labels.len(), 60);
        assert!(d.labels.iter().all(|&l| l < 3));
        assert!(d.data.max_abs() > 0.5, "images should have ink");
    }

    #[test]
    fn classes_are_linearly_separable_by_nearest_template() {
        let cfg = DigitConfig { noise: 0.05, ..Default::default() };
        let d = digits(150, cfg);
        let pred: Vec<u32> = (0..150)
            .map(|j| {
                let x = d.data.col(j);
                let mut best = (f64::INFINITY, 0u32);
                for c in 0..3 {
                    let t = d.centers.col(c);
                    let dist: f64 = x.iter().zip(t).map(|(a, b)| (a - b) * (a - b)).sum();
                    if dist < best.0 {
                        best = (dist, c as u32);
                    }
                }
                best.1
            })
            .collect();
        let acc = clustering_accuracy(&pred, &d.labels, 3);
        assert!(acc > 0.95, "template-NN accuracy {acc}");
    }

    #[test]
    fn pixel_energy_is_nonuniform() {
        // the property that makes preconditioning matter: corner pixels are
        // almost always dark, center pixels carry the energy
        let d = digits(200, DigitConfig { noise: 0.0, ..Default::default() });
        let mut row_energy = vec![0.0f64; 784];
        for j in 0..200 {
            for (i, v) in d.data.col(j).iter().enumerate() {
                row_energy[i] += v * v;
            }
        }
        let max = row_energy.iter().cloned().fold(0.0f64, f64::max);
        let corner = row_energy[0];
        assert!(corner < 0.01 * max, "corner {corner} vs max {max}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = digits(10, DigitConfig::default());
        let b = digits(10, DigitConfig::default());
        assert_eq!(a.labels, b.labels);
        assert!((a.data.sub(&b.data)).max_abs() == 0.0);
    }

    #[test]
    fn procedural_classes_beyond_three() {
        let d = digits(40, DigitConfig { classes: 5, seed: 2, ..Default::default() });
        assert_eq!(d.centers.cols(), 5);
        assert!(d.labels.iter().any(|&l| l >= 3));
    }
}
