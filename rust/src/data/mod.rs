//! Synthetic workload generators matching each of the paper's experiments,
//! plus the out-of-core chunk store used for the big-data tests.
//!
//! MNIST substitution (see DESIGN.md §2): the digit experiments use
//! [`digits`], a deterministic generator of 28×28 stroke-structured
//! classes with per-sample deformations — same `p = 784`, same
//! three-class setup, same heavy spatial correlation structure that makes
//! preconditioning matter.

mod digits;
mod store;

pub use digits::{digits, DigitConfig, DigitStream, DIGIT_P};
pub use store::{ChunkStore, ChunkStoreReader};

use crate::linalg::{cholesky, orthonormalize, Mat};
use crate::rng::Pcg64;

/// A labeled synthetic dataset.
pub struct Dataset {
    /// p×n data, samples as columns.
    pub data: Mat,
    /// Ground-truth labels (empty when not applicable).
    pub labels: Vec<u32>,
    /// Ground-truth cluster centers / principal components when defined.
    pub centers: Mat,
}

/// Isotropic Gaussian blobs around `k` random centers (Fig. 6 workload).
/// Centers are drawn uniformly in `[-1,1]^p` scaled by `1/sqrt(p)`·4 so
/// clusters are well separated relative to `noise`.
pub fn gaussian_blobs(p: usize, n: usize, k: usize, noise: f64, rng: &mut Pcg64) -> Dataset {
    let centers = Mat::from_fn(p, k, |_, _| (2.0 * rng.next_f64() - 1.0) * 4.0 / (p as f64).sqrt());
    let mut data = Mat::zeros(p, n);
    let mut labels = Vec::with_capacity(n);
    for j in 0..n {
        let c = rng.next_range(k as u32);
        labels.push(c);
        let center = centers.col(c as usize);
        let col = data.col_mut(j);
        for i in 0..p {
            col[i] = center[i] + noise * rng.normal();
        }
    }
    Dataset { data, labels, centers }
}

/// Fig. 2 workload: `x_i = x̄ + ε_i`, `ε_i ~ N(0, I_p)`, fixed Gaussian `x̄`.
pub fn mean_plus_noise(p: usize, n: usize, rng: &mut Pcg64) -> Dataset {
    let xbar: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
    let mut data = Mat::zeros(p, n);
    for j in 0..n {
        let col = data.col_mut(j);
        for i in 0..p {
            col[i] = xbar[i] + rng.normal();
        }
    }
    let centers = Mat::from_vec(p, 1, xbar).unwrap();
    Dataset { data, labels: Vec::new(), centers }
}

/// Figs. 3/4 + Table I workload: the spiked model
/// `x_i = Σ_j κ_ij λ_j u_j` with iid `κ ~ N(0,1)`.
/// `canonical_pcs` picks the `u_j` as canonical basis vectors (the Fig. 4 /
/// Table I adversarial case); otherwise a random orthonormal basis.
pub fn spiked(
    p: usize,
    n: usize,
    lambdas: &[f64],
    canonical_pcs: bool,
    rng: &mut Pcg64,
) -> Dataset {
    let k = lambdas.len();
    let u = if canonical_pcs {
        // k distinct canonical basis vectors, chosen at random
        let mut idx: Vec<u32> = (0..p as u32).collect();
        rng.shuffle(&mut idx);
        let mut u = Mat::zeros(p, k);
        for (t, &i) in idx[..k].iter().enumerate() {
            u.set(i as usize, t, 1.0);
        }
        u
    } else {
        orthonormalize(&Mat::from_fn(p, k, |_, _| rng.normal()))
    };
    let mut data = Mat::zeros(p, n);
    for j in 0..n {
        let col = data.col_mut(j);
        for t in 0..k {
            let kap = rng.normal() * lambdas[t];
            let ucol = u.col(t);
            for i in 0..p {
                col[i] += kap * ucol[i];
            }
        }
    }
    Dataset { data, labels: Vec::new(), centers: u }
}

/// Fig. 1 workload: multivariate t with `df` degrees of freedom and
/// Toeplitz covariance `C_ij = 2·0.5^{|i−j|}`:
/// `x = L z / sqrt(χ²_df / df)` with `C = L Lᵀ`.
pub fn multivariate_t(p: usize, n: usize, df: f64, rng: &mut Pcg64) -> Dataset {
    let mut c = Mat::zeros(p, p);
    for i in 0..p {
        for j in 0..p {
            c.set(i, j, 2.0 * 0.5f64.powi((i as i32 - j as i32).abs()));
        }
    }
    let l = cholesky(&c).expect("Toeplitz covariance is SPD");
    let mut data = Mat::zeros(p, n);
    let mut z = vec![0.0; p];
    for jcol in 0..n {
        rng.fill_normal(&mut z);
        let denom = (rng.chi2(df) / df).sqrt().max(1e-12);
        let col = data.col_mut(jcol);
        // col = L z / denom  (L lower-triangular)
        for i in 0..p {
            let mut s = 0.0;
            for kk in 0..=i {
                s += l.get(i, kk) * z[kk];
            }
            col[i] = s / denom;
        }
    }
    Dataset { data, labels: Vec::new(), centers: Mat::zeros(p, 0) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_labels_consistent() {
        let mut rng = Pcg64::seed(1);
        let d = gaussian_blobs(16, 200, 4, 0.01, &mut rng);
        assert_eq!(d.labels.len(), 200);
        // each sample is closest to its own center
        for j in 0..200 {
            let truth = d.labels[j] as usize;
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..4 {
                let dist: f64 = d
                    .data
                    .col(j)
                    .iter()
                    .zip(d.centers.col(c))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            assert_eq!(best.1, truth, "sample {j}");
        }
    }

    #[test]
    fn spiked_canonical_basis() {
        let mut rng = Pcg64::seed(3);
        let d = spiked(32, 100, &[3.0, 2.0], true, &mut rng);
        // centers are canonical basis vectors
        for t in 0..2 {
            let col = d.centers.col(t);
            assert_eq!(col.iter().filter(|&&v| v == 1.0).count(), 1);
            assert_eq!(col.iter().filter(|&&v| v != 0.0).count(), 1);
        }
    }

    #[test]
    fn spiked_lies_in_span() {
        let mut rng = Pcg64::seed(5);
        let d = spiked(24, 50, &[2.0, 1.0, 0.5], false, &mut rng);
        // every sample is in the span of centers: residual after projection ~ 0
        for j in 0..50 {
            let x = d.data.col(j);
            let mut residual: Vec<f64> = x.to_vec();
            for t in 0..3 {
                let u = d.centers.col(t);
                let dot: f64 = u.iter().zip(x).map(|(a, b)| a * b).sum();
                for i in 0..24 {
                    residual[i] -= dot * u[i];
                }
            }
            let r: f64 = residual.iter().map(|v| v * v).sum();
            assert!(r < 1e-16, "residual {r}");
        }
    }

    #[test]
    fn mvt_heavy_tail_and_covariance_shape() {
        let mut rng = Pcg64::seed(7);
        let d = multivariate_t(8, 5000, 1.0, &mut rng);
        let maxabs = d.data.max_abs();
        assert!(maxabs > 50.0, "df=1 should produce extreme outliers, max={maxabs}");
    }

    #[test]
    fn mean_plus_noise_centers() {
        let mut rng = Pcg64::seed(9);
        let d = mean_plus_noise(8, 20_000, &mut rng);
        let mean = d.data.col_mean();
        for i in 0..8 {
            assert!((mean[i] - d.centers.get(i, 0)).abs() < 0.05);
        }
    }
}
