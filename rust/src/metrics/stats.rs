//! Small summary statistics used by every experiment table.

/// Mean and (population) standard deviation of a sample.
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Median (of a copy; input untouched).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Five-number-ish summary for experiment rows.
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n−1 denominator).
    pub std: f64,
    /// Smallest value.
    pub min: f64,
    /// Largest value.
    pub max: f64,
    /// Median value.
    pub median: f64,
}

impl Summary {
    /// Summarize a sample.
    pub fn of(xs: &[f64]) -> Summary {
        let (mean, std) = mean_std(xs);
        Summary {
            mean,
            std,
            min: xs.iter().copied().fold(f64::INFINITY, f64::min),
            max: xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            median: median(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0, 4.0]);
        assert!((m - 2.5).abs() < 1e-12);
        assert!((s - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn summary_bounds() {
        let s = Summary::of(&[2.0, -1.0, 5.0]);
        assert_eq!(s.min, -1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 2.0);
    }
}
