//! Serve-daemon observability: lock-free counters, gauges, and
//! log₂-bucketed latency histograms with p50/p99 readout, dumped as a
//! JSON object on a `stats` request and again at shutdown.
//!
//! Everything here is `AtomicU64`-based so the hot paths (one query, one
//! ingest batch) record without taking a lock, and a `stats` reader
//! never blocks a writer. Histogram percentiles are therefore
//! *bucketed* estimates: a reported p99 is the geometric midpoint of
//! the power-of-two microsecond bucket the true p99 falls in (≤ ~41%
//! relative error by construction), which is the standard trade for a
//! fixed-size lock-free histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Power-of-two microsecond buckets: bucket `i` counts latencies in
/// `[2^i, 2^{i+1})` µs (bucket 0 additionally absorbs sub-µs samples).
/// 40 buckets cover ~12.7 days — far past any per-request duration.
const BUCKETS: usize = 40;

/// Lock-free log₂ latency histogram (microsecond domain).
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one duration.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u128::from(u64::MAX)) as u64;
        let idx = if us <= 1 {
            0
        } else {
            ((63 - us.leading_zeros()) as usize).min(BUCKETS - 1)
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Bucketed quantile estimate in microseconds (`q` in `[0, 1]`);
    /// 0 when the histogram is empty.
    pub fn quantile_us(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= rank {
                // geometric midpoint of [2^i, 2^{i+1}) µs
                let lo = (1u64 << i) as f64;
                return lo * std::f64::consts::SQRT_2;
            }
        }
        self.max_us.load(Ordering::Relaxed) as f64
    }

    /// Mean latency in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Largest recorded latency in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// JSON object: `{"count":..,"mean_us":..,"p50_us":..,"p99_us":..,"max_us":..}`.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"count\":{},\"mean_us\":{:.3},\"p50_us\":{:.3},\"p99_us\":{:.3},\"max_us\":{}}}",
            self.count(),
            self.mean_us(),
            self.quantile_us(0.50),
            self.quantile_us(0.99),
            self.max_us()
        )
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// The serve daemon's counter/histogram registry. One instance lives for
/// the daemon's lifetime, shared by every request handler and worker
/// thread.
pub struct ServeMetrics {
    /// Requests received (every protocol line, well-formed or not).
    pub requests: AtomicU64,
    /// Requests answered with a typed error (`ok: false`).
    pub errors: AtomicU64,
    /// Ingest batches rejected because the bounded queue was full.
    pub backpressure_rejections: AtomicU64,
    /// Connections rejected because every transport worker slot was busy.
    pub conn_rejections: AtomicU64,
    /// Raw sample columns accepted into the ingest queue.
    pub ingested_rows: AtomicU64,
    /// Ingest batches accepted into the queue.
    pub ingested_batches: AtomicU64,
    /// Model refreshes that published a new snapshot.
    pub refreshes: AtomicU64,
    /// Model refreshes that failed (daemon degrades to the stale snapshot).
    pub refresh_failures: AtomicU64,
    /// Snapshot persists that failed (the model still serves from memory,
    /// but a restarted daemon would cold-start).
    pub snapshot_persist_failures: AtomicU64,
    /// Current ingest queue depth (batches accepted, not yet absorbed).
    pub queue_depth: AtomicU64,
    /// Coalesced query panels executed by the batching lane.
    pub batches_executed: AtomicU64,
    /// Samples answered through those panels (`batched_samples /
    /// batches_executed` is the realized mean batch size).
    pub batched_samples: AtomicU64,
    /// Per-query handler latency.
    pub query_latency: LatencyHistogram,
    /// Time a query request spent parked in the batching lane before
    /// its panel started executing.
    pub query_wait: LatencyHistogram,
    /// Kernel execution time of one coalesced panel (all samples).
    pub query_exec: LatencyHistogram,
    /// Per-ingest-request handler latency (parse + enqueue, not absorb).
    pub ingest_latency: LatencyHistogram,
    /// Full refresh-cycle duration (fold + merge + finalize + swap).
    pub refresh_duration: LatencyHistogram,
    started: Instant,
}

impl ServeMetrics {
    /// A zeroed registry with the uptime clock started now.
    pub fn new() -> Self {
        ServeMetrics {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            backpressure_rejections: AtomicU64::new(0),
            conn_rejections: AtomicU64::new(0),
            ingested_rows: AtomicU64::new(0),
            ingested_batches: AtomicU64::new(0),
            refreshes: AtomicU64::new(0),
            refresh_failures: AtomicU64::new(0),
            snapshot_persist_failures: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            batches_executed: AtomicU64::new(0),
            batched_samples: AtomicU64::new(0),
            query_latency: LatencyHistogram::new(),
            query_wait: LatencyHistogram::new(),
            query_exec: LatencyHistogram::new(),
            ingest_latency: LatencyHistogram::new(),
            refresh_duration: LatencyHistogram::new(),
            started: Instant::now(),
        }
    }

    /// Seconds since the registry was created.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Ingest throughput over the daemon's lifetime (rows/second).
    pub fn ingest_rows_per_s(&self) -> f64 {
        let up = self.uptime_s();
        if up <= 0.0 {
            0.0
        } else {
            self.ingested_rows.load(Ordering::Relaxed) as f64 / up
        }
    }

    /// The full registry as one JSON object (numbers only — no strings
    /// that would need escaping).
    pub fn to_json(&self) -> String {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        format!(
            "{{\"uptime_s\":{:.3},\"requests\":{},\"errors\":{},\
             \"backpressure_rejections\":{},\"conn_rejections\":{},\
             \"ingested_rows\":{},\"ingested_batches\":{},\
             \"ingest_rows_per_s\":{:.3},\"refreshes\":{},\
             \"refresh_failures\":{},\"snapshot_persist_failures\":{},\
             \"queue_depth\":{},\"batches_executed\":{},\
             \"batched_samples\":{},\"query_latency\":{},\
             \"query_wait\":{},\"query_exec\":{},\"ingest_latency\":{},\
             \"refresh_duration\":{}}}",
            self.uptime_s(),
            g(&self.requests),
            g(&self.errors),
            g(&self.backpressure_rejections),
            g(&self.conn_rejections),
            g(&self.ingested_rows),
            g(&self.ingested_batches),
            self.ingest_rows_per_s(),
            g(&self.refreshes),
            g(&self.refresh_failures),
            g(&self.snapshot_persist_failures),
            g(&self.queue_depth),
            g(&self.batches_executed),
            g(&self.batched_samples),
            self.query_latency.to_json(),
            self.query_wait.to_json(),
            self.query_exec.to_json(),
            self.ingest_latency.to_json(),
            self.refresh_duration.to_json()
        )
    }
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_quantiles() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.5), 0.0);
        for us in [1u64, 2, 3, 5, 9, 17, 100, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.max_us(), 1000);
        // p50 falls in a low bucket, p99 in the 1000 µs bucket
        let p50 = h.quantile_us(0.50);
        let p99 = h.quantile_us(0.99);
        assert!(p50 >= 1.0 && p50 <= 8.0, "p50 {p50}");
        assert!(p99 >= 512.0 && p99 <= 1024.0 * 2.0, "p99 {p99}");
        assert!(p50 <= p99);
        // the dump is a JSON object with the advertised keys
        let json = h.to_json();
        for key in ["count", "mean_us", "p50_us", "p99_us", "max_us"] {
            assert!(json.contains(key), "{json}");
        }
    }

    #[test]
    fn registry_dump_contains_every_series() {
        let m = ServeMetrics::new();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.ingested_rows.fetch_add(128, Ordering::Relaxed);
        m.query_latency.record(Duration::from_micros(7));
        let json = m.to_json();
        for key in [
            "uptime_s",
            "requests",
            "errors",
            "backpressure_rejections",
            "conn_rejections",
            "ingested_rows",
            "ingested_batches",
            "ingest_rows_per_s",
            "refreshes",
            "refresh_failures",
            "snapshot_persist_failures",
            "queue_depth",
            "batches_executed",
            "batched_samples",
            "query_latency",
            "query_wait",
            "query_exec",
            "ingest_latency",
            "refresh_duration",
        ] {
            assert!(json.contains(&format!("\"{key}\"")), "{key} missing from {json}");
        }
    }
}
