//! Evaluation metrics and measurement utilities.

mod hungarian;
pub mod serve;
mod stats;
mod timer;

pub use hungarian::{clustering_accuracy, hungarian_max};
pub use serve::{LatencyHistogram, ServeMetrics};
pub use stats::{mean_std, median, Summary};
pub use timer::Timer;
