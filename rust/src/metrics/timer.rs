//! Wall-clock timing with named phases — backs the paper's timing
//! breakdowns (Tables III/IV/V: total / sample / precondition / load).

use std::time::Instant;

/// Accumulating phase timer.
#[derive(Debug, Default)]
pub struct Timer {
    phases: Vec<(String, f64)>,
}

impl Timer {
    /// Empty timer.
    pub fn new() -> Self {
        Timer::default()
    }

    /// Time a closure and accumulate under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Add `secs` to phase `name` (creating it if new).
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.phases.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.phases.push((name.to_string(), secs));
        }
    }

    /// Seconds accumulated under `name` (0 if absent).
    pub fn get(&self, name: &str) -> f64 {
        self.phases.iter().find(|(n, _)| n == name).map(|(_, s)| *s).unwrap_or(0.0)
    }

    /// Total across all phases.
    pub fn total(&self) -> f64 {
        self.phases.iter().map(|(_, s)| s).sum()
    }

    /// `(name, seconds)` pairs — in insertion order for a timer that was
    /// only ever [`add`](Self::add)ed to, in **name order** after any
    /// [`merge`](Self::merge) (the canonical merged order).
    pub fn phases(&self) -> &[(String, f64)] {
        &self.phases
    }

    /// Merge another timer's phases into this one, then canonicalize the
    /// phase list to name order.
    ///
    /// The sort is the merge-law fix: without it, `a.merge(&b)` and
    /// `b.merge(&a)` reported the same totals in *different phase
    /// orders* (whichever side received kept its insertion order), so
    /// merged reports from distributed partials depended on merge order.
    /// Per-phase *sums* are still floating-point accumulations — exactly
    /// order-invariant only when the addends are exactly representable
    /// (e.g. the integer-quarters used in the regression tests); real
    /// wall-clock merges agree to f64 rounding.
    pub fn merge(&mut self, other: &Timer) {
        for (n, s) in &other.phases {
            self.add(n, *s);
        }
        self.phases.sort_by(|a, b| a.0.cmp(&b.0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_by_name() {
        let mut t = Timer::new();
        t.add("a", 1.0);
        t.add("b", 2.0);
        t.add("a", 0.5);
        assert_eq!(t.get("a"), 1.5);
        assert_eq!(t.get("b"), 2.0);
        assert_eq!(t.get("missing"), 0.0);
        assert!((t.total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = Timer::new();
        let v = t.time("work", || 42);
        assert_eq!(v, 42);
        assert!(t.get("work") >= 0.0);
    }

    #[test]
    fn merge() {
        let mut a = Timer::new();
        a.add("x", 1.0);
        let mut b = Timer::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert_eq!(a.get("x"), 3.0);
        assert_eq!(a.get("y"), 3.0);
    }

    #[test]
    fn merge_is_order_invariant() {
        // regression for the pre-distributed-fit bug: the merged phase
        // order followed the receiving timer's insertion order, so
        // a⊕b and b⊕a (same totals) printed different phase lists.
        // Values are integer quarters — exactly representable, so the
        // sums must be bitwise equal in every merge order too.
        let mk = |pairs: &[(&str, f64)]| {
            let mut t = Timer::new();
            for (n, s) in pairs {
                t.add(n, *s);
            }
            t
        };
        let a = mk(&[("load", 1.25), ("eig", 0.5)]);
        let b = mk(&[("accumulate", 2.75), ("load", 0.25)]);
        let c = mk(&[("eig", 4.5), ("accumulate", 0.25)]);

        let fold = |order: &[&Timer]| {
            let mut acc = Timer::new();
            for t in order {
                acc.merge(t);
            }
            acc
        };
        let reference = fold(&[&a, &b, &c]);
        for order in [[&a, &c, &b], [&b, &a, &c], [&c, &b, &a], [&c, &a, &b], [&b, &c, &a]] {
            let got = fold(&order);
            assert_eq!(got.phases().len(), reference.phases().len());
            for ((n1, s1), (n2, s2)) in got.phases().iter().zip(reference.phases()) {
                assert_eq!(n1, n2, "phase order must be canonical");
                assert_eq!(s1.to_bits(), s2.to_bits(), "phase {n1} sum drifted");
            }
        }
        // canonical order is name order
        let names: Vec<&str> = reference.phases().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["accumulate", "eig", "load"]);
    }

    #[test]
    fn single_timer_keeps_insertion_order() {
        // the CLI prints phases in the order the driver timed them; only
        // merge canonicalizes
        let mut t = Timer::new();
        t.add("z_load", 1.0);
        t.add("a_eig", 2.0);
        let names: Vec<&str> = t.phases().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["z_load", "a_eig"]);
    }
}
