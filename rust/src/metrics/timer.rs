//! Wall-clock timing with named phases — backs the paper's timing
//! breakdowns (Tables III/IV/V: total / sample / precondition / load).

use std::time::Instant;

/// Accumulating phase timer.
#[derive(Debug, Default)]
pub struct Timer {
    phases: Vec<(String, f64)>,
}

impl Timer {
    /// Empty timer.
    pub fn new() -> Self {
        Timer::default()
    }

    /// Time a closure and accumulate under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Add `secs` to phase `name` (creating it if new).
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.phases.iter_mut().find(|(n, _)| n == name) {
            e.1 += secs;
        } else {
            self.phases.push((name.to_string(), secs));
        }
    }

    /// Seconds accumulated under `name` (0 if absent).
    pub fn get(&self, name: &str) -> f64 {
        self.phases.iter().find(|(n, _)| n == name).map(|(_, s)| *s).unwrap_or(0.0)
    }

    /// Total across all phases.
    pub fn total(&self) -> f64 {
        self.phases.iter().map(|(_, s)| s).sum()
    }

    /// `(name, seconds)` pairs in insertion order.
    pub fn phases(&self) -> &[(String, f64)] {
        &self.phases
    }

    /// Merge another timer's phases into this one.
    pub fn merge(&mut self, other: &Timer) {
        for (n, s) in &other.phases {
            self.add(n, *s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_by_name() {
        let mut t = Timer::new();
        t.add("a", 1.0);
        t.add("b", 2.0);
        t.add("a", 0.5);
        assert_eq!(t.get("a"), 1.5);
        assert_eq!(t.get("b"), 2.0);
        assert_eq!(t.get("missing"), 0.0);
        assert!((t.total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn time_closure_returns_value() {
        let mut t = Timer::new();
        let v = t.time("work", || 42);
        assert_eq!(v, 42);
        assert!(t.get("work") >= 0.0);
    }

    #[test]
    fn merge() {
        let mut a = Timer::new();
        a.add("x", 1.0);
        let mut b = Timer::new();
        b.add("x", 2.0);
        b.add("y", 3.0);
        a.merge(&b);
        assert_eq!(a.get("x"), 3.0);
        assert_eq!(a.get("y"), 3.0);
    }
}
