//! Hungarian (Kuhn–Munkres) assignment and label-permutation clustering
//! accuracy — the paper's accuracy metric (correct assignments after the
//! best cluster↔class matching, normalized by n).

/// Maximum-weight perfect matching on a square `n×n` benefit matrix
/// (row-major `benefit[i][j]`), returned as `perm[row] = col`.
/// O(n³) potentials implementation of the Hungarian algorithm.
pub fn hungarian_max(benefit: &[Vec<f64>]) -> Vec<usize> {
    let n = benefit.len();
    if n == 0 {
        return Vec::new();
    }
    // Convert to min-cost with a large offset.
    let maxval = benefit
        .iter()
        .flat_map(|r| r.iter())
        .fold(f64::NEG_INFINITY, |m, &v| m.max(v));
    let cost = |i: usize, j: usize| maxval - benefit[i][j];

    // Standard O(n³) Hungarian with potentials (1-indexed internals).
    let inf = f64::INFINITY;
    let mut u = vec![0.0; n + 1];
    let mut v = vec![0.0; n + 1];
    let mut p = vec![0usize; n + 1]; // p[col] = row matched to col (1-indexed)
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost(i0 - 1, j - 1) - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut perm = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            perm[p[j] - 1] = j - 1;
        }
    }
    perm
}

/// Clustering accuracy: fraction of samples whose predicted cluster maps
/// to their true label under the best cluster↔label matching.
/// `k` must upper-bound both label alphabets.
pub fn clustering_accuracy(pred: &[u32], truth: &[u32], k: usize) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 1.0;
    }
    let mut confusion = vec![vec![0.0f64; k]; k];
    for (&a, &b) in pred.iter().zip(truth) {
        confusion[a as usize][b as usize] += 1.0;
    }
    let perm = hungarian_max(&confusion);
    let correct: f64 = (0..k).map(|c| confusion[c][perm[c]]).sum();
    correct / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::forall;

    #[test]
    fn identity_matching() {
        let benefit = vec![
            vec![10.0, 1.0, 1.0],
            vec![1.0, 10.0, 1.0],
            vec![1.0, 1.0, 10.0],
        ];
        assert_eq!(hungarian_max(&benefit), vec![0, 1, 2]);
    }

    #[test]
    fn crossed_matching() {
        let benefit = vec![vec![1.0, 9.0], vec![9.0, 1.0]];
        assert_eq!(hungarian_max(&benefit), vec![1, 0]);
    }

    #[test]
    fn matches_brute_force_on_random_instances() {
        forall("hungarian_vs_brute", 40, |g| {
            let n = g.int(1, 5) as usize;
            let benefit: Vec<Vec<f64>> =
                (0..n).map(|_| (0..n).map(|_| g.float(0.0, 10.0)).collect()).collect();
            let perm = hungarian_max(&benefit);
            let got: f64 = (0..n).map(|i| benefit[i][perm[i]]).sum();
            // brute force over all permutations
            let mut idx: Vec<usize> = (0..n).collect();
            let mut best = f64::NEG_INFINITY;
            permute(&mut idx, 0, &mut |p| {
                let s: f64 = (0..n).map(|i| benefit[i][p[i]]).sum();
                if s > best {
                    best = s;
                }
            });
            assert!((got - best).abs() < 1e-9, "got {got} best {best}");
        });
    }

    fn permute(v: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
        if k == v.len() {
            f(v);
            return;
        }
        for i in k..v.len() {
            v.swap(k, i);
            permute(v, k + 1, f);
            v.swap(k, i);
        }
    }

    #[test]
    fn accuracy_perfect_after_relabel() {
        let pred = [1u32, 1, 0, 0, 2, 2];
        let truth = [0u32, 0, 1, 1, 2, 2];
        assert!((clustering_accuracy(&pred, &truth, 3) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn accuracy_partial() {
        let pred = [0u32, 0, 0, 1];
        let truth = [0u32, 0, 1, 1];
        assert!((clustering_accuracy(&pred, &truth, 2) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn accuracy_is_permutation_invariant() {
        forall("acc_perm_invariant", 20, |g| {
            let n = 50;
            let k = 4usize;
            let truth: Vec<u32> = (0..n).map(|_| g.int(0, k as i64 - 1) as u32).collect();
            let pred: Vec<u32> = truth
                .iter()
                .map(|&t| if g.bool(0.8) { t } else { g.int(0, k as i64 - 1) as u32 })
                .collect();
            let base = clustering_accuracy(&pred, &truth, k);
            // relabel clusters by a fixed permutation
            let relabeled: Vec<u32> = pred.iter().map(|&c| (c + 1) % k as u32).collect();
            let after = clustering_accuracy(&relabeled, &truth, k);
            assert!((base - after).abs() < 1e-12);
        });
    }
}
