//! Explicit-SIMD kernel layer for the three hot paths (FWHT butterflies,
//! sparse-dense assignment distances, covariance dot/scatter), with a
//! scalar fallback and one-time runtime dispatch.
//!
//! # Dispatch
//!
//! [`detect`] probes the CPU once (cached) and returns the widest
//! supported [`Isa`]; `PDS_SIMD=scalar|sse2|avx2` caps the result for
//! A/B runs (never raises it above what the CPU supports). [`active`]
//! is what the hot paths consult; [`force`] overrides it process-wide
//! and exists for the single-threaded bench harness, which times
//! scalar-vs-SIMD arms inside one process — tests use the explicit
//! `isa` parameter on each kernel instead, because `force` is global
//! state and `cargo test` runs in parallel.
//!
//! # Invariance contract
//!
//! Every kernel here is **bitwise identical** to its scalar reference in
//! `f64`: the vector arithmetic performs the same additions and
//! multiplications on the same operands in the same order as the scalar
//! chains (lane-parallelism only reorders *independent* work). The
//! property tests in this module pin that equality across odd lengths,
//! misaligned offsets, and duplicate slots, so the repo-wide guarantee —
//! bitwise invariance to worker count and chunk granularity — holds not
//! just *within* an ISA mode but *across* Scalar/SSE2/AVX2 on the same
//! inputs. The `f32` storage mode differs from `f64` only by the initial
//! value quantization (≤ 0.5 ulp of `f32` per stored value, exact
//! widening afterwards); see `Precision` in [`crate::sparse`].
//!
//! # Kernel notes (measured on AVX2, see `BENCH_hotpaths.json`)
//!
//! * FWHT: a fused 16-wide first pass (stages h=1,2,4,8 via
//!   `hadd/hsub/blend` in-register butterflies) plus 4-wide radix-4 and
//!   radix-16 stage kernels; radix-16 is restricted to strides ≤ 256
//!   because at an 8 KB stride its 16 concurrent lines alias into one
//!   L1 set and thrash an 8-way cache.
//! * Assignment: a 4-center kernel over a transposed center panel with
//!   broadcast column values — AVX2 *gathers* lose to scalar here
//!   (centers are L1-resident), so the single-center distance used by
//!   k-means++ seeding stays scalar everywhere.
//! * Dot/scatter: per-column fused axpy kernels; the block width
//!   `b ≈ 5–14` is too short to pay a non-inlinable `target_feature`
//!   call per nonzero slot.

#[cfg(target_arch = "x86_64")]
pub(crate) mod x86;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Instruction-set tier a kernel can be dispatched at. Ordered:
/// `Scalar < Sse2 < Avx2`, so `min` with [`detect`] clamps a request to
/// what the CPU supports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Isa {
    /// Portable scalar path — bit-identical to the pre-SIMD kernels.
    Scalar,
    /// 2-wide `f64` (x86-64 baseline): FWHT stages and dot/scatter; the
    /// assignment kernel has no SSE2 variant and falls back to scalar.
    Sse2,
    /// 4-wide `f64` via AVX2: all three hot paths.
    Avx2,
}

impl Isa {
    /// Stable lowercase name (CLI/env/bench row labels).
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
        }
    }

    /// Parse a lowercase tier name as accepted by `PDS_SIMD`.
    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            _ => None,
        }
    }
}

fn detect_raw() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Isa::Avx2;
        }
        // SSE2 is part of the x86-64 baseline.
        return Isa::Sse2;
    }
    #[allow(unreachable_code)]
    Isa::Scalar
}

/// Widest [`Isa`] this process will dispatch to: the CPU's best tier,
/// optionally capped (never raised) by the `PDS_SIMD` env var. Probed
/// once and cached.
pub fn detect() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        let hw = detect_raw();
        match std::env::var("PDS_SIMD") {
            Ok(s) => match Isa::parse(&s) {
                Some(req) => req.min(hw),
                None => {
                    eprintln!(
                        "warning: PDS_SIMD={s:?} not one of scalar|sse2|avx2; ignoring"
                    );
                    hw
                }
            },
            Err(_) => hw,
        }
    })
}

/// `force(None)` state: defer to [`detect`].
static FORCED: AtomicU8 = AtomicU8::new(0);

/// Override [`active`] process-wide (clamped to [`detect`], so forcing a
/// tier the CPU lacks is safe). `force(None)` restores auto-detection.
///
/// Intended for the single-threaded bench harness only — this is global
/// state, so racing it against concurrent kernel calls (e.g. parallel
/// `cargo test`) makes *which* tier runs nondeterministic (never unsafe:
/// every tier computes bit-identical `f64` results).
pub fn force(isa: Option<Isa>) {
    let v = match isa {
        None => 0,
        Some(Isa::Scalar) => 1,
        Some(Isa::Sse2) => 2,
        Some(Isa::Avx2) => 3,
    };
    FORCED.store(v, Ordering::Relaxed);
}

/// The [`Isa`] hot paths should dispatch at right now: the [`force`]d
/// tier if set (clamped to [`detect`]), else [`detect`].
pub fn active() -> Isa {
    match FORCED.load(Ordering::Relaxed) {
        1 => Isa::Scalar,
        2 => Isa::Sse2.min(detect()),
        3 => Isa::Avx2.min(detect()),
        _ => detect(),
    }
}

// ---------------------------------------------------------------------
// Scalar reference kernels. These are the ground truth the SIMD variants
// are pinned against, and the dispatch fallback.
// ---------------------------------------------------------------------

/// Masked squared distances from one sparse column to a group of 4
/// centers stored as a transposed panel (`panel[j * 4 + c]` = coordinate
/// `j` of center `c`; length `4 * p`). Scalar reference: each lane `c`
/// runs exactly the dual-accumulator chain of the single-center
/// `masked_dist2` (pairs into `s0`/`s1`, odd tail into `s0`).
pub fn masked_dist2_x4_scalar(
    indices: &[u32],
    values: &[f64],
    panel: &[f64],
    out: &mut [f64; 4],
) {
    assert_eq!(indices.len(), values.len());
    let mut s0 = [0.0f64; 4];
    let mut s1 = [0.0f64; 4];
    let pairs = indices.len() / 2;
    for t in 0..pairs {
        let j0 = indices[2 * t] as usize * 4;
        let j1 = indices[2 * t + 1] as usize * 4;
        let v0 = values[2 * t];
        let v1 = values[2 * t + 1];
        for c in 0..4 {
            let d0 = v0 - panel[j0 + c];
            let d1 = v1 - panel[j1 + c];
            s0[c] += d0 * d0;
            s1[c] += d1 * d1;
        }
    }
    if indices.len() % 2 == 1 {
        let last = indices.len() - 1;
        let j = indices[last] as usize * 4;
        let v = values[last];
        for c in 0..4 {
            let d = v - panel[j + c];
            s0[c] += d * d;
        }
    }
    for c in 0..4 {
        out[c] = s0[c] + s1[c];
    }
}

/// [`masked_dist2_x4_scalar`] over `f32` stored values, widened exactly
/// to `f64` before the arithmetic (all accumulation stays `f64`).
pub fn masked_dist2_x4_f32_scalar(
    indices: &[u32],
    values: &[f32],
    panel: &[f64],
    out: &mut [f64; 4],
) {
    assert_eq!(indices.len(), values.len());
    let mut s0 = [0.0f64; 4];
    let mut s1 = [0.0f64; 4];
    let pairs = indices.len() / 2;
    for t in 0..pairs {
        let j0 = indices[2 * t] as usize * 4;
        let j1 = indices[2 * t + 1] as usize * 4;
        let v0 = values[2 * t] as f64;
        let v1 = values[2 * t + 1] as f64;
        for c in 0..4 {
            let d0 = v0 - panel[j0 + c];
            let d1 = v1 - panel[j1 + c];
            s0[c] += d0 * d0;
            s1[c] += d1 * d1;
        }
    }
    if indices.len() % 2 == 1 {
        let last = indices.len() - 1;
        let j = indices[last] as usize * 4;
        let v = values[last] as f64;
        for c in 0..4 {
            let d = v - panel[j + c];
            s0[c] += d * d;
        }
    }
    for c in 0..4 {
        out[c] = s0[c] + s1[c];
    }
}

/// Accumulate one sparse column's contribution to the dot phase:
/// `dcol[i] += values[t] * bt[indices[t] * b + i]` for every nonzero
/// slot `t` and `i < b = dcol.len()` (`bt` is the transposed block,
/// row-major `p × b`). Scalar reference for the estimator phase-1 loop.
pub fn col_dot_scalar(dcol: &mut [f64], indices: &[u32], values: &[f64], bt: &[f64]) {
    assert_eq!(indices.len(), values.len());
    let b = dcol.len();
    for (t, &j) in indices.iter().enumerate() {
        let v = values[t];
        let col = &bt[j as usize * b..j as usize * b + b];
        for (d, x) in dcol.iter_mut().zip(col) {
            *d += v * x;
        }
    }
}

/// Scatter one column's dot vector back to the output rows:
/// `out[(indices[t] - row_base) * b + i] += values[t] * dcol[i]` for
/// every slot `t` (all `indices` must lie in
/// `[row_base, row_base + out.len()/b)`). Scalar reference for the
/// estimator phase-2 loop.
pub fn col_scatter_scalar(
    out: &mut [f64],
    indices: &[u32],
    values: &[f64],
    row_base: u32,
    dcol: &[f64],
) {
    assert_eq!(indices.len(), values.len());
    let b = dcol.len();
    for (t, &j) in indices.iter().enumerate() {
        let v = values[t];
        let o = (j - row_base) as usize * b;
        let orow = &mut out[o..o + b];
        for (o, x) in orow.iter_mut().zip(dcol) {
            *o += v * x;
        }
    }
}

// ---------------------------------------------------------------------
// Safe dispatchers. Each clamps `isa` to the detected tier, validates
// bounds, then calls the matching kernel; tiers without a variant fall
// back down (results are bit-identical either way).
// ---------------------------------------------------------------------

#[inline]
fn clamp(isa: Isa) -> Isa {
    isa.min(detect())
}

/// Dispatched [`masked_dist2_x4_scalar`]: AVX2 uses the 4-lane panel
/// kernel; SSE2 has no variant and runs scalar.
pub fn masked_dist2_x4(
    isa: Isa,
    indices: &[u32],
    values: &[f64],
    panel: &[f64],
    out: &mut [f64; 4],
) {
    match clamp(isa) {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            assert_eq!(indices.len(), values.len());
            assert!(indices.iter().all(|&j| j as usize * 4 + 4 <= panel.len()));
            // SAFETY: AVX2 is detected (clamp) and indices are in-bounds
            // for `panel` (asserted above).
            unsafe { x86::masked_dist2_x4_avx2(indices, values, panel, out) }
        }
        _ => masked_dist2_x4_scalar(indices, values, panel, out),
    }
}

/// Dispatched [`masked_dist2_x4_f32_scalar`] (packed `f32` storage,
/// `f64` accumulation).
pub fn masked_dist2_x4_f32(
    isa: Isa,
    indices: &[u32],
    values: &[f32],
    panel: &[f64],
    out: &mut [f64; 4],
) {
    match clamp(isa) {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            assert_eq!(indices.len(), values.len());
            assert!(indices.iter().all(|&j| j as usize * 4 + 4 <= panel.len()));
            // SAFETY: AVX2 detected; indices in-bounds (asserted).
            unsafe { x86::masked_dist2_x4_f32_avx2(indices, values, panel, out) }
        }
        _ => masked_dist2_x4_f32_scalar(indices, values, panel, out),
    }
}

/// Dispatched [`col_dot_scalar`] (4-wide on AVX2, 2-wide on SSE2).
pub fn col_dot(isa: Isa, dcol: &mut [f64], indices: &[u32], values: &[f64], bt: &[f64]) {
    let b = dcol.len();
    match clamp(isa) {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            assert_eq!(indices.len(), values.len());
            assert!(indices.iter().all(|&j| j as usize * b + b <= bt.len()));
            // SAFETY: AVX2 detected; indices in-bounds for `bt`.
            unsafe { x86::col_dot_avx2(dcol, indices, values, bt) }
        }
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => {
            assert_eq!(indices.len(), values.len());
            assert!(indices.iter().all(|&j| j as usize * b + b <= bt.len()));
            // SAFETY: SSE2 is the x86-64 baseline; indices in-bounds.
            unsafe { x86::col_dot_sse2(dcol, indices, values, bt) }
        }
        _ => col_dot_scalar(dcol, indices, values, bt),
    }
}

/// Dispatched [`col_scatter_scalar`] (4-wide on AVX2, 2-wide on SSE2).
pub fn col_scatter(
    isa: Isa,
    out: &mut [f64],
    indices: &[u32],
    values: &[f64],
    row_base: u32,
    dcol: &[f64],
) {
    let b = dcol.len();
    match clamp(isa) {
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            assert_eq!(indices.len(), values.len());
            assert!(indices.iter().all(
                |&j| j >= row_base && (j - row_base) as usize * b + b <= out.len()
            ));
            // SAFETY: AVX2 detected; local rows in-bounds for `out`.
            unsafe { x86::col_scatter_avx2(out, indices, values, row_base, dcol) }
        }
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => {
            assert_eq!(indices.len(), values.len());
            assert!(indices.iter().all(
                |&j| j >= row_base && (j - row_base) as usize * b + b <= out.len()
            ));
            // SAFETY: SSE2 baseline; local rows in-bounds for `out`.
            unsafe { x86::col_scatter_sse2(out, indices, values, row_base, dcol) }
        }
        _ => col_scatter_scalar(out, indices, values, row_base, dcol),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// ISA tiers available on the test machine (always includes Scalar).
    fn tiers() -> Vec<Isa> {
        let mut v = vec![Isa::Scalar];
        if detect() >= Isa::Sse2 {
            v.push(Isa::Sse2);
        }
        if detect() >= Isa::Avx2 {
            v.push(Isa::Avx2);
        }
        v
    }

    /// Random strictly-increasing index set of size `m` into `0..p`,
    /// optionally with a duplicated weighted slot appended (the kernels
    /// must handle repeated indices — weighted chunks produce them).
    fn random_slots(
        rng: &mut Pcg64,
        p: usize,
        m: usize,
        dup: bool,
    ) -> (Vec<u32>, Vec<f64>) {
        let mut idx: Vec<u32> = Vec::with_capacity(m);
        let mut seen = vec![false; p];
        while idx.len() < m {
            let j = rng.next_range(p as u32);
            if !seen[j as usize] {
                seen[j as usize] = true;
                idx.push(j);
            }
        }
        idx.sort_unstable();
        let mut vals: Vec<f64> = (0..m).map(|_| rng.normal()).collect();
        if dup && m > 0 {
            idx.push(idx[m - 1]);
            vals.push(rng.normal() * 2.0);
        }
        (idx, vals)
    }

    #[test]
    fn masked_dist2_x4_matches_per_lane_reference() {
        // the scalar x4 kernel must equal four independent runs of the
        // k-means++ `masked_dist2` chain (same pairing, same order)
        let mut rng = Pcg64::seed(11);
        for &(p, m) in &[(16usize, 1usize), (64, 5), (128, 17), (512, 51)] {
            for dup in [false, true] {
                let (idx, vals) = random_slots(&mut rng, p, m, dup);
                let centers: Vec<Vec<f64>> = (0..4)
                    .map(|_| (0..p).map(|_| rng.normal()).collect())
                    .collect();
                let mut panel = vec![0.0f64; 4 * p];
                for (c, col) in centers.iter().enumerate() {
                    for (j, &v) in col.iter().enumerate() {
                        panel[j * 4 + c] = v;
                    }
                }
                let mut got = [0.0f64; 4];
                masked_dist2_x4_scalar(&idx, &vals, &panel, &mut got);
                for c in 0..4 {
                    let want = crate::kmeans::plusplus::masked_dist2(
                        &idx,
                        &vals,
                        &centers[c],
                    );
                    assert_eq!(
                        got[c].to_bits(),
                        want.to_bits(),
                        "p={p} m={m} dup={dup} lane {c}"
                    );
                }
            }
        }
    }

    #[test]
    fn masked_dist2_x4_simd_bitwise_matches_scalar() {
        let mut rng = Pcg64::seed(12);
        for isa in tiers() {
            for &(p, m) in &[(16usize, 1usize), (32, 2), (64, 7), (256, 33), (512, 52)]
            {
                for dup in [false, true] {
                    let (idx, vals) = random_slots(&mut rng, p, m, dup);
                    let panel: Vec<f64> =
                        (0..4 * p).map(|_| rng.normal()).collect();
                    let mut want = [0.0f64; 4];
                    masked_dist2_x4_scalar(&idx, &vals, &panel, &mut want);
                    let mut got = [0.0f64; 4];
                    masked_dist2_x4(isa, &idx, &vals, &panel, &mut got);
                    for c in 0..4 {
                        assert_eq!(
                            got[c].to_bits(),
                            want[c].to_bits(),
                            "isa={} p={p} m={m} dup={dup} lane {c}",
                            isa.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn masked_dist2_x4_f32_simd_matches_scalar_and_widening() {
        // the f32-storage kernel equals the scalar f32 reference bit for
        // bit, and both equal the f64 kernel run on exactly-widened
        // values (f32 -> f64 is exact, so the arithmetic is identical)
        let mut rng = Pcg64::seed(13);
        for isa in tiers() {
            for &(p, m) in &[(64usize, 7usize), (128, 20), (512, 51)] {
                let (idx, vals64) = random_slots(&mut rng, p, m, false);
                let vals32: Vec<f32> = vals64.iter().map(|&v| v as f32).collect();
                let widened: Vec<f64> = vals32.iter().map(|&v| v as f64).collect();
                let panel: Vec<f64> = (0..4 * p).map(|_| rng.normal()).collect();
                let mut want = [0.0f64; 4];
                masked_dist2_x4_f32_scalar(&idx, &vals32, &panel, &mut want);
                let mut got = [0.0f64; 4];
                masked_dist2_x4_f32(isa, &idx, &vals32, &panel, &mut got);
                let mut via_f64 = [0.0f64; 4];
                masked_dist2_x4(isa, &idx, &widened, &panel, &mut via_f64);
                for c in 0..4 {
                    assert_eq!(got[c].to_bits(), want[c].to_bits(), "isa={}", isa.name());
                    assert_eq!(got[c].to_bits(), via_f64[c].to_bits());
                }
            }
        }
    }

    #[test]
    fn col_dot_and_scatter_bitwise_match_scalar() {
        // b sweeps through every remainder class of the 4-wide and
        // 2-wide kernels, including b < lane width
        let mut rng = Pcg64::seed(14);
        for isa in tiers() {
            for &b in &[1usize, 2, 3, 4, 5, 7, 8, 11, 13, 14, 16, 17] {
                for &(p, m) in &[(32usize, 5usize), (256, 77)] {
                    for dup in [false, true] {
                        let (idx, vals) = random_slots(&mut rng, p, m, dup);
                        let bt: Vec<f64> = (0..p * b).map(|_| rng.normal()).collect();
                        let mut want = vec![0.0f64; b];
                        let mut got = vec![0.0f64; b];
                        // seed accumulators with a nonzero prefix sum
                        for i in 0..b {
                            want[i] = (i as f64) * 0.25;
                            got[i] = (i as f64) * 0.25;
                        }
                        col_dot_scalar(&mut want, &idx, &vals, &bt);
                        col_dot(isa, &mut got, &idx, &vals, &bt);
                        for i in 0..b {
                            assert_eq!(
                                got[i].to_bits(),
                                want[i].to_bits(),
                                "col_dot isa={} b={b} i={i}",
                                isa.name()
                            );
                        }
                        // scatter the (shared) dot vector back out, with
                        // a nonzero row base to exercise offsetting
                        let row_base = 0u32;
                        let mut owant = vec![0.1f64; p * b];
                        let mut ogot = owant.clone();
                        col_scatter_scalar(&mut owant, &idx, &vals, row_base, &want);
                        col_scatter(isa, &mut ogot, &idx, &vals, row_base, &want);
                        assert!(owant
                            .iter()
                            .zip(&ogot)
                            .all(|(a, b)| a.to_bits() == b.to_bits()));
                    }
                }
            }
        }
    }

    #[test]
    fn col_scatter_respects_row_base_window() {
        let mut rng = Pcg64::seed(15);
        let b = 6usize;
        // indices restricted to [100, 160); output covers only that window
        let idx: Vec<u32> = (0..24).map(|t| 100 + 2 * t + (t % 2)).collect();
        let vals: Vec<f64> = (0..24).map(|_| rng.normal()).collect();
        let dcol: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
        let rows = 60usize;
        for isa in tiers() {
            let mut want = vec![0.0f64; rows * b];
            let mut got = want.clone();
            col_scatter_scalar(&mut want, &idx, &vals, 100, &dcol);
            col_scatter(isa, &mut got, &idx, &vals, 100, &dcol);
            assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn misaligned_slices_are_handled() {
        // loadu-only kernels must accept arbitrarily offset slices: run
        // the same workload through sub-slices starting at odd offsets
        let mut rng = Pcg64::seed(16);
        let p = 128usize;
        let m = 21usize;
        let raw: Vec<f64> = (0..4 * p + 3).map(|_| rng.normal()).collect();
        let panel = &raw[3..3 + 4 * p]; // 8-byte aligned, 32-byte misaligned
        let (idx, vals) = random_slots(&mut rng, p, m, false);
        for isa in tiers() {
            let mut want = [0.0f64; 4];
            masked_dist2_x4_scalar(&idx, &vals, panel, &mut want);
            let mut got = [0.0f64; 4];
            masked_dist2_x4(isa, &idx, &vals, panel, &mut got);
            for c in 0..4 {
                assert_eq!(got[c].to_bits(), want[c].to_bits(), "isa={}", isa.name());
            }
        }
    }

    #[test]
    fn env_and_force_are_clamped_to_detect() {
        // force above the detected tier must clamp, never crash
        force(Some(Isa::Avx2));
        assert!(active() <= detect());
        force(Some(Isa::Scalar));
        assert_eq!(active(), Isa::Scalar);
        force(None);
        assert_eq!(active(), detect());
    }

    #[test]
    fn isa_parse_roundtrips() {
        for isa in [Isa::Scalar, Isa::Sse2, Isa::Avx2] {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
        }
        assert_eq!(Isa::parse("avx512"), None);
    }
}
