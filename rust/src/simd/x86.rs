//! x86-64 kernel variants (AVX2 4-wide, SSE2 2-wide). Every function
//! here is a transliteration of the scalar reference chain in
//! [`super`] / [`crate::transform`] with lane-parallelism over
//! *independent* butterflies/centers/rows only — the per-result
//! floating-point operation sequence is unchanged, so outputs are
//! bitwise identical to the scalar path (pinned by the property tests
//! in `simd::tests` and `transform::fwht::tests`).
//!
//! No FMA anywhere: `a + b*c` contracted to a fused multiply-add rounds
//! once instead of twice and would break bit-identity with the scalar
//! kernels, so every multiply-accumulate is an explicit
//! `add(mul(..))` pair.
//!
//! # Safety
//!
//! All functions require the advertised target feature (`avx2` ones
//! must only be called when `detect() >= Isa::Avx2`) and in-bounds
//! index sets; the safe dispatchers in [`super`] check both.

use crate::transform::fwht::{radix4_first_pass, FWHT_BLOCK};
use std::arch::x86_64::*;

// ---------------------------------------------------------------------
// FWHT stage kernels (AVX2)
// ---------------------------------------------------------------------

/// Fused first pass over 16-element tiles: stages h=1,2 in-register
/// (`hadd`/`hsub`/`blend` per 4-lane quad) and stages h=4,8 as vertical
/// quad butterflies. `n % 16 == 0`. Outputs scaled by `s` (used only
/// when the whole transform is a single tile, p = 16).
///
/// # Safety
/// Requires AVX2. `x` must be valid for reads and writes of `n`
/// contiguous `f64`s with no other live reference into that range
/// (the kernel loads and stores every element exactly once per tile),
/// and `n % 16 == 0` so each 16-element tile `[i, i+16)` is in
/// bounds. No alignment requirement: all accesses are unaligned
/// (`loadu`/`storeu`).
#[target_feature(enable = "avx2")]
unsafe fn tile16_pass_avx2(x: *mut f64, n: usize, s: f64) {
    let vs = _mm256_set1_pd(s);
    let scaled = s != 1.0;
    let mut i = 0;
    while i < n {
        let p = x.add(i);
        let mut q = [_mm256_setzero_pd(); 4];
        for (k, qk) in q.iter_mut().enumerate() {
            // v = [a, b, c, d]  ->  [a+b, a-b, c+d, c-d] (stages h=1,2
            // happen on the transposed pair layout below)
            let v = _mm256_loadu_pd(p.add(4 * k));
            let hadd = _mm256_hadd_pd(v, v); // [a+b, a+b, c+d, c+d]
            let hsub = _mm256_hsub_pd(v, v); // [a-b, a-b, c-d, c-d]
            let t = _mm256_blend_pd::<0b1010>(hadd, hsub); // [ab, amb, cd, cmd]
            let v1 = _mm256_permute2f128_pd::<0x00>(t, t); // [ab, amb, ab, amb]
            let v2 = _mm256_permute2f128_pd::<0x11>(t, t); // [cd, cmd, cd, cmd]
            // stage h=2: [ab+cd, amb+cmd, ab-cd, amb-cmd]
            *qk = _mm256_blend_pd::<0b1100>(
                _mm256_add_pd(v1, v2),
                _mm256_sub_pd(v1, v2),
            );
        }
        // stages h=4 and h=8 across the four quads (radix-4 butterfly)
        let a = _mm256_add_pd(q[0], q[1]);
        let b = _mm256_sub_pd(q[0], q[1]);
        let c = _mm256_add_pd(q[2], q[3]);
        let d = _mm256_sub_pd(q[2], q[3]);
        let mut o = [
            _mm256_add_pd(a, c),
            _mm256_add_pd(b, d),
            _mm256_sub_pd(a, c),
            _mm256_sub_pd(b, d),
        ];
        if scaled {
            for v in o.iter_mut() {
                *v = _mm256_mul_pd(*v, vs);
            }
        }
        for (k, &ok) in o.iter().enumerate() {
            _mm256_storeu_pd(p.add(4 * k), ok);
        }
        i += 16;
    }
}

/// One radix-2 stage at stride `h` (`h % 4 == 0`, `h >= 4`), outputs
/// scaled by `s` — the 4-wide version of `stage_radix2`.
///
/// # Safety
/// Requires AVX2. `x` must be valid for reads and writes of `n`
/// contiguous `f64`s, exclusively (each butterfly reads and rewrites
/// the disjoint pair `i`, `i+h`). `n` must be a power of two and a
/// multiple of `2*h`, and `h % 4 == 0` with `h >= 4`, so every 4-wide
/// access at `i` and `i+h` stays inside `[0, n)`. Unaligned
/// `loadu`/`storeu` throughout — no alignment requirement.
#[target_feature(enable = "avx2")]
unsafe fn stage_radix2_avx2(x: *mut f64, n: usize, h: usize, s: f64) {
    let vs = _mm256_set1_pd(s);
    let step = 2 * h;
    let mut base = 0;
    while base < n {
        let mut i = base;
        while i < base + h {
            let a = _mm256_loadu_pd(x.add(i));
            let b = _mm256_loadu_pd(x.add(i + h));
            _mm256_storeu_pd(x.add(i), _mm256_mul_pd(_mm256_add_pd(a, b), vs));
            _mm256_storeu_pd(x.add(i + h), _mm256_mul_pd(_mm256_sub_pd(a, b), vs));
            i += 4;
        }
        base += step;
    }
}

/// Two fused radix-2 stages (strides `h`, `2h`) — 4-wide
/// `stage_radix4`. `h % 4 == 0`, `h >= 4`.
///
/// # Safety
/// Requires AVX2. `x` must be valid for exclusive reads and writes of
/// `n` contiguous `f64`s; `n` must be a power of two and a multiple of
/// `4*h`, and `h % 4 == 0` with `h >= 4`, so the four 4-wide accesses
/// at `i + {0,1,2,3}*h` stay inside `[0, n)` for every `i` the loop
/// visits. Unaligned `loadu`/`storeu` — no alignment requirement.
#[target_feature(enable = "avx2")]
unsafe fn stage_radix4_avx2(x: *mut f64, n: usize, h: usize, s: f64) {
    let vs = _mm256_set1_pd(s);
    let step = 4 * h;
    let mut base = 0;
    while base < n {
        let mut i = base;
        while i < base + h {
            let x0 = _mm256_loadu_pd(x.add(i));
            let x1 = _mm256_loadu_pd(x.add(i + h));
            let x2 = _mm256_loadu_pd(x.add(i + 2 * h));
            let x3 = _mm256_loadu_pd(x.add(i + 3 * h));
            let a = _mm256_add_pd(x0, x1);
            let b = _mm256_sub_pd(x0, x1);
            let c = _mm256_add_pd(x2, x3);
            let d = _mm256_sub_pd(x2, x3);
            _mm256_storeu_pd(x.add(i), _mm256_mul_pd(_mm256_add_pd(a, c), vs));
            _mm256_storeu_pd(x.add(i + h), _mm256_mul_pd(_mm256_add_pd(b, d), vs));
            _mm256_storeu_pd(x.add(i + 2 * h), _mm256_mul_pd(_mm256_sub_pd(a, c), vs));
            _mm256_storeu_pd(x.add(i + 3 * h), _mm256_mul_pd(_mm256_sub_pd(b, d), vs));
            i += 4;
        }
        base += step;
    }
}

/// Four fused radix-2 stages (strides `h..8h`) in one sweep — two
/// back-to-back radix-4 butterflies held in registers. Worth it only
/// while all 16 concurrent lines fit distinct L1 sets, hence the
/// `h <= 256` guard at the call site. `h % 4 == 0`, `h >= 4`.
///
/// # Safety
/// Requires AVX2. `x` must be valid for exclusive reads and writes of
/// `n` contiguous `f64`s; `n` must be a power of two and a multiple of
/// `16*h`, and `h % 4 == 0` with `h >= 4`, so the sixteen 4-wide
/// accesses at `i + k*h` (`k < 16`) stay inside `[0, n)`. The
/// `h <= 256` guard is a performance condition only, not a safety
/// one. Unaligned `loadu`/`storeu` — no alignment requirement.
#[target_feature(enable = "avx2")]
unsafe fn stage_radix16_avx2(x: *mut f64, n: usize, h: usize, s: f64) {
    let vs = _mm256_set1_pd(s);
    let scaled = s != 1.0;
    let step = 16 * h;
    let mut base = 0;
    while base < n {
        let mut i = base;
        while i < base + h {
            let mut q = [_mm256_setzero_pd(); 16];
            for (k, qk) in q.iter_mut().enumerate() {
                *qk = _mm256_loadu_pd(x.add(i + k * h));
            }
            // level 1: radix-4 butterfly inside each group of 4 strides
            let mut y = [_mm256_setzero_pd(); 16];
            for g in 0..4 {
                let a = _mm256_add_pd(q[4 * g], q[4 * g + 1]);
                let b = _mm256_sub_pd(q[4 * g], q[4 * g + 1]);
                let c = _mm256_add_pd(q[4 * g + 2], q[4 * g + 3]);
                let d = _mm256_sub_pd(q[4 * g + 2], q[4 * g + 3]);
                y[4 * g] = _mm256_add_pd(a, c);
                y[4 * g + 1] = _mm256_add_pd(b, d);
                y[4 * g + 2] = _mm256_sub_pd(a, c);
                y[4 * g + 3] = _mm256_sub_pd(b, d);
            }
            // level 2: radix-4 butterfly across the groups
            for j in 0..4 {
                let a = _mm256_add_pd(y[j], y[j + 4]);
                let b = _mm256_sub_pd(y[j], y[j + 4]);
                let c = _mm256_add_pd(y[j + 8], y[j + 12]);
                let d = _mm256_sub_pd(y[j + 8], y[j + 12]);
                let mut o = [
                    _mm256_add_pd(a, c),
                    _mm256_add_pd(b, d),
                    _mm256_sub_pd(a, c),
                    _mm256_sub_pd(b, d),
                ];
                if scaled {
                    for v in o.iter_mut() {
                        *v = _mm256_mul_pd(*v, vs);
                    }
                }
                _mm256_storeu_pd(x.add(i + j * h), o[0]);
                _mm256_storeu_pd(x.add(i + (j + 4) * h), o[1]);
                _mm256_storeu_pd(x.add(i + (j + 8) * h), o[2]);
                _mm256_storeu_pd(x.add(i + (j + 12) * h), o[3]);
            }
            i += 4;
        }
        base += step;
    }
}

/// Run stages `from_h..n/2` greedily: peel one radix-2 if the stage
/// count is odd, then radix-16 while `16h <= n` *and* `h <= 256` (the
/// L1-aliasing guard), else radix-4. Radix-16 consumes 4 stages and
/// radix-4 consumes 2, both even, so after the peel the schedule always
/// lands exactly on `n`. Fusion regroups but never reorders the
/// butterfly arithmetic, so the result is bit-identical to the scalar
/// `fwht_stages`.
///
/// # Safety
/// Requires AVX2. `x` must be valid for exclusive reads and writes of
/// `n` contiguous `f64`s; `n` must be a power of two, `from_h` a power
/// of two with `4 <= from_h <= n` and `from_h % 4 == 0`. Every stage
/// kernel dispatched here then receives an `h` that divides `n` with
/// the radix as a further factor, which is exactly their bounds
/// precondition. No alignment requirement.
#[target_feature(enable = "avx2")]
unsafe fn fwht_stages_avx2(x: *mut f64, n: usize, from_h: usize, scale: f64) {
    let mut h = from_h;
    let stages = (n / h).trailing_zeros();
    if stages % 2 == 1 {
        stage_radix2_avx2(x, n, h, if 2 * h == n { scale } else { 1.0 });
        h *= 2;
    }
    while h < n {
        if 16 * h <= n && h <= 256 {
            stage_radix16_avx2(x, n, h, if 16 * h == n { scale } else { 1.0 });
            h *= 16;
        } else {
            stage_radix4_avx2(x, n, h, if 4 * h == n { scale } else { 1.0 });
            h *= 4;
        }
    }
}

/// Full normalized in-place FWHT, AVX2 schedule: a 16-wide fused first
/// pass plus greedy radix-16/radix-4 stages, cache-blocked at
/// [`FWHT_BLOCK`] exactly like the scalar transform.
///
/// # Safety
/// Requires AVX2 (the caller must have checked `detect() >= Isa::Avx2`);
/// `x.len()` must be a power of two `>= 16`. The `&mut` slice already
/// guarantees exclusivity and validity of the whole range; accesses are
/// unaligned (`loadu`/`storeu`), so no alignment precondition.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn fwht_avx2(x: &mut [f64]) {
    let p = x.len();
    debug_assert!(p >= 16 && p & (p - 1) == 0);
    let scale = 1.0 / (p as f64).sqrt();
    let ptr = x.as_mut_ptr();
    if p <= FWHT_BLOCK {
        tile16_pass_avx2(ptr, p, if p == 16 { scale } else { 1.0 });
        if p > 16 {
            fwht_stages_avx2(ptr, p, 16, scale);
        }
    } else {
        let mut base = 0;
        while base < p {
            tile16_pass_avx2(ptr.add(base), FWHT_BLOCK, 1.0);
            fwht_stages_avx2(ptr.add(base), FWHT_BLOCK, 16, 1.0);
            base += FWHT_BLOCK;
        }
        fwht_stages_avx2(ptr, p, FWHT_BLOCK, scale);
    }
}

// ---------------------------------------------------------------------
// FWHT stage kernels (SSE2 — x86-64 baseline, 2-wide)
// ---------------------------------------------------------------------

/// 2-wide radix-2 stage (`h % 2 == 0`, `h >= 2`).
///
/// # Safety
/// SSE2 is the x86-64 baseline, so no feature check is needed. `x`
/// must be valid for exclusive reads and writes of `n` contiguous
/// `f64`s; `n` must be a power of two and a multiple of `2*h`, and
/// `h % 2 == 0` with `h >= 2`, so every 2-wide access at `i` and
/// `i+h` stays inside `[0, n)`. Unaligned `loadu`/`storeu` — no
/// alignment requirement.
unsafe fn stage_radix2_sse2(x: *mut f64, n: usize, h: usize, s: f64) {
    let vs = _mm_set1_pd(s);
    let step = 2 * h;
    let mut base = 0;
    while base < n {
        let mut i = base;
        while i < base + h {
            let a = _mm_loadu_pd(x.add(i));
            let b = _mm_loadu_pd(x.add(i + h));
            _mm_storeu_pd(x.add(i), _mm_mul_pd(_mm_add_pd(a, b), vs));
            _mm_storeu_pd(x.add(i + h), _mm_mul_pd(_mm_sub_pd(a, b), vs));
            i += 2;
        }
        base += step;
    }
}

/// 2-wide fused radix-4 stage (`h % 2 == 0`, `h >= 2`).
///
/// # Safety
/// SSE2 is the x86-64 baseline. `x` must be valid for exclusive reads
/// and writes of `n` contiguous `f64`s; `n` must be a power of two and
/// a multiple of `4*h`, and `h % 2 == 0` with `h >= 2`, so the four
/// 2-wide accesses at `i + {0,1,2,3}*h` stay inside `[0, n)`.
/// Unaligned `loadu`/`storeu` — no alignment requirement.
unsafe fn stage_radix4_sse2(x: *mut f64, n: usize, h: usize, s: f64) {
    let vs = _mm_set1_pd(s);
    let step = 4 * h;
    let mut base = 0;
    while base < n {
        let mut i = base;
        while i < base + h {
            let x0 = _mm_loadu_pd(x.add(i));
            let x1 = _mm_loadu_pd(x.add(i + h));
            let x2 = _mm_loadu_pd(x.add(i + 2 * h));
            let x3 = _mm_loadu_pd(x.add(i + 3 * h));
            let a = _mm_add_pd(x0, x1);
            let b = _mm_sub_pd(x0, x1);
            let c = _mm_add_pd(x2, x3);
            let d = _mm_sub_pd(x2, x3);
            _mm_storeu_pd(x.add(i), _mm_mul_pd(_mm_add_pd(a, c), vs));
            _mm_storeu_pd(x.add(i + h), _mm_mul_pd(_mm_add_pd(b, d), vs));
            _mm_storeu_pd(x.add(i + 2 * h), _mm_mul_pd(_mm_sub_pd(a, c), vs));
            _mm_storeu_pd(x.add(i + 3 * h), _mm_mul_pd(_mm_sub_pd(b, d), vs));
            i += 2;
        }
        base += step;
    }
}

/// 2-wide mirror of the scalar `fwht_stages` schedule (radix-2 peel,
/// then radix-4).
///
/// # Safety
/// SSE2 is the x86-64 baseline. `x` must be valid for exclusive reads
/// and writes of `n` contiguous `f64`s; `n` must be a power of two,
/// `from_h` a power of two with `2 <= from_h <= n` and
/// `from_h % 2 == 0`. The dispatched stage kernels then receive an `h`
/// dividing `n` with the radix as a further factor — their bounds
/// precondition. No alignment requirement.
unsafe fn fwht_stages_sse2(x: *mut f64, n: usize, from_h: usize, scale: f64) {
    let mut h = from_h;
    let stages = (n / h).trailing_zeros();
    if stages % 2 == 1 {
        stage_radix2_sse2(x, n, h, if 2 * h == n { scale } else { 1.0 });
        h *= 2;
    }
    while h < n {
        stage_radix4_sse2(x, n, h, if 4 * h == n { scale } else { 1.0 });
        h *= 4;
    }
}

/// Full normalized in-place FWHT, SSE2 schedule: scalar fused first
/// pass (stages h=1,2 are intra-pair and don't vectorize at 2 lanes)
/// plus 2-wide stages, cache-blocked like the scalar transform.
/// `x.len()` must be a power of two `>= 16`.
pub(crate) fn fwht_sse2(x: &mut [f64]) {
    let p = x.len();
    debug_assert!(p >= 16 && p & (p - 1) == 0);
    let scale = 1.0 / (p as f64).sqrt();
    if p <= FWHT_BLOCK {
        radix4_first_pass(x);
        // SAFETY: SSE2 is the x86-64 baseline; strides stay in-bounds
        // because p is a power of two >= 16.
        unsafe { fwht_stages_sse2(x.as_mut_ptr(), p, 4, scale) };
    } else {
        for blk in x.chunks_exact_mut(FWHT_BLOCK) {
            radix4_first_pass(blk);
            // SAFETY: as above, within one FWHT_BLOCK.
            unsafe { fwht_stages_sse2(blk.as_mut_ptr(), FWHT_BLOCK, 4, 1.0) };
        }
        // SAFETY: cross-block stages, strides FWHT_BLOCK..p/2 in-bounds.
        unsafe { fwht_stages_sse2(x.as_mut_ptr(), p, FWHT_BLOCK, scale) };
    }
}

// ---------------------------------------------------------------------
// Assignment kernel (AVX2)
// ---------------------------------------------------------------------

/// 4-center masked squared distances over a transposed center panel
/// (`panel[j*4 + c]`). Lane `c` executes exactly the scalar
/// `masked_dist2` chain against center `c`: pairs of slots feed two
/// independent accumulators, the odd tail goes to the first, and the
/// final result is their sum. Values are *broadcast* and center rows
/// *loaded* — no gathers (measured slower than scalar here).
///
/// # Safety
/// Requires AVX2; `indices.len() == values.len()` (the
/// `get_unchecked` loads index both slices by `t < indices.len()`)
/// and every `indices[t]*4 + 4 <= panel.len()` so the 4-wide center
/// row load stays inside the panel. All inputs are shared borrows and
/// `out` is exclusive, so aliasing is ruled out by the signature;
/// panel loads are unaligned — no alignment precondition.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn masked_dist2_x4_avx2(
    indices: &[u32],
    values: &[f64],
    panel: &[f64],
    out: &mut [f64; 4],
) {
    let ct = panel.as_ptr();
    let len = indices.len();
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let pairs = len / 2;
    for t in 0..pairs {
        let v0 = _mm256_set1_pd(*values.get_unchecked(2 * t));
        let v1 = _mm256_set1_pd(*values.get_unchecked(2 * t + 1));
        let c0 = _mm256_loadu_pd(ct.add(4 * *indices.get_unchecked(2 * t) as usize));
        let c1 =
            _mm256_loadu_pd(ct.add(4 * *indices.get_unchecked(2 * t + 1) as usize));
        let d0 = _mm256_sub_pd(v0, c0);
        let d1 = _mm256_sub_pd(v1, c1);
        acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(d0, d0));
        acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(d1, d1));
    }
    if len % 2 == 1 {
        let t = len - 1;
        let v = _mm256_set1_pd(*values.get_unchecked(t));
        let c = _mm256_loadu_pd(ct.add(4 * *indices.get_unchecked(t) as usize));
        let d = _mm256_sub_pd(v, c);
        acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(d, d));
    }
    _mm256_storeu_pd(out.as_mut_ptr(), _mm256_add_pd(acc0, acc1));
}

/// [`masked_dist2_x4_avx2`] over packed `f32` stored values: each value
/// is widened exactly to `f64` at broadcast time, so the arithmetic —
/// and the result — is identical to the `f64` kernel on pre-widened
/// input.
///
/// # Safety
/// As [`masked_dist2_x4_avx2`].
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn masked_dist2_x4_f32_avx2(
    indices: &[u32],
    values: &[f32],
    panel: &[f64],
    out: &mut [f64; 4],
) {
    let ct = panel.as_ptr();
    let len = indices.len();
    let mut acc0 = _mm256_setzero_pd();
    let mut acc1 = _mm256_setzero_pd();
    let pairs = len / 2;
    for t in 0..pairs {
        let v0 = _mm256_set1_pd(*values.get_unchecked(2 * t) as f64);
        let v1 = _mm256_set1_pd(*values.get_unchecked(2 * t + 1) as f64);
        let c0 = _mm256_loadu_pd(ct.add(4 * *indices.get_unchecked(2 * t) as usize));
        let c1 =
            _mm256_loadu_pd(ct.add(4 * *indices.get_unchecked(2 * t + 1) as usize));
        let d0 = _mm256_sub_pd(v0, c0);
        let d1 = _mm256_sub_pd(v1, c1);
        acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(d0, d0));
        acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(d1, d1));
    }
    if len % 2 == 1 {
        let t = len - 1;
        let v = _mm256_set1_pd(*values.get_unchecked(t) as f64);
        let c = _mm256_loadu_pd(ct.add(4 * *indices.get_unchecked(t) as usize));
        let d = _mm256_sub_pd(v, c);
        acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(d, d));
    }
    _mm256_storeu_pd(out.as_mut_ptr(), _mm256_add_pd(acc0, acc1));
}

// ---------------------------------------------------------------------
// Dot/scatter kernels
// ---------------------------------------------------------------------

/// 4-wide fused per-column dot phase: for each nonzero slot `t`,
/// `dcol[i] += values[t] * bt[indices[t]*b + i]`.
///
/// # Safety
/// Requires AVX2; `indices.len() == values.len()` and every
/// `indices[t]*b + b <= bt.len()` (with `b = dcol.len()`), so each
/// row window read from `bt` is in bounds. `dcol` is the only target
/// written and is held exclusively; reads are from distinct shared
/// slices. Unaligned accesses — no alignment precondition.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn col_dot_avx2(
    dcol: &mut [f64],
    indices: &[u32],
    values: &[f64],
    bt: &[f64],
) {
    let b = dcol.len();
    let dp = dcol.as_mut_ptr();
    let bp = bt.as_ptr();
    for t in 0..indices.len() {
        let v = *values.get_unchecked(t);
        let vv = _mm256_set1_pd(v);
        let bc = bp.add(*indices.get_unchecked(t) as usize * b);
        let mut i = 0;
        while i + 4 <= b {
            let acc = _mm256_loadu_pd(dp.add(i));
            let x = _mm256_loadu_pd(bc.add(i));
            _mm256_storeu_pd(dp.add(i), _mm256_add_pd(acc, _mm256_mul_pd(vv, x)));
            i += 4;
        }
        while i < b {
            *dp.add(i) += v * *bc.add(i);
            i += 1;
        }
    }
}

/// 2-wide [`col_dot_avx2`].
///
/// # Safety
/// `indices.len() == values.len()` and every
/// `indices[t]*b + b <= bt.len()` with `b = dcol.len()` (SSE2 is the
/// x86-64 baseline, so no feature check). Aliasing and alignment as
/// [`col_dot_avx2`]: exclusive `dcol`, shared inputs, unaligned
/// accesses.
pub(crate) unsafe fn col_dot_sse2(
    dcol: &mut [f64],
    indices: &[u32],
    values: &[f64],
    bt: &[f64],
) {
    let b = dcol.len();
    let dp = dcol.as_mut_ptr();
    let bp = bt.as_ptr();
    for t in 0..indices.len() {
        let v = *values.get_unchecked(t);
        let vv = _mm_set1_pd(v);
        let bc = bp.add(*indices.get_unchecked(t) as usize * b);
        let mut i = 0;
        while i + 2 <= b {
            let acc = _mm_loadu_pd(dp.add(i));
            let x = _mm_loadu_pd(bc.add(i));
            _mm_storeu_pd(dp.add(i), _mm_add_pd(acc, _mm_mul_pd(vv, x)));
            i += 2;
        }
        if i < b {
            *dp.add(i) += v * *bc.add(i);
        }
    }
}

/// 4-wide fused per-column scatter phase: for each slot `t`,
/// `out[(indices[t]-row_base)*b + i] += values[t] * dcol[i]`.
///
/// # Safety
/// Requires AVX2; `indices.len() == values.len()`, every
/// `indices[t] >= row_base` (the subtraction must not wrap), and
/// `(indices[t]-row_base)*b + b <= out.len()` with `b = dcol.len()`,
/// so each written row window lies inside `out`. `out` is exclusive
/// and `dcol` shared, ruling out aliasing between the accumulator and
/// the broadcast column. Unaligned accesses — no alignment
/// precondition.
#[target_feature(enable = "avx2")]
pub(crate) unsafe fn col_scatter_avx2(
    out: &mut [f64],
    indices: &[u32],
    values: &[f64],
    row_base: u32,
    dcol: &[f64],
) {
    let b = dcol.len();
    let op = out.as_mut_ptr();
    let dp = dcol.as_ptr();
    for t in 0..indices.len() {
        let v = *values.get_unchecked(t);
        let vv = _mm256_set1_pd(v);
        let orow = op.add((*indices.get_unchecked(t) - row_base) as usize * b);
        let mut i = 0;
        while i + 4 <= b {
            let acc = _mm256_loadu_pd(orow.add(i));
            let x = _mm256_loadu_pd(dp.add(i));
            _mm256_storeu_pd(orow.add(i), _mm256_add_pd(acc, _mm256_mul_pd(vv, x)));
            i += 4;
        }
        while i < b {
            *orow.add(i) += v * *dp.add(i);
            i += 1;
        }
    }
}

/// 2-wide [`col_scatter_avx2`].
///
/// # Safety
/// Index/window bounds, aliasing, and (absence of) alignment
/// preconditions exactly as [`col_scatter_avx2`]; SSE2 is the x86-64
/// baseline, so no feature check is required.
pub(crate) unsafe fn col_scatter_sse2(
    out: &mut [f64],
    indices: &[u32],
    values: &[f64],
    row_base: u32,
    dcol: &[f64],
) {
    let b = dcol.len();
    let op = out.as_mut_ptr();
    let dp = dcol.as_ptr();
    for t in 0..indices.len() {
        let v = *values.get_unchecked(t);
        let vv = _mm_set1_pd(v);
        let orow = op.add((*indices.get_unchecked(t) - row_base) as usize * b);
        let mut i = 0;
        while i + 2 <= b {
            let acc = _mm_loadu_pd(orow.add(i));
            let x = _mm_loadu_pd(dp.add(i));
            _mm_storeu_pd(orow.add(i), _mm_add_pd(acc, _mm_mul_pd(vv, x)));
            i += 2;
        }
        if i < b {
            *orow.add(i) += v * *dp.add(i);
        }
    }
}
