//! Cross-client query micro-batching.
//!
//! Every `query` / `query_batch` request is submitted to one shared
//! [`BatchQueue`]; a dedicated worker coalesces whatever is in flight —
//! across connections — into a panel, bounded by a wait window
//! (`--batch-window-us`) and a size cap (`--batch-max`), and executes
//! the panel through [`ModelSnapshot::query_panel`] in one pass over
//! the snapshot's SIMD kernel layouts. Results are demuxed back to each
//! request in order.
//!
//! Correctness is by construction, not by luck: the panel path *is* the
//! per-sample path (a single query is a panel of one), so batching can
//! never change an answer — it only amortizes dispatch, snapshot
//! loading, and scratch allocation across the panel. Latency is
//! attributed per request: time parked in the queue feeds the
//! `query_wait` histogram, kernel execution feeds `query_exec`, and the
//! end-to-end figure stays in `query_latency` as before.
//!
//! The pending queue needs no separate depth bound: every submitter
//! blocks on its own reply slot, so at most one request per connection
//! slot (plus the pipe client) can be parked at once — the transport's
//! connection cap is the queue bound.

use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::metrics::ServeMetrics;

use super::snapshot::{ModelSnapshot, QueryResult, SnapshotCell};

/// One parked request awaiting the next panel.
struct Pending {
    samples: Vec<Vec<f64>>,
    enqueued: Instant,
    reply: SyncSender<Reply>,
}

/// What the batch worker sends back for one request.
pub(crate) enum Reply {
    /// No model has been published yet.
    NoModel,
    /// This request's samples did not match the model dimension
    /// (rejected whole; its panel-mates are unaffected).
    BadRequest(String),
    /// The batch lane failed (worker died or kernel error).
    Internal(&'static str),
    /// The reply did not arrive within the request timeout.
    Timeout,
    /// The daemon is shutting down.
    Shutdown,
    /// Answered: results in request-sample order, plus the exact
    /// snapshot they were computed from.
    Answer {
        /// The snapshot every sample in this request was answered from.
        snapshot: Arc<ModelSnapshot>,
        /// Degraded-mode flag captured at execution time.
        stale: bool,
        /// One result per submitted sample, in order.
        results: Vec<QueryResult>,
    },
}

struct LaneState {
    pending: Vec<Pending>,
    shutdown: bool,
}

/// The shared submission queue of the batching lane.
pub(crate) struct BatchQueue {
    state: Mutex<LaneState>,
    cv: Condvar,
}

impl BatchQueue {
    pub(crate) fn new() -> Self {
        BatchQueue {
            state: Mutex::new(LaneState { pending: Vec::new(), shutdown: false }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, LaneState> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Submit one request (any number of samples) and block for its
    /// reply, up to `timeout`.
    pub(crate) fn submit(&self, samples: Vec<Vec<f64>>, timeout: Duration) -> Reply {
        let (tx, rx) = sync_channel(1);
        {
            let mut st = self.lock();
            if st.shutdown {
                return Reply::Shutdown;
            }
            st.pending.push(Pending { samples, enqueued: Instant::now(), reply: tx });
        }
        self.cv.notify_all();
        match rx.recv_timeout(timeout) {
            Ok(reply) => reply,
            Err(RecvTimeoutError::Timeout) => Reply::Timeout,
            Err(RecvTimeoutError::Disconnected) => {
                Reply::Internal("batch lane is gone (worker exited)")
            }
        }
    }

    /// Raise the shutdown flag: the worker answers what is already
    /// parked, then exits; later submissions get [`Reply::Shutdown`].
    pub(crate) fn begin_shutdown(&self) {
        self.lock().shutdown = true;
        self.cv.notify_all();
    }
}

/// The batching lane: park until work arrives, coalesce within the
/// window, execute one panel, demux, repeat.
pub(crate) fn run_batch_worker(
    queue: Arc<BatchQueue>,
    cell: Arc<SnapshotCell>,
    metrics: Arc<ServeMetrics>,
    window: Duration,
    batch_max: usize,
) {
    loop {
        let batch = {
            let mut st = queue.lock();
            while st.pending.is_empty() && !st.shutdown {
                st = match queue.cv.wait(st) {
                    Ok(g) => g,
                    Err(poisoned) => poisoned.into_inner(),
                };
            }
            if st.pending.is_empty() {
                return; // shutdown, nothing left to answer
            }
            // bounded coalescing wait: later requests may join this
            // panel until the window elapses or it is full
            let deadline = Instant::now() + window;
            loop {
                let queued: usize = st.pending.iter().map(|r| r.samples.len()).sum();
                if queued >= batch_max || st.shutdown {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                st = match queue.cv.wait_timeout(st, deadline - now) {
                    Ok((g, _)) => g,
                    Err(poisoned) => poisoned.into_inner().0,
                };
            }
            // drain whole requests (a request is never split across
            // panels) up to batch_max samples — always at least one, so
            // an oversized query_batch still executes, as one panel
            let mut take = 0usize;
            let mut total = 0usize;
            for r in &st.pending {
                if take > 0 && total + r.samples.len() > batch_max {
                    break;
                }
                total += r.samples.len();
                take += 1;
            }
            st.pending.drain(..take).collect::<Vec<_>>()
        };
        execute(batch, &cell, &metrics);
    }
}

/// Run one coalesced panel and demux the results.
fn execute(batch: Vec<Pending>, cell: &SnapshotCell, metrics: &ServeMetrics) {
    let t0 = Instant::now();
    for r in &batch {
        metrics.query_wait.record(t0.duration_since(r.enqueued));
    }
    // one coherent (snapshot, stale) pair — a separate load()/is_stale()
    // sequence could pair this panel's model with another version's flag
    // if a publish lands between the two reads
    let (snap, stale) = cell.load_with_stale();
    let Some(snap) = snap else {
        for r in batch {
            let _ = r.reply.try_send(Reply::NoModel);
        }
        return;
    };
    let dim = snap.dim();
    // all-or-nothing validation per request: a malformed request is
    // rejected whole and excluded, so it cannot poison its panel-mates
    let mut rows: Vec<&[f64]> = Vec::new();
    let mut rejected: Vec<Option<String>> = Vec::with_capacity(batch.len());
    for r in &batch {
        match r.samples.iter().enumerate().find(|(_, s)| s.len() != dim) {
            Some((i, s)) => rejected.push(Some(format!(
                "samples[{i}] has {} entries, the model dimension is {dim}",
                s.len()
            ))),
            None => {
                rows.extend(r.samples.iter().map(Vec::as_slice));
                rejected.push(None);
            }
        }
    }
    let results = if rows.is_empty() { Ok(Vec::new()) } else { snap.query_panel(&rows) };
    let mut results = match results {
        Ok(r) => r.into_iter(),
        Err(_) => {
            for r in batch {
                let _ = r.reply.try_send(Reply::Internal("batched query kernel failed"));
            }
            return;
        }
    };
    if !rows.is_empty() {
        // Relaxed: monotonic stats counter, no ordering with other data
        metrics.batches_executed.fetch_add(1, Ordering::Relaxed);
        // Relaxed: monotonic stats counter, no ordering with other data
        metrics.batched_samples.fetch_add(rows.len() as u64, Ordering::Relaxed);
        metrics.query_exec.record(t0.elapsed());
    }
    for (r, bad) in batch.into_iter().zip(rejected) {
        match bad {
            Some(msg) => {
                let _ = r.reply.try_send(Reply::BadRequest(msg));
            }
            None => {
                let picked: Vec<QueryResult> = results.by_ref().take(r.samples.len()).collect();
                let _ = r.reply.try_send(Reply::Answer {
                    snapshot: snap.clone(),
                    stale,
                    results: picked,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::serve::snapshot::{ModelKind, PcaSnapshot};
    use crate::sparse::Precision;

    fn spawn_lane(
        cell: Arc<SnapshotCell>,
        window: Duration,
        batch_max: usize,
    ) -> (Arc<BatchQueue>, Arc<ServeMetrics>, std::thread::JoinHandle<()>) {
        let queue = Arc::new(BatchQueue::new());
        let metrics = Arc::new(ServeMetrics::new());
        let handle = {
            let (q, c, m) = (queue.clone(), cell.clone(), metrics.clone());
            std::thread::spawn(move || run_batch_worker(q, c, m, window, batch_max))
        };
        (queue, metrics, handle)
    }

    fn identity_snapshot(p: usize) -> ModelSnapshot {
        ModelSnapshot::new(
            1,
            8,
            Precision::F64,
            ModelKind::Pca(PcaSnapshot {
                components: Mat::from_fn(p, p, |i, j| f64::from(u8::from(i == j))),
                mean: vec![0.0; p],
                eigenvalues: vec![1.0; p],
            }),
        )
    }

    #[test]
    fn lane_answers_demuxes_and_shuts_down() {
        let cell = Arc::new(SnapshotCell::new());
        let (queue, metrics, handle) = spawn_lane(cell.clone(), Duration::from_micros(50), 8);
        let timeout = Duration::from_secs(30);

        // no model yet → typed NoModel
        assert!(matches!(queue.submit(vec![vec![1.0, 2.0]], timeout), Reply::NoModel));

        cell.publish(identity_snapshot(2));
        match queue.submit(vec![vec![1.0, 2.0], vec![3.0, 4.0]], timeout) {
            Reply::Answer { snapshot, stale, results } => {
                assert_eq!(snapshot.version, 1);
                assert!(!stale);
                assert_eq!(results.len(), 2);
                match &results[1] {
                    QueryResult::Projection { coords } => assert_eq!(coords, &vec![3.0, 4.0]),
                    _ => panic!("expected projection"),
                }
            }
            _ => panic!("expected answer"),
        }
        // a wrong-dimension request is rejected whole, with the index
        match queue.submit(vec![vec![1.0, 2.0], vec![0.0; 3]], timeout) {
            Reply::BadRequest(msg) => assert!(msg.contains("samples[1]"), "{msg}"),
            _ => panic!("expected bad request"),
        }
        assert!(metrics.batches_executed.load(Ordering::Relaxed) >= 1);
        assert_eq!(metrics.batched_samples.load(Ordering::Relaxed), 2);
        assert!(metrics.query_wait.count() >= 3);

        queue.begin_shutdown();
        handle.join().unwrap();
        // submissions after shutdown are typed, not hangs
        assert!(matches!(queue.submit(vec![vec![1.0, 2.0]], timeout), Reply::Shutdown));
    }
}
