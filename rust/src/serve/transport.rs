//! Socket transports for the serve daemon: TCP (`--listen HOST:PORT`)
//! and Unix domain sockets (`--socket PATH`), both running the same
//! bounded worker pool.
//!
//! The pool replaces thread-per-connection: the acceptor hands each
//! connection to one of `conn_slots` long-lived workers through a
//! bounded channel. The cap is exact — an `active` counter tracks
//! queued-plus-in-service connections, and the acceptor only enqueues
//! while `active < conn_slots`, so the channel can never reject an
//! admitted connection. A connection beyond the cap gets one typed
//! `backpressure` error line and is closed (never a silent hang), and
//! the rejection is counted in `conn_rejections`.
//!
//! Protocol framing is identical on every transport: newline-delimited
//! JSON, one request line in, one response line out (see
//! [`protocol`](super::protocol)).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::error::Result;

use super::protocol::{error_response, CODE_BACKPRESSURE};
use super::{spawn_signal_watcher, Client, Daemon, ServeConfig};

/// Accept-loop poll interval while the listener is idle (the loop also
/// checks the shutdown flag at this cadence).
const ACCEPT_POLL: Duration = Duration::from_millis(20);

/// A nonblocking listener the accept loop can poll. `poll_accept`
/// returns a ready (blocking-mode) stream, `None` when nothing is
/// pending, or a fatal listener error.
trait Listener {
    type Stream: Read + Write + Send + 'static;
    fn poll_accept(&self) -> Result<Option<Self::Stream>>;
}

struct Tcp(TcpListener);

impl Listener for Tcp {
    type Stream = std::net::TcpStream;
    fn poll_accept(&self) -> Result<Option<Self::Stream>> {
        match self.0.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                Ok(Some(stream))
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

#[cfg(unix)]
struct Unix(std::os::unix::net::UnixListener);

#[cfg(unix)]
impl Listener for Unix {
    type Stream = std::os::unix::net::UnixStream;
    fn poll_accept(&self) -> Result<Option<Self::Stream>> {
        match self.0.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                Ok(Some(stream))
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Ok(None),
            Err(e) => Err(e.into()),
        }
    }
}

/// Run the daemon on a TCP listener bound to `addr` (`HOST:PORT`; port
/// 0 picks an ephemeral port). Prints `pds serve: listening on ADDR` —
/// with the resolved port — to stderr once bound. Stops on
/// SIGTERM/SIGINT or a `shutdown` request from any connection.
pub fn run_tcp(cfg: ServeConfig, addr: &str) -> Result<()> {
    let daemon = Daemon::start(cfg)?;
    spawn_signal_watcher(daemon.shared.clone())?;
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    eprintln!("pds serve: listening on {}", listener.local_addr()?);
    run_listener(daemon, Tcp(listener))
}

/// Run the daemon on a Unix domain socket at `path`. Removes a stale
/// socket file first (and again on exit); stops on SIGTERM/SIGINT or a
/// `shutdown` request from any connection.
#[cfg(unix)]
pub fn run_socket(cfg: ServeConfig, path: &std::path::Path) -> Result<()> {
    use std::os::unix::net::UnixListener;

    let daemon = Daemon::start(cfg)?;
    spawn_signal_watcher(daemon.shared.clone())?;
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)?;
    listener.set_nonblocking(true)?;
    eprintln!("pds serve: listening on {}", path.display());
    let result = run_listener(daemon, Unix(listener));
    let _ = std::fs::remove_file(path);
    result
}

/// The shared accept loop: spawn the worker pool, admit connections up
/// to the slot cap, reject the rest with one typed line, and shut the
/// daemon down when the flag is raised.
fn run_listener<L: Listener>(daemon: Daemon, listener: L) -> Result<()> {
    let slots = daemon.shared.conn_slots;
    // queued + in-service connections; the admission decision reads it
    // before enqueueing, so try_send below can never see a full channel
    let active = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = sync_channel::<L::Stream>(slots);
    let rx = Arc::new(Mutex::new(rx));
    let mut workers = Vec::with_capacity(slots);
    for i in 0..slots {
        let (rx, active, client) = (rx.clone(), active.clone(), daemon.client());
        workers.push(
            std::thread::Builder::new().name(format!("pds-serve-conn-{i}")).spawn(move || {
                loop {
                    let stream = {
                        let guard = match rx.lock() {
                            Ok(g) => g,
                            Err(poisoned) => poisoned.into_inner(),
                        };
                        guard.recv()
                    };
                    match stream {
                        Ok(stream) => {
                            serve_connection(stream, &client);
                            // SeqCst: frees a slot; pairs with the
                            // acceptor's SeqCst load so admission never
                            // overshoots conn_slots
                            active.fetch_sub(1, Ordering::SeqCst);
                        }
                        Err(_) => return, // acceptor dropped the channel
                    }
                }
            })?,
        );
    }

    // SeqCst: must observe a shutdown stored by any handler thread
    while !daemon.shared.shutdown.load(Ordering::SeqCst) {
        match listener.poll_accept() {
            Ok(Some(mut stream)) => {
                // SeqCst: admission check; pairs with the workers'
                // SeqCst fetch_sub (only this single acceptor thread
                // increments, so check-then-act cannot overshoot)
                if active.load(Ordering::SeqCst) >= slots {
                    // Relaxed: monotonic stats counter, no ordering with other data
                    daemon.shared.metrics.conn_rejections.fetch_add(1, Ordering::Relaxed);
                    let line = error_response(
                        CODE_BACKPRESSURE,
                        &format!("all {slots} connection slots are busy; retry later"),
                    );
                    let _ = stream
                        .write_all(line.as_bytes())
                        .and_then(|()| stream.write_all(b"\n"))
                        .and_then(|()| stream.flush());
                    // dropped: the rejection line is this connection's
                    // entire conversation
                } else {
                    // SeqCst: reserve the slot before enqueueing so the
                    // channel can never reject an admitted connection
                    active.fetch_add(1, Ordering::SeqCst);
                    if tx.try_send(stream).is_err() {
                        // unreachable by construction; keep the counter
                        // honest anyway
                        // SeqCst: release the reservation taken above
                        active.fetch_sub(1, Ordering::SeqCst);
                    }
                }
            }
            Ok(None) => std::thread::sleep(ACCEPT_POLL),
            Err(e) => return Err(e),
        }
    }
    // disconnect the pool: idle workers see the closed channel and exit;
    // a worker mid-connection finishes its client on its own time (the
    // daemon's shutdown below does not depend on it)
    drop(tx);
    let (manifest, stats) = daemon.shutdown();
    eprintln!("{stats}");
    manifest.map(|_| ())
}

/// Serve one established connection: newline-delimited JSON request
/// lines in, one response line out each, until EOF, an I/O error, or a
/// `shutdown` request.
fn serve_connection<S: Read + Write>(stream: S, client: &Client) {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (response, quit) = client.handle_line(trimmed);
        let out = reader.get_mut();
        if out.write_all(response.as_bytes()).is_err()
            || out.write_all(b"\n").is_err()
            || out.flush().is_err()
        {
            break;
        }
        if quit {
            break;
        }
    }
}
