//! Immutable model snapshots, the batched query kernel, the atomic
//! swap cell, and warm-start snapshot persistence.
//!
//! The refresh loop builds a complete new [`ModelSnapshot`] offline,
//! then publishes it into the [`SnapshotCell`] under a write lock held
//! only for the pointer swap. Query handlers clone the `Arc` out under
//! a read lock and answer entirely from that immutable value, so a
//! query observes exactly one model version end to end and never blocks
//! on (or is torn by) a concurrent refresh.
//!
//! ## One kernel, every batch size
//!
//! There is exactly one query execution path: [`ModelSnapshot::query_panel`]
//! runs a panel of samples through the SIMD kernels from [`crate::simd`]
//! (`col_dot` for PCA projection, `masked_dist2_x4` for K-means
//! assignment), iterating samples in panel order with per-snapshot
//! precomputed transposed layouts. The per-sample [`query`](ModelSnapshot::query)
//! is literally a panel of one, so batched and single-sample answers are
//! **bitwise identical at every batch size**, and the SIMD layer's own
//! property tests extend that identity across ISA tiers
//! (scalar/SSE2/AVX2). At [`Precision::F32`] the sample values are
//! quantized through `f32` once per query (exact widening back to `f64`,
//! `f64` accumulation — the Lazy SPCA recipe, arXiv:1709.07175), so f32
//! stores answer queries at the precision they were fitted at.
//!
//! ## Persistence
//!
//! Every published snapshot is also serialized as a versioned,
//! CRC-checked `.pdsp` artifact ([`SNAPSHOT_FILE`], kind
//! [`kind::SNAPSHOT`]) next to the store manifest, via the same
//! temp-file + fsync + rename discipline the manifest uses. A restarted
//! daemon loads it at startup and serves the last fitted model
//! immediately instead of returning `no_model` until the first refresh;
//! a truncated, tampered, or foreign artifact is a typed error and
//! degrades to a cold start, never a panic.

use std::path::Path;
use std::sync::{Arc, RwLock};

use crate::distributed::{decode_artifact, encode_artifact, kind, PayloadReader, PayloadWriter};
use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::simd::{self, Isa};
use crate::sparse::Precision;

/// File name of the persisted snapshot artifact, written next to the
/// store manifest at each successful publish.
pub const SNAPSHOT_FILE: &str = "snapshot.pdsp";

/// Payload format version this build writes for persisted snapshots.
pub const SNAPSHOT_VERSION: u32 = 1;

/// A published PCA model (original data domain — components and mean
/// are already unmixed through the ROS adjoint where applicable).
pub struct PcaSnapshot {
    /// Top-k principal components, `p_orig × k` (columns are PCs).
    pub components: Mat,
    /// Estimated sample mean, length `p_orig`.
    pub mean: Vec<f64>,
    /// Eigenvalues matching the components.
    pub eigenvalues: Vec<f64>,
}

/// A published K-means model (original data domain).
pub struct KmeansSnapshot {
    /// Cluster centers, `p_orig × k` (columns are centers).
    pub centers: Mat,
    /// Worst-cluster Eq. 43 center-error bound, evaluated at the
    /// coreset-estimated cluster sizes (see the serve module docs).
    /// `NaN` — serialized as JSON `null` — when the theory does not
    /// cover the fit (weighted sampling schemes), per the repo's
    /// "never present an unbacked number" rule.
    pub center_bound: f64,
    /// Lloyd iterations of the winning weighted-K-means restart.
    pub iterations: usize,
    /// Whether that restart converged.
    pub converged: bool,
}

/// The task-specific payload of a snapshot.
pub enum ModelKind {
    /// A PCA fit.
    Pca(PcaSnapshot),
    /// A K-means fit.
    Kmeans(KmeansSnapshot),
}

/// Kernel-shaped layouts precomputed once per snapshot so the batched
/// query path pays the transpose exactly once per publish, not per
/// query.
enum QueryCache {
    /// PCA: components transposed row-major (`bt[j*k + c]` = component
    /// `c` at feature `j`) — the layout [`simd::col_dot`] consumes.
    Pca {
        /// `p × k` components in `col_dot`'s row-major transposed form.
        components_t: Vec<f64>,
    },
    /// K-means: centers regrouped into 4-wide transposed panels
    /// (`panel[j*4 + lane]`, ragged lanes zero-padded) — the layout
    /// [`simd::masked_dist2_x4`] consumes.
    Kmeans {
        /// `ceil(k/4)` panels of length `p*4`.
        panels: Vec<Vec<f64>>,
    },
}

/// One immutable published model: everything a query needs, plus the
/// provenance a client sees (`model_version`, sample count). Construct
/// with [`ModelSnapshot::new`], which precomputes the kernel layouts.
pub struct ModelSnapshot {
    /// Monotone version, bumped once per successful refresh.
    pub version: u64,
    /// Samples the model was fitted on.
    pub n: usize,
    /// The fitted model.
    pub kind: ModelKind,
    /// Query-side value precision, mirroring the store the model was
    /// fitted from (f32 stores quantize query samples the same way).
    precision: Precision,
    /// The full index set `0..p`: a dense sample viewed as a sparse
    /// vector that keeps every coordinate, for the masked SIMD kernels.
    all_idx: Vec<u32>,
    cache: QueryCache,
}

/// The outcome of a query against one snapshot.
pub enum QueryResult {
    /// PCA: the sample's coordinates in the fitted PC basis.
    Projection {
        /// `componentsᵀ (x − mean)`, length k.
        coords: Vec<f64>,
    },
    /// K-means: nearest-center assignment.
    Assignment {
        /// Index of the nearest center.
        cluster: u32,
        /// Squared Euclidean distance to that center.
        distance2: f64,
        /// The snapshot's Eq. 43 worst-cluster center-error bound
        /// (`NaN` → JSON `null` when not applicable).
        center_bound: f64,
    },
}

impl ModelSnapshot {
    /// Build a snapshot, precomputing the transposed kernel layouts the
    /// batched query path executes against.
    pub fn new(version: u64, n: usize, precision: Precision, kind: ModelKind) -> ModelSnapshot {
        let (p, cache) = match &kind {
            ModelKind::Pca(pca) => {
                let (p, k) = (pca.components.rows(), pca.components.cols());
                let mut components_t = vec![0.0f64; p * k];
                for c in 0..k {
                    let col = pca.components.col(c);
                    for (j, &v) in col.iter().enumerate() {
                        components_t[j * k + c] = v;
                    }
                }
                (p, QueryCache::Pca { components_t })
            }
            ModelKind::Kmeans(km) => {
                let (p, k) = (km.centers.rows(), km.centers.cols());
                let groups = (k + 3) / 4;
                let mut panels = Vec::with_capacity(groups);
                for g in 0..groups {
                    let mut panel = vec![0.0f64; p * 4];
                    for lane in 0..4 {
                        let c = g * 4 + lane;
                        if c < k {
                            let col = km.centers.col(c);
                            for (j, &v) in col.iter().enumerate() {
                                panel[j * 4 + lane] = v;
                            }
                        }
                    }
                    panels.push(panel);
                }
                (p, QueryCache::Kmeans { panels })
            }
        };
        let all_idx: Vec<u32> = (0..p as u32).collect();
        ModelSnapshot { version, n, kind, precision, all_idx, cache }
    }

    /// The query-side value precision this snapshot answers at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The sample dimension queries must match (`p_orig`).
    pub fn dim(&self) -> usize {
        match &self.kind {
            ModelKind::Pca(pca) => pca.mean.len(),
            ModelKind::Kmeans(km) => km.centers.rows(),
        }
    }

    /// Answer one query from this snapshot alone (no locks, no I/O).
    /// The sample must have [`dim`](Self::dim) entries. A panel of one:
    /// bitwise identical to the same sample inside any batch.
    pub fn query(&self, sample: &[f64]) -> Result<QueryResult> {
        let mut out = self.query_panel(&[sample])?;
        out.pop().ok_or_else(|| Error::Invalid("query panel returned no result".into()))
    }

    /// Answer a panel of queries through the SIMD kernels at the
    /// auto-detected ISA tier. Results are in sample order.
    pub fn query_panel(&self, samples: &[&[f64]]) -> Result<Vec<QueryResult>> {
        self.query_panel_at(simd::active(), samples)
    }

    /// [`query_panel`](Self::query_panel) pinned to an explicit ISA
    /// tier — the entry point tests and benchmarks use to assert the
    /// batched path is bitwise identical across tiers without touching
    /// the process-global ISA override.
    pub fn query_panel_at(&self, isa: Isa, samples: &[&[f64]]) -> Result<Vec<QueryResult>> {
        let p = self.dim();
        for (i, s) in samples.iter().enumerate() {
            if s.len() != p {
                return Err(Error::Invalid(format!(
                    "query sample {i} has {} entries, the model dimension is {p}",
                    s.len()
                )));
            }
        }
        let mut out = Vec::with_capacity(samples.len());
        match (&self.kind, &self.cache) {
            (ModelKind::Pca(pca), QueryCache::Pca { components_t }) => {
                let k = pca.components.cols();
                // one scratch buffer for the whole panel — batching
                // amortizes the allocation across samples
                let mut centered = vec![0.0f64; p];
                for &s in samples {
                    match self.precision {
                        Precision::F64 => {
                            for j in 0..p {
                                centered[j] = s[j] - pca.mean[j];
                            }
                        }
                        // quantize the *centered* sample: widening
                        // f32 → f64 is exact, accumulation stays f64
                        Precision::F32 => {
                            for j in 0..p {
                                centered[j] = (s[j] - pca.mean[j]) as f32 as f64;
                            }
                        }
                    }
                    let mut coords = vec![0.0f64; k];
                    simd::col_dot(isa, &mut coords, &self.all_idx, &centered, components_t);
                    out.push(QueryResult::Projection { coords });
                }
            }
            (ModelKind::Kmeans(km), QueryCache::Kmeans { panels }) => {
                let k = km.centers.cols();
                let mut q32 = match self.precision {
                    Precision::F32 => vec![0.0f32; p],
                    Precision::F64 => Vec::new(),
                };
                for &s in samples {
                    if self.precision == Precision::F32 {
                        for j in 0..p {
                            q32[j] = s[j] as f32;
                        }
                    }
                    let mut best = f64::INFINITY;
                    let mut best_c = 0u32;
                    let mut d4 = [0.0f64; 4];
                    for (g, panel) in panels.iter().enumerate() {
                        match self.precision {
                            Precision::F64 => {
                                simd::masked_dist2_x4(isa, &self.all_idx, s, panel, &mut d4);
                            }
                            Precision::F32 => {
                                simd::masked_dist2_x4_f32(isa, &self.all_idx, &q32, panel, &mut d4);
                            }
                        }
                        for (lane, &d) in d4.iter().enumerate() {
                            let c = g * 4 + lane;
                            // strict < in ascending center order: ties
                            // go to the lowest index, like assign_dense
                            if c < k && d < best {
                                best = d;
                                best_c = c as u32;
                            }
                        }
                    }
                    out.push(QueryResult::Assignment {
                        cluster: best_c,
                        distance2: best.max(0.0),
                        center_bound: km.center_bound,
                    });
                }
            }
            _ => {
                return Err(Error::Invalid(
                    "snapshot query cache does not match the model kind".into(),
                ))
            }
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Persistence: the `.pdsp` snapshot artifact (docs/FORMAT.md §4.3).

/// Task tag in the persisted payload.
const TASK_PCA: u8 = 0;
const TASK_KMEANS: u8 = 1;
/// Precision tag in the persisted payload.
const PREC_F64: u8 = 0;
const PREC_F32: u8 = 1;

impl ModelSnapshot {
    /// Serialize into a `.pdsp` artifact (kind [`kind::SNAPSHOT`],
    /// version [`SNAPSHOT_VERSION`], CRC-checked envelope).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = PayloadWriter::new();
        w.u8(match &self.kind {
            ModelKind::Pca(_) => TASK_PCA,
            ModelKind::Kmeans(_) => TASK_KMEANS,
        });
        w.u8(match self.precision {
            Precision::F64 => PREC_F64,
            Precision::F32 => PREC_F32,
        });
        w.u64(self.version);
        w.u64(self.n as u64);
        match &self.kind {
            ModelKind::Pca(pca) => {
                w.u64(pca.components.rows() as u64);
                w.u64(pca.components.cols() as u64);
                w.f64s(pca.components.as_slice());
                w.f64s(&pca.mean);
                w.f64s(&pca.eigenvalues);
            }
            ModelKind::Kmeans(km) => {
                w.u64(km.centers.rows() as u64);
                w.u64(km.centers.cols() as u64);
                w.f64s(km.centers.as_slice());
                w.f64(km.center_bound);
                w.u64(km.iterations as u64);
                w.u8(u8::from(km.converged));
            }
        }
        encode_artifact(kind::SNAPSHOT, SNAPSHOT_VERSION, &w.finish())
    }

    /// Deserialize a persisted snapshot. Truncation, tampering, and
    /// trailing bytes are [`Error::Corrupt`]; a foreign artifact kind or
    /// a version newer than this build is [`Error::Invalid`]. Never
    /// panics on hostile bytes.
    pub fn from_bytes(bytes: &[u8]) -> Result<ModelSnapshot> {
        let (version, k, payload) = decode_artifact(bytes)?;
        if k != kind::SNAPSHOT {
            return Err(Error::Invalid(format!(
                "artifact kind {k} is not a model snapshot (kind {})",
                kind::SNAPSHOT
            )));
        }
        if version > SNAPSHOT_VERSION {
            return Err(Error::Invalid(format!(
                "snapshot version {version} is newer than this build's {SNAPSHOT_VERSION}"
            )));
        }
        let mut r = PayloadReader::new(payload);
        let task = r.u8()?;
        let precision = match r.u8()? {
            PREC_F64 => Precision::F64,
            PREC_F32 => Precision::F32,
            other => {
                return Err(Error::Corrupt(format!("snapshot: unknown precision tag {other}")))
            }
        };
        let model_version = r.u64()?;
        let n = r.len()?;
        let p = r.len()?;
        let cols = r.len()?;
        let pk = match (p, cols) {
            (0, _) | (_, 0) => None,
            _ => p.checked_mul(cols),
        }
        .ok_or_else(|| Error::Corrupt(format!("snapshot: implausible shape {p} x {cols}")))?;
        let snap_kind = match task {
            TASK_PCA => {
                let components = Mat::from_vec(p, cols, r.f64s(pk)?)?;
                let mean = r.f64s(p)?;
                let eigenvalues = r.f64s(cols)?;
                ModelKind::Pca(PcaSnapshot { components, mean, eigenvalues })
            }
            TASK_KMEANS => {
                let centers = Mat::from_vec(p, cols, r.f64s(pk)?)?;
                let center_bound = r.f64()?;
                let iterations = r.len()?;
                let converged = match r.u8()? {
                    0 => false,
                    1 => true,
                    other => {
                        return Err(Error::Corrupt(format!(
                            "snapshot: converged flag {other} is not 0/1"
                        )))
                    }
                };
                ModelKind::Kmeans(KmeansSnapshot { centers, center_bound, iterations, converged })
            }
            other => return Err(Error::Corrupt(format!("snapshot: unknown task tag {other}"))),
        };
        r.finish()?;
        Ok(ModelSnapshot::new(model_version, n, precision, snap_kind))
    }

    /// Persist atomically into `dir` (next to the store manifest): temp
    /// file, fsync, rename — a crash mid-write leaves either the
    /// previous snapshot or this one on disk, never a torn artifact.
    pub fn write_atomic(&self, dir: &Path) -> Result<()> {
        let tmp = dir.join(format!("{SNAPSHOT_FILE}.tmp"));
        {
            use std::io::Write as _;
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&self.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, dir.join(SNAPSHOT_FILE))?;
        Ok(())
    }

    /// Load the persisted snapshot from `dir`, if one exists.
    /// `Ok(None)` when no snapshot has ever been persisted there.
    pub fn load(dir: &Path) -> Result<Option<ModelSnapshot>> {
        let path = dir.join(SNAPSHOT_FILE);
        if !path.exists() {
            return Ok(None);
        }
        Ok(Some(ModelSnapshot::from_bytes(&std::fs::read(&path)?)?))
    }
}

/// The swap cell: holds the current snapshot (if any) plus the
/// degraded-mode flag, **together under one lock**. Writers (the
/// refresh loop) publish whole snapshots; readers (query handlers)
/// clone the `Arc` out. Lock poisoning is deliberately ignored — a
/// panicked refresh must degrade the daemon, not wedge every query
/// forever.
///
/// The stale flag lives inside the `RwLock` rather than in a separate
/// atomic: an earlier layout kept it in an `AtomicBool` next to the
/// lock, which let a reader pair snapshot version `N` with the
/// staleness verdict of version `N±1` (publish swapped the pointer
/// under the lock, then cleared the flag after releasing it). Under
/// ThreadSanitizer-style interleaving a `query_batch` or `stats`
/// response could therefore report a *fresh* model as `stale: true` or
/// a failed refresh as healthy. One lock, one coherent pair — see
/// [`SnapshotCell::load_with_stale`].
pub struct SnapshotCell {
    slot: RwLock<CellState>,
}

/// The lock-protected pair: which model is live, and whether the most
/// recent refresh attempt for it failed.
struct CellState {
    snapshot: Option<Arc<ModelSnapshot>>,
    stale: bool,
}

impl SnapshotCell {
    /// An empty cell (no model yet, not stale).
    pub fn new() -> Self {
        SnapshotCell { slot: RwLock::new(CellState { snapshot: None, stale: false }) }
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, CellState> {
        match self.slot.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, CellState> {
        match self.slot.write() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// The current snapshot, if one has been published.
    pub fn load(&self) -> Option<Arc<ModelSnapshot>> {
        self.read().snapshot.clone()
    }

    /// The current snapshot together with the staleness verdict **for
    /// that same snapshot**, read under one read-lock acquisition.
    /// Query and stats handlers must use this instead of a
    /// `load()` + `is_stale()` pair, which could interleave with a
    /// concurrent publish and pair one version's model with another
    /// version's flag.
    pub fn load_with_stale(&self) -> (Option<Arc<ModelSnapshot>>, bool) {
        let guard = self.read();
        (guard.snapshot.clone(), guard.stale)
    }

    /// Publish a new snapshot and clear the stale flag in the same
    /// critical section. The write lock is held only for the pointer
    /// swap and the flag store.
    pub fn publish(&self, snapshot: ModelSnapshot) {
        let arc = Arc::new(snapshot);
        let mut guard = self.write();
        guard.snapshot = Some(arc);
        guard.stale = false;
    }

    /// Mark the current snapshot stale (a refresh failed; the daemon
    /// keeps serving the previous model with `stale: true`).
    pub fn mark_stale(&self) {
        self.write().stale = true;
    }

    /// Whether the daemon is in degraded mode (last refresh failed).
    pub fn is_stale(&self) -> bool {
        self.read().stale
    }

    /// The published version (0 before the first publish).
    pub fn version(&self) -> u64 {
        self.read().snapshot.as_ref().map(|s| s.version).unwrap_or(0)
    }

    /// Version and staleness as one coherent pair (the `stats`
    /// handler's view).
    pub fn version_with_stale(&self) -> (u64, bool) {
        let guard = self.read();
        (guard.snapshot.as_ref().map(|s| s.version).unwrap_or(0), guard.stale)
    }
}

impl Default for SnapshotCell {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn pca_snapshot(version: u64) -> ModelSnapshot {
        // components = identity on the first 2 of 3 dims, mean = 1-vector
        let components = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]).unwrap();
        ModelSnapshot::new(
            version,
            10,
            Precision::F64,
            ModelKind::Pca(PcaSnapshot {
                components,
                mean: vec![1.0; 3],
                eigenvalues: vec![2.0, 1.0],
            }),
        )
    }

    /// A random p=13 snapshot of each kind at the given precision
    /// (13 exercises ragged SIMD tails; k=6 exercises a ragged lane
    /// group for K-means).
    fn random_snapshot(task: u8, precision: Precision, seed: u64) -> ModelSnapshot {
        let mut rng = Pcg64::seed(seed);
        let (p, k) = (13, 6);
        if task == TASK_PCA {
            ModelSnapshot::new(
                3,
                100,
                precision,
                ModelKind::Pca(PcaSnapshot {
                    components: Mat::from_fn(p, k, |_, _| rng.normal()),
                    mean: (0..p).map(|_| rng.normal()).collect(),
                    eigenvalues: (0..k).map(|_| rng.normal().abs()).collect(),
                }),
            )
        } else {
            ModelSnapshot::new(
                3,
                100,
                precision,
                ModelKind::Kmeans(KmeansSnapshot {
                    centers: Mat::from_fn(p, k, |_, _| rng.normal()),
                    center_bound: 0.25,
                    iterations: 7,
                    converged: true,
                }),
            )
        }
    }

    /// Scalar plus the detected tier (when it is more than scalar).
    fn tiers() -> Vec<Isa> {
        let mut t = vec![Isa::Scalar];
        let d = simd::detect();
        if d != Isa::Scalar {
            t.push(d);
        }
        t
    }

    fn bits(r: &QueryResult) -> Vec<u64> {
        match r {
            QueryResult::Projection { coords } => coords.iter().map(|c| c.to_bits()).collect(),
            QueryResult::Assignment { cluster, distance2, center_bound } => {
                vec![u64::from(*cluster), distance2.to_bits(), center_bound.to_bits()]
            }
        }
    }

    #[test]
    fn pca_query_projects_centered_sample() {
        let snap = pca_snapshot(1);
        match snap.query(&[2.0, 3.0, 4.0]).unwrap() {
            QueryResult::Projection { coords } => assert_eq!(coords, vec![1.0, 2.0]),
            _ => panic!("expected projection"),
        }
        // dimension mismatch is a typed error
        assert!(matches!(snap.query(&[1.0]), Err(Error::Invalid(_))));
    }

    #[test]
    fn kmeans_query_assigns_nearest_center() {
        let centers = Mat::from_vec(2, 2, vec![0.0, 0.0, 10.0, 10.0]).unwrap();
        let snap = ModelSnapshot::new(
            1,
            4,
            Precision::F64,
            ModelKind::Kmeans(KmeansSnapshot {
                centers,
                center_bound: 0.5,
                iterations: 3,
                converged: true,
            }),
        );
        match snap.query(&[9.0, 9.0]).unwrap() {
            QueryResult::Assignment { cluster, distance2, center_bound } => {
                assert_eq!(cluster, 1);
                assert!((distance2 - 2.0).abs() < 1e-12);
                assert_eq!(center_bound, 0.5);
            }
            _ => panic!("expected assignment"),
        }
    }

    /// The tentpole invariant: the batched panel is bitwise identical
    /// to the per-sample path at every batch size and ISA tier, for
    /// both tasks and both precisions.
    #[test]
    fn batched_query_is_bitwise_identical_to_per_sample() {
        let mut rng = Pcg64::seed(9);
        for task in [TASK_PCA, TASK_KMEANS] {
            for precision in [Precision::F64, Precision::F32] {
                let snap = random_snapshot(task, precision, 42);
                let p = snap.dim();
                let samples: Vec<Vec<f64>> =
                    (0..64).map(|_| (0..p).map(|_| rng.normal()).collect()).collect();
                let singles: Vec<Vec<u64>> =
                    samples.iter().map(|s| bits(&snap.query(s).unwrap())).collect();
                for isa in tiers() {
                    for batch in [1usize, 2, 3, 7, 64] {
                        for start in [0usize, 5] {
                            let rows: Vec<&[f64]> = samples
                                [start..(start + batch).min(samples.len())]
                                .iter()
                                .map(Vec::as_slice)
                                .collect();
                            let got = snap.query_panel_at(isa, &rows).unwrap();
                            assert_eq!(got.len(), rows.len());
                            for (i, r) in got.iter().enumerate() {
                                assert_eq!(
                                    bits(r),
                                    singles[start + i],
                                    "task={task} prec={precision:?} isa={} batch={batch} i={i}",
                                    isa.name()
                                );
                            }
                        }
                    }
                }
            }
        }
    }

    /// Serde round trip preserves answers bitwise for both model kinds
    /// at both precisions.
    #[test]
    fn snapshot_artifact_round_trips_bitwise() {
        let mut rng = Pcg64::seed(3);
        for task in [TASK_PCA, TASK_KMEANS] {
            for precision in [Precision::F64, Precision::F32] {
                let snap = random_snapshot(task, precision, 7);
                let back = ModelSnapshot::from_bytes(&snap.to_bytes()).unwrap();
                assert_eq!(back.version, snap.version);
                assert_eq!(back.n, snap.n);
                assert_eq!(back.precision(), precision);
                assert_eq!(back.dim(), snap.dim());
                let sample: Vec<f64> = (0..snap.dim()).map(|_| rng.normal()).collect();
                assert_eq!(
                    bits(&back.query(&sample).unwrap()),
                    bits(&snap.query(&sample).unwrap())
                );
                if let (ModelKind::Kmeans(a), ModelKind::Kmeans(b)) = (&snap.kind, &back.kind) {
                    assert_eq!(a.iterations, b.iterations);
                    assert_eq!(a.converged, b.converged);
                }
            }
        }
    }

    /// Hostile bytes are typed errors, never panics: every truncation
    /// prefix and every single-bit flip is `Corrupt`, a foreign artifact
    /// kind and a from-the-future version are `Invalid`.
    #[test]
    fn damaged_snapshot_artifacts_are_typed_errors() {
        let snap = random_snapshot(TASK_KMEANS, Precision::F64, 11);
        let bytes = snap.to_bytes();
        for cut in 0..bytes.len() {
            match ModelSnapshot::from_bytes(&bytes[..cut]) {
                Err(Error::Corrupt(_)) => {}
                Err(e) => panic!("truncation at {cut} must be Corrupt, got {e:?}"),
                Ok(_) => panic!("truncation at {cut} must fail"),
            }
        }
        for byte in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[byte] ^= 0x40;
            assert!(
                ModelSnapshot::from_bytes(&bad).is_err(),
                "bit flip at byte {byte} must be an error"
            );
        }
        // a valid envelope of a different kind is Invalid, not Corrupt
        let foreign = encode_artifact(kind::MEAN, 1, &[0u8; 16]);
        assert!(matches!(ModelSnapshot::from_bytes(&foreign), Err(Error::Invalid(_))));
        // a snapshot from a future build is Invalid
        let future = encode_artifact(kind::SNAPSHOT, SNAPSHOT_VERSION + 1, &[0u8; 16]);
        assert!(matches!(ModelSnapshot::from_bytes(&future), Err(Error::Invalid(_))));
    }

    /// `write_atomic` + `load` round trip on disk; a missing file is
    /// `Ok(None)`, a truncated file is typed `Corrupt`.
    #[test]
    fn snapshot_persists_and_reloads_from_disk() {
        let dir =
            std::env::temp_dir().join(format!("pds_snap_persist_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(ModelSnapshot::load(&dir).unwrap().is_none());
        let snap = random_snapshot(TASK_PCA, Precision::F32, 5);
        snap.write_atomic(&dir).unwrap();
        let back = ModelSnapshot::load(&dir).unwrap().expect("persisted snapshot loads");
        assert_eq!(back.version, snap.version);
        assert_eq!(back.precision(), Precision::F32);
        // newer publish overwrites atomically
        let next = random_snapshot(TASK_PCA, Precision::F32, 6);
        next.write_atomic(&dir).unwrap();
        assert_eq!(ModelSnapshot::load(&dir).unwrap().unwrap().version, next.version);
        // truncate on disk: typed Corrupt at load
        let bytes = std::fs::read(dir.join(SNAPSHOT_FILE)).unwrap();
        std::fs::write(dir.join(SNAPSHOT_FILE), &bytes[..bytes.len() / 2]).unwrap();
        assert!(matches!(ModelSnapshot::load(&dir), Err(Error::Corrupt(_))));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cell_swaps_and_tracks_staleness() {
        let cell = SnapshotCell::new();
        assert!(cell.load().is_none());
        assert_eq!(cell.version(), 0);
        cell.publish(pca_snapshot(1));
        assert_eq!(cell.version(), 1);
        assert!(!cell.is_stale());
        // a failed refresh degrades but keeps the old snapshot
        cell.mark_stale();
        assert!(cell.is_stale());
        assert_eq!(cell.version(), 1);
        // the next successful publish clears the flag
        cell.publish(pca_snapshot(2));
        assert!(!cell.is_stale());
        assert_eq!(cell.version(), 2);
        // the coherent accessors agree with the scalar ones when quiescent
        let (snap, stale) = cell.load_with_stale();
        assert_eq!(snap.unwrap().version, 2);
        assert!(!stale);
        assert_eq!(cell.version_with_stale(), (2, false));
    }

    /// Regression for the torn (snapshot, stale) pair: the writer
    /// publishes version `i` and marks the cell stale only after odd
    /// publishes, so a coherent reader can never observe an
    /// even-versioned snapshot with `stale == true`. The pre-fix layout
    /// (stale in an `AtomicBool` cleared *after* the publish released
    /// the write lock) let readers pair version `i` with version
    /// `i-1`'s flag, and this hammer caught it within a few thousand
    /// iterations under ThreadSanitizer-style schedules.
    #[test]
    fn load_with_stale_never_tears_under_concurrent_publish() {
        use std::sync::atomic::{AtomicBool, Ordering};

        let cell = Arc::new(SnapshotCell::new());
        let done = Arc::new(AtomicBool::new(false));
        let mut readers = Vec::new();
        for _ in 0..4 {
            let (cell, done) = (cell.clone(), done.clone());
            readers.push(std::thread::spawn(move || {
                while !done.load(Ordering::SeqCst) {
                    let (snap, stale) = cell.load_with_stale();
                    if let Some(s) = snap {
                        assert!(
                            !(s.version % 2 == 0 && stale),
                            "torn pair: even version {} observed with stale=true",
                            s.version
                        );
                    }
                    let (version, stale) = cell.version_with_stale();
                    assert!(
                        !(version > 0 && version % 2 == 0 && stale),
                        "torn pair: even version {version} observed with stale=true"
                    );
                }
            }));
        }
        for version in 1..=2000u64 {
            cell.publish(pca_snapshot(version));
            if version % 2 == 1 {
                cell.mark_stale();
            }
        }
        done.store(true, Ordering::SeqCst);
        for r in readers {
            r.join().expect("reader observed a torn (snapshot, stale) pair");
        }
        assert_eq!(cell.version_with_stale(), (2000, false));
    }
}
