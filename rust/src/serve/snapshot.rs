//! Immutable model snapshots and the atomic swap cell.
//!
//! The refresh loop builds a complete new [`ModelSnapshot`] offline,
//! then publishes it into the [`SnapshotCell`] under a write lock held
//! only for the pointer swap. Query handlers clone the `Arc` out under
//! a read lock and answer entirely from that immutable value, so a
//! query observes exactly one model version end to end and never blocks
//! on (or is torn by) a concurrent refresh.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use crate::error::{Error, Result};
use crate::linalg::Mat;

/// A published PCA model (original data domain — components and mean
/// are already unmixed through the ROS adjoint where applicable).
pub struct PcaSnapshot {
    /// Top-k principal components, `p_orig × k` (columns are PCs).
    pub components: Mat,
    /// Estimated sample mean, length `p_orig`.
    pub mean: Vec<f64>,
    /// Eigenvalues matching the components.
    pub eigenvalues: Vec<f64>,
}

/// A published K-means model (original data domain).
pub struct KmeansSnapshot {
    /// Cluster centers, `p_orig × k` (columns are centers).
    pub centers: Mat,
    /// Worst-cluster Eq. 43 center-error bound, evaluated at the
    /// coreset-estimated cluster sizes (see the serve module docs).
    /// `NaN` — serialized as JSON `null` — when the theory does not
    /// cover the fit (weighted sampling schemes), per the repo's
    /// "never present an unbacked number" rule.
    pub center_bound: f64,
    /// Lloyd iterations of the winning weighted-K-means restart.
    pub iterations: usize,
    /// Whether that restart converged.
    pub converged: bool,
}

/// The task-specific payload of a snapshot.
pub enum ModelKind {
    /// A PCA fit.
    Pca(PcaSnapshot),
    /// A K-means fit.
    Kmeans(KmeansSnapshot),
}

/// One immutable published model: everything a query needs, plus the
/// provenance a client sees (`model_version`, sample count).
pub struct ModelSnapshot {
    /// Monotone version, bumped once per successful refresh.
    pub version: u64,
    /// Samples the model was fitted on.
    pub n: usize,
    /// The fitted model.
    pub kind: ModelKind,
}

/// The outcome of a query against one snapshot.
pub enum QueryResult {
    /// PCA: the sample's coordinates in the fitted PC basis.
    Projection {
        /// `components? (x − mean)`, length k.
        coords: Vec<f64>,
    },
    /// K-means: nearest-center assignment.
    Assignment {
        /// Index of the nearest center.
        cluster: u32,
        /// Squared Euclidean distance to that center.
        distance2: f64,
        /// The snapshot's Eq. 43 worst-cluster center-error bound
        /// (`NaN` → JSON `null` when not applicable).
        center_bound: f64,
    },
}

impl ModelSnapshot {
    /// The sample dimension queries must match (`p_orig`).
    pub fn dim(&self) -> usize {
        match &self.kind {
            ModelKind::Pca(pca) => pca.mean.len(),
            ModelKind::Kmeans(km) => km.centers.rows(),
        }
    }

    /// Answer one query from this snapshot alone (no locks, no I/O).
    /// The sample must have [`dim`](Self::dim) entries.
    pub fn query(&self, sample: &[f64]) -> Result<QueryResult> {
        if sample.len() != self.dim() {
            return Err(Error::Invalid(format!(
                "query sample has {} entries, the model dimension is {}",
                sample.len(),
                self.dim()
            )));
        }
        match &self.kind {
            ModelKind::Pca(pca) => {
                let centered: Vec<f64> =
                    sample.iter().zip(&pca.mean).map(|(x, m)| x - m).collect();
                Ok(QueryResult::Projection { coords: pca.components.matvec_transa(&centered) })
            }
            ModelKind::Kmeans(km) => {
                let x = Mat::from_vec(km.centers.rows(), 1, sample.to_vec())?;
                let (assign, obj) = crate::kmeans::assign_dense(&x, &km.centers);
                Ok(QueryResult::Assignment {
                    cluster: assign[0],
                    distance2: obj.max(0.0),
                    center_bound: km.center_bound,
                })
            }
        }
    }
}

/// The swap cell: holds the current snapshot (if any) plus the
/// degraded-mode flag. Writers (the refresh loop) publish whole
/// snapshots; readers (query handlers) clone the `Arc` out. Lock
/// poisoning is deliberately ignored — a panicked refresh must degrade
/// the daemon, not wedge every query forever.
pub struct SnapshotCell {
    slot: RwLock<Option<Arc<ModelSnapshot>>>,
    stale: AtomicBool,
}

impl SnapshotCell {
    /// An empty cell (no model yet, not stale).
    pub fn new() -> Self {
        SnapshotCell { slot: RwLock::new(None), stale: AtomicBool::new(false) }
    }

    /// The current snapshot, if one has been published.
    pub fn load(&self) -> Option<Arc<ModelSnapshot>> {
        let guard = match self.slot.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        guard.clone()
    }

    /// Publish a new snapshot and clear the stale flag. The write lock
    /// is held only for the pointer swap.
    pub fn publish(&self, snapshot: ModelSnapshot) {
        let arc = Arc::new(snapshot);
        {
            let mut guard = match self.slot.write() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            *guard = Some(arc);
        }
        self.stale.store(false, Ordering::SeqCst);
    }

    /// Mark the current snapshot stale (a refresh failed; the daemon
    /// keeps serving the previous model with `stale: true`).
    pub fn mark_stale(&self) {
        self.stale.store(true, Ordering::SeqCst);
    }

    /// Whether the daemon is in degraded mode (last refresh failed).
    pub fn is_stale(&self) -> bool {
        self.stale.load(Ordering::SeqCst)
    }

    /// The published version (0 before the first publish).
    pub fn version(&self) -> u64 {
        self.load().map(|s| s.version).unwrap_or(0)
    }
}

impl Default for SnapshotCell {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pca_snapshot(version: u64) -> ModelSnapshot {
        // components = identity on the first 2 of 3 dims, mean = 1-vector
        let components = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0]).unwrap();
        ModelSnapshot {
            version,
            n: 10,
            kind: ModelKind::Pca(PcaSnapshot {
                components,
                mean: vec![1.0; 3],
                eigenvalues: vec![2.0, 1.0],
            }),
        }
    }

    #[test]
    fn pca_query_projects_centered_sample() {
        let snap = pca_snapshot(1);
        match snap.query(&[2.0, 3.0, 4.0]).unwrap() {
            QueryResult::Projection { coords } => assert_eq!(coords, vec![1.0, 2.0]),
            _ => panic!("expected projection"),
        }
        // dimension mismatch is a typed error
        assert!(matches!(snap.query(&[1.0]), Err(Error::Invalid(_))));
    }

    #[test]
    fn kmeans_query_assigns_nearest_center() {
        let centers = Mat::from_vec(2, 2, vec![0.0, 0.0, 10.0, 10.0]).unwrap();
        let snap = ModelSnapshot {
            version: 1,
            n: 4,
            kind: ModelKind::Kmeans(KmeansSnapshot {
                centers,
                center_bound: 0.5,
                iterations: 3,
                converged: true,
            }),
        };
        match snap.query(&[9.0, 9.0]).unwrap() {
            QueryResult::Assignment { cluster, distance2, center_bound } => {
                assert_eq!(cluster, 1);
                assert!((distance2 - 2.0).abs() < 1e-12);
                assert_eq!(center_bound, 0.5);
            }
            _ => panic!("expected assignment"),
        }
    }

    #[test]
    fn cell_swaps_and_tracks_staleness() {
        let cell = SnapshotCell::new();
        assert!(cell.load().is_none());
        assert_eq!(cell.version(), 0);
        cell.publish(pca_snapshot(1));
        assert_eq!(cell.version(), 1);
        assert!(!cell.is_stale());
        // a failed refresh degrades but keeps the old snapshot
        cell.mark_stale();
        assert!(cell.is_stale());
        assert_eq!(cell.version(), 1);
        // the next successful publish clears the flag
        cell.publish(pca_snapshot(2));
        assert!(!cell.is_stale());
        assert_eq!(cell.version(), 2);
    }
}
