//! The daemon's background ingest lane.
//!
//! Client handlers validate raw sample batches and `try_send` them into
//! a bounded channel (a full channel is a typed `backpressure` error,
//! never a block). One worker thread owns the [`Sparsifier`] and the
//! live [`SparseStoreWriter`]: it compresses each batch, appends it,
//! and durably publishes a manifest checkpoint every time a shard
//! completes — so a daemon killed at any instant leaves a CRC-clean,
//! openable store covering every completed shard.
//!
//! A writer failure (disk full, I/O error) does not kill the daemon:
//! the worker records the error, drops further batches (still counting
//! them so `flush` waiters never hang), and the query path keeps
//! serving from the last snapshot — the degraded mode.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::linalg::Mat;
use crate::metrics::ServeMetrics;
use crate::sampling::Sparsifier;
use crate::store::{SparseStoreWriter, StoreManifest};

/// How often the worker re-checks the shutdown flag while the queue is
/// idle.
const IDLE_POLL: Duration = Duration::from_millis(25);

/// One queued unit of work: raw sample columns (`p_orig × n`), already
/// validated by the request handler.
pub struct IngestBatch {
    /// The raw samples, one per column.
    pub data: Mat,
}

/// Ingest-lane progress counters, updated under one mutex and broadcast
/// on [`IngestShared::cv`] — what `flush` and `stats` handlers read.
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestProgress {
    /// Batches accepted into the queue since startup.
    pub enqueued: u64,
    /// Batches taken off the queue and fully handled (compressed and
    /// appended, or deliberately dropped after a writer failure).
    pub absorbed: u64,
    /// Columns appended to the writer (flushed shards + its buffer).
    pub total_cols: usize,
    /// Columns covered by the last durable manifest (checkpoint or
    /// finish) — what a crashed daemon is guaranteed to keep.
    pub durable_cols: usize,
    /// The worker exited (writer finalized, or failed terminally).
    pub finished: bool,
}

/// State shared between the ingest worker and the request handlers.
pub struct IngestShared {
    /// Progress counters (guarded; see [`IngestProgress`]).
    pub progress: Mutex<IngestProgress>,
    /// Notified after every absorbed batch and at worker exit.
    pub cv: Condvar,
    /// First writer error, if any — once set, the lane is dead and
    /// later batches are dropped (the daemon itself keeps serving).
    pub error: Mutex<Option<String>>,
}

impl IngestShared {
    /// Fresh shared state (all counters zero, no error).
    pub fn new() -> Self {
        IngestShared {
            progress: Mutex::new(IngestProgress::default()),
            cv: Condvar::new(),
            error: Mutex::new(None),
        }
    }

    /// Lock the progress counters, surviving a poisoned lock (a panicked
    /// peer must not wedge the daemon).
    pub fn lock_progress(&self) -> MutexGuard<'_, IngestProgress> {
        match self.progress.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// The recorded writer error, if the lane has failed.
    pub fn error_message(&self) -> Option<String> {
        match self.error.lock() {
            Ok(g) => g.clone(),
            Err(poisoned) => poisoned.into_inner().clone(),
        }
    }

    fn set_error(&self, msg: String) {
        let mut slot = match self.error.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if slot.is_none() {
            *slot = Some(msg);
        }
    }

    /// Block until `absorbed >= goal` batches are handled (or the worker
    /// exits), up to `timeout`. Returns whether the goal was reached.
    pub fn wait_absorbed(&self, goal: u64, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut pg = self.lock_progress();
        loop {
            if pg.absorbed >= goal || pg.finished {
                return pg.absorbed >= goal;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (guard, _) = match self.cv.wait_timeout(pg, deadline - now) {
                Ok(r) => r,
                Err(poisoned) => poisoned.into_inner(),
            };
            pg = guard;
        }
    }
}

impl Default for IngestShared {
    fn default() -> Self {
        Self::new()
    }
}

/// The worker loop. Owns the sparsifier and writer; runs until the
/// channel disconnects (all senders dropped) or `shutdown` is raised,
/// then drains the remaining backlog and finalizes the store. Returns
/// the final manifest.
pub fn run_ingest_worker(
    rx: Receiver<IngestBatch>,
    sp: Sparsifier,
    precondition: bool,
    mut writer: SparseStoreWriter,
    shared: Arc<IngestShared>,
    metrics: Arc<ServeMetrics>,
    shutdown: Arc<AtomicBool>,
) -> Result<StoreManifest> {
    let mut checkpointed_shards = 0usize;
    loop {
        match rx.recv_timeout(IDLE_POLL) {
            Ok(batch) => {
                absorb(&sp, precondition, &mut writer, &mut checkpointed_shards, batch, &shared, &metrics);
            }
            Err(RecvTimeoutError::Timeout) => {
                // SeqCst: must observe a shutdown stored by any handler
                // thread (the queue may stay empty forever after it)
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // batches accepted before the shutdown flag went up still land
    while let Ok(batch) = rx.try_recv() {
        absorb(&sp, precondition, &mut writer, &mut checkpointed_shards, batch, &shared, &metrics);
    }

    let result = if shared.error_message().is_none() {
        writer.finish()
    } else {
        // the lane already failed mid-stream; don't let finish() turn a
        // partially-buffered writer into a second confusing error —
        // publish the shards that did land and report the first failure
        let _ = writer.checkpoint();
        Err(Error::Invalid(format!(
            "ingest writer failed: {}",
            shared.error_message().unwrap_or_default()
        )))
    };

    let mut pg = shared.lock_progress();
    if let Ok(manifest) = &result {
        pg.total_cols = manifest.n;
        pg.durable_cols = manifest.n;
    }
    pg.finished = true;
    drop(pg);
    shared.cv.notify_all();
    result
}

/// Handle one dequeued batch: compress, append, checkpoint on shard
/// boundaries. Errors poison the lane (recorded, later batches dropped)
/// but never propagate — the daemon must keep serving queries.
fn absorb(
    sp: &Sparsifier,
    precondition: bool,
    writer: &mut SparseStoreWriter,
    checkpointed_shards: &mut usize,
    batch: IngestBatch,
    shared: &IngestShared,
    metrics: &ServeMetrics,
) {
    let mut durable = None;
    if shared.error_message().is_none() {
        match ingest_one(sp, precondition, writer, checkpointed_shards, &batch) {
            Ok(d) => durable = d,
            Err(e) => shared.set_error(e.to_string()),
        }
    }
    let mut pg = shared.lock_progress();
    pg.absorbed += 1;
    pg.total_cols = writer.columns_written();
    if let Some(n) = durable {
        pg.durable_cols = n;
    }
    // Relaxed: stats gauge; the progress lock held here already orders
    // it against the enqueued/absorbed counters
    metrics.queue_depth.store(pg.enqueued.saturating_sub(pg.absorbed), Ordering::Relaxed);
    drop(pg);
    shared.cv.notify_all();
}

/// Compress + append one batch; returns the new durable column count if
/// a checkpoint was written.
fn ingest_one(
    sp: &Sparsifier,
    precondition: bool,
    writer: &mut SparseStoreWriter,
    checkpointed_shards: &mut usize,
    batch: &IngestBatch,
) -> Result<Option<usize>> {
    let start = writer.columns_written();
    let chunk = if precondition {
        sp.compress_chunk(&batch.data, start)?
    } else {
        sp.compress_chunk_no_precondition(&batch.data, start)?
    };
    writer.append(chunk)?;
    if writer.completed_shards() > *checkpointed_shards {
        let durable = writer.checkpoint()?;
        *checkpointed_shards = writer.completed_shards();
        return Ok(durable);
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::sampling::SparsifyConfig;
    use crate::store::SparseStoreReader;
    use crate::transform::TransformKind;
    use std::sync::mpsc::sync_channel;

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("pds_serve_ingest_{tag}_{}", std::process::id()))
    }

    #[test]
    fn worker_ingests_checkpoints_and_finalizes() {
        let dir = temp_dir("ok");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 7 };
        let sp = Sparsifier::new(16, cfg).unwrap();
        let writer = SparseStoreWriter::create(&dir, &sp, cfg, true, 8).unwrap();
        let shared = Arc::new(IngestShared::new());
        let metrics = Arc::new(ServeMetrics::new());
        let shutdown = Arc::new(AtomicBool::new(false));

        let (tx, rx) = sync_channel::<IngestBatch>(8);
        let worker = {
            let (shared, metrics, shutdown) =
                (shared.clone(), metrics.clone(), shutdown.clone());
            std::thread::spawn(move || {
                run_ingest_worker(rx, sp, true, writer, shared, metrics, shutdown)
            })
        };

        let mut rng = Pcg64::seed(3);
        for _ in 0..3 {
            let data = Mat::from_fn(16, 6, |_, _| rng.normal());
            tx.send(IngestBatch { data }).unwrap();
            shared.lock_progress().enqueued += 1;
        }
        assert!(shared.wait_absorbed(3, Duration::from_secs(10)), "flush timed out");
        // 18 columns at shard_cols=8: two full shards must be durable
        // (checkpointed) before shutdown
        assert_eq!(shared.lock_progress().durable_cols, 16);
        drop(tx); // disconnect ends the worker
        let manifest = worker.join().unwrap().unwrap();
        assert_eq!(manifest.n, 18);
        assert!(shared.lock_progress().finished);

        // the finalized store reads back CRC-clean
        let mut reader = SparseStoreReader::open(&dir).unwrap().with_verify(true);
        let mut cols = 0;
        while let Some(chunk) = reader.next_chunk().unwrap() {
            cols += chunk.n();
        }
        assert_eq!(cols, 18);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn writer_failure_poisons_the_lane_not_the_daemon() {
        let dir = temp_dir("err");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 7 };
        let sp = Sparsifier::new(16, cfg).unwrap();
        let writer = SparseStoreWriter::create(&dir, &sp, cfg, true, 8).unwrap();
        let shared = Arc::new(IngestShared::new());
        let metrics = Arc::new(ServeMetrics::new());
        let shutdown = Arc::new(AtomicBool::new(false));

        let (tx, rx) = sync_channel::<IngestBatch>(8);
        let worker = {
            let (shared, metrics, shutdown) =
                (shared.clone(), metrics.clone(), shutdown.clone());
            std::thread::spawn(move || {
                run_ingest_worker(rx, sp, true, writer, shared, metrics, shutdown)
            })
        };

        // a wrong-dimension batch makes the compressor fail inside the
        // worker (handlers normally reject this; the worker must survive
        // it regardless)
        tx.send(IngestBatch { data: Mat::zeros(4, 2) }).unwrap();
        shared.lock_progress().enqueued += 1;
        // and a good batch after it is dropped, not wedged
        tx.send(IngestBatch { data: Mat::zeros(16, 2) }).unwrap();
        shared.lock_progress().enqueued += 1;

        assert!(shared.wait_absorbed(2, Duration::from_secs(10)), "absorb timed out");
        assert!(shared.error_message().is_some());
        drop(tx);
        assert!(worker.join().unwrap().is_err(), "a failed lane must report the failure");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
