//! The serve daemon's newline-delimited JSON protocol.
//!
//! One request object per line in, one response object per line out.
//!
//! Requests (`cmd` selects the verb):
//!
//! ```text
//! {"cmd":"ingest","samples":[[x00,…,x0p],…]}      enqueue raw sample columns
//! {"cmd":"query","sample":[x0,…,xp]}              project / assign one sample
//! {"cmd":"query_batch","samples":[[x00,…,x0p],…]} project / assign many samples in one round trip
//! {"cmd":"stats"}                                 dump the metrics registry
//! {"cmd":"refresh"}                               force a model refresh, wait for it
//! {"cmd":"flush"}                                 wait until enqueued batches are absorbed
//! {"cmd":"shutdown"}                              graceful stop (writer finalized)
//! ```
//!
//! Responses always carry `"ok"`: `{"ok":true,…}` on success,
//! `{"ok":false,"code":C,"error":MSG}` on a typed failure. Model-backed
//! responses additionally carry `"model_version"` (monotone, bumped per
//! successful refresh) and `"stale"` (true when the last refresh failed
//! and the daemon is serving the previous snapshot — the degraded mode).
//! A `query_batch` response answers every sample from one snapshot and
//! carries a `"results"` array in request order. Malformed lines,
//! oversized batches, and full queues are all typed errors; the daemon
//! never closes an established connection in response to a bad request.
//! (The one connection-scoped rejection is the transport's: a connection
//! beyond `--conn-slots` receives a single `backpressure` error line and
//! is closed — see the serve module docs.)
//!
//! Both query verbs run through the daemon's batching lane: requests in
//! flight at the same moment — across all connections — coalesce into
//! one SIMD panel, which answers them bit-identically to one-at-a-time
//! execution (a single query is a panel of one).

use crate::error::{Error, Result};

use super::json::Json;

/// Typed error code: the request line was not a valid protocol message.
pub const CODE_BAD_REQUEST: &str = "bad_request";
/// Typed error code: the bounded ingest queue is full (backpressure —
/// retry later; nothing was enqueued).
pub const CODE_BACKPRESSURE: &str = "backpressure";
/// Typed error code: no model snapshot has been published yet.
pub const CODE_NO_MODEL: &str = "no_model";
/// Typed error code: the request's wait budget elapsed (the operation
/// may still complete in the background).
pub const CODE_TIMEOUT: &str = "timeout";
/// Typed error code: the daemon is shutting down and no longer accepts
/// ingest.
pub const CODE_SHUTDOWN: &str = "shutdown";
/// Typed error code: an internal failure (e.g. the ingest writer hit an
/// I/O error); the daemon keeps serving queries from the last snapshot.
pub const CODE_INTERNAL: &str = "internal";

/// A parsed protocol request.
#[derive(Debug, PartialEq)]
pub enum Request {
    /// Enqueue raw sample columns (each of the store's original
    /// dimension) for sparsification and ingest.
    Ingest {
        /// The batch: one inner array per sample column.
        samples: Vec<Vec<f64>>,
    },
    /// Project one sample onto the fitted PCs / assign it to the nearest
    /// center, from the current snapshot.
    Query {
        /// The sample, in the store's original dimension.
        sample: Vec<f64>,
    },
    /// Answer many samples in one round trip, all from one snapshot.
    QueryBatch {
        /// The samples, each in the store's original dimension.
        samples: Vec<Vec<f64>>,
    },
    /// Dump the metrics registry.
    Stats,
    /// Force a model refresh and wait (bounded) for it to complete.
    Refresh,
    /// Wait (bounded) until every batch enqueued so far has been
    /// absorbed by the ingest thread and completed shards are durable.
    Flush,
    /// Graceful shutdown.
    Shutdown,
}

impl Request {
    /// Parse one protocol line. Every failure is [`Error::Invalid`] with
    /// a message suitable for a `bad_request` response.
    pub fn parse(line: &str) -> Result<Request> {
        let root = Json::parse(line)?;
        let cmd = root
            .get("cmd")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Invalid("request needs a string `cmd` field".into()))?;
        match cmd {
            "ingest" => {
                let rows = root
                    .get("samples")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| Error::Invalid("ingest needs a `samples` array".into()))?;
                if rows.is_empty() {
                    return Err(Error::Invalid("ingest: `samples` is empty".into()));
                }
                let mut samples = Vec::with_capacity(rows.len());
                for (i, row) in rows.iter().enumerate() {
                    samples.push(number_vec(row, &format!("samples[{i}]"))?);
                }
                Ok(Request::Ingest { samples })
            }
            "query" => {
                let sample = root
                    .get("sample")
                    .ok_or_else(|| Error::Invalid("query needs a `sample` array".into()))?;
                Ok(Request::Query { sample: number_vec(sample, "sample")? })
            }
            "query_batch" => {
                let rows = root.get("samples").and_then(Json::as_arr).ok_or_else(|| {
                    Error::Invalid("query_batch needs a `samples` array".into())
                })?;
                if rows.is_empty() {
                    return Err(Error::Invalid("query_batch: `samples` is empty".into()));
                }
                let mut samples = Vec::with_capacity(rows.len());
                for (i, row) in rows.iter().enumerate() {
                    samples.push(number_vec(row, &format!("samples[{i}]"))?);
                }
                Ok(Request::QueryBatch { samples })
            }
            "stats" => Ok(Request::Stats),
            "refresh" => Ok(Request::Refresh),
            "flush" => Ok(Request::Flush),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(Error::Invalid(format!("unknown cmd {other:?}"))),
        }
    }
}

/// Extract a JSON array of finite numbers. Non-finite values (JSON
/// cannot express NaN, but `1e999` overflows to infinity) are rejected:
/// they would silently poison every downstream estimate.
fn number_vec(value: &Json, what: &str) -> Result<Vec<f64>> {
    let items = value
        .as_arr()
        .ok_or_else(|| Error::Invalid(format!("{what} must be an array of numbers")))?;
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        let v = item
            .as_f64()
            .ok_or_else(|| Error::Invalid(format!("{what}[{i}] is not a number")))?;
        if !v.is_finite() {
            return Err(Error::Invalid(format!("{what}[{i}] is not finite")));
        }
        out.push(v);
    }
    Ok(out)
}

/// Serialize a success response: `{"ok":true, …fields}`.
pub fn ok_response(fields: Vec<(&str, Json)>) -> String {
    let mut obj = vec![("ok".to_string(), Json::Bool(true))];
    obj.extend(fields.into_iter().map(|(k, v)| (k.to_string(), v)));
    Json::Obj(obj).to_string()
}

/// Serialize a typed error response:
/// `{"ok":false,"code":code,"error":message}`.
pub fn error_response(code: &str, message: &str) -> String {
    Json::Obj(vec![
        ("ok".to_string(), Json::Bool(false)),
        ("code".to_string(), Json::Str(code.to_string())),
        ("error".to_string(), Json::Str(message.to_string())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_parse() {
        assert_eq!(
            Request::parse(r#"{"cmd":"ingest","samples":[[1,2],[3,4]]}"#).unwrap(),
            Request::Ingest { samples: vec![vec![1.0, 2.0], vec![3.0, 4.0]] }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"query","sample":[0.5,1.5]}"#).unwrap(),
            Request::Query { sample: vec![0.5, 1.5] }
        );
        assert_eq!(
            Request::parse(r#"{"cmd":"query_batch","samples":[[1,2],[3,4]]}"#).unwrap(),
            Request::QueryBatch { samples: vec![vec![1.0, 2.0], vec![3.0, 4.0]] }
        );
        for (line, want) in [
            (r#"{"cmd":"stats"}"#, Request::Stats),
            (r#"{"cmd":"refresh"}"#, Request::Refresh),
            (r#"{"cmd":"flush"}"#, Request::Flush),
            (r#"{"cmd":"shutdown"}"#, Request::Shutdown),
        ] {
            assert_eq!(Request::parse(line).unwrap(), want);
        }
    }

    #[test]
    fn bad_requests_are_typed_errors() {
        for bad in [
            "not json",
            r#"{"cmd":"teleport"}"#,
            r#"{"cmd":42}"#,
            r#"{"cmd":"ingest"}"#,
            r#"{"cmd":"ingest","samples":[]}"#,
            r#"{"cmd":"ingest","samples":[["x"]]}"#,
            r#"{"cmd":"query","sample":[1e999]}"#, // overflows to inf
            r#"{"cmd":"query"}"#,
            r#"{"cmd":"query_batch"}"#,
            r#"{"cmd":"query_batch","samples":[]}"#,
            r#"{"cmd":"query_batch","samples":[[1],"x"]}"#,
        ] {
            assert!(
                matches!(Request::parse(bad), Err(Error::Invalid(_))),
                "{bad:?} must be Invalid"
            );
        }
    }

    #[test]
    fn responses_have_the_envelope() {
        let ok = ok_response(vec![("rows", Json::Num(4.0))]);
        assert_eq!(ok, r#"{"ok":true,"rows":4}"#);
        let err = error_response(CODE_BACKPRESSURE, "queue full");
        assert_eq!(err, r#"{"ok":false,"code":"backpressure","error":"queue full"}"#);
    }
}
