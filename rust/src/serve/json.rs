//! A minimal JSON value type for the serve wire protocol.
//!
//! The build is offline (no serde); the protocol needs exactly: parse a
//! request line into a tree, pull typed fields out, and serialize a
//! response tree back. This module implements that subset of RFC 8259 —
//! full escape handling (including `\uXXXX` surrogate pairs), a
//! recursion-depth cap so a hostile request cannot overflow the stack,
//! and `null` for non-finite numbers on output (JSON has no NaN).
//! Malformed input surfaces [`Error::Invalid`], never a panic — the
//! daemon treats every parse failure as a typed `bad_request`.

use std::fmt;

use crate::error::{Error, Result};

/// Nesting depth cap for the parser (objects + arrays). Protocol
/// messages are ≤ 3 levels deep; 64 leaves headroom while keeping a
/// pathological `[[[[…` request from exhausting the stack.
const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Numbers are `f64` (the protocol's counts stay
/// far below 2^53, where that representation is exact).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in insertion order (the protocol never relies on
    /// duplicate keys; lookup returns the first match).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON document; trailing non-whitespace is an
    /// error.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let value = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after the JSON document"));
        }
        Ok(value)
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The value as a number, if it is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The value as a string slice, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool, if it is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The value as an array slice, if it is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            // JSON has no NaN/Infinity; emit null so a failed bound or
            // an empty statistic serializes as "not a number" honestly
            Json::Num(v) if !v.is_finite() => f.write_str("null"),
            Json::Num(v) => write!(f, "{v}"),
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{item}")?;
                }
                f.write_str("]")
            }
            Json::Obj(fields) => {
                f.write_str("{")?;
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Invalid(format!("json (byte {}): {msg}", self.pos))
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8, what: &str) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(what))
        }
    }

    fn eat_literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'n') => self.eat_literal("null", Json::Null),
            Some(b't') => self.eat_literal("true", Json::Bool(true)),
            Some(b'f') => self.eat_literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'[', "expected `[`")?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.eat(b'{', "expected `{`")?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':', "expected `:` after object key")?;
            self.skip_ws();
            let value = self.value(depth + 1)?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if matches!(b, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        let v: f64 = text.parse().map_err(|_| self.err("invalid number"))?;
        Ok(Json::Num(v))
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let slice = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("invalid \\u escape"))?;
        let cp =
            u32::from_str_radix(slice, 16).map_err(|_| self.err("invalid \\u escape"))?;
        self.pos += 4;
        Ok(cp)
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"', "expected string")?;
        let mut out = String::new();
        loop {
            // advance over a full UTF-8 scalar at a time: the remaining
            // input is a str slice, so char boundaries are free
            let rest = std::str::from_utf8(&self.bytes[self.pos..])
                .map_err(|_| self.err("invalid utf-8 in string"))?;
            let mut chars = rest.chars();
            let c = chars.next().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += c.len_utf8();
            match c {
                '"' => return Ok(out),
                '\\' => {
                    let esc = chars.next().ok_or_else(|| self.err("unterminated escape"))?;
                    self.pos += esc.len_utf8();
                    match esc {
                        '"' => out.push('"'),
                        '\\' => out.push('\\'),
                        '/' => out.push('/'),
                        'b' => out.push('\u{0008}'),
                        'f' => out.push('\u{000C}'),
                        'n' => out.push('\n'),
                        'r' => out.push('\r'),
                        't' => out.push('\t'),
                        'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..=0xDBFF).contains(&hi) {
                                // surrogate pair: require \uDC00–\uDFFF next
                                if self.bytes.get(self.pos) != Some(&b'\\')
                                    || self.bytes.get(self.pos + 1) != Some(&b'u')
                                {
                                    return Err(self.err("unpaired high surrogate"));
                                }
                                self.pos += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..=0xDFFF).contains(&lo) {
                                    return Err(self.err("invalid low surrogate"));
                                }
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else if (0xDC00..=0xDFFF).contains(&hi) {
                                return Err(self.err("unpaired low surrogate"));
                            } else {
                                hi
                            };
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("invalid \\u code point"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if (c as u32) < 0x20 => {
                    return Err(self.err("unescaped control character in string"))
                }
                c => out.push(c),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_protocol_shapes() {
        let line = r#"{"cmd":"ingest","samples":[[1.5,-2],[0,3e2]]}"#;
        let v = Json::parse(line).unwrap();
        assert_eq!(v.get("cmd").and_then(Json::as_str), Some("ingest"));
        let samples = v.get("samples").and_then(Json::as_arr).unwrap();
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].as_arr().unwrap()[1].as_f64(), Some(-2.0));
        assert_eq!(samples[1].as_arr().unwrap()[1].as_f64(), Some(300.0));
        // serialize → reparse is identity
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = Json::parse(r#"{"s":"a\"b\\c\n\u0041\u00e9\ud83d\ude00"}"#).unwrap();
        assert_eq!(v.get("s").and_then(Json::as_str), Some("a\"b\\c\nAé😀"));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
        // control characters are escaped on output
        let s = Json::Str("\u{0001}".into()).to_string();
        assert_eq!(s, "\"\\u0001\"");
    }

    #[test]
    fn malformed_input_is_typed_error_not_panic() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\"}",
            "{\"a\":}",
            "tru",
            "nul",
            "1.2.3",
            "\"unterminated",
            "\"bad \\q escape\"",
            "\"\\ud800 unpaired\"",
            "{} trailing",
            "\u{0007}",
            "--1",
        ] {
            assert!(
                matches!(Json::parse(bad), Err(Error::Invalid(_))),
                "{bad:?} must be Invalid"
            );
        }
        // depth bomb: error, not a stack overflow
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
        assert_eq!(Json::Num(3.0).to_string(), "3");
    }
}
