//! The daemon's model-refresh loop.
//!
//! A dedicated thread wakes on a timer (or a `refresh` request) and
//! re-fits the model **incrementally**: only shards that appeared since
//! the last cycle are folded — into a running
//! [`PcaPartial`](crate::distributed::PcaPartial) (PCA) or
//! [`CoresetPartial`](crate::distributed::CoresetPartial) (K-means) via
//! the [`PartialFit`] merge law — then the merged partial is finalized
//! and the result published into the [`SnapshotCell`] as a new model
//! version — and persisted as a versioned `.pdsp` artifact next to the
//! store manifest, so a restarted daemon warm-starts from the last
//! published model. A store with no new shards is a no-op, so the
//! steady-state cost of the loop is one manifest read.
//!
//! A failed refresh never kills the daemon: the failure is counted,
//! the previous snapshot is marked stale, and the loop retries on the
//! next tick (the degraded mode — clients see `stale: true`, never a
//! dropped connection).

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::coordinator::{
    coreset_partial_for_shards, pca_partial_for_shards, pca_report_from_partial, FitOutcome,
};
use crate::distributed::{weighted_kmeans, CoresetPartial, PartialFit, PcaPartial};
use crate::error::{Error, Result};
use crate::kmeans::{assign_dense, KmeansOpts, CENTER_BOUND_DELTA};
use crate::linalg::Mat;
use crate::metrics::{ServeMetrics, Timer};
use crate::sampling::Sparsifier;
use crate::store::{ShardEntry, SparseStoreReader, MANIFEST_FILE};

use super::snapshot::{KmeansSnapshot, ModelKind, ModelSnapshot, PcaSnapshot, SnapshotCell};
use super::ServeTask;

/// Fit-side parameters of the refresh loop (fixed at daemon start).
pub struct RefreshParams {
    /// The live store directory (written by the ingest lane).
    pub dir: PathBuf,
    /// Which model to maintain.
    pub task: ServeTask,
    /// PCA: components to keep.
    pub topk: usize,
    /// K-means: cluster count.
    pub k: usize,
    /// K-means: Lloyd options for the coreset solve.
    pub kmeans_opts: KmeansOpts,
    /// K-means: merge-and-reduce coreset node capacity.
    pub coreset_capacity: usize,
    /// Periodic refresh interval.
    pub interval: Duration,
    /// Version the warm-start snapshot was loaded at (0 on a cold
    /// start): the first refresh publishes `initial_version + 1`, so
    /// versions stay monotone across daemon restarts.
    pub initial_version: u64,
}

/// Refresh handshake state: `refresh` requests bump `requested`, the
/// loop bumps `completed` after each attempt, and waiters block on the
/// condvar until their goal epoch completes.
#[derive(Debug, Default)]
pub struct RefreshStatus {
    /// Epochs requested by clients.
    pub requested: u64,
    /// Epochs the loop has finished attempting (success or failure).
    pub completed: u64,
    /// Message of the most recent failed attempt; `None` after a
    /// successful or no-op attempt.
    pub last_error: Option<String>,
}

/// Shared handle for requesting refreshes and waiting on them.
pub struct RefreshCtl {
    /// Guarded epoch counters.
    pub state: Mutex<RefreshStatus>,
    /// Notified on every request and every completed attempt.
    pub cv: Condvar,
}

impl RefreshCtl {
    /// Fresh control state (epoch 0, no error).
    pub fn new() -> Self {
        RefreshCtl { state: Mutex::new(RefreshStatus::default()), cv: Condvar::new() }
    }

    /// Lock the status, surviving poisoning (a panicked refresh thread
    /// must not wedge request handlers).
    pub fn lock_state(&self) -> MutexGuard<'_, RefreshStatus> {
        match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Request a refresh; returns the goal epoch to wait for.
    pub fn request(&self) -> u64 {
        let mut st = self.lock_state();
        st.requested += 1;
        let goal = st.requested;
        drop(st);
        self.cv.notify_all();
        goal
    }

    /// Wait until attempt `goal` completes, up to `timeout`. Returns the
    /// attempt's error message (`Ok(None)` = clean) or `Err(())` on
    /// timeout.
    pub fn wait_completed(
        &self,
        goal: u64,
        timeout: Duration,
    ) -> std::result::Result<Option<String>, ()> {
        let deadline = Instant::now() + timeout;
        let mut st = self.lock_state();
        loop {
            if st.completed >= goal {
                return Ok(st.last_error.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(());
            }
            let (guard, _) = match self.cv.wait_timeout(st, deadline - now) {
                Ok(r) => r,
                Err(poisoned) => poisoned.into_inner(),
            };
            st = guard;
        }
    }
}

impl Default for RefreshCtl {
    fn default() -> Self {
        Self::new()
    }
}

/// The incremental fit state the loop carries between cycles: which
/// shards are already folded, the running partials, and the version
/// counter.
struct FitState {
    folded: BTreeSet<usize>,
    pca: Option<PcaPartial>,
    coreset: Option<CoresetPartial>,
    /// Columns covered by the folded shards (the K-means sample count).
    n_cols: usize,
    /// Shard-fold passes performed (reported as `sparse_passes`).
    folds: usize,
    /// New shards were folded but no snapshot published yet (a finalize
    /// failed) — retry finalization even if no further shards appear.
    dirty: bool,
    version: u64,
}

impl FitState {
    fn new(initial_version: u64) -> Self {
        FitState {
            folded: BTreeSet::new(),
            pca: None,
            coreset: None,
            n_cols: 0,
            folds: 0,
            dirty: false,
            version: initial_version,
        }
    }
}

/// The refresh loop. Runs until `shutdown` is raised; one final wakeup
/// is guaranteed after the flag goes up so a `refresh` request cannot
/// strand a waiter forever (it observes `completed` or times out).
pub fn run_refresh_worker(
    params: RefreshParams,
    cell: Arc<SnapshotCell>,
    ctl: Arc<RefreshCtl>,
    metrics: Arc<ServeMetrics>,
    shutdown: Arc<AtomicBool>,
) {
    let mut fit = FitState::new(params.initial_version);
    // SeqCst: must observe a shutdown stored by any handler thread
    while !shutdown.load(Ordering::SeqCst) {
        // sleep until the interval elapses, a refresh is requested, or
        // shutdown is raised
        {
            let deadline = Instant::now() + params.interval;
            let mut st = ctl.lock_state();
            // SeqCst (shutdown): checked inside the condvar wait loop so
            // a shutdown raised mid-wait is never missed
            while st.requested <= st.completed && !shutdown.load(Ordering::SeqCst) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (guard, _) = match ctl.cv.wait_timeout(st, deadline - now) {
                    Ok(r) => r,
                    Err(poisoned) => poisoned.into_inner(),
                };
                st = guard;
            }
        }
        // SeqCst: re-check after the wait — do not start a refresh the
        // shutdown sequence will not wait for
        if shutdown.load(Ordering::SeqCst) {
            break;
        }

        let goal = ctl.lock_state().requested;
        let t0 = Instant::now();
        let outcome = refresh_once(&params, &mut fit, &cell, &metrics);
        metrics.refresh_duration.record(t0.elapsed());
        let error = match outcome {
            Ok(true) => {
                // Relaxed: monotonic stats counter, no ordering with other data
                metrics.refreshes.fetch_add(1, Ordering::Relaxed);
                None
            }
            Ok(false) => None,
            Err(e) => {
                // Relaxed: monotonic stats counter, no ordering with other data
                metrics.refresh_failures.fetch_add(1, Ordering::Relaxed);
                // degrade: keep serving the previous snapshot, flagged
                cell.mark_stale();
                Some(e.to_string())
            }
        };
        let mut st = ctl.lock_state();
        st.last_error = error;
        st.completed = st.completed.max(goal);
        drop(st);
        ctl.cv.notify_all();
    }
    // unblock any refresh waiter that raced the shutdown flag
    let mut st = ctl.lock_state();
    st.completed = st.completed.max(st.requested);
    drop(st);
    ctl.cv.notify_all();
}

/// One refresh attempt. `Ok(true)` published a new snapshot, `Ok(false)`
/// was a no-op (no store yet / nothing new), `Err` degrades the daemon.
fn refresh_once(
    params: &RefreshParams,
    fit: &mut FitState,
    cell: &SnapshotCell,
    metrics: &ServeMetrics,
) -> Result<bool> {
    if !params.dir.join(MANIFEST_FILE).exists() {
        // the ingest lane has not checkpointed a single shard yet
        return Ok(false);
    }
    let mut reader = SparseStoreReader::open(&params.dir)?;
    let sp = reader.sparsifier()?;
    let preconditioned = reader.manifest().preconditioned;
    let precision = reader.manifest().precision;
    let new: Vec<ShardEntry> = reader
        .manifest()
        .shards
        .iter()
        .filter(|s| !fit.folded.contains(&s.index))
        .cloned()
        .collect();
    if new.is_empty() && !fit.dirty {
        return Ok(false);
    }

    let snapshot = match params.task {
        ServeTask::Pca => {
            if !new.is_empty() {
                let fresh = pca_partial_for_shards(&mut reader, &sp, &new)?;
                fold(fit, &new, |state| match &mut state.pca {
                    Some(acc) => acc.merge_from(&fresh),
                    none => {
                        *none = Some(fresh);
                        Ok(())
                    }
                })?;
            }
            let partial = fit
                .pca
                .as_ref()
                .ok_or_else(|| Error::Invalid("refresh: no PCA partial folded yet".into()))?;
            let report = pca_report_from_partial(
                partial,
                &sp,
                params.topk,
                preconditioned,
                Timer::new(),
                fit.folds,
            )?;
            let FitOutcome::Pca(pca_fit) = report.outcome else {
                return Err(Error::Invalid("refresh: PCA plan returned a non-PCA outcome".into()));
            };
            ModelSnapshot::new(
                fit.version + 1,
                report.n,
                precision,
                ModelKind::Pca(PcaSnapshot {
                    components: pca_fit.pca.components,
                    mean: pca_fit.mean,
                    eigenvalues: pca_fit.pca.eigenvalues,
                }),
            )
        }
        ServeTask::Kmeans => {
            if !new.is_empty() {
                let fresh = coreset_partial_for_shards(
                    &mut reader,
                    &sp,
                    &new,
                    params.coreset_capacity,
                    params.kmeans_opts.seed,
                )?;
                fold(fit, &new, |state| match &mut state.coreset {
                    Some(acc) => acc.merge_from(&fresh),
                    none => {
                        *none = Some(fresh);
                        Ok(())
                    }
                })?;
            }
            let partial = fit
                .coreset
                .as_ref()
                .ok_or_else(|| Error::Invalid("refresh: no coreset folded yet".into()))?;
            let (points, weights) = partial.points();
            let (centers_pre, iterations, converged) =
                weighted_kmeans(&points, &weights, params.k, &params.kmeans_opts)?;
            let centers =
                if preconditioned { sp.unmix(&centers_pre) } else { sp.truncate(&centers_pre) };
            let center_bound = coreset_center_bound(&sp, &points, &weights, &centers_pre);
            ModelSnapshot::new(
                fit.version + 1,
                fit.n_cols,
                precision,
                ModelKind::Kmeans(KmeansSnapshot { centers, center_bound, iterations, converged }),
            )
        }
    };

    fit.version = snapshot.version;
    fit.dirty = false;
    // persist before publishing: a daemon restarted after this point
    // warm-starts at exactly the version clients were answered from. A
    // persist failure only degrades restart behavior (cold start), so
    // it is counted and logged, never allowed to fail the refresh.
    if let Err(e) = snapshot.write_atomic(&params.dir) {
        // Relaxed: monotonic stats counter, no ordering with other data
        metrics.snapshot_persist_failures.fetch_add(1, Ordering::Relaxed);
        eprintln!("pds serve: warning: snapshot persist failed (a restarted daemon will cold-start): {e}");
    }
    cell.publish(snapshot);
    Ok(true)
}

/// Bookkeeping around one successful shard fold: run the merge, then
/// mark the shards folded and the state dirty (so a later finalize
/// failure is retried without re-reading these shards).
fn fold(
    fit: &mut FitState,
    new: &[ShardEntry],
    merge: impl FnOnce(&mut FitState) -> Result<()>,
) -> Result<()> {
    // split the borrow: merge mutates the partial slots through the
    // closure, the bookkeeping below mutates the counters
    merge(fit)?;
    for s in new {
        fit.folded.insert(s.index);
        fit.n_cols += s.n_cols;
    }
    fit.folds += 1;
    fit.dirty = true;
    Ok(())
}

/// Eq. 43 worst-cluster center-error bound, evaluated at the
/// coreset-estimated cluster sizes: assign the (unit-weight-scaled)
/// coreset points to the fitted centers and round each cluster's total
/// weight to its estimated population. The bound covers the uniform
/// sampling schemes only — weighted (hybrid) fits return `NaN`
/// (serialized as JSON `null`), never a number the theory does not
/// back. Since the cluster sizes are estimates (not exact counts as in
/// the Lloyd path), the serve docs present this as an indicative bound.
fn coreset_center_bound(
    sp: &Sparsifier,
    points: &Mat,
    weights: &[f64],
    centers_pre: &Mat,
) -> f64 {
    if sp.weighted() {
        return f64::NAN;
    }
    let (assign, _) = assign_dense(points, centers_pre);
    let mut cluster_weight = vec![0.0f64; centers_pre.cols()];
    for (j, &a) in assign.iter().enumerate() {
        cluster_weight[a as usize] += weights[j];
    }
    let mut worst = f64::NAN;
    for &w in &cluster_weight {
        if w >= 0.5 {
            // clamp before the cast: a pathological weight sum must not
            // overflow the usize conversion
            let n_k = (w.round().min(1e18) as usize).max(1);
            let b = crate::estimators::center_error_bound(sp.p(), sp.m(), n_k, CENTER_BOUND_DELTA);
            if !(b <= worst) {
                worst = b;
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refresh_ctl_handshake() {
        let ctl = RefreshCtl::new();
        let goal = ctl.request();
        assert_eq!(goal, 1);
        // not completed yet: a zero-timeout wait times out
        assert!(ctl.wait_completed(goal, Duration::from_millis(0)).is_err());
        {
            let mut st = ctl.lock_state();
            st.completed = goal;
            st.last_error = None;
        }
        assert_eq!(ctl.wait_completed(goal, Duration::from_millis(0)), Ok(None));
    }

    #[test]
    fn center_bound_is_nan_for_weighted_schemes() {
        use crate::sampling::{Scheme, SparsifyConfig};
        use crate::transform::TransformKind;
        let cfg = SparsifyConfig { gamma: 0.5, transform: TransformKind::Hadamard, seed: 1 };
        let sp = Sparsifier::with_scheme(16, cfg, Scheme::Hybrid).unwrap();
        let points = Mat::zeros(16, 4);
        let centers = Mat::zeros(16, 2);
        let b = coreset_center_bound(&sp, &points, &[1.0; 4], &centers);
        assert!(b.is_nan());

        // the uniform scheme gets a finite bound once clusters have weight
        let sp = Sparsifier::with_scheme(16, cfg, Scheme::Precond).unwrap();
        let mut points = Mat::zeros(16, 4);
        for j in 0..4 {
            points.col_mut(j)[0] = if j < 2 { -1.0 } else { 1.0 };
        }
        let mut centers = Mat::zeros(16, 2);
        centers.col_mut(0)[0] = -1.0;
        centers.col_mut(1)[0] = 1.0;
        let b = coreset_center_bound(&sp, &points, &[100.0; 4], &centers);
        assert!(b.is_finite() && b > 0.0, "bound {b}");
    }
}
